"""Load generator for the serving front-end: Poisson ingest arrivals +
Zipf-skewed resolve traffic.

Drives a :class:`repro.stream.serving.ServingFrontend` the way a live
deployment would be driven:

* **arrivals** are an open-loop Poisson process at ``arrival_rate``
  requests/sec (exponential inter-arrival gaps, seeded rng) — or, with
  ``arrival_rate=inf``, an offered-load sweep that submits as fast as
  admission control lets it (what the ``serving`` block of
  ``stream_throughput`` uses to measure *sustained* coalesced ingest
  throughput);
* **queries** come from ``n_readers`` concurrent reader threads issuing
  ``resolve_many`` over Zipf-skewed entity ids (``zipf_a``): a few hot
  entities absorb most of the traffic, the tail is cold — the usual
  shape of entity-lookup workloads.  Readers run against the lock-free
  published snapshot, so their latency histogram
  (``resolve.latency_ms``) is pure read-path cost even while ingests
  are in flight.

``run_load`` returns the measured block: sustained committed-entity
throughput, coalescing shape (batches, mean coalesced size), queue
wait and resolve-latency percentiles (p50/p99 from the exact-sample
``repro.obs`` histograms), and the admission-shed count.

CLI (standalone)::

    python -m benchmarks.loadgen [--rate R] [--requests N] [--readers K]
                                 [--admission block|reject] [--seed S]

or via the harness (smoke-sized): ``python -m benchmarks.run --smoke
loadgen``.  Everything is seeded; two runs with the same arguments
offer identical request/query schedules.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from benchmarks.common import SMOKE, hepth, row, timed
from repro import obs
from repro.data.synthetic import arrival_stream
from repro.stream import (
    AdmissionError,
    ResolveService,
    ServingConfig,
    ServingFrontend,
)

# harness-run (smoke/default) scenario sizes; the CLI overrides them
N_REQUESTS = 48 if SMOKE else 200
REQUEST_ENTITIES = 4  # paper-aligned arrival batches (~one paper each)


@dataclasses.dataclass(frozen=True)
class LoadgenConfig:
    """One load scenario (arrival process + query mix), fully seeded."""

    arrival_rate: float = float("inf")  # requests/sec; inf = offered load
    n_readers: int = 2
    reader_qps: float = 200.0  # per-reader resolve_many calls/sec
    reader_batch: int = 32  # ids per resolve_many call
    zipf_a: float = 1.3  # query skew (>1; lower = heavier tail)
    seed: int = 0
    submit_timeout: float | None = None  # per-submit bound (block policy)


def poisson_schedule(rng: np.random.Generator, rate: float, n: int) -> np.ndarray:
    """Arrival offsets (seconds from t0) of an n-event Poisson process."""
    if not np.isfinite(rate):
        return np.zeros(n)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def zipf_ids(
    rng: np.random.Generator, n_entities: int, size: int, a: float
) -> np.ndarray:
    """Zipf-skewed entity ids: rank r is queried with mass ~ 1/r^a,
    folded onto the live id range (hot mass lands on the low ids)."""
    return (rng.zipf(a, size=size) - 1) % max(n_entities, 1)


def run_load(
    frontend: ServingFrontend, requests, cfg: LoadgenConfig
) -> dict:
    """Offer ``requests`` (name/edges/ids triples) to ``frontend`` on the
    configured arrival schedule, with Zipf readers querying throughout;
    block until everything admitted has committed, return the stats."""
    obs.reset()
    rng = np.random.default_rng(cfg.seed)
    sched = poisson_schedule(rng, cfg.arrival_rate, len(requests))
    n0 = frontend.snapshot().n_entities
    stop = threading.Event()
    counts = [0] * cfg.n_readers

    def reader(i: int) -> None:
        r = np.random.default_rng(cfg.seed + 1000 + i)
        period = 1.0 / cfg.reader_qps if cfg.reader_qps else 0.0
        while not stop.is_set():
            n_live = frontend.snapshot().n_entities
            ids = zipf_ids(r, n_live or 1, cfg.reader_batch, cfg.zipf_a)
            frontend.resolve_many(ids)
            counts[i] += cfg.reader_batch
            if period:
                time.sleep(period)

    threads = [
        threading.Thread(target=reader, args=(i,))
        for i in range(cfg.n_readers)
    ]
    for t in threads:
        t.start()

    shed = 0
    t0 = time.perf_counter()
    for k, (names, edges, ids) in enumerate(requests):
        target = t0 + sched[k]
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        try:
            frontend.submit(names, edges, ids, timeout=cfg.submit_timeout)
        except AdmissionError:
            shed += 1
    frontend.drain(timeout=600)
    wall = time.perf_counter() - t0
    stop.set()
    for t in threads:
        t.join()

    reg = obs.get_registry()
    committed = frontend.snapshot().n_entities - n0
    lat = reg.histogram("resolve.latency_ms").summary()
    wait = reg.histogram("serve.queue.wait_ms").summary()
    csize = reg.histogram("serve.batch.coalesced_size").summary()
    offered = len(requests)
    return {
        "arrival_rate": (
            None if not np.isfinite(cfg.arrival_rate) else cfg.arrival_rate
        ),
        "n_requests": offered,
        "shed": int(reg.value("serve.admission.shed")),
        "entities_offered": sum(len(r[0]) for r in requests),
        "entities_committed": int(committed),
        "wall_s": round(wall, 3),
        "entities_per_s": round(committed / max(wall, 1e-9), 1),
        "n_batches": int(reg.value("serve.batches")),
        "mean_coalesced_size": round(csize["mean"], 1),
        "queue_wait_p50_ms": round(wait["p50"], 3),
        "queue_wait_p99_ms": round(wait["p99"], 3),
        "n_readers": cfg.n_readers,
        "queries": int(sum(counts)),
        "qps_total": round(sum(counts) / max(wall, 1e-9), 1),
        "p50_ms": round(lat["p50"], 4),
        "p99_ms": round(lat["p99"], 4),
    }


def dataset_requests(n_requests: int, request_entities: int = REQUEST_ENTITIES):
    """Paper-aligned request stream: the hepth corpus split into
    ~``request_entities``-reference arrival batches."""
    ds = hepth()
    batches = arrival_stream(ds, batch_size=request_entities)
    return [
        (b.names, b.edges, [int(i) for i in b.ids])
        for b in batches[:n_requests]
    ]


def main(argv: list[str] | None = None) -> dict:
    rate = float("inf")
    n_requests = N_REQUESTS
    n_readers = 2
    admission = "block"
    seed = 0
    if argv:
        it = iter(argv)
        for a in it:
            if a == "--rate":
                rate = float(next(it))
            elif a == "--requests":
                n_requests = int(next(it))
            elif a == "--readers":
                n_readers = int(next(it))
            elif a == "--admission":
                admission = next(it)
            elif a == "--seed":
                seed = int(next(it))
            else:
                raise SystemExit(f"unknown argument {a!r}\n\n{__doc__}")
    requests, gen_s = timed(lambda: dataset_requests(n_requests))
    row(f"# loadgen: hepth, {len(requests)} requests x ~{REQUEST_ENTITIES} "
        f"entities (corpus prep {gen_s:.1f}s)")
    svc = ResolveService(scheme="smp")
    cfg = LoadgenConfig(arrival_rate=rate, n_readers=n_readers, seed=seed)
    with ServingFrontend(
        svc, ServingConfig(admission=admission)
    ) as fe:
        stats = run_load(fe, requests, cfg)
    row(
        "arrival_rate,n_requests,shed,entities,wall_s,entities_per_s,"
        "n_batches,mean_coalesced_size,queue_wait_p99_ms,"
        "n_readers,qps_total,p50_ms,p99_ms"
    )
    row(
        stats["arrival_rate"] if stats["arrival_rate"] is not None else "inf",
        stats["n_requests"],
        stats["shed"],
        stats["entities_committed"],
        stats["wall_s"],
        stats["entities_per_s"],
        stats["n_batches"],
        stats["mean_coalesced_size"],
        stats["queue_wait_p99_ms"],
        stats["n_readers"],
        stats["qps_total"],
        stats["p50_ms"],
        stats["p99_ms"],
    )
    return {"benchmark": "loadgen", "dataset": "hepth", "smoke": SMOKE,
            "load": [stats]}


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
