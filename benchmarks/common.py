"""Shared benchmark utilities: datasets, timing, CSV emission."""

from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import metrics as metricslib
from repro.core import pipeline
from repro.data.synthetic import SynthConfig, make_dataset

# CPU-CI scale factors; the generators scale to the paper's full sizes
# (HEPTH 58,515 refs / DBLP 50,195 / DBLP-BIG 4.6M) with scale=1.0 and
# scale~90 respectively.
HEPTH_SCALE = float(__import__("os").environ.get("BENCH_HEPTH_SCALE", 0.12))
DBLP_SCALE = float(__import__("os").environ.get("BENCH_DBLP_SCALE", 0.12))


@functools.lru_cache(maxsize=None)
def hepth():
    return make_dataset(SynthConfig.hepth(scale=HEPTH_SCALE, seed=7))


@functools.lru_cache(maxsize=None)
def dblp():
    return make_dataset(SynthConfig.dblp(scale=DBLP_SCALE, seed=11))


@functools.lru_cache(maxsize=None)
def prepared(which: str):
    ds = hepth() if which == "hepth" else dblp()
    packed, gg, t = pipeline.prepare(ds.entities, ds.relations)
    return ds, packed, gg, t


def evaluate(ds, res) -> metricslib.PRF:
    return pipeline.evaluate(res, ds.entities.truth)


def row(*cols) -> str:
    line = ",".join(str(c) for c in cols)
    print(line, flush=True)
    return line


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0
