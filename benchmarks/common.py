"""Shared benchmark utilities: datasets, timing, CSV emission.

``SMOKE`` (env ``BENCH_SMOKE=1``, set by ``benchmarks.run --smoke``)
shrinks the default corpora so CI can exercise every benchmark module
end to end in seconds; modules consult it to trim their own grids too.
"""

from __future__ import annotations

import functools
import os
import time

from repro.core import metrics as metricslib
from repro.core import pipeline
from repro.data.synthetic import SynthConfig, make_dataset

SMOKE = os.environ.get("BENCH_SMOKE") == "1"

# CPU-CI scale factors; the generators scale to the paper's full sizes
# (HEPTH 58,515 refs / DBLP 50,195 / DBLP-BIG 4.6M) with scale=1.0 and
# scale~90 respectively.
_DEFAULT_SCALE = "0.03" if SMOKE else "0.12"
HEPTH_SCALE = float(os.environ.get("BENCH_HEPTH_SCALE", _DEFAULT_SCALE))
DBLP_SCALE = float(os.environ.get("BENCH_DBLP_SCALE", _DEFAULT_SCALE))


@functools.lru_cache(maxsize=None)
def hepth():
    return make_dataset(SynthConfig.hepth(scale=HEPTH_SCALE, seed=7))


@functools.lru_cache(maxsize=None)
def dblp():
    return make_dataset(SynthConfig.dblp(scale=DBLP_SCALE, seed=11))


@functools.lru_cache(maxsize=None)
def prepared(which: str):
    ds = hepth() if which == "hepth" else dblp()
    packed, gg, t = pipeline.prepare(ds.entities, ds.relations)
    return ds, packed, gg, t


def evaluate(ds, res) -> metricslib.PRF:
    return pipeline.evaluate(res, ds.entities.truth)


def row(*cols) -> str:
    line = ",".join(str(c) for c in cols)
    print(line, flush=True)
    return line


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0
