"""CI gate: fail if scale-robust perf invariants regress.

``python -m benchmarks.check_bench BASELINE.json FRESH.json``

Two baselines are gated, dispatched on the JSON's ``benchmark`` field:

* ``BENCH_parallel.json`` — the ``dispatches_per_round`` of every
  scheme: bounded by O(bins + quiescence points) per round with the bin
  count capped by ``DEFAULT_BINS``, so a smoke-scale run is comparable
  to the committed default-scale baseline.  A regression to the legacy
  O(bins x rounds) dispatch pattern blows well past the slack.
* ``BENCH_stream.json`` — the O(dirty) ingest-path ratios:
  ``splice_per_dirty`` (cover rows staged per dirty neighborhood) and
  ``splice_per_visit`` (grounding array rows spliced per pair visited).
  Both are ~O(1) by construction; a regression to per-ingest full
  repacking / full grounding materialization scales them with the
  corpus.  Gated as max-over-entries so smoke batch sizes need not
  match the committed grid.

Wall times are recorded in the JSON for the trajectory but never gated
(CI machines are noisy).
"""

from __future__ import annotations

import json
import sys

# Multiplicative + additive slack: quiescence-point counts can shift by
# a round or two between corpus scales; a true regression to the legacy
# O(bins x rounds) dispatch pattern blows well past this.
REL_SLACK = 1.5
ABS_SLACK = 2.0

# Stream splice ratios are ~O(1); corpus-scale effects (totality-group /
# leftover-chunk churn) shift them by fractions, a full-restage
# regression multiplies them by the cover/pair count.
STREAM_REL_SLACK = 2.0
STREAM_ABS_SLACK = 1.0


def _check_parallel(base: dict, fresh: dict, failures: list[str]) -> None:
    for inst, iblock in base.get("instances", {}).items():
        fblock = fresh.get("instances", {}).get(inst, {})
        for scheme, b in iblock.get("schemes", {}).items():
            tag = f"{inst}/{scheme}"
            got = fblock.get("schemes", {}).get(scheme)
            if got is None:
                failures.append(f"{tag}: missing from fresh results")
                continue
            limit = b["dispatches_per_round"] * REL_SLACK + ABS_SLACK
            if got["dispatches_per_round"] > limit:
                failures.append(
                    f"{tag}: dispatches_per_round "
                    f"{got['dispatches_per_round']} > limit {limit:.2f} "
                    f"(baseline {b['dispatches_per_round']})"
                )
            else:
                print(
                    f"ok {tag}: dispatches_per_round "
                    f"{got['dispatches_per_round']} <= {limit:.2f}"
                )


def _max_ratio(entries: list[dict], key: str) -> float | None:
    vals = [e[key] for e in entries if key in e]
    return max(vals) if vals else None


def _check_stream(base: dict, fresh: dict, failures: list[str]) -> None:
    for block, key in (
        ("throughput", "splice_per_dirty"),
        ("grounding", "splice_per_visit"),
    ):
        b = _max_ratio(base.get(block, []), key)
        got = _max_ratio(fresh.get(block, []), key)
        tag = f"stream/{block}"
        if b is None:
            failures.append(f"{tag}: {key} missing from baseline")
            continue
        if got is None:
            failures.append(f"{tag}: {key} missing from fresh results")
            continue
        limit = b * STREAM_REL_SLACK + STREAM_ABS_SLACK
        if got > limit:
            failures.append(
                f"{tag}: {key} {got} > limit {limit:.2f} (baseline {b})"
            )
        else:
            print(f"ok {tag}: {key} {got} <= {limit:.2f}")


def main(argv: list[str]) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    with open(argv[0]) as f:
        base = json.load(f)
    with open(argv[1]) as f:
        fresh = json.load(f)
    failures: list[str] = []
    if fresh.get("benchmark") == "stream_throughput" or "throughput" in fresh:
        _check_stream(base, fresh, failures)
    else:
        _check_parallel(base, fresh, failures)
    if failures:
        print("BENCH REGRESSION:\n  " + "\n  ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
