"""CI gate: fail if scale-robust perf invariants regress.

``python -m benchmarks.check_bench [--gate=NAME] BASELINE.json FRESH.json``

Three baselines are gated, dispatched on the JSON's ``benchmark`` field.
Each invariant family is a :class:`Gate` in a registry keyed by name and
baseline family; ``--gate=all`` (the default, and what CI runs — one
invocation per baseline file) runs every gate that applies to the file,
``--gate=NAME`` restricts to one for local triage:

* ``BENCH_parallel.json``
  - ``dispatch``: the ``dispatches_per_round`` of every scheme, bounded
    by O(bins + quiescence points) per round with the bin count capped
    by ``DEFAULT_BINS``, so a smoke-scale run is comparable to the
    committed default-scale baseline.  A regression to the legacy
    O(bins x rounds) dispatch pattern blows well past the slack.
  - ``promotion``: ``promote_host_scans`` of the fused engine must be
    exactly 0 — step-7 delta checks run batched on device
    (``repro.core.parallel.DevicePromoter``); any host coupling-COO
    walk is a regression, no slack.
  - ``matchers``: the ``fig4_matchers`` block — every matcher family in
    the baseline must still be measured, the optimal assignment must
    keep its quality edge over the greedy ablation, and no family's F1
    may regress below the baseline minus slack (quality is a model
    property of the corpus, so it is stable across machine speeds).
* ``BENCH_stream.json``
  - ``stream``: the O(dirty) ingest-path ratios — ``splice_per_dirty``
    (cover rows staged per dirty neighborhood), ``splice_per_visit``
    (grounding array rows spliced per pair visited) and
    ``growth_copy_per_row`` (backing-buffer rows memcpy'd per row
    placed; amortized O(1) under capacity doubling, O(bin) per append
    under the old per-ingest ``np.concatenate``).  All ~O(1) by
    construction and gated as max-over-entries so smoke batch sizes
    need not match the committed grid.
  - ``lru``: the bounded-serving-memory block — peak array-resident
    bins must not exceed the configured LRU capacity (exact, no slack),
    the eviction path must have actually fired, and promotion must have
    done zero host scans.
  - ``transfer``: the device-transfer block — host->device upload bytes
    per unit of per-site work (``gcache_upload_per_reground_row``,
    ``promoter_upload_per_pair_ingest``,
    ``prepare_upload_per_row_ingest``, from the
    ``transfer.{gcache,promoter,prepare}_bytes`` registry counters).
    Per-unit byte cost is bounded by the bin shapes, so the ratios are
    comparable across corpus scales; a regression to O(corpus)
    re-uploads per ingest multiplies them far past the slack.
  - ``recovery``: the durability block — the WAL append overhead must
    stay under 10% of the ingest p50 (fsync-per-append riding on a
    much larger delta+fixpoint cost), and recovery (snapshot restore +
    WAL tail replay) must have reached the uninterrupted run's state
    digest bit-for-bit (``fixpoint_equal``).  Absolute bounds, not
    baseline-relative: both hold at every corpus scale.
  - ``tails``: the serving block — coalesced ingest throughput must
    beat the per-arrival synchronous baseline by the speedup floor
    (5x at full scale; a lower absolute floor at smoke scale, where
    tiny corpora shrink the fixed per-ingest cost being amortized and
    the fresh JSON's ``smoke`` flag says which regime applies), the
    readers must actually have sampled latency, and the resolve p99
    under concurrent load is gated baseline-relative with generous
    slack (CI boxes are noisy; losing the lock-free read path
    multiplies p99 by ingest wall time, far past it).
* ``BENCH_shard.json``
  - ``shard``: the sharded-serving block — the state digest must be
    identical across shard counts {1, 2, 4} (bit-for-bit the
    single-host fixpoint; absolute, no slack), every replica set must
    agree among itself, ingest throughput and aggregate resolve QPS
    must be present and positive, and the 2-shard QPS scaling
    efficiency must clear an absolute floor wherever the measuring
    host has >= 2 cores (reads are replica-local, so read capacity is
    the axis that scales with the shard count).  A missing
    ``BENCH_shard.json`` fails the step loudly rather than reading as
    "gate does not apply".

Wall times are recorded in the JSON for the trajectory but never gated
(CI machines are noisy).
"""

from __future__ import annotations

import dataclasses
import json
import sys
from typing import Callable

# Multiplicative + additive slack: quiescence-point counts can shift by
# a round or two between corpus scales; a true regression to the legacy
# O(bins x rounds) dispatch pattern blows well past this.
REL_SLACK = 1.5
ABS_SLACK = 2.0

# Stream splice ratios are ~O(1); corpus-scale effects (totality-group /
# leftover-chunk churn) shift them by fractions, a full-restage
# regression multiplies them by the cover/pair count.
STREAM_REL_SLACK = 2.0
STREAM_ABS_SLACK = 1.0

# Matcher quality: F1 on the synthetic corpora is deterministic up to
# tiny tie-break drift between numpy versions; a family losing its
# separation (or dropping out of the benchmark) moves far past this.
MATCHERS_F1_ABS_SLACK = 0.02


@dataclasses.dataclass(frozen=True)
class Gate:
    """One named invariant family over (baseline, fresh) JSON blobs.

    ``family`` names the baseline file the gate reads (``parallel`` /
    ``stream`` / ``shard``, dispatched on the fresh JSON's ``benchmark``
    field), so ``--gate=all`` runs exactly the gates that apply."""

    name: str
    family: str
    fn: Callable[[dict, dict, list], None]


_GATES: dict[str, Gate] = {}


def register_gate(name: str, family: str):
    """Register ``fn(base, fresh, failures)`` as the gate ``name``."""

    def deco(fn):
        _GATES[name] = Gate(name=name, family=family, fn=fn)
        return fn

    return deco


def gate_names() -> tuple[str, ...]:
    return tuple(_GATES)

# Durability: fsync-per-append rides on a much larger delta+fixpoint
# ingest; a WAL that costs a tenth of the ingest p50 means the append
# path regressed (e.g. re-pickling state instead of the batch).
RECOVERY_MAX_WAL_OVERHEAD_FRAC = 0.10

# Serving coalescing: the full-scale speedup floor is the acceptance
# bar (>= 5x over per-arrival ingest); smoke corpora amortize a much
# smaller fixed cost, so CI gates a lower absolute floor there.
TAILS_MIN_SPEEDUP = 5.0
TAILS_SMOKE_MIN_SPEEDUP = 1.5
# p99 resolve latency under concurrent load, baseline-relative: the
# lock-free read path is ~fixed cost; regressing to reads that wait on
# an in-flight ingest multiplies p99 by ingest wall time.
TAILS_P99_REL_SLACK = 3.0
TAILS_P99_ABS_SLACK = 1.0  # ms

# Transfer ratios: per-unit byte costs shift with bin-shape mix between
# corpus scales; an O(corpus)-re-upload regression scales them with the
# corpus, far past this.
TRANSFER_REL_SLACK = 2.0
TRANSFER_ABS_SLACK = 64.0  # bytes per unit

# Sharded serving: aggregate resolve QPS at 2 shards must retain this
# fraction of perfect 2x scaling.  Reads are replica-local (no
# collectives), so losing the floor means reads started waiting on
# cross-shard state.  Only enforced where two shards can actually run
# in parallel (cpu_count >= 2, recorded in the fresh JSON) — N
# co-scheduled replicas on one core timeshare it.
SHARD_MIN_QPS_EFF_2 = 0.35


@register_gate("dispatch", "parallel")
def _check_dispatch(base: dict, fresh: dict, failures: list[str]) -> None:
    for inst, iblock in base.get("instances", {}).items():
        fblock = fresh.get("instances", {}).get(inst, {})
        for scheme, b in iblock.get("schemes", {}).items():
            tag = f"{inst}/{scheme}"
            got = fblock.get("schemes", {}).get(scheme)
            if got is None:
                failures.append(f"{tag}: missing from fresh results")
                continue
            limit = b["dispatches_per_round"] * REL_SLACK + ABS_SLACK
            if got["dispatches_per_round"] > limit:
                failures.append(
                    f"{tag}: dispatches_per_round "
                    f"{got['dispatches_per_round']} > limit {limit:.2f} "
                    f"(baseline {b['dispatches_per_round']})"
                )
            else:
                print(
                    f"ok {tag}: dispatches_per_round "
                    f"{got['dispatches_per_round']} <= {limit:.2f}"
                )


@register_gate("promotion", "parallel")
def _check_promotion_parallel(
    _base: dict, fresh: dict, failures: list[str]
) -> None:
    """Fused engine: zero host promotion scans, exact (no slack)."""
    checked = 0
    for inst, iblock in fresh.get("instances", {}).items():
        for scheme, got in iblock.get("schemes", {}).items():
            tag = f"{inst}/{scheme}"
            scans = got.get("promote_host_scans")
            if scans is None:
                failures.append(f"{tag}: promote_host_scans missing")
                continue
            checked += 1
            if scans != 0:
                failures.append(
                    f"{tag}: promote_host_scans {scans} != 0 — the fused "
                    "engine fell back to the host coupling-COO walk"
                )
            else:
                print(f"ok {tag}: promote_host_scans == 0")
    if not checked:
        failures.append("promotion: no schemes found in fresh results")


def _max_ratio(entries: list[dict], key: str) -> float | None:
    vals = [e[key] for e in entries if key in e]
    return max(vals) if vals else None


@register_gate("stream", "stream")
def _check_stream_ratios(base: dict, fresh: dict, failures: list[str]) -> None:
    for block, key in (
        ("throughput", "splice_per_dirty"),
        ("throughput", "growth_copy_per_row"),
        ("grounding", "splice_per_visit"),
    ):
        b = _max_ratio(base.get(block, []), key)
        got = _max_ratio(fresh.get(block, []), key)
        tag = f"stream/{block}"
        if b is None:
            failures.append(f"{tag}: {key} missing from baseline")
            continue
        if got is None:
            failures.append(f"{tag}: {key} missing from fresh results")
            continue
        limit = b * STREAM_REL_SLACK + STREAM_ABS_SLACK
        if got > limit:
            failures.append(
                f"{tag}: {key} {got} > limit {limit:.2f} (baseline {b})"
            )
        else:
            print(f"ok {tag}: {key} {got} <= {limit:.2f}")


@register_gate("lru", "stream")
def _check_lru(_base: dict, fresh: dict, failures: list[str]) -> None:
    """Bounded serving memory: exact bounds, independent of baseline."""
    entries = fresh.get("serving_memory", [])
    if not entries:
        failures.append("serving_memory: block missing from fresh results")
        return
    for e in entries:
        cap = e.get("lru_capacity")
        peak = e.get("peak_resident_bins")
        tag = f"stream/serving_memory[capacity={cap}]"
        if cap is None or peak is None:
            failures.append(f"{tag}: lru_capacity/peak_resident_bins missing")
            continue
        if peak > cap:
            failures.append(
                f"{tag}: peak_resident_bins {peak} > capacity {cap} — the "
                "LRU bound did not hold"
            )
        else:
            print(f"ok {tag}: peak_resident_bins {peak} <= {cap}")
        if e.get("n_bins", 0) > cap and not e.get("evictions", 0):
            failures.append(
                f"{tag}: no evictions despite {e.get('n_bins')} bins — the "
                "eviction path was not exercised"
            )
        scans = e.get("promote_host_scans")
        if scans is None:
            failures.append(f"{tag}: promote_host_scans missing")
        elif scans != 0:
            failures.append(f"{tag}: promote_host_scans {scans} != 0")
        else:
            print(f"ok {tag}: promote_host_scans == 0")


@register_gate("transfer", "stream")
def _check_transfer(base: dict, fresh: dict, failures: list[str]) -> None:
    """Upload bytes per unit of per-site work, baseline-relative."""
    base_entries = base.get("transfer", [])
    fresh_entries = fresh.get("transfer", [])
    if not fresh_entries:
        failures.append("transfer: block missing from fresh results")
        return
    if not base_entries:
        failures.append("transfer: block missing from baseline")
        return
    for key in (
        "gcache_upload_per_reground_row",
        "promoter_upload_per_pair_ingest",
        "prepare_upload_per_row_ingest",
    ):
        b = _max_ratio(base_entries, key)
        got = _max_ratio(fresh_entries, key)
        tag = "stream/transfer"
        if b is None:
            failures.append(f"{tag}: {key} missing from baseline")
            continue
        if got is None:
            failures.append(f"{tag}: {key} missing from fresh results")
            continue
        limit = b * TRANSFER_REL_SLACK + TRANSFER_ABS_SLACK
        if got > limit:
            failures.append(
                f"{tag}: {key} {got} > limit {limit:.2f} (baseline {b})"
            )
        else:
            print(f"ok {tag}: {key} {got} <= {limit:.2f}")
    # the accounting itself must have seen traffic: a parallel-engine
    # ingest run with zero recorded bytes means the counters came unwired
    for key in ("gcache_bytes", "prepare_bytes"):
        got = _max_ratio(fresh_entries, key)
        if not got:
            failures.append(
                f"stream/transfer: {key} is 0/missing — transfer "
                "accounting not recording"
            )
        else:
            print(f"ok stream/transfer: {key} {got} > 0")


@register_gate("recovery", "stream")
def _check_recovery(_base: dict, fresh: dict, failures: list[str]) -> None:
    """Durability block: WAL overhead bound + bit-for-bit replay."""
    entries = fresh.get("recovery", [])
    if not entries:
        failures.append("recovery: block missing from fresh results")
        return
    for e in entries:
        tag = f"stream/recovery[batch_size={e.get('batch_size')}]"
        frac = e.get("wal_overhead_frac")
        if frac is None:
            failures.append(f"{tag}: wal_overhead_frac missing")
        elif frac >= RECOVERY_MAX_WAL_OVERHEAD_FRAC:
            failures.append(
                f"{tag}: wal_overhead_frac {frac} >= "
                f"{RECOVERY_MAX_WAL_OVERHEAD_FRAC} — the WAL append is no "
                "longer a small fraction of the ingest"
            )
        else:
            print(
                f"ok {tag}: wal_overhead_frac {frac} < "
                f"{RECOVERY_MAX_WAL_OVERHEAD_FRAC}"
            )
        if e.get("fixpoint_equal") is not True:
            failures.append(
                f"{tag}: fixpoint_equal is "
                f"{e.get('fixpoint_equal')!r} — recovery did not reach the "
                "uninterrupted run's state digest"
            )
        else:
            print(f"ok {tag}: fixpoint_equal (snapshot + WAL tail replay)")
        if not e.get("replayed_records"):
            failures.append(
                f"{tag}: replayed_records is 0/missing — the WAL tail "
                "replay was not exercised"
            )
        else:
            print(f"ok {tag}: replayed {e['replayed_records']} WAL records")


@register_gate("tails", "stream")
def _check_tails(base: dict, fresh: dict, failures: list[str]) -> None:
    """Serving block: coalescing speedup floor + p99 under load."""
    entries = fresh.get("serving", [])
    if not entries:
        failures.append("serving: block missing from fresh results")
        return
    floor = (
        TAILS_SMOKE_MIN_SPEEDUP if fresh.get("smoke") else TAILS_MIN_SPEEDUP
    )
    base_p99 = _max_ratio(base.get("serving", []), "p99_ms")
    for e in entries:
        tag = f"stream/serving[n_requests={e.get('n_requests')}]"
        speedup = e.get("speedup")
        if speedup is None:
            failures.append(f"{tag}: speedup missing")
        elif speedup < floor:
            failures.append(
                f"{tag}: coalescing speedup {speedup} < floor {floor} "
                "over per-arrival synchronous ingest"
            )
        else:
            print(f"ok {tag}: speedup {speedup} >= {floor}")
        if not e.get("queries"):
            failures.append(
                f"{tag}: no reader queries recorded — the concurrent-load "
                "latency measurement did not run"
            )
            continue
        p99 = e.get("p99_ms")
        if p99 is None:
            failures.append(f"{tag}: p99_ms missing")
            continue
        if base_p99 is None:
            failures.append("stream/serving: p99_ms missing from baseline")
            continue
        limit = base_p99 * TAILS_P99_REL_SLACK + TAILS_P99_ABS_SLACK
        if p99 > limit:
            failures.append(
                f"{tag}: resolve p99 under load {p99}ms > limit "
                f"{limit:.2f}ms (baseline {base_p99}ms)"
            )
        else:
            print(f"ok {tag}: p99 under load {p99}ms <= {limit:.2f}ms")


@register_gate("matchers", "parallel")
def _check_matchers(base: dict, fresh: dict, failures: list[str]) -> None:
    """fig4_matchers block: family coverage + quality separation."""
    bblock = base.get("fig4_matchers", {}).get("families", {})
    fblock = fresh.get("fig4_matchers", {}).get("families", {})
    if not bblock:
        failures.append("matchers: fig4_matchers block missing from baseline")
        return
    if not fblock:
        failures.append(
            "matchers: fig4_matchers block missing from fresh results"
        )
        return
    for fam, b in sorted(bblock.items()):
        tag = f"matchers/{fam}"
        got = fblock.get(fam)
        if got is None:
            failures.append(f"{tag}: family missing from fresh results")
            continue
        floor = b["f1"] - MATCHERS_F1_ABS_SLACK
        if got["f1"] < floor:
            failures.append(
                f"{tag}: f1 {got['f1']} < floor {floor:.3f} "
                f"(baseline {b['f1']})"
            )
        else:
            print(f"ok {tag}: f1 {got['f1']} >= {floor:.3f}")
    opt = fblock.get("hungarian")
    greedy = fblock.get("hungarian_greedy")
    if opt is not None and greedy is not None:
        if opt["f1"] < greedy["f1"]:
            failures.append(
                f"matchers: hungarian f1 {opt['f1']} < greedy "
                f"{greedy['f1']} — optimal assignment lost its edge"
            )
        else:
            print(
                f"ok matchers: hungarian f1 {opt['f1']} >= greedy "
                f"{greedy['f1']}"
            )


@register_gate("shard", "shard")
def _check_shard(base: dict, fresh: dict, failures: list[str]) -> None:
    """Sharded-serving block: bit-for-bit digest equality across shard
    counts (absolute — the ISSUE-9 equivalence bar at benchmark scale)
    plus the read-capacity scaling floor at 2 shards."""
    entries = fresh.get("shards", [])
    if not entries:
        failures.append("shard: 'shards' block missing from fresh results")
        return
    want = {e.get("n_shards") for e in base.get("shards", [])} or {1, 2, 4}
    got_counts = {e.get("n_shards") for e in entries}
    missing = want - got_counts
    if missing:
        failures.append(
            f"shard: shard counts {sorted(missing)} missing from fresh "
            f"results (have {sorted(got_counts)})"
        )
    by_n = {e["n_shards"]: e for e in entries}
    for n, e in sorted(by_n.items()):
        tag = f"shard[n={n}]"
        for key in ("refs", "ingest_refs_per_s", "resolve_qps_total"):
            if not e.get(key):
                failures.append(f"{tag}: {key} is 0/missing")
        if not e.get("digest"):
            failures.append(f"{tag}: digest missing")
        if e.get("replicas_agree") is not True:
            failures.append(
                f"{tag}: replicas_agree is {e.get('replicas_agree')!r} — "
                "the cross-replica digest all-gather disagreed"
            )
        else:
            print(f"ok {tag}: {e.get('ingest_refs_per_s')} refs/s ingest, "
                  f"{e.get('resolve_qps_total')} QPS, replicas agree")
    digests = {e.get("digest") for e in entries}
    if len(digests) != 1:
        failures.append(
            "shard: state digests diverged across shard counts — the "
            "sharded fixpoint is not bit-for-bit the single-host one: "
            + ", ".join(
                f"n={n}:{str(e.get('digest'))[:12]}"
                for n, e in sorted(by_n.items())
            )
        )
    else:
        print(f"ok shard: one digest across {sorted(by_n)} shards "
              "(bit-for-bit the 1-shard fixpoint)")
    e2 = by_n.get(2)
    if e2 is not None:
        eff = e2.get("qps_scaling_eff")
        if eff is None:
            failures.append("shard[n=2]: qps_scaling_eff missing")
        elif (fresh.get("cpu_count") or 1) < 2:
            print(f"note shard[n=2]: qps_scaling_eff {eff} not gated — "
                  f"measured on cpu_count={fresh.get('cpu_count')}, two "
                  "shards cannot run in parallel there")
        elif eff < SHARD_MIN_QPS_EFF_2:
            failures.append(
                f"shard[n=2]: qps_scaling_eff {eff} < floor "
                f"{SHARD_MIN_QPS_EFF_2} — aggregate resolve QPS no longer "
                "scales with the shard count"
            )
        else:
            print(f"ok shard[n=2]: qps_scaling_eff {eff} >= "
                  f"{SHARD_MIN_QPS_EFF_2}")


def _baseline_family(fresh: dict) -> str:
    """Which baseline file the fresh JSON belongs to."""
    if fresh.get("benchmark") == "shard_scaling" or "shards" in fresh:
        return "shard"
    if fresh.get("benchmark") == "stream_throughput" or "throughput" in fresh:
        return "stream"
    return "parallel"


def main(argv: list[str]) -> int:
    gate = "all"
    args = []
    for a in argv:
        if a.startswith("--gate="):
            gate = a.split("=", 1)[1]
        else:
            args.append(a)
    if gate != "all" and gate not in _GATES:
        print(f"unknown gate {gate!r}; choose from {gate_names()} or all")
        return 2
    if len(args) != 2:
        print(__doc__)
        return 2
    try:
        with open(args[0]) as f:
            base = json.load(f)
        with open(args[1]) as f:
            fresh = json.load(f)
    except OSError as e:
        # a gated baseline that was never produced must fail its CI
        # step loudly, not slip through as "gate does not apply"
        print(f"BENCH GATE INPUT MISSING: {e}")
        return 1
    family = _baseline_family(fresh)
    to_run = [
        g for g in _GATES.values()
        if g.family == family and gate in ("all", g.name)
    ]
    if not to_run:
        print(f"gate {gate!r} does not apply to {args[1]}")
        return 2
    failures: list[str] = []
    for g in to_run:
        g.fn(base, fresh, failures)
    if failures:
        print("BENCH REGRESSION:\n  " + "\n  ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
