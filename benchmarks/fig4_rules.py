"""Fig. 4: the RULES (dedupalog-style Type-I) matcher.

NO-MP vs SMP vs FULL (whole dataset as one instance — feasible because
RULES is fast/linear, as in Appendix C), on both datasets.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import evaluate, prepared, row, timed
from repro.core import metrics as metricslib
from repro.core import pipeline
from repro.core.closure import transitive_closure
from repro.core.cover import Cover, pack_cover
from repro.core.driver import run_smp
from repro.core.rules import RulesMatcher


def full_run(ds, gg):
    """RULES on the entire entity set as one neighborhood."""
    ents = list(range(len(ds.entities)))
    cover = Cover(
        core=[np.asarray(ents, dtype=np.int64)],
        full=[np.asarray(ents, dtype=np.int64)],
    )
    packed = pack_cover(cover, ds.entities, ds.relations,
                        k_bins=(max(8, len(ents)),))
    res = run_smp(packed, RulesMatcher())
    return transitive_closure(res.matches)


def run(which: str):
    ds, packed, gg, _ = prepared(which)
    truth = ds.entities.truth
    row(f"# fig4 rules {which}")
    row("dataset,scheme,precision,recall,f1,wall_s,completeness_vs_full")
    full, t_full = timed(lambda: full_run(ds, gg))
    prf_full = metricslib.prf(full, truth, candidate_gids=gg.gids)

    for scheme in ("nomp", "smp"):
        res, t = timed(lambda s=scheme: pipeline.resolve(
            ds.entities, ds.relations, scheme=s, matcher=RulesMatcher(),
            packed=packed, gg=gg,
        ))
        prf = evaluate(ds, res)
        comp = metricslib.completeness(res.closed, full)
        row(which, scheme, f"{prf.precision:.4f}", f"{prf.recall:.4f}",
            f"{prf.f1:.4f}", f"{t:.3f}", f"{comp:.4f}")
    row(which, "full", f"{prf_full.precision:.4f}", f"{prf_full.recall:.4f}",
        f"{prf_full.f1:.4f}", f"{t_full:.3f}", "1.0000")


def main():
    run("hepth")
    run("dblp")


if __name__ == "__main__":
    main()
