"""Fig. 3(f): running time vs number of neighborhoods.

FULL = the matcher on the first k neighborhoods *merged into one
instance* (super-linear, infeasible beyond small k — the paper's
exponential curve); MMP = message passing over the same k neighborhoods
(linear in k, Theorem 5).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import prepared, row, timed
from repro.core.cover import Cover, pack_cover
from repro.core.driver import run_mmp
from repro.core.global_grounding import build_global_grounding
from repro.core.mln import MLNMatcher, PAPER_LEARNED


def main():
    ds, packed, gg, _ = prepared("hepth")
    n = packed.num_neighborhoods
    fractions = [0.06, 0.125, 0.25, 0.5, 1.0]
    row("# fig3f: time vs #neighborhoods (hepth)")
    row("k_neighborhoods,mmp_s,full_s,full_merged_entities")
    m = MLNMatcher(PAPER_LEARNED)
    for f in fractions:
        k = max(2, int(n * f))
        sub = Cover(core=packed.cover.core[:k], full=packed.cover.full[:k])
        sub_packed = pack_cover(sub, ds.entities, ds.relations)
        sub_gg = build_global_grounding(
            sub_packed.pair_levels, ds.relations, PAPER_LEARNED
        )
        _, t_mmp = timed(lambda: run_mmp(sub_packed, m, sub_gg))

        # FULL: merge the k neighborhoods into one giant instance.  The
        # padded pair axis grows ~quadratically with the merged entity
        # count; cap it to keep CPU CI finite (mirrors the paper, which
        # could not run FULL past 2.5k neighborhoods).
        ents = sorted({int(e) for mem in sub.full for e in mem})
        if len(ents) <= 72:
            merged = Cover(
                core=[np.asarray(ents, dtype=np.int64)],
                full=[np.asarray(ents, dtype=np.int64)],
            )
            mp = pack_cover(merged, ds.entities, ds.relations,
                            k_bins=(max(8, len(ents)),))
            _, t_full = timed(lambda: run_mmp(
                mp, m,
                build_global_grounding(mp.pair_levels, ds.relations, PAPER_LEARNED),
            ))
            full_s = f"{t_full:.3f}"
        else:
            full_s = "infeasible"
        row(k, f"{t_mmp:.3f}", full_s, len(ents))


if __name__ == "__main__":
    main()
