"""Kernel microbenchmarks: jnp reference path wall time on CPU + the
analytic MXU-tile roofline for the Pallas kernels on TPU v5e.

Wall times here time the *reference* path (this container has no TPU);
the derived column reports the kernel's ideal v5e time from its FLOP
count at 197 TFLOP/s bf16 (compute term) vs its HBM bytes at 819 GB/s
(memory term) — i.e. which side of the roofline each kernel sits on.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SMOKE, row

PEAK_FLOPS = 197e12
HBM_BW = 819e9

# --smoke: one small shape per kernel so CI exercises every code path
# without paying for the full grid.
ICM_SIZES = (128,) if SMOKE else (128, 512)
SCORE_SIZES = ((4, 16, 64),) if SMOKE else ((8, 64, 128),)
SIM_SIZES = ((256, 128),) if SMOKE else ((1024, 128),)
ATTN_SEQ = 256 if SMOKE else 1024


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def main():
    rng = np.random.default_rng(0)
    row("# kernels: cpu-ref wall vs v5e roofline terms")
    row("kernel,shape,cpu_ms,flops,v5e_compute_us,v5e_memory_us,bound")

    from repro.kernels.icm_sweep import ref as icm_ref

    for P in ICM_SIZES:
        u = jnp.asarray(rng.standard_normal(P).astype(np.float32))
        C = jnp.asarray(rng.standard_normal((P, P)).astype(np.float32))
        X = jnp.asarray((rng.random((P, P)) < 0.3).astype(np.float32))
        f = jax.jit(icm_ref.sweep_matrix)
        t = _time(f, u, C, X)
        flops = 2 * P * P * P
        bytes_ = (3 * P * P + P) * 4
        ct, mt = flops / PEAK_FLOPS, bytes_ / HBM_BW
        row("icm_sweep", f"P{P}", f"{t*1e3:.3f}", flops,
            f"{ct*1e6:.2f}", f"{mt*1e6:.2f}", "compute" if ct > mt else "memory")

    from repro.kernels.mln_score import ref as score_ref

    for B, S, P in SCORE_SIZES:
        u = jnp.asarray(rng.standard_normal((B, P)).astype(np.float32))
        C = jnp.asarray(rng.standard_normal((B, P, P)).astype(np.float32))
        X = jnp.asarray((rng.random((B, S, P)) < 0.3).astype(np.float32))
        f = jax.jit(score_ref.score_sets)
        t = _time(f, u, C, X)
        flops = B * (2 * S * P * P + 2 * S * P)
        bytes_ = (B * P * P + B * S * P + B * P) * 4
        ct, mt = flops / PEAK_FLOPS, bytes_ / HBM_BW
        row("mln_score", f"B{B}S{S}P{P}", f"{t*1e3:.3f}", flops,
            f"{ct*1e6:.2f}", f"{mt*1e6:.2f}", "compute" if ct > mt else "memory")

    from repro.kernels.ngram_sim import ref as sim_ref

    for M, F in SIM_SIZES:
        A = jnp.asarray(rng.standard_normal((M, F)).astype(np.float32))
        f = jax.jit(lambda a: sim_ref.sim_above(a, a, 0.7))
        t = _time(f, A)
        flops = 2 * M * M * F
        bytes_ = (2 * M * F + M * M) * 4
        ct, mt = flops / PEAK_FLOPS, bytes_ / HBM_BW
        row("ngram_sim", f"M{M}F{F}", f"{t*1e3:.3f}", flops,
            f"{ct*1e6:.2f}", f"{mt*1e6:.2f}", "compute" if ct > mt else "memory")

    from repro.kernels.flash_attn import ref as fa_ref

    B, S, H, hkv, hd = 1, ATTN_SEQ, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, hkv, hd)).astype(np.float32))
    f = jax.jit(lambda q, k, v: fa_ref.attention(q, k, v, 0.125))
    t = _time(f, q, k, v)
    flops = 2 * 2 * B * H * S * S * hd / 2  # causal half
    bytes_ = (B * S * H * hd + 2 * B * S * hkv * hd) * 2 + B * S * H * hd * 4
    ct, mt = flops / PEAK_FLOPS, bytes_ / HBM_BW
    row("flash_attn", f"S{S}H{H}", f"{t*1e3:.3f}", int(flops),
        f"{ct*1e6:.2f}", f"{mt*1e6:.2f}", "compute" if ct > mt else "memory")


if __name__ == "__main__":
    main()
