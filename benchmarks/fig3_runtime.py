"""Fig. 3(d)/(e): running times of NO-MP / SMP / MMP (MLN matcher).

Reproduces the paper's counter-intuitive §6.2 observation: message
passing *reduces* total time because evidence shrinks the active
neighborhoods and the matcher is super-linear in neighborhood size.
"""

from __future__ import annotations

from benchmarks.common import prepared, row, timed
from repro.core import pipeline


def run(which: str):
    ds, packed, gg, cover_t = prepared(which)
    row(f"# fig3_runtime {which} (cover build: {cover_t:.2f}s)")
    row("dataset,scheme,wall_s,evals,rounds,messages")
    for scheme in ("nomp", "smp", "mmp"):
        res, t = timed(lambda s=scheme: pipeline.resolve(
            ds.entities, ds.relations, scheme=s, packed=packed, gg=gg
        ))
        row(which, scheme, f"{t:.3f}", res.result.neighborhood_evals,
            res.result.rounds, res.result.messages_emitted)


def main():
    run("hepth")
    run("dblp")


if __name__ == "__main__":
    main()
