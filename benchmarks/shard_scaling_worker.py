"""Per-shard worker for the ``shard_scaling`` benchmark.

Run by path (not ``-m``) from ``benchmarks.shard_scaling``, one process
per shard, with ``PYTHONPATH`` pointing at ``src``.  Topology comes
from the environment — ``REPRO_SHARD_COORD`` / ``_N`` / ``_ID`` for a
multi-process ``jax.distributed`` CPU mesh, nothing for the 1-shard
degenerate case — and sizing from ``SHARD_BENCH_SCALE`` /
``SHARD_BENCH_QUERIES`` / ``SHARD_BENCH_SCHEME``.  Both must be read
before jax initializes, which is why this is a subprocess.

Measures the two serving axes on its replica and prints one
``RESULT {json}`` line:

* ingest wall time over the full arrival stream (every replica ingests
  every batch — the host state is SPMD-replicated; the bin rounds and
  the LSH probe union are what's sharded), and
* resolve QPS against the published snapshot under a Zipf key
  distribution (reads are replica-local: no collectives, so read
  capacity sums across shards).

The state digest and the cross-replica agreement bit ride along so the
benchmark doubles as an equivalence check at benchmark scale.
"""

from __future__ import annotations

import json
import os
import sys
import time


def _zipf_keys(rng, n_ids, n_queries, s=1.2):
    """Truncated Zipf over a shuffled id space (hot keys are arbitrary
    ids, not the lowest ones)."""
    import numpy as np

    ranks = np.arange(1, n_ids + 1, dtype=np.float64)
    p = ranks**-s
    p /= p.sum()
    perm = rng.permutation(n_ids)
    return perm[rng.choice(n_ids, size=n_queries, p=p)]


def main() -> None:
    import numpy as np

    from repro.stream.shard import ShardContext, ShardCoordinator

    scale = float(os.environ.get("SHARD_BENCH_SCALE", "0.12"))
    n_queries = int(os.environ.get("SHARD_BENCH_QUERIES", "2000"))
    scheme = os.environ.get("SHARD_BENCH_SCHEME", "smp")

    ctx = ShardContext.create()

    from repro.data.synthetic import SynthConfig, arrival_stream, make_dataset

    ds = make_dataset(SynthConfig.hepth(scale=scale, seed=7))
    batches = arrival_stream(ds, batch_size=64)
    coord = ShardCoordinator(ctx, scheme=scheme, parallel=True)

    t0 = time.perf_counter()
    n_refs = 0
    for b in batches:
        coord.ingest(list(b.names), b.edges, ids=[int(x) for x in b.ids])
        n_refs += len(b.names)
    ingest_s = time.perf_counter() - t0

    snap = coord.snapshot()
    keys = _zipf_keys(np.random.default_rng(0), n_refs, n_queries)
    t0 = time.perf_counter()
    for chunk in np.array_split(keys, max(1, n_queries // 256)):
        snap.resolve_many([int(k) for k in chunk])
    resolve_s = max(time.perf_counter() - t0, 1e-9)

    print(
        "RESULT "
        + json.dumps(
            {
                "shard_id": ctx.shard_id,
                "n_shards": ctx.n_shards,
                "refs": n_refs,
                "ingest_s": round(ingest_s, 3),
                "ingest_refs_per_s": round(n_refs / ingest_s, 2),
                "resolve_qps": round(n_queries / resolve_s, 1),
                "n_queries": n_queries,
                "digest": coord.digest(),
                "agree": bool(coord.digests_agree()),
            }
        ),
        flush=True,
    )


if __name__ == "__main__":
    sys.exit(main())
