"""Streaming ingest throughput: entities/sec vs micro-batch size.

For each micro-batch size the whole corpus is streamed through
``ResolveService`` and we report sustained ingest throughput, the mean
dirty-neighborhood fraction, the mean *replay fraction* (ids swept by
the localized canopy replay over corpus size — the quantity that was
1.0 per ingest before localization), the cover-splice accounting
(``cover_splice_rows``: neighborhood rows actually (re)staged by the
incremental assembly — ``splice_per_dirty`` stays O(1) because only
dirty neighborhoods are staged, where full per-ingest repacking would
scale it with the cover), and the matcher-evaluation saving vs
re-running the batch pipeline from scratch at every arrival point.

A second block measures the incremental-grounding cost on the MMP path:
mean/max candidate pairs visited per ``GroundingMaintainer.apply_delta``
against the total candidate-pair count — the O(dirty) claim for the
grounding, measurable per ingest (a from-scratch rebuild would visit
every pair every time) — plus the array-splice accounting
(``grounding_splice_rows`` / ``splice_per_visit``: grounding rows
patched per pair visited; a full per-ingest materialization would scale
it with the candidate-pair count).  ``splice_per_dirty`` and
``splice_per_visit`` are scale-robust ratios gated in CI by
``benchmarks.check_bench`` against the committed ``BENCH_stream.json``.

A third block measures the serving read path: ``snapshot()`` /
``resolve_many()`` QPS from N concurrent reader threads while the whole
corpus is being ingested — readers only ever observe committed
fixpoints (the snapshot is cached between ingests), so read throughput
should not collapse under ingest load.

A fourth block measures *bounded serving memory*: the whole corpus is
streamed through the parallel engine with an LRU ``GroundingCache``
capacity below the bin count, recording the peak array-resident bin
count (must stay <= the capacity), the eviction / cold-reground
traffic the bound costs, and the step-7 promotion host-scan count
(must stay 0: promotion's delta checks run batched on device).  The
throughput block additionally reports the packed-array append
accounting (``growth_copy_per_row``: rows memcpy'd by the
capacity-doubling buffers per row placed — amortized O(1), where the
former per-append ``np.concatenate`` re-copied the bin every ingest).
All of these are gated in CI by ``benchmarks.check_bench`` against the
committed ``BENCH_stream.json``.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import SMOKE, hepth, row, timed
from repro.core import pipeline
from repro.core.driver import run_smp
from repro.core.mln import MLNMatcher, PAPER_LEARNED
from repro.data.synthetic import arrival_stream, truncate
from repro.stream import ResolveService

BATCH_SIZES = (8, 32) if SMOKE else (16, 64, 256)
GROUNDING_BATCH_SIZES = (32,) if SMOKE else (64,)
LRU_BATCH_SIZE = 16 if SMOKE else 64
LRU_CAPACITY = 1
READER_COUNTS = (2,) if SMOKE else (1, 4)
READER_BATCH_SIZE = 64  # ids per resolve_many() call
READER_INGEST_BATCH = 8 if SMOKE else 32  # keep several ingest commits in flight
READER_MAX_INGESTS = 3  # bound the contention window per cell


def _scratch_evals(ds, batches) -> int:
    """Matcher evals of a from-scratch batch re-run at every arrival."""
    total = 0
    m = MLNMatcher(PAPER_LEARNED)
    for b in batches:
        pre = truncate(ds, int(b.ids[-1]) + 1)
        packed, _, _ = pipeline.prepare(pre.entities, pre.relations)
        total += run_smp(packed, m).neighborhood_evals
    return total


def _mean(xs) -> float:
    return sum(xs) / max(len(xs), 1)


def _reader_qps(ds, n_readers: int) -> dict:
    """resolve_many() QPS from reader threads under concurrent ingest."""
    batches = arrival_stream(ds, batch_size=READER_INGEST_BATCH)
    svc = ResolveService(scheme="smp")
    svc.ingest(batches[0].names, batches[0].edges, ids=batches[0].ids)
    stop = threading.Event()
    counts = [0] * n_readers

    def reader(i: int) -> None:
        rng = np.random.default_rng(i)
        done = 0
        while not stop.is_set():
            snap = svc.snapshot()
            ids = rng.integers(0, max(snap.n_entities, 1), size=READER_BATCH_SIZE)
            snap.resolve_many(ids)
            done += READER_BATCH_SIZE
            # pace the reader like a network client would be paced — a
            # GIL-saturating spin loop would measure starvation, not QPS
            time.sleep(0.0005)
        counts[i] = done

    threads = [threading.Thread(target=reader, args=(i,)) for i in range(n_readers)]
    for t in threads:
        t.start()

    def _ingest_rest():
        for b in batches[1 : 1 + READER_MAX_INGESTS]:
            svc.ingest(b.names, b.edges, ids=b.ids)

    _, ingest_s = timed(_ingest_rest)
    stop.set()
    for t in threads:
        t.join()
    queries = sum(counts)
    return {
        "n_readers": n_readers,
        "ingest_s": round(ingest_s, 3),
        "queries": queries,
        "qps_total": round(queries / max(ingest_s, 1e-9), 1),
    }


def main() -> dict:
    ds = hepth()
    n = ds.n_refs
    out = {"benchmark": "stream_throughput", "dataset": "hepth",
           "smoke": SMOKE, "throughput": [], "grounding": [], "readers": []}
    row("# stream_throughput: hepth, scheme=smp")
    row(
        "batch_size,n_batches,entities,ingest_s,entities_per_s,"
        "dirty_frac,replay_frac,splice_rows,splice_per_dirty,"
        "stream_evals,scratch_evals,eval_saving"
    )
    for bs in BATCH_SIZES:
        batches = arrival_stream(ds, batch_size=bs)
        svc = ResolveService(scheme="smp")

        def _run():
            for b in batches:
                svc.ingest(b.names, b.edges, ids=b.ids)

        _, t = timed(_run)
        dirty_frac = _mean(
            [r.n_dirty / max(r.n_neighborhoods, 1) for r in svc.reports]
        )
        replay_frac = _mean(
            [r.replay_visits / max(r.n_entities, 1) for r in svc.reports]
        )
        splice_rows = sum(r.cover_splice_rows for r in svc.reports)
        splice_per_dirty = splice_rows / max(
            sum(r.n_dirty for r in svc.reports), 1
        )
        cd = svc.delta.cover_delta
        rows_placed = cd.total_append_rows + cd.total_restack_rows
        growth_copy_per_row = cd.total_growth_copy_rows / max(rows_placed, 1)
        scratch = _scratch_evals(ds, batches)
        row(
            bs,
            len(batches),
            n,
            f"{t:.2f}",
            f"{n / t:.1f}",
            f"{dirty_frac:.3f}",
            f"{replay_frac:.3f}",
            splice_rows,
            f"{splice_per_dirty:.2f}",
            svc.total_evals,
            scratch,
            f"{scratch / max(svc.total_evals, 1):.1f}x",
        )
        out["throughput"].append({
            "batch_size": bs,
            "entities": n,
            "ingest_s": round(t, 3),
            "entities_per_s": round(n / t, 1),
            "dirty_frac": round(dirty_frac, 4),
            "replay_frac": round(replay_frac, 4),
            "cover_splice_rows": int(splice_rows),
            "splice_per_dirty": round(splice_per_dirty, 3),
            "append_rows": int(cd.total_append_rows),
            "growth_copy_rows": int(cd.total_growth_copy_rows),
            "growth_copy_per_row": round(growth_copy_per_row, 3),
            "stream_evals": int(svc.total_evals),
            "scratch_evals": int(scratch),
        })

    row("")
    row("# stream_throughput: incremental grounding cost, scheme=mmp")
    row(
        "batch_size,entities,total_pairs,grounding_visits_mean,"
        "grounding_visits_max,visit_frac_mean,splice_rows,splice_per_visit"
    )
    for bs in GROUNDING_BATCH_SIZES:
        batches = arrival_stream(ds, batch_size=bs)
        svc = ResolveService(scheme="mmp")
        for b in batches:
            svc.ingest(b.names, b.edges, ids=b.ids)
        total_pairs = len(svc.delta.packed.pair_levels)
        visits = [r.grounding_pair_visits for r in svc.reports]
        splice = sum(r.grounding_splice_rows for r in svc.reports)
        splice_per_visit = splice / max(sum(visits), 1)
        row(
            bs,
            n,
            total_pairs,
            f"{_mean(visits):.1f}",
            max(visits),
            f"{_mean(visits) / max(total_pairs, 1):.4f}",
            splice,
            f"{splice_per_visit:.2f}",
        )
        out["grounding"].append({
            "batch_size": bs,
            "total_pairs": int(total_pairs),
            "visits_mean": round(_mean(visits), 1),
            "visits_max": int(max(visits)),
            "grounding_splice_rows": int(splice),
            "splice_per_visit": round(splice_per_visit, 3),
        })

    row("")
    row("# stream_throughput: bounded serving memory (parallel engine, "
        "LRU grounding cache)")
    row(
        "lru_capacity,n_bins,peak_resident_bins,evictions,cold_regrounds,"
        "promote_host_scans,ingest_s"
    )
    batches = arrival_stream(ds, batch_size=LRU_BATCH_SIZE)
    svc = ResolveService(
        scheme="mmp", parallel=True, gcache_capacity=LRU_CAPACITY
    )

    def _run_lru():
        for b in batches:
            svc.ingest(b.names, b.edges, ids=b.ids)

    _, t_lru = timed(_run_lru)
    g = svc.engine.gcache
    host_scans = sum(r.promote_host_scans for r in svc.reports)
    row(
        LRU_CAPACITY,
        len(svc.delta.packed.bins),
        g.peak_resident_bins,
        g.evictions,
        g.cold_regrounds,
        host_scans,
        f"{t_lru:.2f}",
    )
    out["serving_memory"] = [{
        "lru_capacity": LRU_CAPACITY,
        "n_bins": len(svc.delta.packed.bins),
        "peak_resident_bins": int(g.peak_resident_bins),
        "evictions": int(g.evictions),
        "cold_regrounds": int(g.cold_regrounds),
        "promote_host_scans": int(host_scans),
        "ingest_s": round(t_lru, 3),
    }]

    row("")
    row("# stream_throughput: resolve_many QPS under concurrent ingest")
    row("n_readers,ingest_s,queries,qps_total")
    for nr in READER_COUNTS:
        stats = _reader_qps(ds, nr)
        row(nr, stats["ingest_s"], stats["queries"], stats["qps_total"])
        out["readers"].append(stats)
    return out


if __name__ == "__main__":
    main()
