"""Streaming ingest throughput: entities/sec vs micro-batch size.

For each micro-batch size the whole corpus is streamed through
``ResolveService`` and we report sustained ingest throughput, the mean
dirty-neighborhood fraction (how much of the cover each arrival
re-activates — the quantity delta maintenance exists to keep small),
and the matcher-evaluation saving vs re-running the batch pipeline from
scratch at every arrival point.
"""

from __future__ import annotations

from benchmarks.common import hepth, row, timed
from repro.core import pipeline
from repro.core.driver import run_smp
from repro.core.mln import MLNMatcher, PAPER_LEARNED
from repro.data.synthetic import arrival_stream, truncate
from repro.stream import ResolveService

BATCH_SIZES = (16, 64, 256)


def _scratch_evals(ds, batches) -> int:
    """Matcher evals of a from-scratch batch re-run at every arrival."""
    total = 0
    m = MLNMatcher(PAPER_LEARNED)
    for b in batches:
        pre = truncate(ds, int(b.ids[-1]) + 1)
        packed, _, _ = pipeline.prepare(pre.entities, pre.relations)
        total += run_smp(packed, m).neighborhood_evals
    return total


def main():
    ds = hepth()
    n = ds.n_refs
    row("# stream_throughput: hepth, scheme=smp")
    row(
        "batch_size,n_batches,entities,ingest_s,entities_per_s,"
        "dirty_frac,stream_evals,scratch_evals,eval_saving"
    )
    for bs in BATCH_SIZES:
        n_batches = max(1, n // bs)
        batches = arrival_stream(ds, n_batches)
        svc = ResolveService(scheme="smp")

        def _run():
            for b in batches:
                svc.ingest(b.names, b.edges, ids=b.ids)

        _, t = timed(_run)
        dirty_frac = sum(
            r.n_dirty / max(r.n_neighborhoods, 1) for r in svc.reports
        ) / len(svc.reports)
        scratch = _scratch_evals(ds, batches)
        row(
            bs,
            len(batches),
            n,
            f"{t:.2f}",
            f"{n / t:.1f}",
            f"{dirty_frac:.3f}",
            svc.total_evals,
            scratch,
            f"{scratch / max(svc.total_evals, 1):.1f}x",
        )


if __name__ == "__main__":
    main()
