"""Streaming ingest throughput: entities/sec vs micro-batch size.

For each micro-batch size the whole corpus is streamed through
``ResolveService`` and we report sustained ingest throughput, the mean
dirty-neighborhood fraction, the mean *replay fraction* (ids swept by
the localized canopy replay over corpus size — the quantity that was
1.0 per ingest before localization), the cover-splice accounting
(``cover_splice_rows``: neighborhood rows actually (re)staged by the
incremental assembly — ``splice_per_dirty`` stays O(1) because only
dirty neighborhoods are staged, where full per-ingest repacking would
scale it with the cover), and the matcher-evaluation saving vs
re-running the batch pipeline from scratch at every arrival point.

A second block measures the incremental-grounding cost on the MMP path:
mean/max candidate pairs visited per ``GroundingMaintainer.apply_delta``
against the total candidate-pair count — the O(dirty) claim for the
grounding, measurable per ingest (a from-scratch rebuild would visit
every pair every time) — plus the array-splice accounting
(``grounding_splice_rows`` / ``splice_per_visit``: grounding rows
patched per pair visited; a full per-ingest materialization would scale
it with the candidate-pair count).  ``splice_per_dirty`` and
``splice_per_visit`` are scale-robust ratios gated in CI by
``benchmarks.check_bench`` against the committed ``BENCH_stream.json``.

A third block measures the serving read path: ``snapshot()`` /
``resolve_many()`` QPS from N concurrent reader threads while the whole
corpus is being ingested — readers only ever observe committed
fixpoints (the snapshot is cached between ingests), so read throughput
should not collapse under ingest load.

A fourth block measures *bounded serving memory*: the whole corpus is
streamed through the parallel engine with an LRU ``GroundingCache``
capacity below the bin count, recording the peak array-resident bin
count (must stay <= the capacity), the eviction / cold-reground
traffic the bound costs, and the step-7 promotion host-scan count
(must stay 0: promotion's delta checks run batched on device).  The
throughput block additionally reports the packed-array append
accounting (``growth_copy_per_row``: rows memcpy'd by the
capacity-doubling buffers per row placed — amortized O(1), where the
former per-append ``np.concatenate`` re-copied the bin every ingest).
All of these are gated in CI by ``benchmarks.check_bench`` against the
committed ``BENCH_stream.json``.

Counters come from the runtime metrics registry (``repro.obs``): each
cell ``obs.reset()``s then reads one ``snapshot()`` — the benchmark no
longer sums per-report dataclass fields by hand.  The readers block
reports ``p50_ms``/``p99_ms`` from the ``resolve.latency_ms`` histogram
(exact percentiles over the reader threads' per-call samples), and a
fifth block reports device-transfer bytes per site
(``transfer.{gcache,promoter,prepare}_bytes``) with scale-robust
upload-per-unit ratios, gated by ``check_bench --gate=transfer``.

The ``recovery`` block measures the durability plane: the corpus is
streamed with the write-ahead log on (fsync'd append per coalesced
ingest) and periodic checkpoints, reporting the WAL overhead as a
fraction of the ingest p50 (``wal_overhead_frac``, gated < 10% by
``check_bench --gate=recovery``) and the recovery latency — wall time
for ``ResolveService.recover`` to restore the latest snapshot and
replay the WAL tail — together with a bit-for-bit digest equality check
(``fixpoint_equal``) against the uninterrupted run.

The final ``serving`` block measures the coalescing front-end
(:mod:`repro.stream.serving`): the same paper-aligned request stream is
ingested once per-arrival synchronously (the baseline a naive
request/response deployment pays — one delta+fixpoint per ~4-entity
request) and once through ``ServingFrontend`` at full offered load with
Zipf-skewed concurrent readers (``benchmarks.loadgen``).  Reported:
sustained coalesced ingest throughput, the speedup over per-arrival,
the coalescing shape (batches / mean size / queue-wait percentiles),
and the readers' resolve p50/p99 — gated by ``check_bench
--gate=tails`` (speedup floor + baseline-relative p99).  The two runs
are asserted to reach the same fixpoint: coalescing is a scheduling
choice, not an accuracy trade.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import SMOKE, hepth, row, timed
from repro import obs
from repro.core import pipeline
from repro.core.driver import run_smp
from repro.core.mln import MLNMatcher, PAPER_LEARNED
from repro.data.synthetic import arrival_stream, truncate
from repro.stream import ResolveService

BATCH_SIZES = (8, 32) if SMOKE else (16, 64, 256)
GROUNDING_BATCH_SIZES = (32,) if SMOKE else (64,)
LRU_BATCH_SIZE = 16 if SMOKE else 64
LRU_CAPACITY = 1
READER_COUNTS = (2,) if SMOKE else (1, 4)
READER_BATCH_SIZE = 64  # ids per resolve_many() call
READER_INGEST_BATCH = 8 if SMOKE else 32  # keep several ingest commits in flight
READER_MAX_INGESTS = 3  # bound the contention window per cell
SERVING_REQUESTS = 48 if SMOKE else 200  # prefix: per-arrival sync is slow
SERVING_REQUEST_ENTITIES = 4  # ~one paper per request
SERVING_MAX_BATCH = 32 if SMOKE else 256
SERVING_READERS = 2
RECOVERY_BATCH_SIZE = 16 if SMOKE else 64


def _scratch_evals(ds, batches) -> int:
    """Matcher evals of a from-scratch batch re-run at every arrival."""
    total = 0
    m = MLNMatcher(PAPER_LEARNED)
    for b in batches:
        pre = truncate(ds, int(b.ids[-1]) + 1)
        packed, _, _ = pipeline.prepare(pre.entities, pre.relations)
        total += run_smp(packed, m).neighborhood_evals
    return total


def _mean(xs) -> float:
    return sum(xs) / max(len(xs), 1)


def _reader_qps(ds, n_readers: int) -> dict:
    """resolve_many() QPS from reader threads under concurrent ingest,
    plus the p50/p99 of the per-call resolve latency histogram the
    snapshot read path records into the metrics registry."""
    batches = arrival_stream(ds, batch_size=READER_INGEST_BATCH)
    svc = ResolveService(scheme="smp")
    svc.ingest(batches[0].names, batches[0].edges, ids=batches[0].ids)
    obs.reset()  # the latency histogram samples only the reader window
    stop = threading.Event()
    counts = [0] * n_readers

    def reader(i: int) -> None:
        rng = np.random.default_rng(i)
        done = 0
        while not stop.is_set():
            snap = svc.snapshot()
            ids = rng.integers(0, max(snap.n_entities, 1), size=READER_BATCH_SIZE)
            snap.resolve_many(ids)
            done += READER_BATCH_SIZE
            # pace the reader like a network client would be paced — a
            # GIL-saturating spin loop would measure starvation, not QPS
            time.sleep(0.0005)
        counts[i] = done

    threads = [threading.Thread(target=reader, args=(i,)) for i in range(n_readers)]
    for t in threads:
        t.start()

    def _ingest_rest():
        for b in batches[1 : 1 + READER_MAX_INGESTS]:
            svc.ingest(b.names, b.edges, ids=b.ids)

    _, ingest_s = timed(_ingest_rest)
    stop.set()
    for t in threads:
        t.join()
    queries = sum(counts)
    lat = obs.get_registry().histogram("resolve.latency_ms").summary()
    return {
        "n_readers": n_readers,
        "ingest_s": round(ingest_s, 3),
        "queries": queries,
        "qps_total": round(queries / max(ingest_s, 1e-9), 1),
        "p50_ms": round(lat["p50"], 4),
        "p99_ms": round(lat["p99"], 4),
    }


def main() -> dict:
    ds = hepth()
    n = ds.n_refs
    out = {"benchmark": "stream_throughput", "dataset": "hepth",
           "smoke": SMOKE, "throughput": [], "grounding": [], "readers": []}
    row("# stream_throughput: hepth, scheme=smp")
    row(
        "batch_size,n_batches,entities,ingest_s,entities_per_s,"
        "dirty_frac,replay_frac,splice_rows,splice_per_dirty,"
        "stream_evals,scratch_evals,eval_saving"
    )
    for bs in BATCH_SIZES:
        batches = arrival_stream(ds, batch_size=bs)
        obs.reset()  # each cell reads the registry's cumulative counters
        svc = ResolveService(scheme="smp")

        def _run():
            for b in batches:
                svc.ingest(b.names, b.edges, ids=b.ids)

        _, t = timed(_run)
        # everything below is read from the metrics-registry snapshot —
        # the benchmark no longer reaches into service/CoverDelta state
        snap = obs.get_registry().snapshot()
        c, h = snap["counters"], snap["histograms"]
        dirty_frac = h["ingest.dirty_frac"]["mean"]
        replay_frac = h["ingest.replay_frac"]["mean"]
        splice_rows = c.get("ingest.cover_splice_rows", 0)
        splice_per_dirty = splice_rows / max(c.get("ingest.n_dirty", 0), 1)
        stream_evals = c.get("ingest.neighborhood_evals", 0)
        append_rows = c.get("cover.append_rows", 0)
        growth_copy_rows = c.get("cover.growth_copy_rows", 0)
        rows_placed = append_rows + c.get("cover.restack_rows", 0)
        growth_copy_per_row = growth_copy_rows / max(rows_placed, 1)
        scratch = _scratch_evals(ds, batches)
        row(
            bs,
            len(batches),
            n,
            f"{t:.2f}",
            f"{n / t:.1f}",
            f"{dirty_frac:.3f}",
            f"{replay_frac:.3f}",
            splice_rows,
            f"{splice_per_dirty:.2f}",
            stream_evals,
            scratch,
            f"{scratch / max(stream_evals, 1):.1f}x",
        )
        out["throughput"].append({
            "batch_size": bs,
            "entities": n,
            "ingest_s": round(t, 3),
            "entities_per_s": round(n / t, 1),
            "dirty_frac": round(dirty_frac, 4),
            "replay_frac": round(replay_frac, 4),
            "cover_splice_rows": int(splice_rows),
            "splice_per_dirty": round(splice_per_dirty, 3),
            "append_rows": int(append_rows),
            "growth_copy_rows": int(growth_copy_rows),
            "growth_copy_per_row": round(growth_copy_per_row, 3),
            "stream_evals": int(stream_evals),
            "scratch_evals": int(scratch),
        })

    row("")
    row("# stream_throughput: incremental grounding cost, scheme=mmp")
    row(
        "batch_size,entities,total_pairs,grounding_visits_mean,"
        "grounding_visits_max,visit_frac_mean,splice_rows,splice_per_visit"
    )
    for bs in GROUNDING_BATCH_SIZES:
        batches = arrival_stream(ds, batch_size=bs)
        obs.reset()
        svc = ResolveService(scheme="mmp")
        for b in batches:
            svc.ingest(b.names, b.edges, ids=b.ids)
        total_pairs = len(svc.delta.packed.pair_levels)
        snap = obs.get_registry().snapshot()
        c = snap["counters"]
        vh = snap["histograms"]["ingest.grounding_pair_visits"]
        visits_mean, visits_max = vh["mean"], vh["max"]
        splice = c.get("ingest.grounding_splice_rows", 0)
        splice_per_visit = splice / max(c.get("ingest.grounding_pair_visits", 0), 1)
        row(
            bs,
            n,
            total_pairs,
            f"{visits_mean:.1f}",
            int(visits_max),
            f"{visits_mean / max(total_pairs, 1):.4f}",
            splice,
            f"{splice_per_visit:.2f}",
        )
        out["grounding"].append({
            "batch_size": bs,
            "total_pairs": int(total_pairs),
            "visits_mean": round(visits_mean, 1),
            "visits_max": int(visits_max),
            "grounding_splice_rows": int(splice),
            "splice_per_visit": round(splice_per_visit, 3),
        })

    row("")
    row("# stream_throughput: bounded serving memory (parallel engine, "
        "LRU grounding cache)")
    row(
        "lru_capacity,n_bins,peak_resident_bins,evictions,cold_regrounds,"
        "promote_host_scans,ingest_s"
    )
    batches = arrival_stream(ds, batch_size=LRU_BATCH_SIZE)
    obs.reset()
    svc = ResolveService(
        scheme="mmp", parallel=True, gcache_capacity=LRU_CAPACITY
    )

    def _run_lru():
        for b in batches:
            svc.ingest(b.names, b.edges, ids=b.ids)

    _, t_lru = timed(_run_lru)
    snap = obs.get_registry().snapshot()
    c = snap["counters"]
    peak = int(snap["gauges"].get("ingest.peak_resident_bins", 0))
    evictions = c.get("ingest.cache_evictions", 0)
    cold = c.get("ingest.cold_regrounds", 0)
    host_scans = c.get("ingest.promote_host_scans", 0)
    row(
        LRU_CAPACITY,
        len(svc.delta.packed.bins),
        peak,
        evictions,
        cold,
        host_scans,
        f"{t_lru:.2f}",
    )
    out["serving_memory"] = [{
        "lru_capacity": LRU_CAPACITY,
        "n_bins": len(svc.delta.packed.bins),
        "peak_resident_bins": peak,
        "evictions": int(evictions),
        "cold_regrounds": int(cold),
        "promote_host_scans": int(host_scans),
        "ingest_s": round(t_lru, 3),
    }]

    # -- device-transfer accounting of the same (mmp, parallel, LRU) run:
    # upload bytes per unit of per-site work.  The ratios are
    # scale-robust (per-row / per-pair byte cost is bounded by the bin
    # shapes), which is what ``check_bench --gate=transfer`` gates —
    # catching an accidental return to O(corpus) re-uploads per ingest.
    row("")
    row("# stream_throughput: device-transfer accounting (same LRU run)")
    row(
        "site,bytes,denominator,bytes_per_unit"
    )
    n_ingests = max(len(batches), 1)
    packed_rows = sum(
        b.entity_mask.shape[0] for b in svc.delta.packed.bins.values()
    )
    total_pairs = len(svc.delta.packed.pair_levels)
    gcache_bytes = c.get("transfer.gcache_bytes", 0)
    promoter_bytes = c.get("transfer.promoter_bytes", 0)
    prepare_bytes = c.get("transfer.prepare_bytes", 0)
    reground_rows = c.get("ingest.reground_rows", 0)
    gcache_per_row = gcache_bytes / max(reground_rows, 1)
    promoter_per_pair_ingest = promoter_bytes / max(total_pairs * n_ingests, 1)
    prepare_per_row_ingest = prepare_bytes / max(packed_rows * n_ingests, 1)
    row("gcache", gcache_bytes, reground_rows, f"{gcache_per_row:.1f}")
    row("promoter", promoter_bytes, total_pairs * n_ingests,
        f"{promoter_per_pair_ingest:.2f}")
    row("prepare", prepare_bytes, packed_rows * n_ingests,
        f"{prepare_per_row_ingest:.2f}")
    out["transfer"] = [{
        "lru_capacity": LRU_CAPACITY,
        "n_ingests": int(n_ingests),
        "total_pairs": int(total_pairs),
        "packed_rows": int(packed_rows),
        "reground_rows": int(reground_rows),
        "gcache_bytes": int(gcache_bytes),
        "promoter_bytes": int(promoter_bytes),
        "prepare_bytes": int(prepare_bytes),
        "upload_bytes_per_ingest_mean": round(
            snap["histograms"]["ingest.upload_bytes"]["mean"], 1
        ),
        "gcache_upload_per_reground_row": round(gcache_per_row, 3),
        "promoter_upload_per_pair_ingest": round(
            promoter_per_pair_ingest, 3
        ),
        "prepare_upload_per_row_ingest": round(prepare_per_row_ingest, 3),
    }]

    row("")
    row("# stream_throughput: resolve_many QPS under concurrent ingest")
    row("n_readers,ingest_s,queries,qps_total,p50_ms,p99_ms")
    for nr in READER_COUNTS:
        stats = _reader_qps(ds, nr)
        row(nr, stats["ingest_s"], stats["queries"], stats["qps_total"],
            stats["p50_ms"], stats["p99_ms"])
        out["readers"].append(stats)

    out["recovery"] = [_recovery_block(ds)]
    out["serving"] = [_serving_block(ds)]
    return out


def _recovery_block(ds) -> dict:
    """Durability cost + recovery latency at full stream scale: WAL
    append overhead per ingest, snapshot+replay wall time, and the
    bit-for-bit fixpoint check recovery must pass."""
    import shutil
    import tempfile

    from repro.stream.digest import state_digest

    batches = arrival_stream(ds, batch_size=RECOVERY_BATCH_SIZE)
    # checkpoint strictly inside the stream so recovery exercises BOTH
    # planes — snapshot restore and a non-empty WAL-tail replay; with
    # too few batches for an interior checkpoint (smoke), go WAL-only
    ckpt_every = len(batches) - 1 if len(batches) > 2 else 0
    tmp = tempfile.mkdtemp(prefix="repro-recovery-")
    try:
        obs.reset()
        svc = ResolveService(
            scheme="smp",
            durability_dir=tmp,
            checkpoint_every=ckpt_every,
        )

        def _run():
            for b in batches:
                svc.ingest(b.names, b.edges, ids=b.ids)

        _, t_ingest = timed(_run)
        want = state_digest(svc)
        snap = obs.get_registry().snapshot()
        wal_ms = snap["histograms"]["wal.append_ms"]
        ingest_p50_ms = snap["histograms"]["ingest.wall_ms"]["p50"]
        wal_overhead_frac = wal_ms["mean"] / max(ingest_p50_ms, 1e-9)
        wal_bytes = snap["counters"].get("wal.bytes", 0)
        svc.close()

        obs.reset()
        rec, t_rec = timed(
            lambda: ResolveService.recover(
                tmp,
                scheme="smp",
                checkpoint_every=ckpt_every,
            )
        )
        fixpoint_equal = state_digest(rec) == want
        replayed = obs.get_registry().value("recover.replayed")
        rec.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    row("")
    row("# stream_throughput: durability (WAL + checkpoint) + recovery")
    row(
        "batch_size,ckpt_every,n_batches,ingest_s,wal_append_ms_mean,"
        "ingest_p50_ms,wal_overhead_frac,wal_bytes,recovery_s,"
        "replayed_records,fixpoint_equal"
    )
    row(
        RECOVERY_BATCH_SIZE,
        ckpt_every,
        len(batches),
        f"{t_ingest:.2f}",
        f"{wal_ms['mean']:.3f}",
        f"{ingest_p50_ms:.1f}",
        f"{wal_overhead_frac:.4f}",
        int(wal_bytes),
        f"{t_rec:.2f}",
        int(replayed),
        fixpoint_equal,
    )
    return {
        "batch_size": RECOVERY_BATCH_SIZE,
        "checkpoint_every": ckpt_every,
        "n_batches": len(batches),
        "ingest_s": round(t_ingest, 3),
        "wal_append_ms_mean": round(wal_ms["mean"], 4),
        "wal_append_ms_p99": round(wal_ms["p99"], 4),
        "ingest_p50_ms": round(ingest_p50_ms, 2),
        "wal_overhead_frac": round(wal_overhead_frac, 5),
        "wal_bytes": int(wal_bytes),
        "recovery_s": round(t_rec, 3),
        "replayed_records": int(replayed),
        "fixpoint_equal": bool(fixpoint_equal),
    }


def _serving_block(ds) -> dict:
    """Coalescing front-end vs per-arrival synchronous ingest, same
    request stream, with Zipf readers live during the coalesced run."""
    from benchmarks.loadgen import LoadgenConfig, run_load
    from repro.stream import ServingConfig, ServingFrontend

    batches = arrival_stream(ds, batch_size=SERVING_REQUEST_ENTITIES)
    requests = [
        (b.names, b.edges, [int(i) for i in b.ids])
        for b in batches[:SERVING_REQUESTS]
    ]
    n_ent = sum(len(r[0]) for r in requests)

    # baseline: one delta+fixpoint ingest per request, no coalescing
    obs.reset()
    sync = ResolveService(scheme="smp")

    def _run_sync():
        for names, edges, ids in requests:
            sync.ingest(names, edges, ids=ids)

    _, t_sync = timed(_run_sync)
    sync_eps = n_ent / max(t_sync, 1e-9)

    # coalesced: full offered load through the frontend, readers live
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(
        svc, ServingConfig(max_batch=SERVING_MAX_BATCH, max_delay_ms=2.0)
    )
    stats = run_load(
        fe,
        requests,
        LoadgenConfig(n_readers=SERVING_READERS, seed=0),
    )
    fe.close()
    # coalescing is a schedule change, not an accuracy trade
    assert svc.matches.as_set() == sync.matches.as_set()

    speedup = stats["entities_per_s"] / max(sync_eps, 1e-9)
    row("")
    row("# stream_throughput: serving front-end (coalesced ingest + "
        "concurrent Zipf readers) vs per-arrival synchronous ingest")
    row(
        "n_requests,entities,sync_entities_per_s,coalesced_entities_per_s,"
        "speedup,n_batches,mean_coalesced_size,queue_wait_p99_ms,"
        "qps_total,p50_ms,p99_ms"
    )
    row(
        len(requests),
        n_ent,
        f"{sync_eps:.1f}",
        stats["entities_per_s"],
        f"{speedup:.1f}x",
        stats["n_batches"],
        stats["mean_coalesced_size"],
        stats["queue_wait_p99_ms"],
        stats["qps_total"],
        stats["p50_ms"],
        stats["p99_ms"],
    )
    return {
        "n_requests": len(requests),
        "request_entities": SERVING_REQUEST_ENTITIES,
        "entities": n_ent,
        "max_batch": SERVING_MAX_BATCH,
        "max_delay_ms": 2.0,
        "n_readers": SERVING_READERS,
        "sync_ingest_s": round(t_sync, 3),
        "sync_entities_per_s": round(sync_eps, 1),
        "coalesced_entities_per_s": stats["entities_per_s"],
        "speedup": round(speedup, 2),
        "n_batches": stats["n_batches"],
        "mean_coalesced_size": stats["mean_coalesced_size"],
        "queue_wait_p50_ms": stats["queue_wait_p50_ms"],
        "queue_wait_p99_ms": stats["queue_wait_p99_ms"],
        "queries": stats["queries"],
        "qps_total": stats["qps_total"],
        "p50_ms": stats["p50_ms"],
        "p99_ms": stats["p99_ms"],
        "shed": stats["shed"],
    }


if __name__ == "__main__":
    main()
