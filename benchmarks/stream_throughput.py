"""Streaming ingest throughput: entities/sec vs micro-batch size.

For each micro-batch size the whole corpus is streamed through
``ResolveService`` and we report sustained ingest throughput, the mean
dirty-neighborhood fraction, the mean *replay fraction* (ids swept by
the localized canopy replay over corpus size — the quantity that was
1.0 per ingest before localization), and the matcher-evaluation saving
vs re-running the batch pipeline from scratch at every arrival point.

A second block measures the incremental-grounding cost on the MMP path:
mean/max candidate pairs visited per ``GroundingMaintainer.apply_delta``
against the total candidate-pair count — the O(dirty) claim for the
grounding, measurable per ingest (a from-scratch rebuild would visit
every pair every time).
"""

from __future__ import annotations

from benchmarks.common import SMOKE, hepth, row, timed
from repro.core import pipeline
from repro.core.driver import run_smp
from repro.core.mln import MLNMatcher, PAPER_LEARNED
from repro.data.synthetic import arrival_stream, truncate
from repro.stream import ResolveService

BATCH_SIZES = (8, 32) if SMOKE else (16, 64, 256)
GROUNDING_BATCH_SIZES = (32,) if SMOKE else (64,)


def _scratch_evals(ds, batches) -> int:
    """Matcher evals of a from-scratch batch re-run at every arrival."""
    total = 0
    m = MLNMatcher(PAPER_LEARNED)
    for b in batches:
        pre = truncate(ds, int(b.ids[-1]) + 1)
        packed, _, _ = pipeline.prepare(pre.entities, pre.relations)
        total += run_smp(packed, m).neighborhood_evals
    return total


def _mean(xs) -> float:
    return sum(xs) / max(len(xs), 1)


def main():
    ds = hepth()
    n = ds.n_refs
    row("# stream_throughput: hepth, scheme=smp")
    row(
        "batch_size,n_batches,entities,ingest_s,entities_per_s,"
        "dirty_frac,replay_frac,stream_evals,scratch_evals,eval_saving"
    )
    for bs in BATCH_SIZES:
        batches = arrival_stream(ds, batch_size=bs)
        svc = ResolveService(scheme="smp")

        def _run():
            for b in batches:
                svc.ingest(b.names, b.edges, ids=b.ids)

        _, t = timed(_run)
        dirty_frac = _mean(
            [r.n_dirty / max(r.n_neighborhoods, 1) for r in svc.reports]
        )
        replay_frac = _mean(
            [r.replay_visits / max(r.n_entities, 1) for r in svc.reports]
        )
        scratch = _scratch_evals(ds, batches)
        row(
            bs,
            len(batches),
            n,
            f"{t:.2f}",
            f"{n / t:.1f}",
            f"{dirty_frac:.3f}",
            f"{replay_frac:.3f}",
            svc.total_evals,
            scratch,
            f"{scratch / max(svc.total_evals, 1):.1f}x",
        )

    row("")
    row("# stream_throughput: incremental grounding cost, scheme=mmp")
    row(
        "batch_size,entities,total_pairs,grounding_visits_mean,"
        "grounding_visits_max,visit_frac_mean"
    )
    for bs in GROUNDING_BATCH_SIZES:
        batches = arrival_stream(ds, batch_size=bs)
        svc = ResolveService(scheme="mmp")
        for b in batches:
            svc.ingest(b.names, b.edges, ids=b.ids)
        total_pairs = len(svc.delta.packed.pair_levels)
        visits = [r.grounding_pair_visits for r in svc.reports]
        row(
            bs,
            n,
            total_pairs,
            f"{_mean(visits):.1f}",
            max(visits),
            f"{_mean(visits) / max(total_pairs, 1):.4f}",
        )


if __name__ == "__main__":
    main()
