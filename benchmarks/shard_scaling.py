"""Sharded-serving scaling: ingest throughput + resolve QPS vs shards.

Spawns one ``jax.distributed`` CPU-mesh worker process per shard
(``shard_scaling_worker.py``) at shard counts {1, 2, 4} over a
10x-hepth synthetic corpus (``scale=1.2`` vs the 0.12 the stream
benchmark uses; smoke drops back to 0.12) and reports, per count:

* **ingest throughput** — refs/s through the full arrival stream,
  bounded by the slowest replica (the host state is SPMD-replicated;
  the device bin rounds and the LSH probe union are what's sharded);
* **aggregate resolve QPS** — the sum of per-replica Zipf-read QPS.
  Reads are replica-local (no collectives), so read capacity is the
  axis that scales with the shard count;
* the **state digest** of every replica — all replicas of a count must
  agree, and every count must land on the 1-shard digest bit-for-bit
  (the ISSUE-9 equivalence bar, re-checked at benchmark scale).

Wall-clock scaling on one box is bounded by the physical core count —
N co-scheduled replicas on fewer than N cores timeshare — so the JSON
records ``cpu_count`` and ``check_bench --gate=shard`` only enforces
the 2-shard efficiency floor where two shards could actually run in
parallel.  Shard counts whose mesh cannot form on this jax build (no
CPU collectives client) fall back to single-process multi-device
sharding (``--xla_force_host_platform_device_count``), recorded as
``mode: multidevice`` — digests must still match.
"""

from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import time
from pathlib import Path

from benchmarks.common import SMOKE, row

SHARD_COUNTS = (1, 2, 4)
SCALE = float(os.environ.get("BENCH_SHARD_SCALE", "0.12" if SMOKE else "1.2"))
# resolves are ~microsecond dict lookups: the count must be large
# enough that the timed read phase spans a scheduler-meaningful window,
# or the QPS ratio between shard counts is pure timer noise
N_QUERIES = 200_000 if SMOKE else 1_000_000
SCHEME = os.environ.get("BENCH_SHARD_SCHEME", "smp")
# per-replica wall: N co-scheduled replicas on a box with < N cores
# timeshare one corpus ingest each, so the 4-shard leg can run ~4x the
# 1-shard wall — the bound must leave headroom for that, not just for
# the single-replica cost
TIMEOUT_S = 900 if SMOKE else 7200

_WORKER = str(Path(__file__).resolve().with_name("shard_scaling_worker.py"))
_SRC = str(Path(__file__).resolve().parent.parent / "src")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _base_env() -> dict:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = _SRC
    env["SHARD_BENCH_SCALE"] = str(SCALE)
    env["SHARD_BENCH_QUERIES"] = str(N_QUERIES)
    env["SHARD_BENCH_SCHEME"] = SCHEME
    # topology is per-spawn; never inherit a stale mesh from the caller
    for k in ("REPRO_SHARD_COORD", "REPRO_SHARD_N", "REPRO_SHARD_ID"):
        env.pop(k, None)
    return env


def _collect(procs) -> list[dict]:
    outs, fail = [], []
    try:
        for p in procs:
            out, err = p.communicate(timeout=TIMEOUT_S)
            if p.returncode != 0:
                fail.append(f"rc={p.returncode}\n{out}\n{err}")
                continue
            res = [ln for ln in out.splitlines() if ln.startswith("RESULT ")]
            if not res:
                fail.append(f"no RESULT line\n{out}\n{err}")
                continue
            outs.append(json.loads(res[-1][len("RESULT "):]))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    if fail:
        raise RuntimeError("shard worker failed:\n" + "\n".join(fail))
    return outs


def _run_multiprocess(n_shards: int) -> list[dict]:
    coord = f"127.0.0.1:{_free_port()}"
    procs = []
    for i in range(n_shards):
        env = _base_env()
        if n_shards > 1:
            env["REPRO_SHARD_COORD"] = coord
            env["REPRO_SHARD_N"] = str(n_shards)
            env["REPRO_SHARD_ID"] = str(i)
        procs.append(
            subprocess.Popen(
                [sys.executable, _WORKER],
                stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
                env=env,
            )
        )
    return _collect(procs)


def _run_multidevice(n_shards: int) -> list[dict]:
    """Fallback when the jax build has no CPU collectives client: one
    process, ``n_shards`` forced host devices, bin rows still sharded."""
    env = _base_env()
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_shards} "
        + env.get("XLA_FLAGS", "")
    )
    proc = subprocess.Popen(
        [sys.executable, _WORKER],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
    )
    return _collect([proc])


def _mesh_available() -> bool:
    """Probe a 2-process mesh once (gloo is not in every jax build)."""
    procs = []
    try:
        coord = f"127.0.0.1:{_free_port()}"
        for i in range(2):
            env = _base_env()
            env["REPRO_SHARD_COORD"] = coord
            env["REPRO_SHARD_N"] = "2"
            env["REPRO_SHARD_ID"] = str(i)
            procs.append(
                subprocess.Popen(
                    [sys.executable, "-c",
                     "from repro.stream.shard import ShardContext\n"
                     "ctx = ShardContext.create()\n"
                     "assert ctx.merger.union({ctx.shard_id}) == {0, 1}\n"],
                    stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                    text=True, env=env,
                )
            )
        ok = True
        for p in procs:
            p.communicate(timeout=300)
            ok = ok and p.returncode == 0
        return ok
    except Exception:
        for p in procs:
            if p.poll() is None:
                p.kill()
        return False


def main() -> dict:
    mesh_ok = _mesh_available()
    if not mesh_ok:
        print("no multi-process CPU mesh on this jax build; "
              "falling back to multi-device sharding")
    shards = []
    row("n_shards", "mode", "refs", "ingest_s", "refs_per_s",
        "resolve_qps", "agree")
    for n in SHARD_COUNTS:
        t0 = time.perf_counter()
        if n == 1 or mesh_ok:
            workers = _run_multiprocess(n)
            mode = "multiprocess" if n > 1 else "single"
        else:
            workers = _run_multidevice(n)
            mode = "multidevice"
        wall = time.perf_counter() - t0
        digests = {w["digest"] for w in workers}
        if len(digests) != 1:
            raise RuntimeError(f"replica digests diverged at {n} shards")
        if not all(w["agree"] for w in workers):
            raise RuntimeError(f"replica digest all-gather disagreed at {n}")
        refs = workers[0]["refs"]
        # system ingest throughput: the corpus is ingested once
        # logically; the slowest replica bounds it
        ingest_s = max(w["ingest_s"] for w in workers)
        entry = {
            "n_shards": n,
            "mode": mode,
            "refs": refs,
            "ingest_s": round(ingest_s, 3),
            "ingest_refs_per_s": round(refs / ingest_s, 2),
            "resolve_qps_total": round(
                sum(w["resolve_qps"] for w in workers), 1
            ),
            "n_queries_per_replica": workers[0]["n_queries"],
            "digest": digests.pop(),
            "replicas_agree": True,
            "wall_s": round(wall, 3),
        }
        shards.append(entry)
        row(n, mode, refs, entry["ingest_s"],
            entry["ingest_refs_per_s"], entry["resolve_qps_total"], 1)
    digest_equal = len({e["digest"] for e in shards}) == 1
    if not digest_equal:
        raise RuntimeError(
            "sharded fixpoint digests diverged across shard counts: "
            + ", ".join(f"{e['n_shards']}:{e['digest'][:12]}" for e in shards)
        )
    base_qps = shards[0]["resolve_qps_total"]
    for e in shards:
        e["qps_scaling_eff"] = round(
            e["resolve_qps_total"] / (e["n_shards"] * base_qps), 3
        )
    row("qps_eff", *[e["qps_scaling_eff"] for e in shards])
    return {
        "benchmark": "shard_scaling",
        "smoke": SMOKE,
        "scheme": SCHEME,
        "scale": SCALE,
        "cpu_count": os.cpu_count(),
        "mesh": mesh_ok,
        "shards": shards,
        "digest_equal": digest_equal,
    }


if __name__ == "__main__":
    main()
