"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [name ...]`` — default runs all.  Output is
CSV-ish blocks, one per artifact.
"""

from __future__ import annotations

import sys
import time

MODULES = [
    ("fig3_accuracy", "Fig 3(a)-(c): P/R/F1 + completeness, MLN"),
    ("fig3_runtime", "Fig 3(d)/(e): running times, MLN"),
    ("fig3_scaling", "Fig 3(f): time vs #neighborhoods"),
    ("table1_parallel", "Table 1: parallel rounds / grid speedup"),
    ("fig4_rules", "Fig 4: RULES matcher"),
    ("stream_throughput", "Streaming ingest: entities/sec vs micro-batch size"),
    ("kernels_bench", "Pallas-kernel roofline microbench"),
]


def main() -> None:
    want = set(sys.argv[1:])
    for name, desc in MODULES:
        if want and name not in want:
            continue
        print(f"\n==== {name}: {desc} ====", flush=True)
        t0 = time.perf_counter()
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        mod.main()
        print(f"==== {name} done in {time.perf_counter()-t0:.1f}s ====", flush=True)


if __name__ == "__main__":
    main()
