"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--smoke] [--json] [--json-dir=DIR] [name ...]``
— default runs all.  Output is CSV-ish blocks, one per artifact.

``--smoke`` shrinks every benchmark to a CI-sized instance (tiny
corpora, fewer shapes) so the benchmark modules are exercised end to
end on every push without burning CI minutes — the numbers are
meaningless at that scale; the point is that the modules can't silently
rot.  It must be handled here, before any benchmark module (and hence
``benchmarks.common``) is imported, because the scale factors are read
from the environment at import time.

``--json`` additionally writes the structured results of the modules
that return them (``table1_parallel`` -> ``BENCH_parallel.json``,
``stream_throughput`` -> ``BENCH_stream.json``, ``shard_scaling`` ->
``BENCH_shard.json``; ``fig4_matchers`` merges into
``BENCH_parallel.json`` under its own key) into ``--json-dir``
(default: the repo root).  The committed copies are the perf baseline
trajectory; CI regenerates them at smoke scale and fails if the
per-round host dispatch counts regress (``benchmarks.check_bench``).

A module that raises fails the run with a non-zero exit *after* the
remaining modules have run, and its JSON is never written — a partial
file would otherwise feed ``check_bench`` a stale or truncated result
that mis-compares against the committed baseline.
"""

from __future__ import annotations

import json
import os
import sys
import time
import traceback

try:  # installed package (pip install -e .) ...
    import repro  # noqa: F401
except ImportError:  # ... or the src-layout checkout without install
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(__file__)), "src"))

MODULES = [
    ("fig3_accuracy", "Fig 3(a)-(c): P/R/F1 + completeness, MLN"),
    ("fig3_runtime", "Fig 3(d)/(e): running times, MLN"),
    ("fig3_scaling", "Fig 3(f): time vs #neighborhoods"),
    ("table1_parallel", "Table 1: parallel rounds / grid speedup"),
    ("fig4_rules", "Fig 4: RULES matcher"),
    ("fig4_matchers", "Fig 4 ext: registered matcher families, quality + runtime"),
    ("stream_throughput", "Streaming ingest: entities/sec vs micro-batch size"),
    ("loadgen", "Serving load generator: Poisson ingest + Zipf readers"),
    ("kernels_bench", "Pallas-kernel roofline microbench"),
    ("shard_scaling", "Sharded serving: ingest/QPS scaling vs shard count"),
]

JSON_FILES = {
    "table1_parallel": "BENCH_parallel.json",
    "stream_throughput": "BENCH_stream.json",
    "shard_scaling": "BENCH_shard.json",
}

# Modules whose result is merged into another module's JSON as one top-
# level key instead of owning a file (fig4_matchers rides in the
# parallel baseline, where check_bench's parallel-family gates look).
JSON_MERGE = {
    "fig4_matchers": ("BENCH_parallel.json", "fig4_matchers"),
}


def main() -> None:
    args = [a for a in sys.argv[1:]]
    if "--smoke" in args:
        args = [a for a in args if a != "--smoke"]
        os.environ["BENCH_SMOKE"] = "1"
    emit_json = "--json" in args
    args = [a for a in args if a != "--json"]
    json_dir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for a in list(args):
        if a.startswith("--json-dir="):
            json_dir = a.split("=", 1)[1]
            args.remove(a)
    want = set(args)
    unknown = want - {name for name, _ in MODULES}
    if unknown:
        raise SystemExit(f"unknown benchmark(s): {sorted(unknown)}")
    failures: list[str] = []
    for name, desc in MODULES:
        if want and name not in want:
            continue
        print(f"\n==== {name}: {desc} ====", flush=True)
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            result = mod.main()
        except Exception:
            # A raising module must fail the whole run (non-zero exit) and
            # must NOT leave a JSON for check_bench to mis-compare; the
            # remaining modules still run so one breakage doesn't mask
            # another's results.
            traceback.print_exc()
            failures.append(name)
            print(f"==== {name} FAILED in {time.perf_counter()-t0:.1f}s ====",
                  flush=True)
            continue
        print(f"==== {name} done in {time.perf_counter()-t0:.1f}s ====", flush=True)
        if emit_json and result is not None and name in JSON_FILES:
            os.makedirs(json_dir, exist_ok=True)
            path = os.path.join(json_dir, JSON_FILES[name])
            with open(path, "w") as f:
                json.dump(result, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"wrote {path}", flush=True)
        elif emit_json and result is not None and name in JSON_MERGE:
            fname, key = JSON_MERGE[name]
            os.makedirs(json_dir, exist_ok=True)
            path = os.path.join(json_dir, fname)
            blob = {}
            if os.path.exists(path):
                with open(path) as f:
                    blob = json.load(f)
            blob[key] = result
            with open(path, "w") as f:
                json.dump(blob, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"merged {key!r} into {path}", flush=True)
    if failures:
        raise SystemExit(f"benchmark module(s) raised: {failures}")


if __name__ == "__main__":
    main()
