"""Table 1: parallel (grid) speedup of NO-MP / SMP / MMP.

The paper ran DBLP-BIG on a 30-machine Hadoop grid (speedup ~11x,
limited by setup overhead + neighborhood-size skew).  Here the grid is
the SPMD mesh: rounds of shard_mapped matcher evaluation with bitset
all-reduce.  On this 1-CPU container the mesh has one shard, so we
report measured 1-shard wall time plus a *skew-derived* speedup model:
the per-round critical path on N shards is the max over shards of
summed per-neighborhood cost (the paper's statistical-skew argument),
with per-neighborhood cost ~ k^2 from the padded bins.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import prepared, row, timed
from repro.core.parallel import run_parallel


def skew_speedup(packed, rounds_hist, n_shards: int, overhead_s: float,
                 t_total: float) -> float:
    """Speedup model: round time = max over shards of sum of k^2 costs
    under random assignment (paper §6.3: 'statistical skew')."""
    rng = np.random.default_rng(0)
    costs = np.array(
        [float(packed.neighborhood_bin[n]) ** 2
         for n in range(packed.num_neighborhoods)]
    )
    per_round_frac = np.asarray(rounds_hist, dtype=np.float64)
    per_round_frac /= max(per_round_frac[0], 1)
    t_seq = t_total
    t_par = overhead_s
    for frac in per_round_frac:
        active = costs[rng.random(len(costs)) < frac]
        if len(active) == 0:
            continue
        shard = rng.integers(0, n_shards, size=len(active))
        per_shard = np.bincount(shard, weights=active, minlength=n_shards)
        t_par += per_shard.max() / max(costs.sum(), 1) * t_seq
    return t_seq / max(t_par, 1e-9)


def main():
    ds, packed, gg, _ = prepared("hepth")
    row("# table1: parallel rounds (SPMD mesh; model for 30 shards)")
    row("scheme,wall_1shard_s,rounds,evals,modeled_speedup_30")
    for scheme in ("nomp", "smp", "mmp"):
        res, t = timed(lambda s=scheme: run_parallel(
            packed, __import__("repro.core.mln", fromlist=["MLNMatcher"]).MLNMatcher(),
            gg, scheme=s,
        ))
        hist = res.history or [packed.num_neighborhoods]
        sp = skew_speedup(packed, hist, 30, overhead_s=0.05 * t, t_total=t)
        row(scheme, f"{t:.3f}", res.rounds, res.neighborhood_evals, f"{sp:.1f}")


if __name__ == "__main__":
    main()
