"""Table 1: parallel (grid) speedup of NO-MP / SMP / MMP.

The paper ran DBLP-BIG on a 30-machine Hadoop grid (speedup ~11x,
limited by setup overhead + neighborhood-size skew).  Here the grid is
the SPMD mesh: rounds of shard_mapped matcher evaluation with bitset
all-reduce.  On this 1-CPU container the mesh has one shard, so we
report measured 1-shard wall time plus a *skew-derived* speedup model:
the per-round critical path on N shards is the max over shards of
summed per-neighborhood cost (the paper's statistical-skew argument),
with per-neighborhood cost ~ k^2 from the padded bins.

Two instances are measured:

* ``hepth`` — the blocking-bound synthetic corpus (few rounds; cost is
  dominated by the first full evaluation pass);
* ``lattice`` — the paper's §2.1 evidence chain scaled up
  (``data.synthetic.make_lattice_cover``): resolution takes ``depth``
  message-passing rounds, which is the *multi-round* configuration
  where the per-round host overhead the device-resident engine removes
  (re-grounding, per-bin dispatch, active-set bookkeeping) dominates.

Each scheme runs twice: the fused device-resident engine (cached
groundings, multi-round ``while_loop`` closure — the default) and the
legacy per-round host loop (``fused=False``).  ``speedup_vs_legacy`` is
the wall-time ratio; ``dispatches_per_round`` is the host-dispatch
metric the CI smoke gate tracks against the committed
``BENCH_parallel.json``.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import SMOKE, prepared, row, timed
from repro import obs
from repro.core.global_grounding import build_global_grounding
from repro.core.mln import MLNMatcher
from repro.core.parallel import run_parallel
from repro.data.synthetic import make_lattice_cover

LATTICE_DEPTH, LATTICE_WIDTH = (6, 2) if SMOKE else (96, 16)


def skew_speedup(packed, rounds_hist, n_shards: int, overhead_s: float,
                 t_total: float) -> float:
    """Speedup model: round time = max over shards of sum of k^2 costs
    under random assignment (paper §6.3: 'statistical skew')."""
    rng = np.random.default_rng(0)
    costs = np.array(
        [float(packed.neighborhood_bin[n]) ** 2
         for n in range(packed.num_neighborhoods)]
    )
    per_round_frac = np.asarray(rounds_hist, dtype=np.float64)
    per_round_frac /= max(per_round_frac[0], 1)
    t_seq = t_total
    t_par = overhead_s
    for frac in per_round_frac:
        active = costs[rng.random(len(costs)) < frac]
        if len(active) == 0:
            continue
        shard = rng.integers(0, n_shards, size=len(active))
        per_shard = np.bincount(shard, weights=active, minlength=n_shards)
        t_par += per_shard.max() / max(costs.sum(), 1) * t_seq
    return t_seq / max(t_par, 1e-9)


def _measure(name: str, packed, gg, matcher, schemes) -> dict:
    out = {
        "n_neighborhoods": int(packed.num_neighborhoods),
        "n_bins": len(packed.bins),
        "schemes": {},
    }
    row(f"# table1[{name}]: parallel rounds (SPMD mesh; model for 30 shards)")
    row(
        "scheme,wall_fused_s,wall_legacy_s,speedup_vs_legacy,rounds,evals,"
        "dispatches,dispatches_legacy,dispatches_per_round,"
        "promote_host_scans,modeled_speedup_30"
    )
    for scheme in schemes:
        # Each run gets its own registry window: run_parallel publishes
        # its EMResult as cumulative ``em.*`` counters, so the bench
        # reads one snapshot per engine instead of dataclass fields.
        obs.reset()
        legacy, t_legacy = timed(
            lambda s=scheme: run_parallel(packed, matcher, gg, scheme=s,
                                          fused=False)
        )
        c_legacy = obs.get_registry().snapshot()["counters"]
        obs.reset()
        res, t_fused = timed(
            lambda s=scheme: run_parallel(packed, matcher, gg, scheme=s)
        )
        c_fused = obs.get_registry().snapshot()["counters"]
        assert res.matches.as_set() == legacy.matches.as_set(), (name, scheme)
        rounds = c_fused.get("em.rounds", 0)
        evals = c_fused.get("em.neighborhood_evals", 0)
        dispatches = c_fused.get("em.dispatches", 0)
        host_scans = c_fused.get("em.promote_host_scans", 0)
        hist = res.history or [packed.num_neighborhoods]
        sp = skew_speedup(packed, hist, 30, overhead_s=0.05 * t_fused,
                          t_total=t_fused)
        dpr = dispatches / max(rounds, 1)
        row(
            scheme,
            f"{t_fused:.3f}",
            f"{t_legacy:.3f}",
            f"{t_legacy / max(t_fused, 1e-9):.1f}x",
            rounds,
            evals,
            dispatches,
            c_legacy.get("em.dispatches", 0),
            f"{dpr:.2f}",
            host_scans,
            f"{sp:.1f}",
        )
        out["schemes"][scheme] = {
            "wall_s": round(t_fused, 4),
            "wall_legacy_s": round(t_legacy, 4),
            "speedup_vs_legacy": round(t_legacy / max(t_fused, 1e-9), 2),
            "rounds": int(rounds),
            "evals": int(evals),
            "dispatches": int(dispatches),
            "dispatches_legacy": int(c_legacy.get("em.dispatches", 0)),
            "dispatches_per_round": round(dpr, 3),
            # host coupling-COO promotion walks of the fused engine —
            # device-resident promotion keeps this 0 (gated in CI); the
            # legacy loop's count shows what the host baseline pays
            "promote_host_scans": int(host_scans),
            "promote_host_scans_legacy": int(
                c_legacy.get("em.promote_host_scans", 0)
            ),
        }
    return out


def main() -> dict:
    out = {"benchmark": "table1_parallel", "smoke": SMOKE, "instances": {}}

    ds, packed, gg, _ = prepared("hepth")
    out["instances"]["hepth"] = _measure(
        "hepth", packed, gg, MLNMatcher(), ("nomp", "smp", "mmp")
    )

    row("")
    lat_packed, lat_rel, lat_weights = make_lattice_cover(
        LATTICE_DEPTH, LATTICE_WIDTH
    )
    lat_gg = build_global_grounding(
        lat_packed.pair_levels, lat_rel, lat_weights
    )
    lat = _measure(
        f"lattice d{LATTICE_DEPTH} w{LATTICE_WIDTH}",
        lat_packed, lat_gg, MLNMatcher(lat_weights), ("smp", "mmp"),
    )
    lat["depth"] = LATTICE_DEPTH
    lat["width"] = LATTICE_WIDTH
    out["instances"]["lattice"] = lat
    return out


if __name__ == "__main__":
    main()
