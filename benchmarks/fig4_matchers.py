"""Fig. 4 (extended): matcher plug-in families through one pipeline.

Every registered family — the paper's MLN (collective + iterative
ablation) and RULES plus the two post-redesign families (Hungarian
optimal assignment with its greedy ablation, embedding cosine
similarity) — runs through the *same* ``pipeline.resolve`` SMP driver
on the bipartite corpus, whose coauthor traps are built to separate
them: greedy assignment takes the locally-heaviest cross edge the
optimal matching avoids, and the MLN's coauthor factor is fooled by the
planted shared-anchor structure the embedding space sees through.

Quality (P/R/F1) and wall time per family go into the committed
``BENCH_parallel.json`` under the ``fig4_matchers`` key;
``check_bench --gate=matchers`` pins the separation (optimal >= greedy,
per-family F1 floors).  The corpus is the same at smoke and full scale
— it is already CI-sized, and identical corpora keep the smoke-run F1
comparable to the committed baseline bit-for-bit.
"""

from __future__ import annotations

from benchmarks.common import SMOKE, row, timed
from repro.core import pipeline
from repro.core.matchers import get_matcher, list_matchers
from repro.data.synthetic import make_bipartite

N_GROUPS = 60
SEED = 1


def main():
    ds = make_bipartite(N_GROUPS, seed=SEED)
    packed, gg, t_prep = pipeline.prepare(ds.entities, ds.relations)
    row(f"# fig4 matcher families (bipartite n_groups={N_GROUPS} "
        f"seed={SEED} refs={ds.n_refs} prepare={t_prep:.3f}s)")
    row("family,precision,recall,f1,wall_s")
    families = {}
    for name in list_matchers():
        res, t = timed(lambda n=name: pipeline.resolve(
            ds.entities, ds.relations, scheme="smp",
            matcher=get_matcher(n), packed=packed, gg=gg,
        ))
        prf = pipeline.evaluate(res, ds.entities.truth)
        row(name, f"{prf.precision:.4f}", f"{prf.recall:.4f}",
            f"{prf.f1:.4f}", f"{t:.3f}")
        families[name] = {
            "precision": round(prf.precision, 4),
            "recall": round(prf.recall, 4),
            "f1": round(prf.f1, 4),
            "wall_s": round(t, 3),
        }
    return {
        "benchmark": "fig4_matchers",
        "smoke": SMOKE,
        "corpus": {
            "generator": "make_bipartite",
            "n_groups": N_GROUPS,
            "seed": SEED,
            "n_refs": ds.n_refs,
        },
        "families": families,
    }


if __name__ == "__main__":
    main()
