"""Fig. 3(a)/(b)/(c): precision/recall/F1 + completeness, MLN matcher.

NO-MP vs SMP vs MMP vs UB on HEPTH-like and DBLP-like data (synthetic
generators mirroring the paper's datasets; ground truth by
construction).  Completeness is measured against UB as in §6.1.
"""

from __future__ import annotations

from benchmarks.common import evaluate, prepared, row
from repro.core import metrics as metricslib
from repro.core import pipeline


def run(which: str):
    ds, packed, gg, _ = prepared(which)
    truth = ds.entities.truth
    results = {}
    for scheme in ("nomp", "smp", "mmp"):
        results[scheme] = pipeline.resolve(
            ds.entities, ds.relations, scheme=scheme, packed=packed, gg=gg
        )
    ub = pipeline.upper_bound(results["mmp"], truth)
    ub_prf = metricslib.prf(ub, truth, candidate_gids=gg.gids)

    row(f"# fig3 {which}: n_refs={len(ds.entities)} "
        f"neighborhoods={packed.num_neighborhoods} pairs={len(gg.gids)}")
    row("dataset,scheme,precision,recall,f1,completeness_vs_ub,evals")
    for scheme, res in results.items():
        prf = evaluate(ds, res)
        comp = metricslib.completeness(res.result.matches, ub)
        row(which, scheme, f"{prf.precision:.4f}", f"{prf.recall:.4f}",
            f"{prf.f1:.4f}", f"{comp:.4f}", res.result.neighborhood_evals)
    # UB row: recall upper bound with precision fixed at 1 (paper's F1-UB)
    f1_ub = 2 * ub_prf.recall / (1 + ub_prf.recall)
    row(which, "ub", "1.0000", f"{ub_prf.recall:.4f}", f"{f1_ub:.4f}",
        "1.0000", 0)


def main():
    run("hepth")
    run("dblp")


if __name__ == "__main__":
    main()
