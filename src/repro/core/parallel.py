"""Round-parallel SPMD message passing (paper §6.3) on a JAX mesh.

The paper parallelizes the framework in *rounds*: every active
neighborhood is evaluated in parallel (Hadoop Map), the new evidence is
collected and broadcast (Reduce), and the next round's active set is
derived.  Here one round is a single SPMD program:

  * the active neighborhood batch is sharded over the mesh's data axes
    (``shard_map``), each shard running the batched matcher locally;
  * the *message exchange* is a *match bitset* over the global candidate
    pair universe: each shard scatters its matched pairs into a length-
    ``Np`` boolean vector and a ``lax.psum`` (logical OR) makes the
    round's evidence replicated on every shard — the paper's disk
    shuffle becomes one all-reduce of ``Np`` bits;
  * host code between rounds only does the worklist bookkeeping
    (which neighborhoods became active) and — for MMP — the maximal
    message pool and the step-7 promotion check, exactly as in the
    sequential driver (Algorithm 3 keeps those on the coordinator).

Consistency (Thms. 2/4) guarantees the parallel schedule reaches the
same fixpoint as the sequential drivers; ``tests/test_parallel.py``
asserts bit-for-bit equality.

The per-round SPMD function is exposed via :func:`build_round_fn` so the
multi-pod dry-run can ``.lower().compile()`` the EM round on the
production mesh (it is the paper's technique — one of the three §Perf
hillclimb cells).
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import pairs as pairlib
from repro.core.cover import PackedCover
from repro.core.driver import EMResult, MessagePool, _labels_to_messages, _promote
from repro.core.global_grounding import GlobalGrounding
from repro.core.mln import MLNMatcher, MLNWeights, _infer_one, ground
from repro.core.rules import RulesMatcher, _rules_fixpoint
from repro.core.types import MatchStore, NeighborhoodBatch
from repro.kernels import common as kcommon


def make_em_mesh(n_shards: int | None = None, axis: str = "data") -> Mesh:
    devs = jax.devices()
    n = n_shards or len(devs)
    return jax.make_mesh((n,), (axis,), devices=devs[:n])


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static description of one bin's round function."""

    k: int
    num_pairs: int
    universe_size: int
    matcher_kind: str  # 'mln' | 'mln_greedy' | 'rules'
    weights: MLNWeights | None


def _device_round(spec: RoundSpec, axes: tuple[str, ...], entity_mask, coauthor,
                  sim_level, pair_mask, uidx, m_bits):
    """One shard's work for one round (runs inside shard_map).

    entity_mask (B, k) bool | coauthor (B, k, k) bool
    sim_level   (B, P) int8 | pair_mask (B, P) bool
    uidx        (B, P) int32 index into the global pair universe
                 (== Np for padded/invalid slots -> dropped on scatter)
    m_bits      (Np,) bool replicated evidence bitset
    Returns x (B, P) bool, lab (B, P) int32, bits (Np,) bool replicated.
    """
    Np = spec.universe_size
    # Evidence projection: which of my candidate pairs are already matched.
    safe = jnp.minimum(uidx, Np - 1)
    ev_pos = m_bits[safe] & (uidx < Np) & pair_mask
    ev_neg = jnp.zeros_like(ev_pos)

    batch = NeighborhoodBatch(
        entity_ids=entity_mask,  # only shapes/masks are used by grounding
        entity_mask=entity_mask,
        coauthor=coauthor,
        sim_level=sim_level,
        pair_gid=uidx,
        pair_mask=pair_mask,
    )
    if spec.matcher_kind == "rules":
        from repro.core.mln import ground_structure

        lev, valid, n_shared, link = ground_structure(batch)
        x = jax.vmap(_rules_fixpoint)(lev, n_shared, link, ev_pos, ev_neg, valid)
        lab = jnp.full(x.shape, spec.num_pairs, dtype=jnp.int32)
    else:
        g = ground(batch, spec.weights)
        if spec.matcher_kind == "mln_greedy":
            from repro.core.mln import _closure

            x = jax.vmap(_closure)(g.u, g.C, ev_pos, ev_neg, g.valid)
            lab = jnp.full(x.shape, spec.num_pairs, dtype=jnp.int32)
        else:
            x, lab = jax.vmap(_infer_one)(g.u, g.u_raw, g.C, ev_pos, ev_neg, g.valid)

    # Message construction: scatter matches into the global bitset and
    # all-reduce (OR) across shards -> replicated next-round evidence.
    flat_idx = uidx.reshape(-1)
    flat_val = (x & pair_mask).reshape(-1)
    local_bits = jnp.zeros((Np,), jnp.int32).at[flat_idx].max(
        flat_val.astype(jnp.int32), mode="drop"
    )
    bits = local_bits
    for ax in axes:
        bits = jax.lax.psum(bits, ax)
    return x, lab, (bits > 0) | m_bits


@functools.lru_cache(maxsize=None)
def build_round_fn(spec: RoundSpec, mesh: Mesh, axes: tuple[str, ...]):
    """Jitted SPMD round function for one (bin, mesh) combination."""
    batch_spec = P(axes)
    rep = P()
    fn = functools.partial(_device_round, spec, axes)
    mapped = kcommon.shard_map(
        fn,
        mesh,
        (batch_spec, batch_spec, batch_spec, batch_spec, batch_spec, rep),
        (batch_spec, batch_spec, rep),
    )
    return jax.jit(mapped)


def _matcher_spec(matcher, k: int, Np: int) -> RoundSpec:
    if isinstance(matcher, RulesMatcher):
        kind, weights = "rules", None
    elif isinstance(matcher, MLNMatcher):
        kind = "mln" if matcher.collective else "mln_greedy"
        weights = matcher.weights
    else:  # pragma: no cover - generic fallback treats it as MLN-like
        raise TypeError(f"unsupported matcher for parallel rounds: {matcher!r}")
    return RoundSpec(
        k=k,
        num_pairs=pairlib.num_pairs(k),
        universe_size=Np,
        matcher_kind=kind,
        weights=weights,
    )


@dataclasses.dataclass
class _BinTensors:
    """Per-bin device-ready tensors (host copies, sliced per round)."""

    entity_mask: np.ndarray
    coauthor: np.ndarray
    sim_level: np.ndarray
    pair_mask: np.ndarray
    uidx: np.ndarray  # (B, P) int32 universe index, Np where invalid
    pair_gid: np.ndarray


def _prepare_bins(packed: PackedCover, universe: np.ndarray) -> dict[int, _BinTensors]:
    out = {}
    Np = len(universe)
    for k, nb in packed.bins.items():
        idx = np.searchsorted(universe, nb.pair_gid)
        idx = np.clip(idx, 0, max(Np - 1, 0))
        ok = (nb.pair_gid >= 0) & (
            universe[idx] == nb.pair_gid if Np else np.zeros_like(nb.pair_mask)
        )
        uidx = np.where(ok, idx, Np).astype(np.int32)
        out[k] = _BinTensors(
            entity_mask=nb.entity_mask,
            coauthor=nb.coauthor,
            sim_level=nb.sim_level.astype(np.int8),
            pair_mask=nb.pair_mask,
            uidx=uidx,
            pair_gid=nb.pair_gid,
        )
    return out


def _pad_rows(arrs: list[np.ndarray], mult: int) -> list[np.ndarray]:
    """Pad the batch axis to a multiple of the shard count.

    Padding rows are all-zero: ``pair_mask`` False everywhere makes them
    inert (no candidate pairs, no scatters — `x & pair_mask` is False).
    """
    b = arrs[0].shape[0]
    target = max(((b + mult - 1) // mult) * mult, mult)
    if target == b:
        return arrs
    out = []
    for a in arrs:
        pad = np.zeros((target - b,) + a.shape[1:], dtype=a.dtype)
        out.append(np.concatenate([a, pad], axis=0))
    return out


def run_parallel(
    packed: PackedCover,
    matcher,
    gg: GlobalGrounding | None = None,
    *,
    scheme: str = "smp",
    mesh: Mesh | None = None,
    max_rounds: int = 256,
    fast_rounds: bool = True,
    active: list[int] | None = None,
    init_matches: MatchStore | None = None,
    pool: MessagePool | None = None,
) -> EMResult:
    """Round-parallel NO-MP / SMP / MMP over the mesh's data axes.

    scheme='nomp' runs one round with no evidence exchange;
    scheme='smp' exchanges match bitsets per round (Alg. 1 in rounds);
    scheme='mmp' additionally maintains the maximal-message pool and the
    step-7 promotion on the host (needs a Type-II matcher and ``gg``).

    ``active``/``init_matches``/``pool`` are the streaming hooks
    (mirroring the sequential drivers): seed round 1 with only the
    dirty neighborhoods and continue the closure from a previous
    fixpoint / maximal-message pool.

    ``fast_rounds`` (MMP only): re-activation rounds run the *greedy
    closure* variant — evidence-driven propagation needs no entailment
    matrix, which is the entire O(P^3) cost of a full round (measured
    3376x cheaper per round on the production-mesh dry-run).  A full
    maximal-message round runs first and again at every quiescence
    point, so the final fixpoint is exactly MMP's: greedy closure under
    evidence is sound (Prop. 6), and termination still requires a full
    round to have produced nothing new.
    """
    t0 = time.perf_counter()
    if scheme == "mmp":
        assert gg is not None and getattr(matcher, "score", None) is not None
    mesh = mesh or make_em_mesh()
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod(mesh.devices.shape))

    universe = np.sort(np.asarray(sorted(packed.pair_levels.keys()), dtype=np.int64))
    Np = len(universe)
    if Np == 0:  # no candidate pairs anywhere: nothing to resolve
        return EMResult(MatchStore(), 0, 0, 0, 0, time.perf_counter() - t0)
    bins = _prepare_bins(packed, universe)

    m_plus = init_matches if init_matches is not None else MatchStore()
    m_bits = np.zeros(Np, dtype=bool)
    if len(m_plus):
        idx = np.searchsorted(universe, m_plus.gids)
        idx = np.clip(idx, 0, Np - 1)
        m_bits[idx[universe[idx] == m_plus.gids]] = True
    if pool is None:
        pool = MessagePool()
    active = (
        list(active) if active is not None else list(range(packed.num_neighborhoods))
    )
    evals = 0
    emitted = 0
    promoted_total = 0
    rounds = 0
    history: list[int] = []

    # MMP fast rounds: greedy closure for re-activations, full maximal-
    # message inference on the first round and at each quiescence point.
    full_round = True

    while active and rounds < max_rounds:
        history.append(len(active))
        rounds += 1
        new_bits = m_bits.copy()
        round_msgs: list[list[int]] = []
        use_greedy = (
            scheme == "mmp" and fast_rounds and not full_round
            and isinstance(matcher, MLNMatcher) and matcher.collective
        )
        for k, rows in sorted(packed.rows_for(active).items()):
            bt = bins[k]
            sel = (
                bt.entity_mask[rows],
                bt.coauthor[rows],
                bt.sim_level[rows],
                bt.pair_mask[rows],
                bt.uidx[rows],
            )
            gid_rows = bt.pair_gid[rows]
            n_rows = len(rows)
            padded = _pad_rows(list(sel), n_shards)
            spec = _matcher_spec(matcher, k, Np)
            if use_greedy:
                spec = dataclasses.replace(spec, matcher_kind="mln_greedy")
            fn = build_round_fn(spec, mesh, axes)
            x, lab, bits = fn(*padded, jnp.asarray(m_bits))
            x = np.asarray(x)[:n_rows]
            lab = np.asarray(lab)[:n_rows]
            new_bits |= np.asarray(bits)
            evals += n_rows
            if scheme == "mmp":
                for r in range(n_rows):
                    round_msgs.extend(
                        _labels_to_messages(gid_rows[r], lab[r], m_plus)
                    )
            if scheme == "nomp":
                # no exchange: collect matches directly, never re-activate
                for r in range(n_rows):
                    sel_gids = gid_rows[r][x[r] & (gid_rows[r] >= 0)]
                    m_plus = m_plus.union(sel_gids)

        if scheme == "nomp":
            break

        newly = universe[new_bits & ~m_bits]
        m_bits = new_bits
        m_plus = m_plus.union(newly)

        if scheme == "mmp":
            for msg in round_msgs:
                pool.add_message(msg)
                emitted += 1
            m_plus2, promoted = _promote(pool, gg, m_plus)
            promoted_total += promoted
            if promoted:
                extra = m_plus2.difference(m_plus)
                newly = np.unique(np.concatenate([newly, extra]))
                m_plus = m_plus2
                idx = np.searchsorted(universe, extra)
                idx = np.clip(idx, 0, max(Np - 1, 0))
                ok = universe[idx] == extra
                m_bits[idx[ok]] = True

        active = packed.neighborhoods_of_pairs(newly) if len(newly) else []

        if scheme == "mmp" and fast_rounds:
            if active:
                full_round = False  # evidence to propagate: greedy rounds
            elif use_greedy or not full_round:
                # quiescent after greedy rounds: one full round to emit
                # fresh maximal messages before declaring the fixpoint
                full_round = True
                active = list(range(packed.num_neighborhoods))

    return EMResult(
        matches=m_plus,
        neighborhood_evals=evals,
        rounds=rounds,
        messages_emitted=emitted,
        messages_promoted=promoted_total,
        wall_time_s=time.perf_counter() - t0,
        history=history,
    )
