"""Device-resident round-parallel SPMD message passing (paper §6.3).

The paper parallelizes the framework in *rounds*: every active
neighborhood is evaluated in parallel (Hadoop Map), the new evidence is
collected and broadcast (Reduce), and the next round's active set is
derived.  Early versions of this module paid O(corpus) host/device
overhead *per round* — re-grounding the MLN on identical static inputs,
one jitted dispatch per size-bin per round (recompiled whenever the
active-row count changed), and Python loops over pair slots to collect
messages.  The engine is now device-resident end to end; the host/device
boundary sits exactly at the *quiescence points*:

* **Grounding cache** (:class:`GroundingCache`): the grounded structures
  (``u``/``u_raw``/``C``/``valid`` for the MLN, ``lev``/``n_shared``/
  ``link``/``valid`` for RULES) are computed once per ``(matcher, bin)``
  and kept on device across rounds.  Rows are fingerprinted by the raw
  bytes of the tensors the grounding reads, so the streaming engine
  reuses cached bins across ingests and *splices* only the dirty rows'
  freshly grounded arrays into place (``rows_ground`` counts exactly the
  recomputed rows).  Serving memory is boundable: an LRU over bins
  (``capacity`` / ``hbm_budget_bytes``) drops cold bins' tensors and
  re-grounds them on demand, bit-for-bit (see the class docstring).

* **Fused multi-round closure** (:func:`build_fused_fn`): rounds that
  touch no host state — all NO-MP/SMP rounds, and MMP's ``fast_rounds``
  greedy re-activation rounds — run inside a single jitted
  ``jax.lax.while_loop``.  The loop body evaluates every bin (batched,
  ``shard_map``-sharded over the mesh's data axes), ORs the matched
  pairs into a replicated match bitset (one ``psum`` per round — the
  paper's disk shuffle), and derives the next round's active set *on
  device* from the ``uidx`` slot-incidence of the newly set bits.  The
  bitset is donated into the call and carried by the loop, so the
  multi-round closure is ONE host dispatch instead of
  O(bins x rounds).

* **Quiescence points**: only MMP's maximal-message *pool merge*
  (Algorithm 3 keeps it on the coordinator) runs on the host.  Full
  maximal-message rounds dispatch once per bin at the *full* bin shape
  with an active-row mask (no per-round recompiles), component labels
  are turned into messages by batched numpy segment ops
  (``driver._labels_to_messages``), and the step-7 promotion delta
  checks run *batched on device* (:class:`DevicePromoter`): the pool's
  group bitsets ship to device and the whole promotion fixpoint is one
  jitted ``while_loop`` — no host walk over the global coupling COO
  (``EMResult.promote_host_scans`` == 0, gated in CI).

Consistency (Thms. 2/4) guarantees the device schedule reaches the same
fixpoint as the sequential drivers: the matcher is monotone, evaluating
a non-incident neighborhood is idempotent (its evidence projection is
unchanged), and deferring step-7 promotion to quiescence points
composes monotone operators whose least fixpoint is schedule-invariant.
``tests/test_parallel_rounds.py`` asserts bit-for-bit equality for all
three schemes, ``fast_rounds`` on and off, against both the sequential
drivers and the legacy per-round host loop (kept under ``fused=False``
as the differential baseline that ``benchmarks/table1_parallel.py``
measures the speedup against).

The per-round SPMD function is exposed via :func:`build_round_fn` so the
multi-pod dry-run can ``.lower().compile()`` the EM round on the
production mesh.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import pairs as pairlib
from repro.core.cover import PackedCover
from repro.core.driver import (
    EMResult,
    MessagePool,
    _labels_to_messages,
    _promote,
    publish_em_result,
)
from repro.obs import profiler_session, record_transfer
from repro.obs import span as obs_span
from repro.core.global_grounding import GlobalGrounding
from repro.core.mln import (
    MLNMatcher,
    MLNWeights,
    _infer_one,
    closure_batch,
    ground,
    ground_structure,
)
from repro.core.rules import _rules_fixpoint, rules_fixpoint_batch
from repro.core.types import MatchStore, NeighborhoodBatch
from repro.kernels import common as kcommon

_HISTORY_CAP = 256  # fused-loop per-round active-count log capacity


def make_em_mesh(n_shards: int | None = None, axis: str = "data") -> Mesh:
    devs = jax.devices()
    n = n_shards or len(devs)
    return jax.make_mesh((n,), (axis,), devices=devs[:n])


# ---------------------------------------------------------------------------
# Device-resident grounding cache
# ---------------------------------------------------------------------------


def _matcher_cache_key(matcher) -> tuple[str, object]:
    """Capability dispatch: a device-capable family declares
    ``parallel_backend() -> (kind, cfg)``, the grounding-cache key that
    selects its registered ground/eval functions below."""
    pb = getattr(matcher, "parallel_backend", None)
    if pb is not None:
        return pb()
    raise TypeError(
        f"matcher {type(matcher).__name__} has no parallel backend "
        f"(registered grounding kinds: {sorted(_GROUND_BUILDERS)}); "
        "host-only families run through the sequential drivers "
        "(run_nomp / run_smp / run_mmp)"
    )


# kind -> builder(cfg) -> fn(entity_ids, entity_mask, coauthor,
# sim_level, pair_mask) -> 4-tuple of (B, ...) device arrays with
# ``valid`` last.  Plug-in families register here (and an eval fn in
# _EVAL_KINDS) to run on the fused device engine.
_GROUND_BUILDERS: dict[str, object] = {}


def register_ground_builder(kind: str, builder) -> None:
    _GROUND_BUILDERS[kind] = builder


def _mln_ground_builder(weights: MLNWeights):
    def f(entity_ids, entity_mask, coauthor, sim_level, pair_mask):
        batch = NeighborhoodBatch(
            entity_ids=entity_ids,
            entity_mask=entity_mask,
            coauthor=coauthor,
            sim_level=sim_level,
            pair_gid=pair_mask,
            pair_mask=pair_mask,
        )
        g = ground(batch, weights)
        return g.u, g.u_raw, g.C, g.valid

    return jax.jit(f)


def _rules_ground_builder(_cfg):
    def f(entity_ids, entity_mask, coauthor, sim_level, pair_mask):
        batch = NeighborhoodBatch(
            entity_ids=entity_ids,
            entity_mask=entity_mask,
            coauthor=coauthor,
            sim_level=sim_level,
            pair_gid=pair_mask,
            pair_mask=pair_mask,
        )
        lev, valid, n_shared, link = ground_structure(batch)
        return lev, n_shared, link, valid

    return jax.jit(f)


def _embed_ground_builder(matcher):
    """Host grounding for the embedding family: pairwise cosine from the
    matcher's append-only per-id embedding memo.  Pure in the entity
    ids (embeddings are deterministic per id and never mutated), so the
    grounding-cache splice/LRU contract holds exactly as for the jitted
    kinds; only dirty rows' ids are ever (re-)encoded."""

    def f(entity_ids, entity_mask, coauthor, sim_level, pair_mask):
        base, valid = matcher.ground_rows(
            np.asarray(entity_ids), np.asarray(pair_mask)
        )
        B = base.shape[0]
        return (
            jnp.asarray(base),
            jnp.asarray(valid),
            jnp.zeros((B, 1, 1), jnp.float32),
            jnp.zeros((B, 1), jnp.float32),
        )

    return f


register_ground_builder("mln", _mln_ground_builder)
register_ground_builder("rules", _rules_ground_builder)
register_ground_builder("embed", _embed_ground_builder)


@functools.lru_cache(maxsize=None)
def _ground_bin_fn(kind: str, cfg):
    """Bin grounding for one ``(kind, cfg)`` key: raw row tensors ->
    device-resident arrays.

    Returns a uniform 4-tuple with ``valid`` last: MLN bins get
    ``(u, u_raw, C, valid)``, RULES bins ``(lev, n_shared, link,
    valid)``, embedding bins ``(base, valid, 0, 0)``.  ``cfg`` must be
    hashable (weights dataclass, matcher instance, or None).
    """
    if kind not in _GROUND_BUILDERS:
        raise TypeError(
            f"no grounding builder registered for kind {kind!r} "
            f"(registered: {sorted(_GROUND_BUILDERS)})"
        )
    return _GROUND_BUILDERS[kind](cfg)


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n else 1


class GroundingCache:
    """Per-bin device-resident grounded structures with splice updates
    and an optional LRU bound on resident device memory.

    ``get`` fingerprints every row by the packer's row key when the
    cover was packed with a ``row_cache`` (``PackedCover.row_keys`` —
    the ``(k, members, intra-edges)`` tuple that by contract changes
    whenever anything feeding the row tensors changes; the streaming
    path always has these, so its per-ingest signature sweep is a tuple
    gather, not a serialization pass), falling back to a fixed-size
    blake2b digest of the raw row bytes for covers packed without a
    row cache.  An unchanged bin is served from cache outright; a bin
    whose rows moved/changed is *spliced* — unchanged rows are gathered
    from the cached device arrays, only fresh rows are re-grounded (the
    O(B * P^2 * k) einsums), padded to a power of two to bound compile
    variants.  The streaming engine holds one cache per service so
    ingests that leave a bin untouched never re-ground it; call
    :meth:`invalidate` to drop everything (e.g. after changing matcher
    weights in place).

    **Serving-memory bound** (``capacity`` / ``hbm_budget_bytes``): the
    cached ``(B, P, P)`` coupling tensors dominate device memory, so a
    long-lived service can cap how many bins stay resident.  Entries
    are LRU-ordered by :meth:`get`; inserting past the bound drops the
    coldest bins' device arrays (their row signatures are kept — host
    tuples, not HBM).  A later ``get`` of an evicted bin *cold
    re-grounds* it from the raw row tensors — grounding is a pure
    function of those tensors, so the recomputed arrays are bit-for-bit
    the evicted ones and every fixpoint is unchanged (tested under
    capacities {1, 2, all}).  Eviction trades compute for memory only.

    Counters (read by tests, ``EMResult`` and ``IngestReport``):
      ``ground_calls``        grounding dispatches issued
      ``rows_ground``         rows whose grounding was actually recomputed
      ``bin_hits``            bins served without re-grounding any row
      ``splice_calls``        bins updated via :meth:`splice` (device scatter)
      ``evictions``           bins whose device arrays were LRU-dropped
      ``cold_regrounds``      gets that re-ground an evicted (unchanged) bin
      ``peak_resident_bins``  high-water mark of array-resident bins
      ``peak_resident_bytes`` high-water mark of tracked device bytes
    """

    def __init__(self, capacity: int | None = None,
                 hbm_budget_bytes: int | None = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"GroundingCache capacity must be >= 1: {capacity}")
        if hbm_budget_bytes is not None and hbm_budget_bytes <= 0:
            raise ValueError(
                f"GroundingCache hbm_budget_bytes must be > 0: {hbm_budget_bytes}"
            )
        self.capacity = capacity
        self.hbm_budget_bytes = hbm_budget_bytes
        # key -> (sigs, arrays | None, nbytes); dict order == LRU order
        # (oldest first), arrays None for entries evicted but remembered
        self._bins: dict[tuple, tuple[tuple, tuple | None, int]] = {}
        self.ground_calls = 0
        self.rows_ground = 0
        self.bin_hits = 0
        self.splice_calls = 0
        self.evictions = 0
        self.cold_regrounds = 0
        self.peak_resident_bins = 0
        self.peak_resident_bytes = 0
        # per-run window peak: run_parallel resets it at run start so
        # EMResult can report the residency high-water of THAT run,
        # while peak_resident_bins stays the cache-lifetime mark
        self.window_peak_bins = 0

    @property
    def bounded(self) -> bool:
        return self.capacity is not None or self.hbm_budget_bytes is not None

    @property
    def resident_bins(self) -> int:
        return sum(1 for _, arrays, _ in self._bins.values() if arrays is not None)

    @property
    def resident_bytes(self) -> int:
        return sum(n for _, arrays, n in self._bins.values() if arrays is not None)

    def invalidate(self) -> None:
        self._bins.clear()

    _TXN_COUNTERS = (
        "ground_calls", "rows_ground", "bin_hits", "splice_calls",
        "evictions", "cold_regrounds", "peak_resident_bins",
        "peak_resident_bytes", "window_peak_bins",
    )

    def journal_rollback(self, t) -> None:
        """Register restoration of this cache into an ingest transaction.

        The entry tuples are immutable, so a shallow copy of the LRU
        dict plus the counter values is an exact pre-ingest snapshot —
        O(bins), not O(rows) (bin count is bounded by
        ``len(k_bins) x matchers``).
        """
        prev_bins = dict(self._bins)
        prev_counters = tuple(getattr(self, c) for c in self._TXN_COUNTERS)

        def undo() -> None:
            self._bins = prev_bins
            for c, v in zip(self._TXN_COUNTERS, prev_counters):
                setattr(self, c, v)

        t.on_rollback(undo)

    def begin_peak_window(self) -> None:
        """Start a fresh residency-peak window (bins already resident
        count toward it — they occupy HBM whether or not this run
        touches them)."""
        self.window_peak_bins = self.resident_bins

    @staticmethod
    def _nbytes(arrays: tuple) -> int:
        return sum(int(a.nbytes) for a in arrays)

    def _touch(self, key: tuple) -> None:
        self._bins[key] = self._bins.pop(key)

    def _store(self, key: tuple, sigs: tuple, arrays: tuple) -> None:
        """Insert/refresh an entry as most-recent, then evict the coldest
        array-resident entries (never the one just stored) until the
        configured bin-count capacity and byte budget both hold."""
        self._bins.pop(key, None)
        self._bins[key] = (sigs, arrays, self._nbytes(arrays))

        def over() -> bool:
            if self.capacity is not None and self.resident_bins > self.capacity:
                return True
            return (
                self.hbm_budget_bytes is not None
                and self.resident_bins > 1
                and self.resident_bytes > self.hbm_budget_bytes
            )

        while over():
            victim = next(
                k for k, (_, arrays, _) in self._bins.items()
                if arrays is not None and k != key
            )
            vsigs, _, _ = self._bins[victim]
            self._bins[victim] = (vsigs, None, 0)
            # keep LRU position: an evicted entry stays coldest until re-used
            self.evictions += 1
        resident = self.resident_bins
        self.peak_resident_bins = max(self.peak_resident_bins, resident)
        self.window_peak_bins = max(self.window_peak_bins, resident)
        self.peak_resident_bytes = max(
            self.peak_resident_bytes, self.resident_bytes
        )

    @staticmethod
    def _row_sigs(bt: _BinTensors, row_keys: tuple | None = None) -> tuple:
        if row_keys is not None:
            return row_keys
        return tuple(
            hashlib.blake2b(
                bt.entity_ids[r].tobytes()
                + bt.entity_mask[r].tobytes()
                + bt.coauthor[r].tobytes()
                + bt.sim_level[r].tobytes()
                + bt.pair_mask[r].tobytes(),
                digest_size=16,
            ).digest()
            for r in range(bt.entity_mask.shape[0])
        )

    def _ground_rows(self, fn, bt: _BinTensors, rows: np.ndarray):
        """Ground a row subset, padded to a power of two (inert rows)."""
        n = len(rows)
        pad = _pow2(n) - n
        ids = bt.entity_ids[rows]
        em = bt.entity_mask[rows]
        co = bt.coauthor[rows]
        lv = bt.sim_level[rows]
        pm = bt.pair_mask[rows]
        if pad:
            ids = np.concatenate(
                [ids, np.full((pad,) + ids.shape[1:], -1, ids.dtype)]
            )
            em = np.concatenate([em, np.zeros((pad,) + em.shape[1:], em.dtype)])
            co = np.concatenate([co, np.zeros((pad,) + co.shape[1:], co.dtype)])
            lv = np.concatenate([lv, np.zeros((pad,) + lv.shape[1:], lv.dtype)])
            pm = np.concatenate([pm, np.zeros((pad,) + pm.shape[1:], pm.dtype)])
        with obs_span("rounds.ground", rows=n):
            record_transfer("gcache", ids, em, co, lv, pm)
            out = fn(ids, em, co, lv, pm)
        self.ground_calls += 1
        self.rows_ground += n
        return tuple(a[:n] for a in out) if pad else out

    def splice(self, matcher_key, bt: _BinTensors, sigs: tuple,
               cached: tuple[tuple, tuple]) -> tuple:
        """Update a cached bin in place on device: gather unchanged rows
        from the cached arrays (by row signature), re-ground *only* the
        fresh rows, and scatter them at their new positions.

        This is the device-side leg of the O(dirty) ingest path: the
        streaming engine's covers arrive with ``PackedCover.row_keys``
        from the :class:`~repro.core.cover.CoverDelta` splice, so the
        signature diff here sees exactly the spliced rows and the
        ``(B, P, P)`` grounded tensors are never rebuilt host-side.
        Returns the updated device arrays (also usable standalone by
        callers that track their own bin cache).
        """
        old_sigs, old_arrays = cached
        fn = _ground_bin_fn(*matcher_key)
        pos_of = {s: i for i, s in enumerate(old_sigs)}
        src = np.asarray([pos_of.get(s, -1) for s in sigs], dtype=np.int64)
        fresh = np.where(src < 0)[0]
        gather = jnp.asarray(np.where(src >= 0, src, 0))
        arrays = tuple(a[gather] for a in old_arrays)
        if len(fresh):
            sub = self._ground_rows(fn, bt, fresh)
            at = jnp.asarray(fresh)
            arrays = tuple(
                a.at[at].set(s) for a, s in zip(arrays, sub)
            )
            self.splice_calls += 1
        else:
            self.bin_hits += 1
        return arrays

    def get(self, matcher_key, k: int, bt: _BinTensors,
            row_keys: tuple | None = None) -> tuple:
        key = (matcher_key, k)
        sigs = self._row_sigs(bt, row_keys)
        cached = self._bins.get(key)
        if cached is not None and cached[0] == sigs and cached[1] is not None:
            self.bin_hits += 1
            self._touch(key)
            return cached[1]
        if cached is None or cached[1] is None:
            # miss, or LRU-evicted arrays: (cold) re-ground every row —
            # grounding is pure in the row tensors, so this reproduces
            # the dropped arrays bit-for-bit.
            if cached is not None:
                self.cold_regrounds += 1
            fn = _ground_bin_fn(*matcher_key)
            arrays = self._ground_rows(fn, bt, np.arange(len(sigs)))
        else:
            arrays = self.splice(matcher_key, bt, sigs, (cached[0], cached[1]))
        self._store(key, sigs, arrays)
        return arrays


# ---------------------------------------------------------------------------
# Bin preparation (host side, once per cover)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _BinTensors:
    """Per-bin device-ready tensors (host copies)."""

    entity_ids: np.ndarray  # (B, k) int, -1 padding
    entity_mask: np.ndarray
    coauthor: np.ndarray
    sim_level: np.ndarray
    pair_mask: np.ndarray
    uidx: np.ndarray  # (B, P) int32 universe index, Np where invalid
    pair_gid: np.ndarray


def _prepare_bins(
    packed: PackedCover, universe: np.ndarray, pad_mult: int = 1
) -> dict[int, _BinTensors]:
    """Stage per-bin tensors; ``pad_mult`` pads the batch axis up front
    (padding rows are inert: ``pair_mask`` False, ``uidx`` == Np,
    ``pair_gid`` == -1) so every later dispatch is full-bin shaped."""
    out = {}
    Np = len(universe)
    for k, nb in packed.bins.items():
        idx = np.searchsorted(universe, nb.pair_gid)
        idx = np.clip(idx, 0, max(Np - 1, 0))
        ok = (nb.pair_gid >= 0) & (
            universe[idx] == nb.pair_gid if Np else np.zeros_like(nb.pair_mask)
        )
        uidx = np.where(ok, idx, Np).astype(np.int32)
        b = nb.entity_mask.shape[0]
        target = max(((b + pad_mult - 1) // pad_mult) * pad_mult, pad_mult)

        def _pad(a, fill):
            if target == b:
                return a
            extra = np.full((target - b,) + a.shape[1:], fill, dtype=a.dtype)
            return np.concatenate([a, extra], axis=0)

        bt = _BinTensors(
            entity_ids=_pad(nb.entity_ids, -1),
            entity_mask=_pad(nb.entity_mask, False),
            coauthor=_pad(nb.coauthor, False),
            sim_level=_pad(nb.sim_level.astype(np.int8), 0),
            pair_mask=_pad(nb.pair_mask, False),
            uidx=_pad(uidx, Np),
            pair_gid=_pad(nb.pair_gid, -1),
        )
        record_transfer(
            "prepare", bt.entity_mask, bt.coauthor, bt.sim_level,
            bt.pair_mask, bt.uidx, bt.pair_gid,
        )
        out[k] = bt
    return out


# ---------------------------------------------------------------------------
# Fused multi-round closure (one dispatch for a whole round sequence)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedSpec:
    """Static shape/kind description of a fused multi-round program."""

    kinds: tuple[str, ...]  # per-bin matcher kind
    ks: tuple[int, ...]
    batch: tuple[int, ...]  # per-bin padded batch size
    num_pairs: tuple[int, ...]
    universe_size: int
    history_cap: int = _HISTORY_CAP  # >= the largest budget ever passed


def _eval_bin_x(kind: str, g, ev_pos, ev_neg):
    """Batched matcher evaluation from cached grounding arrays."""
    if kind == "rules":
        lev, n_shared, link, valid = g
        return rules_fixpoint_batch(lev, n_shared, link, ev_pos, ev_neg, valid)
    if kind == "mln_greedy":
        u, _, C, valid = g
        return closure_batch(u, C, ev_pos, ev_neg, valid)
    if kind == "embed":
        base, valid, _z0, _z1 = g
        return (base | ev_pos) & valid & ~ev_neg
    u, u_raw, C, valid = g
    x, _ = jax.vmap(_infer_one)(u, u_raw, C, ev_pos, ev_neg, valid)
    return x


def _fused_rounds(spec: FusedSpec, axes: tuple[str, ...], *args):
    """Multi-round closure body (runs inside shard_map).

    ``args`` is, per bin, ``(g0, g1, g2, g3, uidx, pair_mask, active0)``
    followed by ``(m_bits, budget)``.  Carries the match bitset, the
    per-bin active-row masks, and the round/eval counters through a
    single ``lax.while_loop``; the next active set is derived on device
    from the ``uidx`` slot incidence of the newly set bits.
    """
    nb = len(spec.kinds)
    per = [args[i * 7 : (i + 1) * 7] for i in range(nb)]
    m_bits = args[7 * nb]
    budget = args[7 * nb + 1]
    Np = spec.universe_size

    def _psum(v):
        for ax in axes:
            v = jax.lax.psum(v, ax)
        return v

    uidxs = [p[4] for p in per]
    safe = [jnp.minimum(u, Np - 1) for u in uidxs]
    inuniv = [(p[4] < Np) & p[5] for p in per]
    actives0 = tuple(p[6] for p in per)

    n0 = _psum(
        functools.reduce(
            jnp.add, [jnp.sum(a.astype(jnp.int32)) for a in actives0]
        )
    )

    def cond(state):
        _, _, rounds, _, n_active, _ = state
        return (n_active > 0) & (rounds < budget)

    def body(state):
        bits, actives, rounds, evals, n_active, hist = state
        hist = hist.at[jnp.minimum(rounds, spec.history_cap - 1)].set(n_active)
        local = jnp.zeros((Np,), jnp.int32)
        for i in range(nb):
            ev_pos = bits[safe[i]] & inuniv[i]
            x = _eval_bin_x(spec.kinds[i], per[i][:4], ev_pos,
                            jnp.zeros_like(ev_pos))
            x = x & inuniv[i] & actives[i][:, None]
            local = local.at[uidxs[i].reshape(-1)].max(
                x.reshape(-1).astype(jnp.int32), mode="drop"
            )
        new_bits = (_psum(local) > 0) | bits
        changed = new_bits & ~bits
        nxt = []
        n_local = jnp.int32(0)
        for i in range(nb):
            act = jnp.any(changed[safe[i]] & inuniv[i], axis=1)
            nxt.append(act)
            n_local = n_local + jnp.sum(act.astype(jnp.int32))
        return (new_bits, tuple(nxt), rounds + 1, evals + n_active,
                _psum(n_local), hist)

    state0 = (
        m_bits,
        actives0,
        jnp.int32(0),
        jnp.int32(0),
        n0,
        jnp.zeros((spec.history_cap,), jnp.int32),
    )
    bits, _, rounds, evals, _, hist = jax.lax.while_loop(cond, body, state0)
    return bits, rounds, evals, hist


@functools.lru_cache(maxsize=64)  # bounded: streaming ingests grow the
# universe/batch shapes, so specs (and their compiled executables) churn
def build_fused_fn(spec: FusedSpec, mesh: Mesh, axes: tuple[str, ...]):
    """Jitted fused multi-round program for one (cover, mesh) shape.

    The match bitset argument is donated: across calls its buffer is
    reused, and inside the call the ``while_loop`` aliases it between
    rounds — the bitset never round-trips to the host mid-closure.
    """
    nbins = len(spec.kinds)
    batch_spec = P(axes)
    rep = P()
    in_specs = tuple([batch_spec] * 7 * nbins) + (rep, rep)
    fn = functools.partial(_fused_rounds, spec, axes)
    mapped = kcommon.shard_map(fn, mesh, in_specs, (rep, rep, rep, rep))
    return jax.jit(mapped, donate_argnums=(7 * nbins,))


# ---------------------------------------------------------------------------
# Device-resident step-7 promotion (quiescence points without host scans)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _promote_loop_fn(num_gids: int, num_coup: int, m_pad: int, k_pad: int):
    """Jitted promotion fixpoint for one (grounding, pool) shape.

    One dispatch runs the whole ``while changed`` sweep of Algorithm 3
    step 7 on device: every sweep evaluates ALL groups' global deltas
    against the current base bitset in a single batched computation
    (``lin + w_co * quad`` over the coupling COO) and promotes every
    group with new pairs and a non-negative delta at once.  Batching
    the sweep is sound because ``w_co >= 0`` makes ``P_E`` supermodular:
    a group's delta is non-decreasing in the base, so a group promotable
    against the sweep-start base is still promotable after any other
    promotion of that sweep — the closure reached is the same least
    fixpoint the sequential group walk reaches (``driver._promote``,
    kept as the host baseline).
    """

    def f(u, coup_p, coup_q, w_co, gidx, gseg, gvalid, base):
        # (K, Np) membership bitsets of the pool groups, scattered once;
        # padded members carry gseg == k_pad and land in a dropped row.
        add = (
            jnp.zeros((k_pad + 1, num_gids), jnp.bool_)
            .at[gseg, gidx].set(True)[:k_pad]
        )

        def cond(state):
            return state[2]

        def body(state):
            bits, promoted, _ = state
            new = add & ~bits[None, :]
            has_new = jnp.any(new, axis=1) & gvalid
            lin = jnp.sum(jnp.where(new, u[None, :], jnp.float32(0)), axis=1)
            both = bits[None, :] | add
            quad_base = jnp.sum(bits[coup_p] & bits[coup_q])
            quad_both = jnp.sum(both[:, coup_p] & both[:, coup_q], axis=1)
            delta = lin + w_co * (quad_both - quad_base).astype(jnp.float32)
            mask = has_new & (delta >= -1e-6)
            bits = bits | jnp.any(add & mask[:, None], axis=0)
            return (bits, promoted + jnp.sum(mask.astype(jnp.int32)),
                    jnp.any(mask))

        bits, promoted, _ = jax.lax.while_loop(
            cond, body, (base, jnp.int32(0), jnp.bool_(True))
        )
        return bits, promoted

    return jax.jit(f)


class DevicePromoter:
    """Step-7 promotion with the delta checks batched on device.

    The host ``driver._promote`` walks the global coupling COO with
    numpy once per group per sweep — an O(groups x couplings) host scan
    at every quiescence point.  This class keeps the grounding's unary
    and coupling arrays on device (uploaded once per grounding) and
    ships the pool's group bitsets alongside, so a quiescence point is
    ONE jitted dispatch running the whole promotion fixpoint
    (:func:`_promote_loop_fn`); the host only assembles the group
    member indices (O(pool), memoized per ``MessagePool.groups()``
    snapshot) and reads back the (Np,) bitset.  ``host_scans`` counts
    fallbacks to the host walk (only taken for ``w_co < 0``, where the
    supermodularity argument for batched sweeps fails) — the quantity
    ``benchmarks/check_bench.py`` gates at zero.
    """

    def __init__(self, gg: GlobalGrounding):
        self.gg = gg
        self.batched_ok = float(gg.w_co) >= 0.0 and len(gg.gids) > 0
        self.dispatches = 0
        self.host_scans = 0
        # (groups list, device arrays): keeps a strong ref to the groups
        # snapshot so identity comparison can never hit a recycled id
        self._groups_memo: tuple[list, tuple | None] | None = None

    def _device_grounding(self) -> tuple:
        # cached ON the grounding object: the streaming maintainer hands
        # out the same GlobalGrounding while no delta is pending, so the
        # upload happens once per grounding *version*, not once per run
        gg = self.gg
        if gg._device is None:
            cp = gg.coup_p.astype(np.int32)
            cq = gg.coup_q.astype(np.int32)
            record_transfer("promoter", gg.u, cp, cq)
            gg._device = (
                jnp.asarray(gg.u),
                jnp.asarray(cp),
                jnp.asarray(cq),
                jnp.float32(gg.w_co),
            )
        return gg._device

    def _group_arrays(self, groups: list[np.ndarray]) -> tuple | None:
        """Flat member-index CSR of the pool groups (pow2-padded), memoized
        on the identity of the ``MessagePool.groups()`` snapshot (the pool
        invalidates it on every mutation)."""
        if self._groups_memo is not None and self._groups_memo[0] is groups:
            return self._groups_memo[1]
        gg = self.gg
        idx_parts: list[np.ndarray] = []
        seg_parts: list[np.ndarray] = []
        n_groups = 0
        for grp in groups:
            idx = gg.index_of(grp)
            idx = idx[idx >= 0]
            if len(idx) < 2:  # retracted below pair size: never promotable
                continue
            idx_parts.append(idx.astype(np.int32))
            seg_parts.append(np.full(len(idx), n_groups, dtype=np.int32))
            n_groups += 1
        if not n_groups:
            out = None
        else:
            gidx = np.concatenate(idx_parts)
            gseg = np.concatenate(seg_parts)
            m_pad = _pow2(len(gidx))
            k_pad = _pow2(n_groups)
            if m_pad > len(gidx):
                pad = m_pad - len(gidx)
                gidx = np.concatenate([gidx, np.zeros(pad, np.int32)])
                gseg = np.concatenate([gseg, np.full(pad, k_pad, np.int32)])
            gvalid = np.zeros(k_pad, dtype=bool)
            gvalid[:n_groups] = True
            record_transfer("promoter", gidx, gseg, gvalid)
            out = (
                jnp.asarray(gidx), jnp.asarray(gseg), jnp.asarray(gvalid),
                m_pad, k_pad,
            )
        self._groups_memo = (groups, out)
        return out

    def promote(self, pool: MessagePool, m_plus: MatchStore):
        """Drop-in for ``driver._promote``: same (matches, promoted) pair.

        ``promoted`` counts group-promotion events; the batched sweep may
        count a group the sequential walk skipped as already-subsumed
        within the same sweep, so only the *match set* (identical by
        supermodularity) is bit-for-bit comparable across engines.
        """
        groups = pool.groups()
        if not groups:
            return m_plus, 0
        if not self.batched_ok:
            self.host_scans += 1
            with obs_span("rounds.promote", host=True):
                return _promote(pool, self.gg, m_plus)
        garrs = self._group_arrays(groups)
        if garrs is None:
            return m_plus, 0
        gg = self.gg
        gidx, gseg, gvalid, m_pad, k_pad = garrs
        base0 = gg.bool_of(m_plus)
        fn = _promote_loop_fn(len(gg.gids), len(gg.coup_p), m_pad, k_pad)
        with obs_span("rounds.promote"):
            record_transfer("promoter", base0)
            bits, promoted = fn(
                *self._device_grounding(), gidx, gseg, gvalid,
                jnp.asarray(base0)
            )
            # int() blocks on the dispatch, so the span bills the device
            # work it launched, not the next host sync
            promoted = int(promoted)
        self.dispatches += 1
        if promoted:
            extra = gg.gids[np.asarray(bits) & ~base0]
            if len(extra):
                m_plus = m_plus.union(extra)
        return m_plus, promoted


# ---------------------------------------------------------------------------
# Full (maximal-message) rounds: one full-bin-shaped dispatch per bin
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BinRoundSpec:
    """Static description of one bin's host-visible full round."""

    kind: str
    k: int
    batch: int
    num_pairs: int
    universe_size: int


def _bin_full_round(spec: BinRoundSpec, axes, gather, g0, g1, g2, g3, uidx,
                    pmask, active, m_bits):
    """One full round of one bin (inside shard_map): evaluate every
    active row from cached grounding arrays, return per-slot matches,
    component labels, and the updated replicated bitset.

    With ``gather=True`` (multi-process meshes) the per-row ``x``/``lab``
    outputs are ``all_gather``-ed back to replicated inside the body:
    the host coordinator reads them into numpy for the maximal-message
    pool merge, and a batch-sharded global array is not addressable as a
    whole on any single host.
    """
    Np = spec.universe_size
    safe = jnp.minimum(uidx, Np - 1)
    inuniv = (uidx < Np) & pmask
    ev_pos = m_bits[safe] & inuniv
    ev_neg = jnp.zeros_like(ev_pos)
    g = (g0, g1, g2, g3)
    if spec.kind == "mln":
        x, lab = jax.vmap(_infer_one)(g0, g1, g2, ev_pos, ev_neg, g3)
    else:
        x = _eval_bin_x(spec.kind, g, ev_pos, ev_neg)
        lab = jnp.full(x.shape, spec.num_pairs, dtype=jnp.int32)
    xm = x & inuniv & active[:, None]
    local = jnp.zeros((Np,), jnp.int32).at[uidx.reshape(-1)].max(
        xm.reshape(-1).astype(jnp.int32), mode="drop"
    )
    bits = local
    for ax in axes:
        bits = jax.lax.psum(bits, ax)
    if gather:
        for ax in axes:
            x = jax.lax.all_gather(x, ax, axis=0, tiled=True)
            lab = jax.lax.all_gather(lab, ax, axis=0, tiled=True)
    return x, lab, (bits > 0) | m_bits


@functools.lru_cache(maxsize=64)  # bounded, same churn as build_fused_fn
def build_bin_round_fn(spec: BinRoundSpec, mesh: Mesh, axes: tuple[str, ...]):
    """Jitted full round for one bin, always dispatched at the full bin
    shape (an active-row mask replaces host-side row gathering, so the
    program compiles once per cover instead of once per active-set
    shape per round).  On a multi-process mesh the row outputs come back
    replicated (gathered in-body) so the coordinator can read them."""
    batch_spec = P(axes)
    rep = P()
    gather = kcommon.mesh_spans_processes(mesh)
    fn = functools.partial(_bin_full_round, spec, axes, gather)
    row_spec = rep if gather else batch_spec
    mapped = kcommon.shard_map(
        fn,
        mesh,
        (batch_spec,) * 7 + (rep,),
        (row_spec, row_spec, rep),
    )
    return jax.jit(mapped)


# ---------------------------------------------------------------------------
# Legacy per-round host loop (build_round_fn stays for the mesh dry-run)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static description of one bin's round function."""

    k: int
    num_pairs: int
    universe_size: int
    matcher_kind: str  # 'mln' | 'mln_greedy' | 'rules'
    weights: MLNWeights | None


def _device_round(spec: RoundSpec, axes: tuple[str, ...], entity_mask, coauthor,
                  sim_level, pair_mask, uidx, m_bits):
    """One shard's work for one legacy round: re-grounds from the raw
    tensors on every call (the per-round overhead the grounding cache
    and fused engine remove — kept as the differential baseline)."""
    Np = spec.universe_size
    safe = jnp.minimum(uidx, Np - 1)
    ev_pos = m_bits[safe] & (uidx < Np) & pair_mask
    ev_neg = jnp.zeros_like(ev_pos)

    batch = NeighborhoodBatch(
        entity_ids=entity_mask,  # only shapes/masks are used by grounding
        entity_mask=entity_mask,
        coauthor=coauthor,
        sim_level=sim_level,
        pair_gid=uidx,
        pair_mask=pair_mask,
    )
    if spec.matcher_kind == "rules":
        lev, valid, n_shared, link = ground_structure(batch)
        x = jax.vmap(_rules_fixpoint)(lev, n_shared, link, ev_pos, ev_neg, valid)
        lab = jnp.full(x.shape, spec.num_pairs, dtype=jnp.int32)
    else:
        g = ground(batch, spec.weights)
        if spec.matcher_kind == "mln_greedy":
            x = closure_batch(g.u, g.C, ev_pos, ev_neg, g.valid)
            lab = jnp.full(x.shape, spec.num_pairs, dtype=jnp.int32)
        else:
            x, lab = jax.vmap(_infer_one)(g.u, g.u_raw, g.C, ev_pos, ev_neg,
                                          g.valid)

    flat_idx = uidx.reshape(-1)
    flat_val = (x & pair_mask).reshape(-1)
    local_bits = jnp.zeros((Np,), jnp.int32).at[flat_idx].max(
        flat_val.astype(jnp.int32), mode="drop"
    )
    bits = local_bits
    for ax in axes:
        bits = jax.lax.psum(bits, ax)
    return x, lab, (bits > 0) | m_bits


@functools.lru_cache(maxsize=None)
def build_round_fn(spec: RoundSpec, mesh: Mesh, axes: tuple[str, ...]):
    """Jitted SPMD round function for one (bin, mesh) combination."""
    batch_spec = P(axes)
    rep = P()
    fn = functools.partial(_device_round, spec, axes)
    mapped = kcommon.shard_map(
        fn,
        mesh,
        (batch_spec, batch_spec, batch_spec, batch_spec, batch_spec, rep),
        (batch_spec, batch_spec, rep),
    )
    return jax.jit(mapped)


def _matcher_spec(matcher, k: int, Np: int) -> RoundSpec:
    kind, weights = _matcher_cache_key(matcher)
    if kind not in ("mln", "rules"):
        raise TypeError(
            f"legacy per-round loop supports only the jit-groundable "
            f"'mln'/'rules' kinds, got {kind!r}; use the fused engine"
        )
    if kind == "mln" and not getattr(matcher, "collective", True):
        kind = "mln_greedy"
    return RoundSpec(
        k=k,
        num_pairs=pairlib.num_pairs(k),
        universe_size=Np,
        matcher_kind=kind,
        weights=weights,
    )


def _pad_rows(arrs: list[np.ndarray], mult: int) -> list[np.ndarray]:
    """Pad the batch axis to a multiple of the shard count.

    Padding rows are all-zero: ``pair_mask`` False everywhere makes them
    inert (no candidate pairs, no scatters — `x & pair_mask` is False).
    """
    b = arrs[0].shape[0]
    target = max(((b + mult - 1) // mult) * mult, mult)
    if target == b:
        return arrs
    out = []
    for a in arrs:
        pad = np.zeros((target - b,) + a.shape[1:], dtype=a.dtype)
        out.append(np.concatenate([a, pad], axis=0))
    return out


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------


def _seed_bits(universe: np.ndarray, m_plus: MatchStore) -> np.ndarray:
    Np = len(universe)
    bits = np.zeros(Np, dtype=bool)
    if len(m_plus):
        idx = np.searchsorted(universe, m_plus.gids)
        idx = np.clip(idx, 0, Np - 1)
        bits[idx[universe[idx] == m_plus.gids]] = True
    return bits


def _set_bits(bits: np.ndarray, universe: np.ndarray, gids: np.ndarray) -> None:
    if not len(gids):
        return
    idx = np.searchsorted(universe, gids)
    idx = np.clip(idx, 0, max(len(universe) - 1, 0))
    bits[idx[universe[idx] == gids]] = True


def run_parallel(
    packed: PackedCover,
    matcher,
    gg: GlobalGrounding | None = None,
    *,
    scheme: str = "smp",
    mesh: Mesh | None = None,
    max_rounds: int = 256,
    fast_rounds: bool = True,
    active: list[int] | None = None,
    init_matches: MatchStore | None = None,
    pool: MessagePool | None = None,
    gcache: GroundingCache | None = None,
    fused: bool = True,
) -> EMResult:
    """Round-parallel NO-MP / SMP / MMP over the mesh's data axes.

    See :func:`_run_parallel_impl` for the engine semantics; this entry
    point additionally (a) runs the whole call inside an opt-in
    ``jax.profiler`` session (:func:`repro.obs.profiler_session`,
    enabled via ``REPRO_JAX_PROFILE_DIR``) and (b) publishes the
    :class:`EMResult` counters into the runtime metrics registry
    (``em.*`` family).
    """
    with profiler_session():
        res = _run_parallel_impl(
            packed, matcher, gg, scheme=scheme, mesh=mesh,
            max_rounds=max_rounds, fast_rounds=fast_rounds, active=active,
            init_matches=init_matches, pool=pool, gcache=gcache,
            fused=fused,
        )
    return publish_em_result(res)


def _run_parallel_impl(
    packed: PackedCover,
    matcher,
    gg: GlobalGrounding | None = None,
    *,
    scheme: str = "smp",
    mesh: Mesh | None = None,
    max_rounds: int = 256,
    fast_rounds: bool = True,
    active: list[int] | None = None,
    init_matches: MatchStore | None = None,
    pool: MessagePool | None = None,
    gcache: GroundingCache | None = None,
    fused: bool = True,
) -> EMResult:
    """Round-parallel NO-MP / SMP / MMP over the mesh's data axes.

    scheme='nomp' runs one round with no evidence exchange;
    scheme='smp' exchanges match bitsets per round (Alg. 1 in rounds);
    scheme='mmp' additionally maintains the maximal-message pool and the
    step-7 promotion on the host (needs a Type-II matcher and ``gg``).

    ``active``/``init_matches``/``pool`` are the streaming hooks
    (mirroring the sequential drivers): seed round 1 with only the
    dirty neighborhoods and continue the closure from a previous
    fixpoint / maximal-message pool.

    ``gcache`` is the persistent grounding cache: the streaming engine
    passes one per service so clean bins are never re-ground across
    ingests; batch callers get a per-run cache (grounding still happens
    exactly once per bin per cover, across all rounds).  A *bounded*
    cache (``GroundingCache(capacity=...)`` or ``hbm_budget_bytes=...``)
    is honored per dispatch: bin arrays are fetched just-in-time, so at
    most ``capacity`` bins stay array-resident between dispatches and
    cold bins re-ground on demand — same fixpoint bit-for-bit, compute
    traded for bounded HBM.

    ``fast_rounds`` (SMP and MMP with the collective MLN): re-activation
    rounds run the *greedy closure* variant — evidence-driven
    propagation needs no entailment matrix, which is the entire O(P^3)
    cost of a full round (measured 3376x cheaper per round on the
    production-mesh dry-run).  With the fused engine those greedy
    rounds run inside a single on-device ``while_loop``; a full round
    (maximal-message inference for MMP, full collective MAP for SMP)
    runs first and again at every quiescence point, so the final
    fixpoint is closed under the full matcher on every neighborhood:
    greedy closure under evidence is sound (Prop. 6), and termination
    still requires a full round to have produced nothing new (Thm. 2/4).

    ``fused=False`` selects the legacy per-round host loop (one dispatch
    per bin per round, re-grounding every time) — the differential
    baseline for tests and ``benchmarks/table1_parallel.py``.
    """
    t0 = time.perf_counter()
    if scheme == "mmp":
        assert gg is not None and getattr(matcher, "score", None) is not None
    mesh = mesh or make_em_mesh()
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod(mesh.devices.shape))

    universe = np.sort(np.asarray(sorted(packed.pair_levels.keys()), dtype=np.int64))
    Np = len(universe)
    if Np == 0:  # no candidate pairs anywhere: nothing to resolve
        return EMResult(
            init_matches if init_matches is not None else MatchStore(),
            0, 0, 0, 0, time.perf_counter() - t0,
        )

    if not fused:
        return _run_parallel_legacy(
            packed, matcher, gg, scheme=scheme, mesh=mesh,
            max_rounds=max_rounds, fast_rounds=fast_rounds, active=active,
            init_matches=init_matches, pool=pool, t0=t0,
            universe=universe, n_shards=n_shards,
        )

    bins = _prepare_bins(packed, universe, pad_mult=n_shards)
    bin_ks = sorted(bins)
    gcache = gcache if gcache is not None else GroundingCache()
    mkey = _matcher_cache_key(matcher)

    _rk_memo: dict[int, tuple | None] = {}

    def bin_row_keys(k):
        # packer row keys (streaming path) double as grounding
        # fingerprints; padding rows get a stable sentinel
        if packed.row_keys is None:
            return None
        if k not in _rk_memo:
            real = tuple(packed.row_keys[int(n)] for n in packed.bin_rows[k])
            pad = bins[k].entity_mask.shape[0] - len(real)
            _rk_memo[k] = real + (("__pad__", k),) * pad
        return _rk_memo[k]

    run_grounds: dict[int, tuple] = {}

    def ground_of(k):
        """Fetch one bin's grounded device arrays.

        Unbounded cache: memoized per run — exactly one ``get`` per bin
        per cover (the historical counter contract).  Bounded cache:
        fetched per dispatch, so between dispatches only the LRU's
        ``capacity`` bins stay array-resident and a cold bin re-grounds
        on demand — the run never pins every bin's ``(B, P, P)`` tensors
        for its whole lifetime.
        """
        if gcache.bounded:
            return gcache.get(mkey, k, bins[k], bin_row_keys(k))
        g = run_grounds.get(k)
        if g is None:
            g = run_grounds[k] = gcache.get(mkey, k, bins[k], bin_row_keys(k))
        return g

    # Multi-process meshes: every argument of a global-mesh dispatch
    # must be a *global* array with an explicit NamedSharding — local
    # per-process jit outputs (the grounding cache) and host numpy are
    # not addressable across hosts.  Grounding tensors are globalized
    # once per (run, bin): within a run the grounds never change, and
    # grounding is deterministic, so the bounded-cache re-fetch would be
    # bit-identical anyway.
    distributed = kcommon.mesh_spans_processes(mesh)
    _global_grounds: dict[int, tuple] = {}

    def dispatch_grounds(k):
        if not distributed:
            return ground_of(k)
        g = _global_grounds.get(k)
        if g is None:
            g = _global_grounds[k] = tuple(
                kcommon.put_sharded(np.asarray(a), mesh, axes)
                for a in ground_of(k)
            )
        return g

    dev_uidx = {k: kcommon.put_sharded(bins[k].uidx, mesh, axes) for k in bin_ks}
    dev_pmask = {
        k: kcommon.put_sharded(bins[k].pair_mask, mesh, axes) for k in bin_ks
    }
    evictions0 = gcache.evictions
    cold0 = gcache.cold_regrounds
    gcache.begin_peak_window()

    # A fused dispatch passes EVERY bin's grounded tensors to one jitted
    # program — transiently full residency, which would defeat a memory
    # bound tighter than the bin count.  In *spill mode* the run instead
    # routes everything through the per-bin full-round loop: each
    # dispatch stages one bin's arrays and releases them, so peak device
    # residency really is capacity (+ the one bin in flight) — memory
    # bought with extra dispatches and cold re-grounds, never with a
    # different fixpoint.
    spill_mode = gcache.hbm_budget_bytes is not None or (
        gcache.capacity is not None and gcache.capacity < len(bin_ks)
    )

    base_kind = mkey[0]
    if base_kind == "mln" and not getattr(matcher, "collective", True):
        base_kind = "mln_greedy"
    if scheme == "mmp" and base_kind not in ("mln", "mln_greedy"):
        raise TypeError(
            f"parallel MMP is wired to the MLN device promoter; kind "
            f"{base_kind!r} emits no multi-pair messages, so run_mmp "
            "(sequential) or scheme='smp' reach the identical fixpoint"
        )

    # step-7 promotion runs on device (batched delta checks, zero host
    # coupling-COO scans); the promoter counts any host fallback.
    promoter = DevicePromoter(gg) if scheme == "mmp" else None

    m_plus = init_matches if init_matches is not None else MatchStore()
    m_bits = _seed_bits(universe, m_plus)
    if pool is None:
        pool = MessagePool()
    active = (
        list(active) if active is not None else list(range(packed.num_neighborhoods))
    )
    evals = 0
    emitted = 0
    promoted_total = 0
    rounds = 0
    full_rounds = 0
    dispatches = 0
    history: list[int] = []

    def masks_for(act_list):
        masks = {
            k: np.zeros(bins[k].entity_mask.shape[0], dtype=bool) for k in bin_ks
        }
        for n in act_list:
            masks[int(packed.neighborhood_bin[n])][
                int(packed.neighborhood_row[n])
            ] = True
        return masks

    def live_rows(act_list):
        """Drop provably inert rows: a neighborhood whose every candidate
        slot is already matched can add no matches (output is a subset of
        its valid slots) and can emit no maximal messages (messages range
        over *undecided* pairs) — evaluating it in a full round is a
        no-op in every driver.  Cost is O(|act_list| slots): only the
        requested rows are inspected, so a small dirty seed set stays
        cheap on a large corpus."""
        keep = []
        for k, rows in packed.rows_for(act_list).items():
            bt = bins[k]
            uidx = bt.uidx[rows]
            un = bt.pair_mask[rows] & (uidx < Np) & ~m_bits[
                np.minimum(uidx, Np - 1)
            ]
            live = np.asarray(rows)[un.any(axis=1)]
            keep.extend(int(packed.bin_rows[k][r]) for r in live)
        return sorted(keep)

    # round history buffer: one slot per possible round so EMResult
    # always has len(history) == rounds, whatever max_rounds the caller
    # picked (rounded up so the compiled shape is stable across calls)
    hist_cap = ((max_rounds + _HISTORY_CAP - 1) // _HISTORY_CAP) * _HISTORY_CAP

    def fused_call(kind, act_masks, budget):
        nonlocal dispatches
        spec = FusedSpec(
            kinds=tuple(kind for _ in bin_ks),
            ks=tuple(bin_ks),
            batch=tuple(bins[k].entity_mask.shape[0] for k in bin_ks),
            num_pairs=tuple(bins[k].pair_mask.shape[1] for k in bin_ks),
            universe_size=Np,
            history_cap=hist_cap,
        )
        fn = build_fused_fn(spec, mesh, axes)
        args = []
        for k in bin_ks:
            args += list(dispatch_grounds(k))
            args += [
                dev_uidx[k], dev_pmask[k],
                kcommon.put_sharded(act_masks[k], mesh, axes),
            ]
        with obs_span("rounds.fused", kind=kind):
            bits, r, ev, hist = fn(
                *args,
                kcommon.put_replicated(m_bits, mesh),
                kcommon.put_replicated(np.asarray(budget, np.int32), mesh),
            )
            # int() blocks on the while_loop, so the span owns its time
            r = int(r)
        dispatches += 1
        # np.array (not asarray): callers assign this to m_bits and
        # mutate it in place, and asarray of a jax buffer is read-only
        return np.array(bits), r, int(ev), [int(h) for h in np.asarray(hist)[:r]]

    def finish():
        return EMResult(
            matches=m_plus,
            neighborhood_evals=evals,
            rounds=rounds,
            messages_emitted=emitted,
            messages_promoted=promoted_total,
            wall_time_s=time.perf_counter() - t0,
            history=history,
            dispatches=dispatches,
            full_rounds=full_rounds,
            peak_resident_bins=gcache.window_peak_bins,
            cache_evictions=gcache.evictions - evictions0,
            cold_regrounds=gcache.cold_regrounds - cold0,
            promote_host_scans=promoter.host_scans if promoter else 0,
        )

    collective = base_kind == "mln"

    def full_round_over(act_list):
        """One host-visible full round: per-bin full-shape dispatches.
        Returns (newly matched gids, messages).  Mutates m_bits/m_plus."""
        nonlocal dispatches, evals, rounds, full_rounds, m_bits, m_plus
        act_masks = masks_for(act_list)
        history.append(len(act_list))
        rounds += 1
        full_rounds += 1
        new_bits = m_bits.copy()
        round_msgs: list[list[int]] = []
        m_bits_dev = kcommon.put_replicated(m_bits, mesh)
        with obs_span("rounds.full", active=len(act_list)):
            for k in bin_ks:
                am = act_masks[k]
                if not am.any():
                    continue
                spec = BinRoundSpec(
                    kind=base_kind,
                    k=k,
                    batch=bins[k].entity_mask.shape[0],
                    num_pairs=bins[k].pair_mask.shape[1],
                    universe_size=Np,
                )
                fn = build_bin_round_fn(spec, mesh, axes)
                x, lab, bits = fn(
                    *dispatch_grounds(k), dev_uidx[k], dev_pmask[k],
                    kcommon.put_sharded(am, mesh, axes), m_bits_dev,
                )
                dispatches += 1
                evals += int(am.sum())
                new_bits |= np.asarray(bits)
                if scheme == "mmp" and collective:
                    round_msgs += _labels_to_messages(
                        bins[k].pair_gid, np.asarray(lab), m_plus, row_mask=am
                    )
        newly = universe[new_bits & ~m_bits]
        m_bits = new_bits
        m_plus = m_plus.union(newly)
        return newly, round_msgs

    if scheme == "nomp":
        # one round, no exchange: a single fused dispatch for cheap
        # matchers, one full-shape dispatch per bin for the collective
        # MLN (shares the compiled full-round programs with SMP/MMP) —
        # and per bin in spill mode, where an all-bins fused dispatch
        # would transiently materialize every bin's tensors.
        if active:
            if collective or spill_mode:
                full_round_over(active)
            else:
                bits, rounds, evals, history = fused_call(
                    base_kind, masks_for(active), 1
                )
                m_plus = m_plus.union(universe[bits & ~m_bits])
        return finish()

    if scheme == "smp" and not collective and not spill_mode:
        # greedy/rules matchers: the whole multi-round closure is ONE
        # fused dispatch — every round body is a cheap batched fixpoint.
        # (In spill mode this falls through to the per-bin round loop
        # below, which stages one bin's tensors at a time.)
        if active:
            bits, rounds, evals, history = fused_call(
                base_kind, masks_for(active), max_rounds
            )
            m_plus = m_plus.union(universe[bits & ~m_bits])
        return finish()

    # -- SMP and MMP: host-visible full rounds + fused greedy segments. ---
    # Re-activation rounds only propagate evidence, so they run as
    # greedy closure inside the fused device loop; a full round over
    # every neighborhood runs at each quiescence point (and first), so
    # the fixpoint is closed under the full matcher — the same soundness
    # argument as MMP's fast_rounds (Prop. 6 + Thm. 2/4), now shared by
    # SMP.  Spill mode disables the fused segments outright (they stage
    # every bin at once): each round is per-bin full dispatches, the
    # memory-for-dispatches trade of a bounded cache.
    greedy_ok = fast_rounds and collective and not spill_mode
    full_round = True
    seeds = list(active)
    bits0 = m_bits.copy()

    def certify_rows():
        """Neighborhoods a quiescence full round must re-check: the
        seeds plus every neighborhood slot-incident to a bit set during
        this run.  Any other neighborhood was at the carried fixpoint
        with unchanged evidence projection, so the full matcher can add
        nothing there — on the streaming path this keeps quiescence
        checks O(dirty + affected), not O(unresolved corpus)."""
        cand = set(seeds)
        changed = universe[m_bits & ~bits0]
        if len(changed):
            cand.update(packed.neighborhoods_of_slot_pairs(changed))
        return sorted(cand)

    active = live_rows(active)
    if scheme == "mmp" and seeds and not active:
        # every seed is inert, but the (streaming-persistent) pool must
        # still be replayed against the current grounding — exactly what
        # run_mmp's step 7 does after evaluating those seeds
        m_plus2, promoted = promoter.promote(pool, m_plus)
        promoted_total += promoted
        if promoted:
            extra = m_plus2.difference(m_plus)
            m_plus = m_plus2
            _set_bits(m_bits, universe, extra)
            active = packed.neighborhoods_of_slot_pairs(extra)
    while active and rounds < max_rounds:
        if greedy_ok and not full_round:
            bits, r, ev, hist = fused_call(
                "mln_greedy", masks_for(active), max_rounds - rounds
            )
            rounds += r
            evals += ev
            history += hist
            newly = universe[bits & ~m_bits]
            m_bits = bits
            m_plus = m_plus.union(newly)
            if scheme == "mmp":
                m_plus2, promoted = promoter.promote(pool, m_plus)
                promoted_total += promoted
                if promoted:
                    extra = m_plus2.difference(m_plus)
                    m_plus = m_plus2
                    _set_bits(m_bits, universe, extra)
                    active = packed.neighborhoods_of_slot_pairs(extra)
                    if active:
                        continue
            # greedy closure quiescent: one full round over every
            # certifiable neighborhood that still has an undecided
            # candidate slot (fresh maximal messages / collective
            # promotions) before declaring the fixpoint
            full_round = True
            active = live_rows(certify_rows())
            continue

        newly, round_msgs = full_round_over(active)
        if scheme == "mmp":
            for msg in round_msgs:
                pool.add_message(msg)
                emitted += 1
            m_plus2, promoted = promoter.promote(pool, m_plus)
            promoted_total += promoted
            if promoted:
                extra = m_plus2.difference(m_plus)
                newly = np.unique(np.concatenate([newly, extra]))
                m_plus = m_plus2
                _set_bits(m_bits, universe, extra)
        active = (
            packed.neighborhoods_of_slot_pairs(newly) if len(newly) else []
        )
        if greedy_ok and active:
            full_round = False
    return finish()


def _run_parallel_legacy(
    packed: PackedCover,
    matcher,
    gg: GlobalGrounding | None,
    *,
    scheme: str,
    mesh: Mesh,
    max_rounds: int,
    fast_rounds: bool,
    active: list[int] | None,
    init_matches: MatchStore | None,
    pool: MessagePool | None,
    t0: float,
    universe: np.ndarray,
    n_shards: int,
) -> EMResult:
    """The pre-fusion host round loop: one dispatch per bin per round,
    re-grounding from raw tensors every time, per-row message walks.
    Kept as the differential baseline (tests assert bit-for-bit equality
    with the fused engine; ``table1_parallel`` reports the speedup)."""
    axes = tuple(mesh.axis_names)
    Np = len(universe)
    bins = _prepare_bins(packed, universe)

    m_plus = init_matches if init_matches is not None else MatchStore()
    m_bits = _seed_bits(universe, m_plus)
    if pool is None:
        pool = MessagePool()
    active = (
        list(active) if active is not None else list(range(packed.num_neighborhoods))
    )
    evals = 0
    emitted = 0
    promoted_total = 0
    rounds = 0
    dispatches = 0
    host_scans = 0
    history: list[int] = []

    # MMP fast rounds: greedy closure for re-activations, full maximal-
    # message inference on the first round and at each quiescence point.
    full_round = True

    while active and rounds < max_rounds:
        history.append(len(active))
        rounds += 1
        new_bits = m_bits.copy()
        round_msgs: list[list[int]] = []
        use_greedy = (
            scheme == "mmp" and fast_rounds and not full_round
            and isinstance(matcher, MLNMatcher) and matcher.collective
        )
        for k, rows in sorted(packed.rows_for(active).items()):
            bt = bins[k]
            sel = (
                bt.entity_mask[rows],
                bt.coauthor[rows],
                bt.sim_level[rows],
                bt.pair_mask[rows],
                bt.uidx[rows],
            )
            gid_rows = bt.pair_gid[rows]
            n_rows = len(rows)
            padded = _pad_rows(list(sel), n_shards)
            spec = _matcher_spec(matcher, k, Np)
            if use_greedy:
                spec = dataclasses.replace(spec, matcher_kind="mln_greedy")
            fn = build_round_fn(spec, mesh, axes)
            x, lab, bits = fn(*padded, jnp.asarray(m_bits))
            dispatches += 1
            x = np.asarray(x)[:n_rows]
            lab = np.asarray(lab)[:n_rows]
            new_bits |= np.asarray(bits)
            evals += n_rows
            if scheme == "mmp":
                round_msgs.extend(_labels_to_messages(gid_rows, lab, m_plus))
            if scheme == "nomp":
                # no exchange: collect matches directly, never re-activate
                for r in range(n_rows):
                    sel_gids = gid_rows[r][x[r] & (gid_rows[r] >= 0)]
                    m_plus = m_plus.union(sel_gids)

        if scheme == "nomp":
            break

        newly = universe[new_bits & ~m_bits]
        m_bits = new_bits
        m_plus = m_plus.union(newly)

        if scheme == "mmp":
            for msg in round_msgs:
                pool.add_message(msg)
                emitted += 1
            m_plus2, promoted = _promote(pool, gg, m_plus)
            host_scans += 1
            promoted_total += promoted
            if promoted:
                extra = m_plus2.difference(m_plus)
                newly = np.unique(np.concatenate([newly, extra]))
                m_plus = m_plus2
                _set_bits(m_bits, universe, extra)

        active = packed.neighborhoods_of_pairs(newly) if len(newly) else []

        if scheme == "mmp" and fast_rounds:
            if active:
                full_round = False  # evidence to propagate: greedy rounds
            elif use_greedy or not full_round:
                # quiescent after greedy rounds: one full round to emit
                # fresh maximal messages before declaring the fixpoint
                full_round = True
                active = list(range(packed.num_neighborhoods))

    return EMResult(
        matches=m_plus,
        neighborhood_evals=evals,
        rounds=rounds,
        messages_emitted=emitted,
        messages_promoted=promoted_total,
        wall_time_s=time.perf_counter() - t0,
        history=history,
        dispatches=dispatches,
        promote_host_scans=host_scans,
    )
