"""Canonical pair indexing for neighborhood candidate pairs.

A neighborhood holds up to ``k`` entities (padded).  Candidate match
variables live on the upper triangle of the ``k x k`` entity grid:
``P = k * (k - 1) // 2`` slots.  This module provides the static
index maps between pair-slot ``p`` and entity slots ``(i, j), i < j``,
plus global pair ids used to exchange matches across neighborhoods.

Global pair id convention: for global entity ids ``a < b``,
``gid = a * GID_STRIDE + b`` stored as int64.  ``GID_STRIDE`` must
exceed the number of entities in the universe.
"""

from __future__ import annotations

import functools

import numpy as np

GID_STRIDE = np.int64(1) << np.int64(32)


@functools.lru_cache(maxsize=None)
def triu_indices(k: int) -> tuple[np.ndarray, np.ndarray]:
    """Static (ii, jj) arrays, each of shape (P,), with ii[p] < jj[p]."""
    ii, jj = np.triu_indices(k, k=1)
    return ii.astype(np.int32), jj.astype(np.int32)


@functools.lru_cache(maxsize=None)
def pair_slot_table(k: int) -> np.ndarray:
    """(k, k) table mapping entity-slot pairs to pair slot (or -1)."""
    ii, jj = triu_indices(k)
    tab = np.full((k, k), -1, dtype=np.int32)
    p = np.arange(len(ii), dtype=np.int32)
    tab[ii, jj] = p
    tab[jj, ii] = p
    return tab


def num_pairs(k: int) -> int:
    return k * (k - 1) // 2


def make_gid(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Global pair id for global entity ids a, b (any order)."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    lo = np.minimum(a, b)
    hi = np.maximum(a, b)
    return lo * GID_STRIDE + hi


def split_gid(gid: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    gid = np.asarray(gid, dtype=np.int64)
    return gid // GID_STRIDE, gid % GID_STRIDE
