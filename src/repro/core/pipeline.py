"""End-to-end EM pipeline: dataset -> cover -> message passing -> metrics.

This is the user-facing entry point gluing together the paper's stages:
canopy covering (§4), packing, global grounding, and a message-passing
scheme (§5) — sequential or round-parallel SPMD.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import metrics as metricslib
from repro.core.closure import transitive_closure
from repro.core.cover import PackedCover, build_cover, pack_cover
from repro.core.driver import EMResult, run_mmp, run_nomp, run_smp
from repro.core.global_grounding import GlobalGrounding, build_global_grounding, ub_matches
from repro.core.mln import MLNMatcher, MLNWeights, PAPER_LEARNED
from repro.core.parallel import run_parallel
from repro.core.types import EntityTable, MatchStore, Relations


@dataclasses.dataclass
class Resolved:
    result: EMResult
    packed: PackedCover
    gg: GlobalGrounding
    closed: MatchStore  # transitive closure of the matches
    cover_time_s: float


def prepare(
    entities: EntityTable,
    relations: Relations,
    *,
    weights: MLNWeights = PAPER_LEARNED,
    k_max: int = 32,
    t_loose: float = 0.70,
    t_tight: float = 0.90,
    thresholds=None,
) -> tuple[PackedCover, GlobalGrounding, float]:
    """Build and pack the total cover + the global grounding."""
    from repro.core import similarity as simlib

    t0 = time.perf_counter()
    cover = build_cover(
        entities, relations, t_loose=t_loose, t_tight=t_tight, k_max=k_max
    )
    packed = pack_cover(
        cover,
        entities,
        relations,
        thresholds=thresholds or simlib.DEFAULT_THRESHOLDS,
    )
    gg = build_global_grounding(packed.pair_levels, relations, weights)
    return packed, gg, time.perf_counter() - t0


def resolve(
    entities: EntityTable,
    relations: Relations,
    *,
    scheme: str = "mmp",
    matcher=None,
    weights: MLNWeights = PAPER_LEARNED,
    parallel: bool = False,
    k_max: int = 32,
    packed: PackedCover | None = None,
    gg: GlobalGrounding | None = None,
    thresholds=None,
    t_loose: float = 0.70,
) -> Resolved:
    """Run the full pipeline with the chosen scheme/matcher."""
    cover_time = 0.0
    if packed is None or gg is None:
        packed, gg, cover_time = prepare(
            entities,
            relations,
            weights=weights,
            k_max=k_max,
            thresholds=thresholds,
            t_loose=t_loose,
        )
    if matcher is None:
        matcher = MLNMatcher(weights) if scheme == "mmp" else MLNMatcher(weights)

    if parallel:
        result = run_parallel(packed, matcher, gg, scheme=scheme)
    elif scheme == "nomp":
        result = run_nomp(packed, matcher)
    elif scheme == "smp":
        result = run_smp(packed, matcher)
    elif scheme == "mmp":
        assert getattr(matcher, "score", None) is not None, (
            "MMP needs a Type-II matcher (score())"
        )
        result = run_mmp(packed, matcher, gg)
    else:
        raise ValueError(f"unknown scheme {scheme!r}")

    closed = transitive_closure(result.matches)
    return Resolved(
        result=result, packed=packed, gg=gg, closed=closed, cover_time_s=cover_time
    )


def evaluate(res: Resolved, truth: np.ndarray) -> metricslib.PRF:
    """P/R/F1 of the (transitively closed) matches against ground truth."""
    return metricslib.prf(res.closed, truth, candidate_gids=res.gg.gids)


def upper_bound(res: Resolved, truth: np.ndarray) -> MatchStore:
    """The paper's UB scheme (§6.1) for this instance."""
    true_gids = metricslib.true_pair_gids(truth, res.gg.gids)
    return ub_matches(res.gg, true_gids)
