"""Host-side global grounding: exact P_E scoring over the full entity set.

MMP step 7 requires checking ``P_E(M+ u M) >= P_E(M+)`` — the paper notes
that while argmax over P_E is expensive, *evaluating* P_E at a given set
is cheap from the model parameters.  This module materializes the global
(sparse) grounded objective once:

    f(S) = sum_{p in S} u_g(p) + sum_{ {p,q} subset S } w_co * link(p, q)

with u_g from the *full* coauthor graph (so u_local <= u_g, consistent
with matcher monotonicity over sub-instances) and one coupling per
unordered linked candidate-pair pair — the paper's §2.1/§2.2 arithmetic.

Also implements the UB scheme of §6.1: for each candidate pair, condition
on the ground truth of all other pairs and take the single-variable MAP.

Two entry points build the grounding:

* :func:`build_global_grounding` — the batch path: one O(sum deg^2)
  pass over every candidate pair.
* :class:`GroundingMaintainer` — the streaming path: holds the same
  state in patchable form and exposes
  ``apply_delta(added_pairs, retracted_pairs, new_edges)``, doing work
  proportional to the delta (the pairs added/retracted plus the pairs
  incident to new relation edges) instead of the corpus.
  ``grounding()`` materializes a :class:`GlobalGrounding` bit-for-bit
  equal to the from-scratch build over the accumulated state — the
  streaming tests assert that equality at every ingest.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import pairs as pairlib, txn
from repro.core.mln import MLNWeights
from repro.core.types import MatchStore, Relations
from repro.obs.registry import get_registry


@dataclasses.dataclass
class GlobalGrounding:
    gids: np.ndarray  # (Np,) sorted candidate pair gids
    u: np.ndarray  # (Np,) f32 global unary
    coup_p: np.ndarray  # (Nc,) int32 index into gids
    coup_q: np.ndarray  # (Nc,) int32 index into gids (p < q)
    w_co: float
    # Device copies of (u, coup_p, coup_q, w_co), populated lazily by
    # repro.core.parallel.DevicePromoter and cached HERE because the
    # grounding object is the natural cache key: the streaming
    # maintainer returns the *same* object while no delta is pending, so
    # consecutive ingests reuse one upload, and a splice returns a fresh
    # object whose stale-free cache repopulates on first use.  The host
    # arrays are never mutated after construction (the splice copies
    # before patching), so a populated cache can never go stale.
    _device: tuple | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def __getstate__(self):
        # The device cache is a lazy upload keyed on this object's
        # identity — it is neither durable nor picklable (checkpointing
        # serializes the grounding; recovery repopulates on first use).
        state = {
            f.name: getattr(self, f.name) for f in dataclasses.fields(self)
        }
        state["_device"] = None
        return state

    def __setstate__(self, state):
        for k, v in state.items():
            setattr(self, k, v)

    def index_of(self, gids: np.ndarray) -> np.ndarray:
        idx = np.searchsorted(self.gids, gids)
        idx = np.clip(idx, 0, len(self.gids) - 1)
        ok = self.gids[idx] == gids
        return np.where(ok, idx, -1)

    def score(self, store: MatchStore) -> float:
        """f(S) for a global match set."""
        x = np.zeros(len(self.gids), dtype=bool)
        idx = self.index_of(store.gids)
        x[idx[idx >= 0]] = True
        lin = float(self.u[x].sum())
        quad = float(self.w_co * np.sum(x[self.coup_p] & x[self.coup_q]))
        return lin + quad

    def delta(self, base: np.ndarray, add: np.ndarray) -> float:
        """f(base u add) - f(base), with base/add boolean over gids."""
        new = add & ~base
        lin = float(self.u[new].sum())
        both = base | add
        quad_new = (
            np.sum(both[self.coup_p] & both[self.coup_q])
            - np.sum(base[self.coup_p] & base[self.coup_q])
        )
        return lin + float(self.w_co * quad_new)

    def bool_of(self, store: MatchStore) -> np.ndarray:
        x = np.zeros(len(self.gids), dtype=bool)
        idx = self.index_of(store.gids)
        x[idx[idx >= 0]] = True
        return x


def build_global_grounding(
    pair_levels: dict[int, int],
    relations: Relations,
    weights: MLNWeights,
    *,
    boundary_relation: str = "coauthor",
) -> GlobalGrounding:
    gids = np.array(sorted(pair_levels.keys()), dtype=np.int64)
    n = len(gids)
    adj = relations.adjacency_sets(boundary_relation)
    w_sim = np.asarray(weights.w_sim, dtype=np.float32)
    w_co = float(weights.w_co)

    u = np.zeros(n, dtype=np.float32)
    gid_to_idx = {int(g): i for i, g in enumerate(gids)}
    coup: set[tuple[int, int]] = set()

    for i, g in enumerate(gids):
        a, b = pairlib.split_gid(np.int64(g))
        a, b = int(a), int(b)
        na, nb = adj.get(a, set()), adj.get(b, set())
        u[i] = w_sim[pair_levels[int(g)]] + w_co * len(na & nb)
        # couplings: candidate (c, d) with c ~ a, d ~ b (either orientation)
        for c in na:
            for d in nb:
                if c == d:
                    continue
                j = gid_to_idx.get(int(pairlib.make_gid(c, d)))
                if j is not None and j != i:
                    coup.add((min(i, j), max(i, j)))

    if coup:
        cp = np.array(sorted(coup), dtype=np.int64)
        coup_p, coup_q = cp[:, 0].astype(np.int32), cp[:, 1].astype(np.int32)
    else:
        coup_p = np.zeros(0, dtype=np.int32)
        coup_q = np.zeros(0, dtype=np.int32)
    return GlobalGrounding(gids=gids, u=u, coup_p=coup_p, coup_q=coup_q, w_co=w_co)


# ---------------------------------------------------------------------------
# Incremental maintenance (streaming ingest path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GroundingDelta:
    """Work accounting for one ``apply_delta`` call.

    ``pairs_visited`` counts the candidate pairs whose unary or coupling
    structure was (re)computed — the quantity the streaming tests bound
    by the dirty set to prove the ingest path does no O(corpus) rebuild.
    """

    pairs_added: int = 0
    pairs_retracted: int = 0
    pairs_visited: int = 0
    edges_added: int = 0
    couplings_added: int = 0
    couplings_removed: int = 0


class GroundingMaintainer:
    """Patchable global grounding for the streaming ingest path.

    Holds the grounding state in delta-friendly form — per-pair
    similarity level and common-neighbor *count* (kept as an exact int
    so the materialized unary reproduces the from-scratch float32
    arithmetic bit-for-bit), the coauthor adjacency, an entity ->
    candidate-pair index, and the coupling set keyed by gid pairs.

    ``apply_delta`` patches that state in place:

    * retracted pairs drop their unary and incident couplings —
      O(coupling degree) each;
    * new relation edges update the common-neighbor counts and create
      couplings only for pairs incident to an edge endpoint —
      O(local pair count x local degree);
    * added pairs compute their unary and couplings from the current
      adjacency — O(deg(a) x deg(b)) each, exactly the per-pair cost of
      the batch build.

    The grounding *computation* — adjacency intersections and coupling
    discovery, the O(sum deg^2) cost of the batch build — touches only
    the delta.  ``grounding()`` keeps the array form live and *splices*
    it per delta (:meth:`_splice`): only the pending rows are
    recomputed (``last_splice_rows`` counts them, surfaced as
    ``IngestReport.grounding_splice_rows``); untouched unary entries and
    coupling rows carry over as memcpy.  Only the very first call pays
    the full vectorized materialization.

    Caller contract: every ``new_edges`` batch must be the *boundary
    relation's* tuples (the maintainer has no relation labels to filter
    by — feeding it another relation's edges would diverge from the
    batch build, which grounds only the boundary relation).
    """

    def __init__(self, weights: MLNWeights):
        self.w_sim = np.asarray(weights.w_sim, dtype=np.float32)
        self.w_co = float(weights.w_co)
        self.levels: dict[int, int] = {}  # gid -> similarity level
        self.common: dict[int, int] = {}  # gid -> |adj(a) & adj(b)|
        self.adj: dict[int, set[int]] = {}  # entity -> coauthor neighbors
        self.pairs_of: dict[int, set[int]] = {}  # entity -> candidate gids
        self.coup: set[tuple[int, int]] = set()  # (min gid, max gid)
        self.coup_adj: dict[int, set[int]] = {}  # gid -> coupled gids
        self.total_pair_visits = 0
        self._gg: GlobalGrounding | None = None
        # pending array-splice deltas accumulated since the last
        # grounding() materialization (see _record_* helpers)
        self._pend_add: set[int] = set()
        self._pend_del: set[int] = set()
        self._pend_u: set[int] = set()
        self._pend_cadd: set[tuple[int, int]] = set()
        self._pend_cdel: set[tuple[int, int]] = set()
        self.last_splice_rows = 0
        self.total_splice_rows = 0

    # -- pending-delta bookkeeping (drives the array splice) --------------

    @staticmethod
    def _sadd(s: set, item) -> None:
        t = txn.active()
        if t is not None:
            t.set_add(s, item)
        else:
            s.add(item)

    @staticmethod
    def _sdiscard(s: set, item) -> None:
        t = txn.active()
        if t is not None:
            t.set_discard(s, item)
        else:
            s.discard(item)

    def _record_pair_added(self, g: int) -> None:
        if g in self._pend_del:
            # the live arrays still hold g: a delete+add cancels to a
            # unary patch (the common-neighbor count may have moved)
            self._sdiscard(self._pend_del, g)
            self._sadd(self._pend_u, g)
        else:
            self._sadd(self._pend_add, g)

    def _record_pair_retracted(self, g: int) -> None:
        if g in self._pend_add:
            self._sdiscard(self._pend_add, g)
        else:
            self._sadd(self._pend_del, g)
        self._sdiscard(self._pend_u, g)

    def _record_unary_changed(self, g: int) -> None:
        if g not in self._pend_add:
            self._sadd(self._pend_u, g)

    def _record_coupling_added(self, key: tuple[int, int]) -> None:
        if key in self._pend_cdel:
            self._sdiscard(self._pend_cdel, key)
        else:
            self._sadd(self._pend_cadd, key)

    def _record_coupling_removed(self, key: tuple[int, int]) -> None:
        if key in self._pend_cadd:
            self._sdiscard(self._pend_cadd, key)
        else:
            self._sadd(self._pend_cdel, key)

    def __len__(self) -> int:
        return len(self.levels)

    @staticmethod
    def _gid(a: int, b: int) -> int:
        lo, hi = (a, b) if a < b else (b, a)
        return lo * int(pairlib.GID_STRIDE) + hi

    def _couple(self, g1: int, g2: int) -> int:
        key = (g1, g2) if g1 < g2 else (g2, g1)
        if key in self.coup:
            return 0
        t = txn.active()
        if t is not None:
            t.set_add(self.coup, key)
            t.save_key(self.coup_adj, g1, copy=set)
            t.save_key(self.coup_adj, g2, copy=set)
        else:
            self.coup.add(key)
        self.coup_adj.setdefault(g1, set()).add(g2)
        self.coup_adj.setdefault(g2, set()).add(g1)
        self._record_coupling_added(key)
        return 1

    # -- the delta API ----------------------------------------------------

    def apply_delta(
        self,
        added_pairs: dict[int, int],
        retracted_pairs,
        new_edges: np.ndarray | None = None,
    ) -> GroundingDelta:
        """Patch the grounding: pair additions/retractions + new edges.

        ``added_pairs`` maps gid -> similarity level (levels are
        name-static, so a gid's level never changes between covers);
        ``retracted_pairs`` are gids that left the candidate set (canopy
        re-splits); ``new_edges`` are this ingest's relation tuples.
        Duplicate edges are ignored (set semantics, as in
        ``Relations.adjacency_sets``); self-loops are skipped
        defensively but must be rejected upstream (``DeltaCover.ingest``
        does) — the batch build counts i in adj(i) for a self-loop, so
        accepting one here would break bit-for-bit equality.
        """
        stats = GroundingDelta()
        visited: set[int] = set()
        t = txn.active()
        if t is not None:
            t.save_attr(self, "total_pair_visits")

        # 1. retractions: drop unary + incident couplings.
        for g in retracted_pairs or ():
            g = int(g)
            if g not in self.levels:
                continue
            if t is not None:
                t.save_key(self.levels, g)
                t.save_key(self.common, g)
            del self.levels[g]
            del self.common[g]
            a, b = (int(x) for x in pairlib.split_gid(np.int64(g)))
            if t is not None:
                t.save_key(self.pairs_of, a, copy=set)
                t.save_key(self.pairs_of, b, copy=set)
            self.pairs_of.get(a, set()).discard(g)
            self.pairs_of.get(b, set()).discard(g)
            if t is not None:
                t.save_key(self.coup_adj, g)
            for g2 in self.coup_adj.pop(g, set()):
                if t is not None:
                    t.save_key(self.coup_adj, g2, copy=set)
                self.coup_adj[g2].discard(g)
                key = (g, g2) if g < g2 else (g2, g)
                self._sdiscard(self.coup, key)
                self._record_coupling_removed(key)
                stats.couplings_removed += 1
            self._record_pair_retracted(g)
            visited.add(g)
            stats.pairs_retracted += 1

        # 2. new relation edges: the only pairs whose common-neighbor
        # count or couplings can change have an endpoint on the edge.
        if new_edges is not None and len(new_edges):
            for x, y in np.asarray(new_edges, dtype=np.int64):
                x, y = int(x), int(y)
                if x == y or y in self.adj.get(x, ()):
                    continue  # self-loop / duplicate: no pairwise evidence
                if t is not None:
                    t.save_key(self.adj, x, copy=set)
                    t.save_key(self.adj, y, copy=set)
                self.adj.setdefault(x, set()).add(y)
                self.adj.setdefault(y, set()).add(x)
                stats.edges_added += 1
                for u, v in ((x, y), (y, x)):
                    for g in self.pairs_of.get(u, ()):
                        a, b = (int(t) for t in pairlib.split_gid(np.int64(g)))
                        z = b if a == u else a
                        visited.add(g)
                        nz = self.adj.get(z, set())
                        if v in nz:  # v is a new common neighbor of (u, z)
                            if t is not None:
                                t.save_key(self.common, g)
                            self.common[g] += 1
                            self._record_unary_changed(g)
                        # new couplings through the (u, v) adjacency link:
                        # partner pairs (v, d) with d adjacent to z.
                        for d in nz:
                            if d == v:
                                continue
                            g2 = self._gid(v, d)
                            if g2 != g and g2 in self.levels:
                                stats.couplings_added += self._couple(g, g2)

        # 3. new pairs: unary + couplings from the current adjacency.
        # Coupling discovery is symmetric (c ~ a and d ~ b iff a ~ c and
        # b ~ d), so pairs added later in this loop find their couplings
        # to pairs added earlier — no second pass needed.
        for g, lev in added_pairs.items():
            g = int(g)
            if g in self.levels:
                continue
            a, b = (int(x) for x in pairlib.split_gid(np.int64(g)))
            na = self.adj.get(a, set())
            nb = self.adj.get(b, set())
            if t is not None:
                t.save_key(self.levels, g)
                t.save_key(self.common, g)
                t.save_key(self.pairs_of, a, copy=set)
                t.save_key(self.pairs_of, b, copy=set)
            self.levels[g] = int(lev)
            self.common[g] = len(na & nb)
            self.pairs_of.setdefault(a, set()).add(g)
            self.pairs_of.setdefault(b, set()).add(g)
            self._record_pair_added(g)
            visited.add(g)
            stats.pairs_added += 1
            for c in na:
                for d in nb:
                    if c == d:
                        continue
                    g2 = self._gid(c, d)
                    if g2 != g and g2 in self.levels:
                        stats.couplings_added += self._couple(g, g2)

        stats.pairs_visited = len(visited)
        self.total_pair_visits += stats.pairs_visited
        get_registry().counter("grounding.pair_visits").inc(stats.pairs_visited)
        return stats

    # -- materialization --------------------------------------------------

    def _unary_of(self, gids: np.ndarray) -> np.ndarray:
        """float32 unaries for ``gids``, with exactly the rounding of the
        scalar batch build: f32(w_sim[lev]) + f32(w_co * common)."""
        lv = np.fromiter((self.levels[int(g)] for g in gids), dtype=np.int64,
                         count=len(gids))
        cn = np.fromiter((self.common[int(g)] for g in gids), dtype=np.float64,
                         count=len(gids))
        return self.w_sim[lv] + (self.w_co * cn).astype(np.float32)

    def _build_full(self) -> GlobalGrounding:
        n = len(self.levels)
        # One aligned pass over the dicts, then argsort — no per-element
        # Python boxing or comparison sorts.
        ks = np.fromiter(self.levels.keys(), dtype=np.int64, count=n)
        lv = np.fromiter(self.levels.values(), dtype=np.int64, count=n)
        cn = np.fromiter(
            (self.common[g] for g in self.levels), dtype=np.float64, count=n
        )
        order = np.argsort(ks)
        gids = ks[order]
        # Scalar build computes  f32(w_sim[lev]) + f32(w_co * count)
        # under NEP-50 weak promotion; replicate the rounding exactly.
        u = self.w_sim[lv[order]] + (self.w_co * cn[order]).astype(np.float32)
        if self.coup:
            cp = np.fromiter(
                (g for pair in self.coup for g in pair),
                dtype=np.int64,
                count=2 * len(self.coup),
            ).reshape(-1, 2)
            pi = np.searchsorted(gids, cp[:, 0]).astype(np.int32)
            qi = np.searchsorted(gids, cp[:, 1]).astype(np.int32)
            row_order = np.lexsort((qi, pi))  # build emits sorted (p, q)
            coup_p, coup_q = pi[row_order], qi[row_order]
        else:
            coup_p = np.zeros(0, dtype=np.int32)
            coup_q = np.zeros(0, dtype=np.int32)
        return GlobalGrounding(
            gids=gids, u=u.astype(np.float32), coup_p=coup_p, coup_q=coup_q,
            w_co=self.w_co,
        )

    def _splice(self, gg: GlobalGrounding) -> GlobalGrounding:
        """Patch the live arrays with the pending delta.

        Only the delta's rows are recomputed (``last_splice_rows`` counts
        them); untouched unary entries and coupling rows are carried over
        as memcpy, so per-ingest materialization cost no longer includes
        the O(P) per-pair host pass of the full build.  Coupling rows are
        kept sorted by (gid_p, gid_q), which equals the full build's
        (index_p, index_q) lexsort because gid order and index order
        coincide.
        """
        gids, u = gg.gids, gg.u
        coup_p = gg.coup_p.astype(np.int64)
        coup_q = gg.coup_q.astype(np.int64)

        def _keys(p_idx, q_idx, n):
            return p_idx * np.int64(n) + q_idx

        # 1. coupling deletions, located in the old index space.
        if self._pend_cdel:
            cd = np.asarray(sorted(self._pend_cdel), dtype=np.int64)
            pi = np.searchsorted(gids, cd[:, 0])
            qi = np.searchsorted(gids, cd[:, 1])
            pos = np.searchsorted(
                _keys(coup_p, coup_q, len(gids)), _keys(pi, qi, len(gids))
            )
            coup_p = np.delete(coup_p, pos)
            coup_q = np.delete(coup_q, pos)

        # 2. gid deletions: remove rows, shift surviving indices down.
        if self._pend_del:
            dl = np.asarray(sorted(self._pend_del), dtype=np.int64)
            pos = np.searchsorted(gids, dl)
            gids = np.delete(gids, pos)
            u = np.delete(u, pos)
            if len(coup_p):
                coup_p -= np.searchsorted(pos, coup_p, side="right")
                coup_q -= np.searchsorted(pos, coup_q, side="right")

        # 3. gid insertions: shift indices up, insert rows in gid order.
        if self._pend_add:
            av = np.asarray(sorted(self._pend_add), dtype=np.int64)
            if len(coup_p):
                coup_p += np.searchsorted(av, gids[coup_p])
                coup_q += np.searchsorted(av, gids[coup_q])
            pos = np.searchsorted(gids, av)
            gids = np.insert(gids, pos, av)
            u = np.insert(u, pos, self._unary_of(av))

        # 4. unary patches for pairs whose common-neighbor count moved.
        if self._pend_u:
            uv = np.asarray(sorted(self._pend_u), dtype=np.int64)
            pos = np.searchsorted(gids, uv)
            if u is gg.u:
                u = u.copy()  # never mutate a previously returned grounding
            u[pos] = self._unary_of(uv)

        # 5. coupling insertions in the new index space.
        if self._pend_cadd:
            ca = np.asarray(sorted(self._pend_cadd), dtype=np.int64)
            pi = np.searchsorted(gids, ca[:, 0])
            qi = np.searchsorted(gids, ca[:, 1])
            pos = np.searchsorted(
                _keys(coup_p, coup_q, len(gids)), _keys(pi, qi, len(gids))
            )
            coup_p = np.insert(coup_p, pos, pi)
            coup_q = np.insert(coup_q, pos, qi)

        self.last_splice_rows = (
            len(self._pend_add) + len(self._pend_del) + len(self._pend_u)
            + len(self._pend_cadd) + len(self._pend_cdel)
        )
        return GlobalGrounding(
            gids=gids,
            u=u,
            coup_p=coup_p.astype(np.int32),
            coup_q=coup_q.astype(np.int32),
            w_co=self.w_co,
        )

    def grounding(self) -> GlobalGrounding:
        """The array-form grounding, spliced in place per delta.

        Bit-for-bit equal to ``build_global_grounding`` over the same
        accumulated pairs/edges: the unary is recomputed from the exact
        integer common-neighbor count with the same float32 rounding as
        the scalar batch loop.  The first call materializes the arrays
        from scratch; every later call splices only the rows the pending
        deltas touched (``last_splice_rows``/``total_splice_rows`` count
        them — the array-form analogue of ``GroundingDelta.
        pairs_visited``).
        """
        t = txn.active()
        if t is not None:
            for a in ("_gg", "last_splice_rows", "total_splice_rows"):
                t.save_attr(self, a)
        pending = (
            self._pend_add or self._pend_del or self._pend_u
            or self._pend_cadd or self._pend_cdel
        )
        if self._gg is not None and not pending:
            self.last_splice_rows = 0
            return self._gg
        if self._gg is None:
            self._gg = self._build_full()
            self.last_splice_rows = len(self._gg.gids) + len(self._gg.coup_p)
        else:
            self._gg = self._splice(self._gg)
        self.total_splice_rows += self.last_splice_rows
        get_registry().counter("grounding.splice_rows").inc(self.last_splice_rows)
        # rebind (not clear()) so a journaled pre-ingest reference keeps
        # its contents for rollback
        if t is not None:
            for a in ("_pend_add", "_pend_del", "_pend_u",
                      "_pend_cadd", "_pend_cdel"):
                t.save_attr(self, a)
        self._pend_add = set()
        self._pend_del = set()
        self._pend_u = set()
        self._pend_cadd = set()
        self._pend_cdel = set()
        return self._gg


def ub_matches(gg: GlobalGrounding, truth_gids: np.ndarray) -> MatchStore:
    """§6.1 UB: decide each pair with ground truth of all others as evidence.

    Single-variable conditional MAP: include p iff
    ``u(p) + w_co * |linked true pairs| >= 0`` (ties keep the pair: the
    Type-II output prefers larger sets).  Supermodularity makes the result
    a superset of the full-run matches (upper bound on recall).
    """
    t = np.zeros(len(gg.gids), dtype=bool)
    idx = gg.index_of(np.asarray(sorted(set(int(g) for g in truth_gids)), dtype=np.int64))
    t[idx[idx >= 0]] = True

    boost = np.zeros(len(gg.gids), dtype=np.float32)
    # coupling contributions from ground-truth-true partners
    np.add.at(boost, gg.coup_p, gg.w_co * t[gg.coup_q])
    np.add.at(boost, gg.coup_q, gg.w_co * t[gg.coup_p])
    keep = (gg.u + boost) >= -1e-6
    return MatchStore(gg.gids[keep])
