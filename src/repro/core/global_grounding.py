"""Host-side global grounding: exact P_E scoring over the full entity set.

MMP step 7 requires checking ``P_E(M+ u M) >= P_E(M+)`` — the paper notes
that while argmax over P_E is expensive, *evaluating* P_E at a given set
is cheap from the model parameters.  This module materializes the global
(sparse) grounded objective once:

    f(S) = sum_{p in S} u_g(p) + sum_{ {p,q} subset S } w_co * link(p, q)

with u_g from the *full* coauthor graph (so u_local <= u_g, consistent
with matcher monotonicity over sub-instances) and one coupling per
unordered linked candidate-pair pair — the paper's §2.1/§2.2 arithmetic.

Also implements the UB scheme of §6.1: for each candidate pair, condition
on the ground truth of all other pairs and take the single-variable MAP.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import pairs as pairlib
from repro.core.mln import MLNWeights
from repro.core.types import MatchStore, Relations


@dataclasses.dataclass
class GlobalGrounding:
    gids: np.ndarray  # (Np,) sorted candidate pair gids
    u: np.ndarray  # (Np,) f32 global unary
    coup_p: np.ndarray  # (Nc,) int32 index into gids
    coup_q: np.ndarray  # (Nc,) int32 index into gids (p < q)
    w_co: float

    def index_of(self, gids: np.ndarray) -> np.ndarray:
        idx = np.searchsorted(self.gids, gids)
        idx = np.clip(idx, 0, len(self.gids) - 1)
        ok = self.gids[idx] == gids
        return np.where(ok, idx, -1)

    def score(self, store: MatchStore) -> float:
        """f(S) for a global match set."""
        x = np.zeros(len(self.gids), dtype=bool)
        idx = self.index_of(store.gids)
        x[idx[idx >= 0]] = True
        lin = float(self.u[x].sum())
        quad = float(self.w_co * np.sum(x[self.coup_p] & x[self.coup_q]))
        return lin + quad

    def delta(self, base: np.ndarray, add: np.ndarray) -> float:
        """f(base u add) - f(base), with base/add boolean over gids."""
        new = add & ~base
        lin = float(self.u[new].sum())
        both = base | add
        quad_new = (
            np.sum(both[self.coup_p] & both[self.coup_q])
            - np.sum(base[self.coup_p] & base[self.coup_q])
        )
        return lin + float(self.w_co * quad_new)

    def bool_of(self, store: MatchStore) -> np.ndarray:
        x = np.zeros(len(self.gids), dtype=bool)
        idx = self.index_of(store.gids)
        x[idx[idx >= 0]] = True
        return x


def build_global_grounding(
    pair_levels: dict[int, int],
    relations: Relations,
    weights: MLNWeights,
    *,
    boundary_relation: str = "coauthor",
) -> GlobalGrounding:
    gids = np.array(sorted(pair_levels.keys()), dtype=np.int64)
    n = len(gids)
    adj = relations.adjacency_sets(boundary_relation)
    w_sim = np.asarray(weights.w_sim, dtype=np.float32)
    w_co = float(weights.w_co)

    u = np.zeros(n, dtype=np.float32)
    gid_to_idx = {int(g): i for i, g in enumerate(gids)}
    coup: set[tuple[int, int]] = set()

    for i, g in enumerate(gids):
        a, b = pairlib.split_gid(np.int64(g))
        a, b = int(a), int(b)
        na, nb = adj.get(a, set()), adj.get(b, set())
        u[i] = w_sim[pair_levels[int(g)]] + w_co * len(na & nb)
        # couplings: candidate (c, d) with c ~ a, d ~ b (either orientation)
        for c in na:
            for d in nb:
                if c == d:
                    continue
                j = gid_to_idx.get(int(pairlib.make_gid(c, d)))
                if j is not None and j != i:
                    coup.add((min(i, j), max(i, j)))

    if coup:
        cp = np.array(sorted(coup), dtype=np.int64)
        coup_p, coup_q = cp[:, 0].astype(np.int32), cp[:, 1].astype(np.int32)
    else:
        coup_p = np.zeros(0, dtype=np.int32)
        coup_q = np.zeros(0, dtype=np.int32)
    return GlobalGrounding(gids=gids, u=u, coup_p=coup_p, coup_q=coup_q, w_co=w_co)


def ub_matches(gg: GlobalGrounding, truth_gids: np.ndarray) -> MatchStore:
    """§6.1 UB: decide each pair with ground truth of all others as evidence.

    Single-variable conditional MAP: include p iff
    ``u(p) + w_co * |linked true pairs| >= 0`` (ties keep the pair: the
    Type-II output prefers larger sets).  Supermodularity makes the result
    a superset of the full-run matches (upper bound on recall).
    """
    t = np.zeros(len(gg.gids), dtype=bool)
    idx = gg.index_of(np.asarray(sorted(set(int(g) for g in truth_gids)), dtype=np.int64))
    t[idx[idx >= 0]] = True

    boost = np.zeros(len(gg.gids), dtype=np.float32)
    # coupling contributions from ground-truth-true partners
    np.add.at(boost, gg.coup_p, gg.w_co * t[gg.coup_q])
    np.add.at(boost, gg.coup_q, gg.w_co * t[gg.coup_p])
    keep = (gg.u + boost) >= -1e-6
    return MatchStore(gg.gids[keep])
