"""Sequential message-passing drivers: NO-MP, SMP (Alg. 1), MMP (Alg. 3).

These are the paper's algorithms verbatim: a host-side worklist of
active neighborhoods, the (batched, JAX) matcher as the black box, and
host-side message bookkeeping.  The round-parallel SPMD version lives in
:mod:`repro.core.parallel`; Theorems 2/4 (consistency) guarantee both
produce the same fixpoint, which the tests verify.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core import txn
from repro.core.cover import PackedCover
from repro.core.global_grounding import GlobalGrounding
from repro.core.matcher import TypeIIMatcher, TypeIMatcher
from repro.core.types import MatchStore


@dataclasses.dataclass
class EMResult:
    matches: MatchStore
    neighborhood_evals: int
    rounds: int
    messages_emitted: int
    messages_promoted: int
    wall_time_s: float
    history: list[int] = dataclasses.field(default_factory=list)
    # Host->device jitted dispatches issued by the round engine — the
    # quantity the device-resident driver collapses from O(bins x rounds)
    # to O(bins + quiescence points).  Sequential drivers count one
    # dispatch per neighborhood evaluation.
    dispatches: int = 0
    # Host-visible full rounds of the fused engine (the quiescence
    # points): every other round ran inside a fused greedy segment.
    full_rounds: int = 0
    # Serving-memory accounting of the bounded GroundingCache (parallel
    # engine): high-water mark of array-resident bins, LRU evictions and
    # cold re-grounds issued during this run.  Zero everywhere for the
    # sequential drivers and for unbounded caches that never evict.
    peak_resident_bins: int = 0
    cache_evictions: int = 0
    cold_regrounds: int = 0
    # Step-7 promotion passes that fell back to the host coupling-COO
    # walk (driver._promote).  The fused engine promotes on device
    # (parallel.DevicePromoter) and keeps this at 0 — gated in CI; the
    # legacy fused=False loop and the sequential run_mmp count every
    # pass here by design (they ARE the host baseline).
    promote_host_scans: int = 0


# EMResult fields published as monotone ``em.*`` counters; the remaining
# fields are a high-water gauge (peak_resident_bins) and a latency
# histogram (wall_time_s -> em.wall_ms).
_EM_COUNTER_FIELDS = (
    "neighborhood_evals",
    "rounds",
    "full_rounds",
    "dispatches",
    "messages_emitted",
    "messages_promoted",
    "cache_evictions",
    "cold_regrounds",
    "promote_host_scans",
)


def publish_em_result(res: EMResult) -> EMResult:
    """Publish an :class:`EMResult` into the runtime metrics registry.

    The dataclass stays the per-call API; the registry (``em.*`` family)
    is the cumulative, process-wide view the benchmarks snapshot.  Every
    driver (sequential and parallel) routes its result through here, so
    ``em.runs`` counts engine invocations regardless of scheme.
    """
    from repro.obs import get_registry

    reg = get_registry()
    reg.counter("em.runs").inc()
    for name in _EM_COUNTER_FIELDS:
        v = int(getattr(res, name))
        if v:
            reg.counter(f"em.{name}").inc(v)
    reg.gauge("em.peak_resident_bins").max(res.peak_resident_bins)
    reg.gauge("em.matches").max(len(res.matches.gids))
    reg.histogram("em.wall_ms").observe(res.wall_time_s * 1e3)
    return res


def _eval_neighborhood(matcher, packed, n, m_plus, with_messages):
    """Run the matcher on neighborhood n with current evidence projected in."""
    k = int(packed.neighborhood_bin[n])
    row = int(packed.neighborhood_row[n])
    nb = packed.bins[k].row(row)
    ev_pos = m_plus.mask_of(nb.pair_gid)
    if with_messages:
        x, lab = matcher.run_with_messages(nb, ev_pos, None)
        return nb, x[0], lab[0]
    x = matcher.run(nb, ev_pos, None)
    return nb, x[0], None


def _new_gids(nb_row_gid, x, m_plus):
    gids = nb_row_gid[x & (nb_row_gid >= 0)]
    fresh = gids[~np.isin(gids, m_plus.gids)]
    return np.unique(fresh)


def run_nomp(packed: PackedCover, matcher: TypeIMatcher) -> EMResult:
    """Each neighborhood evaluated once, no messages (baseline NO-MP)."""
    t0 = time.perf_counter()
    m_plus = MatchStore()
    evals = 0
    for n in range(packed.num_neighborhoods):
        nb, x, _ = _eval_neighborhood(matcher, packed, n, MatchStore(), False)
        m_plus = m_plus.union(_new_gids(nb.pair_gid[0], x, m_plus))
        evals += 1
    return publish_em_result(
        EMResult(m_plus, evals, 1, 0, 0, time.perf_counter() - t0,
                 dispatches=evals)
    )


def run_smp(
    packed: PackedCover,
    matcher: TypeIMatcher,
    order: list[int] | None = None,
    max_evals: int | None = None,
    *,
    init_matches: MatchStore | None = None,
) -> EMResult:
    """Algorithm 1 (SMP).

    ``order`` doubles as a *partial* worklist hook for the streaming
    engine: with ``init_matches`` set to a previous fixpoint and
    ``order`` to the dirty neighborhoods only, the run continues the
    monotone closure from that state — re-activation through
    ``neighborhoods_of_pairs`` pulls in any neighborhood that new
    evidence touches, so the fixpoint equals a full run (Thm. 2).
    """
    t0 = time.perf_counter()
    n_nb = packed.num_neighborhoods
    seeds = list(order if order is not None else range(n_nb))
    worklist = deque(seeds)
    in_list = [False] * n_nb
    for n in seeds:
        in_list[n] = True
    m_plus = init_matches if init_matches is not None else MatchStore()
    evals = 0
    cap = max_evals or n_nb * 64
    while worklist and evals < cap:
        n = worklist.popleft()
        in_list[n] = False
        nb, x, _ = _eval_neighborhood(matcher, packed, n, m_plus, False)
        new = _new_gids(nb.pair_gid[0], x, m_plus)
        evals += 1
        if len(new):
            m_plus = m_plus.union(new)
            for m in packed.neighborhoods_of_pairs(new):
                if m != n and not in_list[m]:
                    worklist.append(m)
                    in_list[m] = True
    return publish_em_result(
        EMResult(m_plus, evals, 1, 0, 0, time.perf_counter() - t0,
                 dispatches=evals)
    )


# ---------------------------------------------------------------------------
# MMP (Alg. 3) with host-side T* merging (Prop. 3) and step-7 promotion
# ---------------------------------------------------------------------------


class MessagePool:
    """Disjoint maximal messages over global pair gids (the set T)."""

    def __init__(self):
        self.parent: dict[int, int] = {}  # union-find over gids
        # groups() memo: _promote replays the partition once per
        # promotion sweep of every round — rebuilding it from the
        # union-find each time was O(|T|) per pass.  Any mutation
        # (add_message / discard) invalidates.
        self._groups: list[np.ndarray] | None = None

    def _find(self, g: int) -> int:
        # entry writes (inserts and path compressions alike) are
        # journaled into the active ingest transaction, mirroring
        # closure.UnionFind — see its docstring for why compressions
        # must be journaled too
        t = txn.active()
        if t is not None and g not in self.parent:
            t.save_key(self.parent, g)
        p = self.parent.setdefault(g, g)
        while p != self.parent[p]:
            if t is not None:
                t.save_key(self.parent, p)
            self.parent[p] = self.parent[self.parent[p]]
            p = self.parent[p]
        if t is not None:
            t.save_key(self.parent, g)
        self.parent[g] = p
        return p

    def add_message(self, gids: list[int]) -> None:
        """T <- (T u {M})* : union-find merge implements Prop. 3."""
        if len(gids) < 2:
            return
        t = txn.active()
        if t is not None:
            t.save_attr(self, "_groups")
        self._groups = None
        r0 = self._find(gids[0])
        for g in gids[1:]:
            r = self._find(g)
            if r != r0:
                if t is not None:
                    t.save_key(self.parent, r)
                self.parent[r] = r0

    def groups(self) -> list[np.ndarray]:
        """Current disjoint groups (memoized; callers must not mutate)."""
        if self._groups is None:
            by_root: dict[int, list[int]] = {}
            for g in list(self.parent.keys()):
                by_root.setdefault(self._find(g), []).append(g)
            self._groups = [
                np.asarray(sorted(v), dtype=np.int64)
                for v in by_root.values()
                if len(v) >= 2
            ]
        return self._groups

    def discard(self, gids) -> None:
        """Remove gids from the pool, keeping the remaining group structure.

        The streaming engine calls this when a cover delta retracts
        candidate pairs: step-7 promotion already filters retracted gids
        against the current grounding, but pruning them here patches the
        pool in place so groups that shrink below two members stop being
        replayed at every subsequent promotion pass.
        """
        drop = {int(g) for g in gids}
        if not drop or not (drop & self.parent.keys()):
            return
        groups = self.groups()
        t = txn.active()
        if t is not None:
            # the rebuild rebinds ``parent`` wholesale; journaling the
            # old dict ref is enough — subsequent writes hit the new one
            t.save_attr(self, "parent")
            t.save_attr(self, "_groups")
        self.parent = {}
        self._groups = None
        for grp in groups:
            self.add_message([int(g) for g in grp if int(g) not in drop])


def _labels_to_messages(
    nb_gid: np.ndarray,
    lab: np.ndarray,
    m_plus,
    row_mask: np.ndarray | None = None,
) -> list[list[int]]:
    """Component labels -> groups of >= 2 unmatched global pairs.

    Batched: ``nb_gid``/``lab`` may be ``(P,)`` (one neighborhood, the
    sequential driver) or ``(B, P)`` (a whole round's bin, the parallel
    driver).  The per-slot Python walk is replaced by numpy segment ops
    keyed on ``(row, label)``; ``row_mask`` restricts extraction to the
    rows the round actually evaluated.
    """
    nb_gid = np.atleast_2d(np.asarray(nb_gid))
    lab = np.atleast_2d(np.asarray(lab))
    B, P = lab.shape
    ok = (lab < P) & (nb_gid >= 0)
    if row_mask is not None:
        ok &= np.atleast_1d(row_mask)[:, None]
    if not ok.any():
        return []
    rows, _ = np.nonzero(ok)
    gids = nb_gid[ok]
    labs = lab[ok].astype(np.int64)
    unmatched = ~np.isin(gids, m_plus.gids)
    if not unmatched.any():
        return []
    key = rows[unmatched] * np.int64(P) + labs[unmatched]
    gids = gids[unmatched]
    order = np.argsort(key, kind="stable")
    key, gids = key[order], gids[order]
    _, starts, counts = np.unique(key, return_index=True, return_counts=True)
    return [
        gids[s : s + c].tolist() for s, c in zip(starts, counts) if c >= 2
    ]


def _promote(pool: MessagePool, gg: GlobalGrounding, m_plus: MatchStore):
    """Step 7: promote every message with nonneg global delta; to fixpoint.

    Only the group's gids present in the grounding are promoted: in a
    batch run that is the whole group, but the streaming engine replays
    a *persistent* pool against a grounding whose candidate set may have
    retracted some gids (canopy re-splits) — those must not leak back
    into the match store.
    """
    promoted = 0
    new_all: list[np.ndarray] = []
    base = gg.bool_of(m_plus)
    changed = True
    while changed:
        changed = False
        for grp in pool.groups():
            idx = gg.index_of(grp)
            grp = grp[idx >= 0]
            idx = idx[idx >= 0]
            if len(grp) < 2:
                continue
            add = np.zeros_like(base)
            add[idx] = True
            if not np.any(add & ~base):
                continue
            if gg.delta(base, add) >= -1e-6:
                base = base | add
                new_all.append(grp)
                promoted += 1
                changed = True
    if new_all:
        m_plus = m_plus.union(np.concatenate(new_all))
    return m_plus, promoted


def run_mmp(
    packed: PackedCover,
    matcher: TypeIIMatcher,
    gg: GlobalGrounding,
    order: list[int] | None = None,
    max_evals: int | None = None,
    *,
    init_matches: MatchStore | None = None,
    pool: MessagePool | None = None,
) -> EMResult:
    """Algorithm 3 (MMP).

    ``order``/``init_matches``/``pool`` are the streaming hooks: the
    incremental engine passes only the dirty neighborhoods plus the
    persistent maximal-message pool — step-7 promotion re-checks every
    stored group against the *current* global grounding, which is how
    the affected slice of the pool gets replayed after a cover delta.
    """
    t0 = time.perf_counter()
    n_nb = packed.num_neighborhoods
    seeds = list(order if order is not None else range(n_nb))
    worklist = deque(seeds)
    in_list = [False] * n_nb
    for n in seeds:
        in_list[n] = True
    m_plus = init_matches if init_matches is not None else MatchStore()
    if pool is None:
        pool = MessagePool()
    evals = 0
    emitted = 0
    promoted_total = 0
    host_scans = 0
    cap = max_evals or n_nb * 64
    while worklist and evals < cap:
        n = worklist.popleft()
        in_list[n] = False
        nb, x, lab = _eval_neighborhood(matcher, packed, n, m_plus, True)
        evals += 1
        new = _new_gids(nb.pair_gid[0], x, m_plus)
        m_plus = m_plus.union(new)
        for msg in _labels_to_messages(nb.pair_gid[0], lab, m_plus):
            pool.add_message(msg)
            emitted += 1
        m_plus2, promoted = _promote(pool, gg, m_plus)
        host_scans += 1
        promoted_total += promoted
        newly = np.concatenate([new, m_plus2.difference(m_plus)]) if promoted else new
        m_plus = m_plus2
        if len(newly):
            for m in packed.neighborhoods_of_pairs(np.unique(newly)):
                if m != n and not in_list[m]:
                    worklist.append(m)
                    in_list[m] = True
    return publish_em_result(EMResult(
        m_plus, evals, 1, emitted, promoted_total, time.perf_counter() - t0,
        dispatches=evals, promote_host_scans=host_scans,
    ))
