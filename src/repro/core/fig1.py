"""The paper's running example (Fig. 1 / Fig. 2 / §2.1-§2.2), as data.

Entities: author references a1,a2, b1,b2,b3, c1,c2,c3, d1.
Similar (level-1) pairs: all (ai,aj), (bi,bj), (ci,cj).
Coauthor edges: a1-b2, a2-b3, b1-c1, b2-c2, b3-c3, c1-d1, c2-d1.
Weights: R1 = -5, R2 = +8 (the §2.1 pedagogical MLN).

Expected behavior (verbatim from the paper):
  * full-run MLN:    {(c1,c2), (b1,b2), (a1,a2), (b2,b3), (c2,c3)}
  * NO-MP:           {(c1,c2)}                       (only C3 matches)
  * SMP:             + (b1,b2)                       (evidence message)
  * MMP:             everything, via maximal messages
                     {(a1,a2),(b2,b3)} + {(b2,b3),(c2,c3)} -> chain closed
"""

from __future__ import annotations

import numpy as np

from repro.core import pairs as pairlib
from repro.core.cover import Cover, PackedCover
from repro.core.types import EntityTable, MatchStore, NeighborhoodBatch, Relations

NAMES = ["a1", "a2", "b1", "b2", "b3", "c1", "c2", "c3", "d1"]
IDX = {n: i for i, n in enumerate(NAMES)}

SIMILAR = [
    ("a1", "a2"),
    ("b1", "b2"),
    ("b1", "b3"),
    ("b2", "b3"),
    ("c1", "c2"),
    ("c1", "c3"),
    ("c2", "c3"),
]

COAUTHOR = [
    ("a1", "b2"),
    ("a2", "b3"),
    ("b1", "c1"),
    ("b2", "c2"),
    ("b3", "c3"),
    ("c1", "d1"),
    ("c2", "d1"),
]

COVERS = {
    "C1": ["a1", "a2", "b1", "b2", "b3"],
    "C2": ["b1", "b2", "b3", "c1", "c2", "c3"],
    "C3": ["c1", "c2", "c3", "d1"],
}

EXPECTED_FULL = {("c1", "c2"), ("b1", "b2"), ("a1", "a2"), ("b2", "b3"), ("c2", "c3")}
EXPECTED_NOMP = {("c1", "c2")}
EXPECTED_SMP = EXPECTED_NOMP | {("b1", "b2")}
EXPECTED_MMP = EXPECTED_FULL


def entities() -> EntityTable:
    return EntityTable(names=list(NAMES), truth=None)


def relations() -> Relations:
    e = np.asarray([[IDX[a], IDX[b]] for a, b in COAUTHOR], dtype=np.int64)
    return Relations(edges={"coauthor": e})


def similar_levels() -> dict[int, int]:
    return {
        int(pairlib.make_gid(IDX[a], IDX[b])): 1 for a, b in SIMILAR
    }


def gid_of(a: str, b: str) -> int:
    return int(pairlib.make_gid(IDX[a], IDX[b]))


def names_of(store: MatchStore) -> set[tuple[str, str]]:
    out = set()
    for g in store.gids:
        a, b = pairlib.split_gid(np.int64(g))
        out.add((NAMES[int(a)], NAMES[int(b)]))
    return out


def _make_neighborhood(member_names: list[str], k: int) -> dict:
    ids = np.full(k, -1, dtype=np.int64)
    members = np.asarray([IDX[n] for n in member_names], dtype=np.int64)
    ids[: len(members)] = members
    emask = ids >= 0
    co = np.zeros((k, k), dtype=bool)
    co_set = {(IDX[a], IDX[b]) for a, b in COAUTHOR}
    for i in range(len(members)):
        for j in range(len(members)):
            a, b = int(ids[i]), int(ids[j])
            if (a, b) in co_set or (b, a) in co_set:
                co[i, j] = True
    P = pairlib.num_pairs(k)
    ii, jj = pairlib.triu_indices(k)
    lev = np.zeros(P, dtype=np.int8)
    gid = np.full(P, -1, dtype=np.int64)
    pmask = np.zeros(P, dtype=bool)
    levels = similar_levels()
    for p in range(P):
        i, j = int(ii[p]), int(jj[p])
        if not (emask[i] and emask[j]):
            continue
        g = int(pairlib.make_gid(int(ids[i]), int(ids[j])))
        lv = levels.get(g, 0)
        if lv:
            lev[p] = lv
            gid[p] = g
            pmask[p] = True
    return dict(ids=ids, emask=emask, co=co, lev=lev, gid=gid, pmask=pmask)


def batch_of(neighborhood_names: list[list[str]], k: int = 8) -> NeighborhoodBatch:
    rows = [_make_neighborhood(m, k) for m in neighborhood_names]
    return NeighborhoodBatch(
        entity_ids=np.stack([r["ids"] for r in rows]),
        entity_mask=np.stack([r["emask"] for r in rows]),
        coauthor=np.stack([r["co"] for r in rows]),
        sim_level=np.stack([r["lev"] for r in rows]),
        pair_gid=np.stack([r["gid"] for r in rows]),
        pair_mask=np.stack([r["pmask"] for r in rows]),
    )


def full_batch(k: int = 16) -> NeighborhoodBatch:
    return batch_of([list(NAMES)], k=k)


def packed_cover(k: int = 8) -> PackedCover:
    """The Fig. 2 cover {C1, C2, C3} packed for the drivers."""
    order = ["C1", "C2", "C3"]
    rows = [_make_neighborhood(COVERS[c], k) for c in order]
    nb = NeighborhoodBatch(
        entity_ids=np.stack([r["ids"] for r in rows]),
        entity_mask=np.stack([r["emask"] for r in rows]),
        coauthor=np.stack([r["co"] for r in rows]),
        sim_level=np.stack([r["lev"] for r in rows]),
        pair_gid=np.stack([r["gid"] for r in rows]),
        pair_mask=np.stack([r["pmask"] for r in rows]),
    )
    cover = Cover(
        core=[np.asarray([IDX[n] for n in COVERS[c]], dtype=np.int64) for c in order],
        full=[np.asarray([IDX[n] for n in COVERS[c]], dtype=np.int64) for c in order],
    )
    levels = {}
    for r in rows:
        for g, lv in zip(r["gid"], r["lev"]):
            if g >= 0:
                levels[int(g)] = int(lv)
    return PackedCover(
        bins={k: nb},
        bin_rows={k: np.arange(3, dtype=np.int64)},
        neighborhood_bin=np.full(3, k, dtype=np.int64),
        neighborhood_row=np.arange(3, dtype=np.int64),
        pair_levels=levels,
        cover=cover,
    )
