"""Undo-log transactions: all-or-nothing mutation of host-side state.

``ResolveService.ingest`` threads one mutation pass through the LSH
index, the delta cover, the grounding maintainer, the message pool and
the engine's match store.  A failure anywhere in that pass (a poisoned
request, an injected fault, an OOM in the round loop) must not leave
the service torn — the paper's O(dirty) locality is exactly what makes
this cheap: each ingest touches a bounded dirty neighborhood, so a
journal of the *touched entries* is an O(dirty) undo log, where a
defensive deep copy of the service state would be O(corpus).

Mechanics: a :class:`Transaction` is a LIFO journal of undo closures.
Mutation sites call :func:`active` (thread-local; ``None`` outside an
ingest, so batch pipelines pay one attribute lookup) and journal the
*pre-image* of whatever they are about to clobber:

* ``save_attr(obj, name)``   — attribute rebind (``self.packed = ...``)
* ``save_key(d, k)``         — dict entry write/delete (first touch wins)
* ``save_len(lst)``          — append-only list growth (undo truncates)
* ``set_add`` / ``set_discard`` — journaled set mutation
* ``on_rollback(fn)``        — arbitrary compensation (e.g. cache drop)

First-touch deduplication (keyed on ``(id(container), key)``) keeps the
journal O(distinct entries touched) even when a hot loop rewrites the
same entry repeatedly, and LIFO replay restores every journaled
location to its pre-transaction value regardless of how many times it
was written afterwards.

In-place ndarray writes are either journaled with explicit pre-image
copies (``save_row`` for the feature-row fill-ins in
``DeltaCover._grow``) or provably unobservable after rollback (packed
bin-buffer tail appends write only beyond every published view length,
so restoring ``_bin_seq``/``_bin_arrays`` hides them) — the journal
never silently aliases a buffer that is about to be scribbled on.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Callable, Iterator

_MISSING = object()

_tls = threading.local()


class Transaction:
    """LIFO journal of undo closures with first-touch dedup."""

    __slots__ = ("_ops", "_seen")

    def __init__(self) -> None:
        self._ops: list[Callable[[], None]] = []
        self._seen: set = set()

    def __len__(self) -> int:
        return len(self._ops)

    # -- journal helpers ----------------------------------------------------

    def save_attr(self, obj: Any, name: str) -> None:
        """Journal ``obj.<name>`` (the *reference*, not a copy) so a
        rebind can be undone.  Callers that mutate the referenced object
        in place must journal those entry writes separately."""
        key = (id(obj), "a", name)
        if key in self._seen:
            return
        self._seen.add(key)
        prev = getattr(obj, name, _MISSING)
        if prev is _MISSING:
            def undo() -> None:
                if hasattr(obj, name):
                    delattr(obj, name)
        else:
            def undo() -> None:
                setattr(obj, name, prev)
        self._ops.append(undo)

    def save_key(self, container: dict, key: Any, copy: Callable | None = None) -> None:
        """Journal one dict entry before a write/delete.  ``copy`` takes
        a pre-image copy when the *value* is about to be mutated in
        place (e.g. a set being grown) rather than rebound."""
        k = (id(container), "k", key)
        if k in self._seen:
            return
        self._seen.add(k)
        if key in container:
            prev = container[key]
            if copy is not None:
                prev = copy(prev)

            def undo() -> None:
                container[key] = prev
        else:
            def undo() -> None:
                container.pop(key, None)
        self._ops.append(undo)

    def save_len(self, seq: list) -> None:
        """Journal an append-only list's length; undo truncates back.
        Entry *overwrites* below the journaled length still need
        ``save_item``."""
        k = (id(seq), "l")
        if k in self._seen:
            return
        self._seen.add(k)
        n = len(seq)

        def undo() -> None:
            del seq[n:]
        self._ops.append(undo)

    def save_item(self, seq: list, i: int) -> None:
        """Journal one list slot before an in-place overwrite."""
        k = (id(seq), "i", i)
        if k in self._seen:
            return
        self._seen.add(k)
        prev = seq[i]

        def undo() -> None:
            if i < len(seq):
                seq[i] = prev
        self._ops.append(undo)

    def save_row(self, arr, i: int) -> None:
        """Journal one ndarray row (pre-image copy) before an in-place
        write — the only journaling path that copies data."""
        k = (id(arr), "r", i)
        if k in self._seen:
            return
        self._seen.add(k)
        prev = arr[i].copy()

        def undo() -> None:
            arr[i] = prev
        self._ops.append(undo)

    def set_add(self, s: set, item: Any) -> None:
        if item not in s:
            s.add(item)
            self._ops.append(lambda: s.discard(item))

    def set_discard(self, s: set, item: Any) -> None:
        if item in s:
            s.discard(item)
            self._ops.append(lambda: s.add(item))

    def on_rollback(self, fn: Callable[[], None]) -> None:
        """Register an arbitrary compensation closure (runs in LIFO
        order with the rest of the journal)."""
        self._ops.append(fn)

    # -- lifecycle ----------------------------------------------------------

    def rollback(self) -> int:
        """Replay the journal in reverse; returns the op count."""
        n = len(self._ops)
        while self._ops:
            self._ops.pop()()
        self._seen.clear()
        return n


def active() -> Transaction | None:
    """The current thread's open transaction, or ``None``."""
    return getattr(_tls, "txn", None)


@contextmanager
def transaction() -> Iterator[Transaction]:
    """Open a transaction for the current thread.  The caller owns the
    abort decision: on exception the journal is rolled back and the
    exception re-raised; on success the journal is simply dropped
    (there is no redo side — state is already final)."""
    if getattr(_tls, "txn", None) is not None:
        raise RuntimeError("nested ingest transactions are not supported")
    t = Transaction()
    _tls.txn = t
    try:
        yield t
    except BaseException:
        _tls.txn = None  # mutation during rollback must not re-journal
        t.rollback()
        raise
    finally:
        _tls.txn = None
