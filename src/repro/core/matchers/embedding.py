"""Embedding-similarity matcher: batched encoder forward + score memo.

The expensive-scorer case the neighborhood decomposition exists to
amortize (the LLM-EM line of PAPERS.md): every pairwise score is a
cosine between per-entity embeddings produced by a *batched* encoder
forward pass.  The matcher keeps an append-only per-entity embedding
memo, so under stream ingest only the **dirty** (never-seen) entity ids
are re-encoded — one batched encoder invocation per matcher call, with
``encode_calls`` / ``encoded_ids`` counters the O(dirty) tests assert
against.

Three encoders:

``hash``
    A deterministic synthetic encoder: entity ids ``2m`` / ``2m + 1``
    share a bucket vector plus small per-id noise (cosine ~0.98 inside
    a bucket, ~0 across buckets).  Needs no names — works on any
    neighborhood batch — and is the default for tests/benchmarks.
``ngram``
    Character-trigram profiles (:func:`repro.core.similarity.
    ngram_profiles`) of the entity's *name*; bind the id -> name table
    with :meth:`EmbeddingMatcher.bind_names` (the streaming
    ``DeltaCover.names`` list is a valid target).
``lm``
    A real model forward: name bytes -> tokens -> prefill logits,
    mean-pooled and L2-normalized via :meth:`repro.serve.engine.Engine.
    encode` on a tiny dense LM (the otherwise-unused ``models/`` +
    ``serve/`` stack).  Ids without a bound name fall back to the hash
    embedding, keeping the encoder total and deterministic.

Well-behavedness: embeddings are deterministic per entity id and
evidence-independent, so the output ``(sim >= tau | ev_pos) & pair_mask
& ~ev_neg`` is idempotent and monotone in both evidence sets (Defs.
2/3); pairwise-independent scores make entity monotonicity hold too.
``score`` is modular (sum of ``sim - tau`` margins) hence supermodular
with equality (Def. 6).  The family emits no multi-pair messages
(labels = P), so NO-MP, SMP and MMP fixpoints coincide; on device it
registers the host-ground backend kind ``"embed"`` in
:mod:`repro.core.parallel`.
"""

from __future__ import annotations

import numpy as np

from repro.core import pairs as pairlib
from repro.core.types import NeighborhoodBatch


def _hash_embed(ids: np.ndarray, dim: int, seed: int) -> np.ndarray:
    """Deterministic per-id embedding: bucket (id // 2) + per-id noise."""
    out = np.empty((len(ids), dim), dtype=np.float32)
    for n, i in enumerate(ids):
        i = int(i)
        base = np.random.default_rng((seed, 7, i // 2)).standard_normal(dim)
        base /= np.linalg.norm(base)
        noise = np.random.default_rng((seed, 11, i)).standard_normal(dim)
        noise /= np.linalg.norm(noise)
        v = base + 0.15 * noise
        out[n] = (v / np.linalg.norm(v)).astype(np.float32)
    return out


class EmbeddingMatcher:
    """Type-II matcher scoring pairs by embedding cosine >= ``tau``."""

    is_probabilistic = True

    def __init__(self, *, encoder: str = "hash", tau: float = 0.92,
                 dim: int = 32, seed: int = 0):
        if encoder not in ("hash", "ngram", "lm"):
            raise ValueError(f"unknown encoder {encoder!r}")
        self.encoder = encoder
        self.tau = float(tau)
        self.dim = int(dim)
        self.seed = int(seed)
        self._memo: dict[int, np.ndarray] = {}  # append-only: id -> vec
        self._names: list | None = None  # id -> name view (mutated by owner)
        self._engine = None  # lazy: lm encoder only
        self.encode_calls = 0  # batched encoder invocations
        self.encoded_ids = 0  # total ids ever encoded (O(dirty) counter)

    def bind_names(self, names_ref: list) -> None:
        """Attach the id -> name table (e.g. ``DeltaCover.names``); a
        live reference, read at encode time."""
        self._names = names_ref

    # -- encoding ----------------------------------------------------------
    def _name_of(self, i: int):
        if self._names is not None and 0 <= i < len(self._names):
            return self._names[i]
        return None

    def _lm_engine(self):
        if self._engine is None:
            from repro.configs.base import ModelConfig
            from repro.models.registry import get_model
            from repro.serve.engine import demo_engine

            cfg = ModelConfig(
                name="em_encoder", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=256,
            )
            self._engine = demo_engine(
                get_model(cfg), batch=8, s_max=32, seed=self.seed
            )
        return self._engine

    def _encode_batch(self, ids: np.ndarray) -> np.ndarray:
        """One batched encoder forward over ``ids`` (all unseen)."""
        if self.encoder == "hash":
            return _hash_embed(ids, self.dim, self.seed)
        names = [self._name_of(int(i)) for i in ids]
        known = [n for n, nm in enumerate(names) if nm is not None]
        out = _hash_embed(ids, self.dim, self.seed)  # nameless fallback
        if not known:
            return out
        if self.encoder == "ngram":
            from repro.core.similarity import ngram_profiles

            vecs = ngram_profiles([names[n] for n in known], dim=self.dim)
        else:  # lm
            prompts = [
                np.frombuffer(
                    names[n].encode("utf-8", "ignore"), dtype=np.uint8
                ).astype(np.int32)[:32]
                for n in known
            ]
            prompts = [p if len(p) else np.zeros(1, np.int32) for p in prompts]
            vecs = self._lm_engine().encode(prompts)
        if vecs.shape[1] != out.shape[1]:
            out = np.zeros((len(ids), vecs.shape[1]), dtype=np.float32)
            out[:, 0] = 1.0  # nameless fallback: shared unit axis
        out[known] = vecs
        return out

    def _ensure(self, ids: np.ndarray) -> None:
        """Encode the not-yet-memoized ids in one batched call."""
        fresh = np.unique(ids[ids >= 0])
        fresh = np.array(
            [i for i in fresh if int(i) not in self._memo], dtype=np.int64
        )
        if not len(fresh):
            return
        vecs = self._encode_batch(fresh)
        self.encode_calls += 1
        self.encoded_ids += len(fresh)
        for i, v in zip(fresh, vecs):
            self._memo[int(i)] = v

    # -- grounding ---------------------------------------------------------
    def ground_rows(
        self, entity_ids: np.ndarray, pair_mask: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """(base, valid) masks for raw (B, k) id rows — the parallel
        backend's host grounding (kind ``"embed"``)."""
        ids = np.asarray(entity_ids)
        pm = np.asarray(pair_mask, dtype=bool)
        self._ensure(ids)
        B, k = ids.shape
        ii, jj = pairlib.triu_indices(k)
        dim = len(next(iter(self._memo.values()))) if self._memo else self.dim
        E = np.zeros((B, k, dim), dtype=np.float32)
        for b in range(B):
            for s in range(k):
                v = self._memo.get(int(ids[b, s]))
                if v is not None:
                    E[b, s] = v
        sims = (E[:, ii] * E[:, jj]).sum(axis=-1)
        base = (sims >= self.tau) & pm
        return base, pm

    def _sims(self, batch: NeighborhoodBatch) -> np.ndarray:
        ids = np.asarray(batch.entity_ids)
        pm = np.asarray(batch.pair_mask, dtype=bool)
        self._ensure(ids)
        k = batch.k
        ii, jj = pairlib.triu_indices(k)
        dim = len(next(iter(self._memo.values()))) if self._memo else self.dim
        E = np.zeros(ids.shape + (dim,), dtype=np.float32)
        for b in range(ids.shape[0]):
            for s in range(ids.shape[1]):
                v = self._memo.get(int(ids[b, s]))
                if v is not None:
                    E[b, s] = v
        return np.where(pm, (E[:, ii] * E[:, jj]).sum(axis=-1), -1.0)

    # -- Type-I interface --------------------------------------------------
    def run(
        self,
        batch: NeighborhoodBatch,
        ev_pos: np.ndarray | None = None,
        ev_neg: np.ndarray | None = None,
    ) -> np.ndarray:
        pm = np.asarray(batch.pair_mask, dtype=bool)
        x = self._sims(batch) >= self.tau
        if ev_pos is not None:
            x = x | np.asarray(ev_pos, dtype=bool)
        x = x & pm
        if ev_neg is not None:
            x = x & ~np.asarray(ev_neg, dtype=bool)
        return x

    def run_with_messages(
        self,
        batch: NeighborhoodBatch,
        ev_pos: np.ndarray | None = None,
        ev_neg: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        x = self.run(batch, ev_pos, ev_neg)
        B, P = x.shape
        return x, np.full((B, P), P, dtype=np.int32)

    # -- Type-II interface -------------------------------------------------
    def score(self, batch: NeighborhoodBatch, x: np.ndarray) -> np.ndarray:
        """Modular: sum of cosine margins over the selected valid pairs."""
        pm = np.asarray(batch.pair_mask, dtype=bool)
        sims = self._sims(batch)
        sel = np.asarray(x, dtype=bool) & pm
        return np.where(sel, sims - self.tau, 0.0).sum(axis=1)

    # -- parallel backend --------------------------------------------------
    def parallel_backend(self) -> tuple[str, "EmbeddingMatcher"]:
        """Host-ground backend key for the round-parallel engine."""
        return ("embed", self)
