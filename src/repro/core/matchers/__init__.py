"""Matcher plug-in registry: the paper's black-box matcher, as an API.

The framework's central claim (§3, Defs. 1–6) is that the neighborhood
decomposition + message passing scales *any* well-behaved EM algorithm.
This package is where "any" becomes concrete: a matcher family registers
itself under a name with a declared **capability surface**
(:class:`MatcherInfo`), and everything downstream — the sequential
drivers, the round-parallel engine, the streaming service, the
conformance test matrix — consumes the family through that declaration
instead of `isinstance` checks.

Capability surface (what a registration declares):

* ``type_ii`` — the family implements Def. 5: ``score(batch, x)``
  (unnormalized log P_E) and ``run_with_messages`` in addition to the
  Type-I ``run``.  MMP (Alg. 3) requires it.
* ``emits_messages`` — ``run_with_messages`` can return non-trivial
  component labels (multi-pair maximal messages, Def. 8).  Families
  whose output needs no joint activation return ``labels == P``
  everywhere; for them NO-MP, SMP and MMP have identical fixpoints.
* ``monotone_entities`` — Def. 3(i) holds (more entities never lose
  matches).  Genuinely false for 1:1 assignment families, where a new
  record can *outcompete* an old match; the property suite skips the
  checker where the family declares it cannot hold.
* ``supermodular`` — Def. 6 holds for ``score`` (hence monotone by
  Prop. 2); checked by the property suite when declared.
* ``device_parallel`` — the family exposes ``parallel_backend()``
  (a ``(kind, cfg)`` grounding key) so :mod:`repro.core.parallel` can
  cache/splice its groundings and fuse its rounds on device.

Usage::

    from repro.core.matchers import get_matcher
    matcher = get_matcher("hungarian")            # defaults
    matcher = get_matcher("embedding", encoder="ngram", tau=0.92)

Built-in families: ``mln`` / ``mln_greedy`` (the paper's collective MLN
matcher, :mod:`repro.core.mln`), ``rules`` (dedupalog-style Type-I,
:mod:`repro.core.rules`), ``hungarian`` / ``hungarian_greedy`` (optimal
vs greedy 1:1 bipartite assignment, :mod:`repro.core.matchers.
assignment`), and ``embedding`` (batched-encoder cosine scorer,
:mod:`repro.core.matchers.embedding`).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.matcher import (  # noqa: F401  (re-export: axiom surface)
    TypeIMatcher,
    TypeIIMatcher,
    check_idempotence,
    check_monotone_entities,
    check_monotone_evidence,
    check_monotone_negative,
    check_supermodular,
)


@dataclasses.dataclass(frozen=True)
class MatcherInfo:
    """One registered matcher family: factory + capability declaration."""

    name: str
    factory: Callable[..., object]
    type_ii: bool  # Def. 5: has score() / run_with_messages()
    emits_messages: bool  # can emit multi-pair maximal messages (Def. 8)
    monotone_entities: bool  # Def. 3(i) declared to hold
    supermodular: bool  # Def. 6 declared to hold for score()
    device_parallel: bool  # has parallel_backend() for core.parallel
    description: str = ""

    def build(self, **cfg):
        return self.factory(**cfg)


_REGISTRY: dict[str, MatcherInfo] = {}


def register_matcher(
    name: str,
    factory: Callable[..., object],
    *,
    type_ii: bool,
    emits_messages: bool,
    monotone_entities: bool,
    supermodular: bool,
    device_parallel: bool,
    description: str = "",
) -> MatcherInfo:
    """Register a matcher family under ``name``.

    Re-registering a name replaces the entry (latest wins) so tests can
    shadow a family with an instrumented variant.
    """
    info = MatcherInfo(
        name=name,
        factory=factory,
        type_ii=type_ii,
        emits_messages=emits_messages,
        monotone_entities=monotone_entities,
        supermodular=supermodular,
        device_parallel=device_parallel,
        description=description,
    )
    _REGISTRY[name] = info
    return info


def get_matcher(name: str, **cfg):
    """Instantiate a registered family: ``get_matcher("hungarian")``."""
    return matcher_info(name).build(**cfg)


def matcher_info(name: str) -> MatcherInfo:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown matcher family {name!r}; registered: {list_matchers()}"
        )
    return _REGISTRY[name]


def list_matchers() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# Built-in families
# --------------------------------------------------------------------------


def _mln_factory(collective: bool):
    def build(weights=None, **cfg):
        from repro.core.mln import PAPER_LEARNED, MLNMatcher

        return MLNMatcher(
            weights if weights is not None else PAPER_LEARNED,
            collective=collective,
            **cfg,
        )

    return build


def _rules_factory(**cfg):
    from repro.core.rules import RulesMatcher

    return RulesMatcher(**cfg)


def _assignment_factory(optimal: bool):
    def build(**cfg):
        from repro.core.matchers.assignment import AssignmentMatcher

        return AssignmentMatcher(optimal=optimal, **cfg)

    return build


def _embedding_factory(**cfg):
    from repro.core.matchers.embedding import EmbeddingMatcher

    return EmbeddingMatcher(**cfg)


register_matcher(
    "mln",
    _mln_factory(collective=True),
    type_ii=True,
    emits_messages=True,
    monotone_entities=True,
    supermodular=True,
    device_parallel=True,
    description="Paper's collective MLN matcher (Appendix B weights)",
)
register_matcher(
    "mln_greedy",
    _mln_factory(collective=False),
    type_ii=True,
    emits_messages=False,
    monotone_entities=True,
    supermodular=True,
    device_parallel=True,
    description="MLN closure-only ablation (no collective promotion)",
)
register_matcher(
    "rules",
    _rules_factory,
    type_ii=False,
    emits_messages=False,
    monotone_entities=False,
    supermodular=False,
    device_parallel=True,
    description="Dedupalog-style hard-rule Type-I matcher (Appendix C)",
)
register_matcher(
    "hungarian",
    _assignment_factory(optimal=True),
    type_ii=True,
    emits_messages=False,
    monotone_entities=False,  # 1:1 competition: a new record can win a slot
    supermodular=True,  # modular score => supermodular with equality
    device_parallel=False,  # host combinatorial solve; sequential drivers
    description="Optimal 1:1 bipartite assignment (Hungarian) matcher",
)
register_matcher(
    "hungarian_greedy",
    _assignment_factory(optimal=False),
    type_ii=True,
    emits_messages=False,
    monotone_entities=False,
    supermodular=True,
    device_parallel=False,
    description="Greedy mutual-best assignment baseline",
)
register_matcher(
    "embedding",
    _embedding_factory,
    type_ii=True,
    emits_messages=False,
    monotone_entities=True,  # pairwise-independent scores
    supermodular=True,  # modular score
    device_parallel=True,  # host-ground backend kind "embed"
    description="Embedding-similarity matcher (batched encoder forward)",
)

__all__ = [
    "MatcherInfo",
    "TypeIMatcher",
    "TypeIIMatcher",
    "check_idempotence",
    "check_monotone_entities",
    "check_monotone_evidence",
    "check_monotone_negative",
    "check_supermodular",
    "get_matcher",
    "list_matchers",
    "matcher_info",
    "register_matcher",
]
