"""Optimal 1:1 bipartite assignment matcher (Hungarian + greedy baseline).

The record-linkage scenario the clustering formulation never touches:
two record sources where every source is internally duplicate-free, so
the right output is a *matching* — each record pairs with at most one
partner — not a transitive cluster.  Sides are encoded by global entity
id parity (even = left source, odd = right source), the convention the
``repro.data.synthetic.make_bipartite`` generator emits.

Edge weights combine the cover's similarity level with the coauthor
signal of the paper's R2 rule::

    w(p) = sim_level(p) + beta * n_shared(p)        (admissible if
                                                     w >= tau and the
                                                     endpoints straddle
                                                     the two sources)

The **optimal** variant solves max-weight bipartite matching per
neighborhood (Hungarian / `scipy.optimize.linear_sum_assignment`, with
an exact bitmask-DP fallback when scipy is absent — neighborhood sides
are <= k_max/2); the **greedy** variant picks admissible edges in
descending weight, skipping used endpoints — the classic baseline the
`benchmarks/fig4_matchers.py` crossing traps separate from the optimum.

Well-behavedness (Defs. 2/3) by construction: the assignment ``A`` is
computed *evidence-independently* from the batch, and the output is the
monotone post-filter ``(A | ev_pos) & valid & ~ev_neg`` — idempotent
(a second run over its own output adds nothing) and monotone in both
evidence sets.  What 1:1 competition fundamentally breaks is Def. 3(i)
entity monotonicity — a newly arrived record can *win* a slot an old
match held — so the family registers ``monotone_entities=False`` and
the streaming deployment contract is group-atomic arrival (all records
of a matching group land in one micro-batch; see ``make_bipartite``).

``score`` is modular — the sum of admissible-edge margins ``w - tau``
over the selected valid pairs — hence supermodular (Def. 6) with
equality, making the family Type-II and MMP-eligible (it simply emits
no multi-pair messages: labels are the trivial ``P`` everywhere, so
NO-MP, SMP and MMP fixpoints coincide).
"""

from __future__ import annotations

import numpy as np

from repro.core import pairs as pairlib
from repro.core.mln import ground_structure
from repro.core.types import NeighborhoodBatch


def _solve_optimal(W: np.ndarray) -> list[tuple[int, int]]:
    """Max-weight bipartite matching on ``W >= 0`` (0 = forbidden edge).

    Returns the selected (row, col) pairs with positive weight.  All
    admissible weights are >= tau > 0, so maximizing with forbidden
    edges at weight 0 and dropping zero-weight selections afterwards is
    exactly max-weight matching over admissible edges.
    """
    try:
        from scipy.optimize import linear_sum_assignment
    except ImportError:
        return _solve_dp(W)
    ri, ci = linear_sum_assignment(W, maximize=True)
    return [(int(i), int(j)) for i, j in zip(ri, ci) if W[i, j] > 0.0]


def _solve_dp(W: np.ndarray) -> list[tuple[int, int]]:
    """Exact bitmask-DP fallback (no scipy): O(nl * 2^nr * nr).

    Neighborhood sides are bounded by k_max/2 (<= 16 at the default
    bins), which keeps the right-side mask space tractable.
    """
    nl, nr = W.shape
    flip = nr > nl
    if flip:
        W = W.T
        nl, nr = W.shape
    if nr > 20:  # pragma: no cover - guarded by k_max
        raise ValueError(f"assignment side {nr} too large for DP fallback")
    full = 1 << nr
    NEG = -1.0e18
    dp = np.full(full, NEG, dtype=np.float64)
    dp[0] = 0.0
    choice = np.full((nl, full), -1, dtype=np.int32)
    masks = np.arange(full, dtype=np.int64)
    for i in range(nl):
        ndp = dp.copy()  # default: left i unassigned
        for j in range(nr):
            if W[i, j] <= 0.0:
                continue
            bit = 1 << j
            src = masks[(masks & bit) == 0]
            cand = dp[src] + W[i, j]
            dst = src | bit
            better = cand > ndp[dst] + 1e-12
            ndp[dst[better]] = cand[better]
            choice[i, dst[better]] = j
        dp = ndp
    mask = int(np.argmax(dp))
    out = []
    for i in range(nl - 1, -1, -1):
        j = int(choice[i, mask])
        if j >= 0:
            out.append((j, i) if flip else (i, j))
            mask ^= 1 << j
    return out


def _solve_greedy(
    W: np.ndarray, keys: np.ndarray
) -> list[tuple[int, int]]:
    """Descending-weight greedy matching; ``keys`` breaks ties
    deterministically (ascending)."""
    ri, ci = np.nonzero(W > 0.0)
    order = np.lexsort((keys[ri, ci], -W[ri, ci]))
    used_l: set[int] = set()
    used_r: set[int] = set()
    out = []
    for e in order:
        i, j = int(ri[e]), int(ci[e])
        if i in used_l or j in used_r:
            continue
        used_l.add(i)
        used_r.add(j)
        out.append((i, j))
    return out


class AssignmentMatcher:
    """1:1 bipartite assignment matcher (``optimal=False`` for greedy).

    Host-only: the per-neighborhood combinatorial solve has no device
    grounding, so the family runs through the sequential drivers
    (``run_nomp``/``run_smp``/``run_mmp``); ``run_parallel`` rejects it
    with a TypeError naming the device-capable families.
    """

    is_probabilistic = True  # Type-II: has score()

    def __init__(self, *, optimal: bool = True, tau: float = 1.0,
                 beta: float = 0.25):
        self.optimal = optimal
        self.tau = float(tau)
        self.beta = float(beta)

    # -- weights ----------------------------------------------------------
    def _weights(self, batch: NeighborhoodBatch):
        """(w, admissible, valid): admissible edges straddle the parity
        sides and clear tau; all evidence-independent."""
        lev, valid, n_shared, _link = ground_structure(batch)
        lev = np.asarray(lev)
        valid = np.asarray(valid)
        n_shared = np.asarray(n_shared)
        ids = np.asarray(batch.entity_ids)
        k = batch.k
        ii, jj = pairlib.triu_indices(k)
        par = (ids % 2).astype(np.int8)  # 0 = left source, 1 = right
        straddles = par[:, ii] != par[:, jj]
        w = lev.astype(np.float64) + self.beta * n_shared.astype(np.float64)
        admissible = valid & straddles & (w >= self.tau) & (lev >= 1)
        return w, admissible, valid

    def _assignment(self, batch: NeighborhoodBatch) -> np.ndarray:
        """Evidence-independent per-neighborhood matching mask (B, P)."""
        w, admissible, _valid = self._weights(batch)
        ids = np.asarray(batch.entity_ids)
        B, P = w.shape
        ii, jj = pairlib.triu_indices(batch.k)
        base = np.zeros((B, P), dtype=bool)
        for b in range(B):
            ps = np.nonzero(admissible[b])[0]
            if not len(ps):
                continue
            # left slot = the even-id endpoint of each admissible edge
            li = np.where(ids[b, ii[ps]] % 2 == 0, ii[ps], jj[ps])
            rj = np.where(ids[b, ii[ps]] % 2 == 0, jj[ps], ii[ps])
            lslots = sorted(set(int(s) for s in li))
            rslots = sorted(set(int(s) for s in rj))
            lof = {s: x for x, s in enumerate(lslots)}
            rof = {s: x for x, s in enumerate(rslots)}
            W = np.zeros((len(lslots), len(rslots)), dtype=np.float64)
            keys = np.zeros_like(W, dtype=np.int64)
            pmap: dict[tuple[int, int], int] = {}
            for p, ls, rs in zip(ps, li, rj):
                e = (lof[int(ls)], rof[int(rs)])
                W[e] = w[b, p]
                keys[e] = p
                pmap[e] = int(p)
            pairs = (_solve_optimal(W) if self.optimal
                     else _solve_greedy(W, keys))
            for e in pairs:
                base[b, pmap[e]] = True
        return base

    # -- Type-I interface -------------------------------------------------
    def run(
        self,
        batch: NeighborhoodBatch,
        ev_pos: np.ndarray | None = None,
        ev_neg: np.ndarray | None = None,
    ) -> np.ndarray:
        _w, _adm, valid = self._weights(batch)
        x = self._assignment(batch)
        if ev_pos is not None:
            x = x | np.asarray(ev_pos, dtype=bool)
        x = x & valid
        if ev_neg is not None:
            x = x & ~np.asarray(ev_neg, dtype=bool)
        return x

    def run_with_messages(
        self,
        batch: NeighborhoodBatch,
        ev_pos: np.ndarray | None = None,
        ev_neg: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        x = self.run(batch, ev_pos, ev_neg)
        B, P = x.shape
        return x, np.full((B, P), P, dtype=np.int32)

    # -- Type-II interface ------------------------------------------------
    def score(self, batch: NeighborhoodBatch, x: np.ndarray) -> np.ndarray:
        """Modular: sum of admissible-edge margins over selected pairs."""
        w, admissible, _valid = self._weights(batch)
        sel = np.asarray(x, dtype=bool) & admissible
        return np.where(sel, w - self.tau, 0.0).sum(axis=1)
