"""The MLN collective entity matcher (paper §2.1, Appendix B) in JAX.

The matcher is the paper's Markov-Logic-Network matcher [Singla & Domingos
2006] restricted to the monotone/supermodular rule class of Appendix A
(Prop. 4: a single ``Match`` term in each implicant) — the exact class for
which the paper's soundness theory holds.

Grounding.  For a neighborhood with entity slots ``0..k-1`` and candidate
pairs ``p = (i, j)`` on the upper triangle (``P = k(k-1)/2`` slots), the
rule set (Appendix B)::

    similar(e1,e2,L)  => equals(e1,e2)                      w_sim[L]
    coauthor(e1,c1) & coauthor(e2,c2) & equals(c1,c2)
                      => equals(e1,e2)                      w_co

grounds to a supermodular pseudo-Boolean objective over x in {0,1}^P ::

    f(x) = sum_p u_p x_p  +  1/2 sum_{p != q} C_pq x_p x_q

    u_p  = w_sim[level_p] + w_co * n_shared(p)      (reflexive Match(d,d))
    C_pq = w_co * link(p, q)

where ``n_shared(p)`` counts shared coauthors of the pair and
``link(p, q)`` is 1 iff matching q fires the coauthor rule for p (one
firing per unordered coupled pair — this follows the paper's §2.1/§2.2
arithmetic: the -10 + 8 and -15 + 16 examples).  All couplings are
nonnegative, hence ``P(S) ~ exp f(S)`` is supermodular (Def. 6) and the
matcher is monotone Type-I (Prop. 2).

MAP inference (the Alchemy/MaxWalkSAT replacement — see DESIGN §3).
TPU-native, branch-free, fixed shape:

  1. *closure*: repeated conditional-delta sweeps ``delta = u + C @ x``
     activating every pair with positive delta (monotone; never
     deactivates) — ``jax.lax.while_loop`` of batched mat-vecs.
  2. *collective promotion*: connected components of the mutual
     entailment graph among still-inactive pairs (the same graph
     COMPUTEMAXIMAL builds), greedily *peeled* of negative-marginal
     members, then activated wholesale when the joint delta is >= 0
     (ties prefer the larger set, per the Type-II output definition).
  3. repeat 1+2 to fixpoint.

Step 2 is what makes the matcher *purely collective* (the paper's
{(a1,a2),(b2,b3),(c2,c3)} chain matches jointly even though every single
pair has negative delta).  The entailment matrix is one (P,P)@(P,P)
matmul per sweep — MXU work, backed by the ``mln_score``/``icm_sweep``
Pallas kernels on TPU.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pairs as pairlib
from repro.core.types import NeighborhoodBatch
from repro.kernels.icm_sweep import ops as icm_ops
from repro.kernels.mln_score import ops as score_ops

NEG = -1.0e9  # unary for invalid / padded pairs
TIE_EPS = 1.0e-5  # "delta >= 0" tolerance (largest-tie preference)


@dataclasses.dataclass(frozen=True)
class MLNWeights:
    """Rule weights. w_sim[0] unused (level 0 = not a candidate)."""

    w_sim: tuple[float, float, float, float]
    w_co: float

    def as_arrays(self):
        return (
            jnp.asarray(self.w_sim, dtype=jnp.float32),
            jnp.float32(self.w_co),
        )


# Appendix B, learned with Alchemy on the bibliographic data.
PAPER_LEARNED = MLNWeights(w_sim=(0.0, -2.28, -3.84, 12.75), w_co=2.46)
# §2.1 pedagogical weights (R1 = -5, R2 = +8), used by the Fig. 1/2 tests.
PEDAGOGICAL = MLNWeights(w_sim=(0.0, -5.0, -5.0, -5.0), w_co=8.0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Grounding:
    """Dense grounded MLN for a batch of neighborhoods."""

    u: jax.Array  # (B, P) f32, NEG where invalid
    u_raw: jax.Array  # (B, P) f32, 0 where invalid (for scoring)
    C: jax.Array  # (B, P, P) f32, symmetric, zero diag, >= 0
    valid: jax.Array  # (B, P) bool

    def tree_flatten(self):
        return (self.u, self.u_raw, self.C, self.valid), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def ground_structure(batch: NeighborhoodBatch):
    """Weight-independent grounded structure of a neighborhood batch.

    Returns (lev, valid, n_shared, link):
      lev      (B, P) int32   similarity level (0 = not a candidate)
      valid    (B, P) bool    candidate-pair validity
      n_shared (B, P) f32     shared-coauthor count (reflexive Match(d,d))
      link     (B, P, P) f32  1 iff matching q fires the coauthor rule
                              for p (zero diagonal, masked to valid pairs)
    Shared by the MLN (weights applied on top) and RULES matchers.
    """
    k = batch.k
    ii, jj = pairlib.triu_indices(k)
    ii = jnp.asarray(ii)
    jj = jnp.asarray(jj)

    co = jnp.asarray(batch.coauthor, dtype=jnp.float32)  # (B, k, k)
    # Defensive: no self-coauthorship, no padded-slot edges.
    emask = jnp.asarray(batch.entity_mask, dtype=jnp.float32)
    co = co * emask[:, :, None] * emask[:, None, :]
    co = co * (1.0 - jnp.eye(k, dtype=jnp.float32))

    lev = jnp.asarray(batch.sim_level, dtype=jnp.int32)  # (B, P)
    valid = jnp.asarray(batch.pair_mask) & (lev > 0)

    # Reflexive boost: n_shared[b, p] = |{d : co(i,d) & co(j,d)}|.
    shared = jnp.einsum("bid,bjd->bij", co, co)  # (B, k, k) counts
    n_shared = shared[:, ii, jj]  # (B, P)
    n_shared = jnp.where(valid, n_shared, 0.0)

    # Couplings: link(p, q) = (co[ip,iq] & co[jp,jq]) | (co[ip,jq] & co[jp,iq])
    co_i = co[:, ii, :]  # (B, P, k)  coauthor rows of first endpoints
    co_j = co[:, jj, :]  # (B, P, k)  coauthor rows of second endpoints
    co_ii = co_i[:, :, ii]  # (B, P, P): co[i_p, i_q]
    co_jj = co_j[:, :, jj]  # co[j_p, j_q]
    co_ij = co_i[:, :, jj]  # co[i_p, j_q]
    co_ji = co_j[:, :, ii]  # co[j_p, i_q]
    link = jnp.clip(co_ii * co_jj + co_ij * co_ji, 0.0, 1.0)
    vf = valid.astype(jnp.float32)
    pmask2 = vf[:, :, None] * vf[:, None, :]
    P = len(pairlib.triu_indices(k)[0])
    link = link * pmask2 * (1.0 - jnp.eye(P, dtype=jnp.float32))
    return lev, valid, n_shared, link


def ground(
    batch: NeighborhoodBatch, weights: MLNWeights
) -> Grounding:
    """Ground the MLN rules on a padded neighborhood batch (jnp)."""
    w_sim, w_co = weights.as_arrays()
    lev, valid, n_shared, link = ground_structure(batch)

    u_raw = jnp.take(w_sim, lev) + w_co * n_shared
    u_raw = jnp.where(valid, u_raw, 0.0)
    u = jnp.where(valid, u_raw, NEG)
    C = w_co * link

    return Grounding(u=u, u_raw=u_raw, C=C, valid=valid)


# ---------------------------------------------------------------------------
# Inference primitives (single neighborhood; vmapped over the batch)
# ---------------------------------------------------------------------------


def _closure(u, C, ev_pos, ev_neg, valid):
    """Monotone greedy closure from ev_pos; ev_neg frozen off. (P,) bool."""
    x0 = ev_pos & valid & ~ev_neg

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        x, _ = state
        delta = icm_ops.sweep(u, C, x.astype(jnp.float32))
        # ">= -TIE_EPS": zero-delta additions keep the score and the
        # Type-II output prefers the larger set among ties.  Sound for
        # supermodular f: marginal(p | x) >= 0 and x subset of the optimum
        # O imply marginal(p | O) >= 0, hence p in O (tie-larger unique O).
        new = (delta >= -TIE_EPS) & valid & ~ev_neg
        x2 = x | new | (ev_pos & valid)
        return x2, jnp.any(x2 != x)

    x, _ = jax.lax.while_loop(cond, body, (x0, jnp.bool_(True)))
    return x


def closure_batch(u, C, ev_pos, ev_neg, valid):
    """Monotone greedy closure for a whole bin in one ``while_loop``.

    All arguments are batched ``(B, P)`` / ``(B, P, P)``; each iteration
    is a single batched conditional-delta sweep (``icm_ops.sweep_batch``)
    and the loop runs until *every* neighborhood is converged — exactly
    the semantics of ``vmap(_closure)`` (the extra iterations a converged
    lane sees are idempotent: the closure is monotone), but with one
    MXU-shaped contraction per iteration instead of B lane-wise sweeps.
    This is the round body the fused device-resident engine
    (:mod:`repro.core.parallel`) keeps inside its multi-round loop.
    """
    x0 = ev_pos & valid & ~ev_neg

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        x, _ = state
        delta = icm_ops.sweep_batch(u, C, x.astype(jnp.float32))
        new = (delta >= -TIE_EPS) & valid & ~ev_neg
        x2 = x | new | (ev_pos & valid)
        return x2, jnp.any(x2 != x)

    x, _ = jax.lax.while_loop(cond, body, (x0, jnp.bool_(True)))
    return x


def _entailment_matrix(u, C, x, ev_neg, valid):
    """X[s, q] = 1 iff q in closure(x U {s}), for every seed pair s.

    One batched closure over the seed axis: (P, P) @ (P, P) matmuls.
    """
    P = u.shape[0]
    eye = jnp.eye(P, dtype=bool)
    seeds = eye & valid[None, :] & ~ev_neg[None, :] & ~x[None, :]
    X0 = seeds | x[None, :]

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        X, _ = state
        delta = icm_ops.sweep_matrix(u, C, X.astype(jnp.float32))
        new = (delta >= -TIE_EPS) & valid[None, :] & ~ev_neg[None, :]
        X2 = X | new | X0
        return X2, jnp.any(X2 != X)

    X, _ = jax.lax.while_loop(cond, body, (X0, jnp.bool_(True)))
    return X, seeds


def _components(adj, nodes):
    """Min-label propagation. adj (P,P) bool symmetric, nodes (P,) bool.

    Returns labels (P,) int32: equal labels <=> same component; invalid
    nodes get label P (out of band).
    """
    P = adj.shape[0]
    big = jnp.int32(P)
    lab0 = jnp.where(nodes, jnp.arange(P, dtype=jnp.int32), big)
    adj = adj & nodes[:, None] & nodes[None, :]

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        lab, _ = state
        nbr = jnp.where(adj, lab[None, :], big)
        lab2 = jnp.minimum(lab, jnp.min(nbr, axis=1))
        return lab2, jnp.any(lab2 != lab)

    lab, _ = jax.lax.while_loop(cond, body, (lab0, jnp.bool_(True)))
    return lab


def _peel_and_promote(u, C, x, lab, valid, ev_neg):
    """Greedy-peel each component, activate those with joint delta >= 0.

    Group matrix G[l, p] = 1 iff lab[p] == l (l ranges over pair slots;
    component labels are min member indices so G rows are mostly empty).
    Peeling: drop members with negative marginal (u + C@(x + s))_p until
    none; then activate components whose joint delta >= -TIE_EPS.
    """
    P = u.shape[0]
    labels = jnp.arange(P, dtype=jnp.int32)
    undecided = valid & ~x & ~ev_neg
    G0 = (lab[None, :] == labels[:, None]) & undecided[None, :]  # (P_l, P)

    xf = x.astype(jnp.float32)
    base = u + C @ xf  # (P,) marginal from already-active set

    def peel_body(state):
        G, i, _ = state
        Gf = G.astype(jnp.float32)
        # marginal of member p of group l: base_p + (C @ s_l)_p
        marg = base[None, :] + Gf @ C  # (P_l, P)
        drop = G & (marg < 0.0)
        # drop only the single worst member per group per iteration
        worst = jnp.argmin(jnp.where(drop, marg, jnp.inf), axis=1)
        any_drop = jnp.any(drop, axis=1)
        onehot = jax.nn.one_hot(worst, P, dtype=bool)
        return G & ~(onehot & any_drop[:, None]), i + 1, jnp.any(any_drop)

    # Peeling drops at most one member per group per iteration; component
    # size is bounded by the neighborhood entity count k ~ sqrt(2P).  The
    # loop exits as soon as an iteration drops nothing (further
    # iterations are idempotent, so this is exactly the bounded-unroll
    # result) — on an already-converged group matrix the peel costs ONE
    # (P, P) matmul instead of ~sqrt(2P) of them, which is what makes
    # quiescence-check rounds cheap.
    peel_iters = int(np.ceil(np.sqrt(2 * P))) + 2

    def peel_cond(state):
        _, i, changed = state
        return changed & (i < peel_iters)

    G, _, _ = jax.lax.while_loop(
        peel_cond, peel_body, (G0, jnp.int32(0), jnp.bool_(True))
    )

    Gf = G.astype(jnp.float32)
    lin = Gf @ base  # (P_l,)
    quad = 0.5 * jnp.sum((Gf @ C) * Gf, axis=1)
    delta = lin + quad
    size = jnp.sum(G, axis=1)
    promote = (delta >= -TIE_EPS) & (size > 0)
    newx = jnp.any(G & promote[:, None], axis=0)
    return x | newx


def _infer_one(u, u_raw, C, ev_pos, ev_neg, valid):
    """Full MAP inference for one neighborhood. Returns (x, lab).

    x   : (P,) bool final match set (includes evidence).
    lab : (P,) int32 entailment-component labels of *undecided* pairs
          (the maximal messages), P where not applicable.
    """

    def round_body(state):
        x, _, _ = state
        x1 = _closure(u, C, ev_pos | x, ev_neg, valid)
        X, seeds = _entailment_matrix(u, C, x1, ev_neg, valid)
        mutual = X & X.T
        undecided = valid & ~x1 & ~ev_neg
        lab = _components(mutual, undecided)
        x2 = _peel_and_promote(u, C, x1, lab, valid, ev_neg)
        x3 = _closure(u, C, x2 | ev_pos, ev_neg, valid)
        return x3, lab, jnp.any(x3 != x)

    def cond(state):
        _, _, changed = state
        return changed

    x0 = jnp.zeros_like(valid)
    state = (x0, jnp.full(valid.shape, valid.shape[0], jnp.int32), jnp.bool_(True))
    # bounded outer fixpoint: while_loop with an explicit change flag
    x, lab, _ = jax.lax.while_loop(cond, round_body, state)
    return x, lab


@functools.lru_cache(maxsize=None)
def _jitted_infer():
    batched = jax.vmap(_infer_one, in_axes=(0, 0, 0, 0, 0, 0))
    return jax.jit(batched)


@functools.lru_cache(maxsize=None)
def _jitted_score():
    def f(u_raw, C, x):
        return score_ops.score_sets(u_raw, C, x[:, None, :].astype(jnp.float32))[:, 0]

    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _jitted_closure_only():
    return jax.jit(closure_batch)


# ---------------------------------------------------------------------------
# Public matcher
# ---------------------------------------------------------------------------


class MLNMatcher:
    """Supermodular Type-II matcher over padded neighborhood batches.

    run(batch, ev_pos, ev_neg)          -> match mask (B, P) bool [Type-I out]
    run_with_messages(batch, ...)       -> (match mask, component labels)
    score(batch, x)                     -> unnormalized log P_E (B,)
    closure_only(batch, ev_pos, ev_neg) -> greedy-only variant (ablation /
                                           the iterative matchers of App. A)
    """

    def __init__(self, weights: MLNWeights = PAPER_LEARNED, collective: bool = True):
        self.weights = weights
        self.collective = collective

    # -- grounding ---------------------------------------------------------
    def ground(self, batch: NeighborhoodBatch) -> Grounding:
        return ground(batch, self.weights)

    def parallel_backend(self) -> tuple[str, MLNWeights]:
        """Grounding key for the round-parallel engine (core.parallel)."""
        return ("mln", self.weights)

    # -- Type-I interface ---------------------------------------------------
    def run(
        self,
        batch: NeighborhoodBatch,
        ev_pos: np.ndarray | None = None,
        ev_neg: np.ndarray | None = None,
    ) -> np.ndarray:
        x, _ = self.run_with_messages(batch, ev_pos, ev_neg)
        return x

    def run_with_messages(
        self,
        batch: NeighborhoodBatch,
        ev_pos: np.ndarray | None = None,
        ev_neg: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        g = self.ground(batch)
        B, P = g.u.shape
        ev_pos = self._mask(ev_pos, (B, P))
        ev_neg = self._mask(ev_neg, (B, P))
        if self.collective:
            x, lab = _jitted_infer()(g.u, g.u_raw, g.C, ev_pos, ev_neg, g.valid)
        else:
            x = _jitted_closure_only()(g.u, g.C, ev_pos, ev_neg, g.valid)
            lab = jnp.full((B, P), P, dtype=jnp.int32)
        return np.asarray(x), np.asarray(lab)

    # -- Type-II interface ---------------------------------------------------
    def score(self, batch: NeighborhoodBatch, x: np.ndarray) -> np.ndarray:
        """Unnormalized log P_E(x) per neighborhood (exact, cheap)."""
        g = self.ground(batch)
        return np.asarray(_jitted_score()(g.u_raw, g.C, jnp.asarray(x)))

    def closure_only(self, batch, ev_pos=None, ev_neg=None) -> np.ndarray:
        g = self.ground(batch)
        B, P = g.u.shape
        ev_pos = self._mask(ev_pos, (B, P))
        ev_neg = self._mask(ev_neg, (B, P))
        return np.asarray(_jitted_closure_only()(g.u, g.C, ev_pos, ev_neg, g.valid))

    @staticmethod
    def _mask(m, shape) -> jax.Array:
        if m is None:
            return jnp.zeros(shape, dtype=bool)
        return jnp.asarray(m, dtype=bool)
