"""Evaluation metrics (paper §2.2.1 and §6.1).

Two families:

* *Accuracy vs. ground truth* — precision / recall / F1 of a match set
  against the true entity labeling (``EntityTable.truth``).  Recall is
  measured over the true-duplicate pairs that are **candidates** (share
  a similarity level >= 1), matching the paper's setup where blocking
  defines the decision universe (1.3M decisions for HEPTH).
* *Framework properties vs. a reference run* — soundness (fraction of
  M(E) also in E(E)) and completeness (fraction of E(E) recovered by
  M(E)), per §2.2.1 Defs. 1-2.

Naming note: these are the paper's *match-quality* metrics.  Runtime
observability — counters, latency histograms, tracing spans,
device-transfer accounting — lives in :mod:`repro.obs` (its registry is
:mod:`repro.obs.registry`); this module is re-exported there as
:mod:`repro.obs.quality` so "metrics" stops meaning two different
things at the same import depth.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import pairs as pairlib
from repro.core.types import MatchStore


@dataclasses.dataclass
class PRF:
    precision: float
    recall: float
    f1: float
    n_pred: int
    n_true: int

    def row(self, name: str) -> str:
        return (
            f"{name},{self.precision:.4f},{self.recall:.4f},{self.f1:.4f},"
            f"{self.n_pred},{self.n_true}"
        )


def true_pair_gids(truth: np.ndarray, candidate_gids: np.ndarray | None = None) -> np.ndarray:
    """gids of all true-duplicate pairs; optionally restricted to candidates."""
    truth = np.asarray(truth, dtype=np.int64)
    order = np.argsort(truth, kind="stable")
    sorted_t = truth[order]
    out: list[np.ndarray] = []
    start = 0
    n = len(truth)
    while start < n:
        end = start
        while end < n and sorted_t[end] == sorted_t[start]:
            end += 1
        if sorted_t[start] >= 0 and end - start >= 2:
            members = order[start:end]
            ii, jj = np.triu_indices(end - start, k=1)
            out.append(pairlib.make_gid(members[ii], members[jj]))
        start = end
    gids = np.unique(np.concatenate(out)) if out else np.zeros(0, dtype=np.int64)
    if candidate_gids is not None:
        gids = gids[np.isin(gids, candidate_gids)]
    return gids


def prf(matches: MatchStore, truth: np.ndarray, candidate_gids: np.ndarray | None = None) -> PRF:
    true_gids = true_pair_gids(truth, candidate_gids)
    pred = matches.gids
    if len(pred) == 0:
        return PRF(1.0, 0.0, 0.0, 0, len(true_gids))
    hits = int(np.isin(pred, true_gids).sum())
    p = hits / len(pred)
    r = hits / max(len(true_gids), 1)
    f1 = 0.0 if p + r == 0 else 2 * p * r / (p + r)
    return PRF(p, r, f1, len(pred), len(true_gids))


def soundness(m: MatchStore, ref: MatchStore) -> float:
    """Fraction of M(E) that is also in E(E). 1.0 when M(E) is empty."""
    if len(m) == 0:
        return 1.0
    return float(np.isin(m.gids, ref.gids).sum() / len(m))


def completeness(m: MatchStore, ref: MatchStore) -> float:
    """Fraction of E(E) recovered by M(E). 1.0 when E(E) is empty."""
    if len(ref) == 0:
        return 1.0
    return float(np.isin(ref.gids, m.gids).sum() / len(ref))
