"""String similarity: Jaro-Winkler (paper-faithful) + hashed n-gram profiles.

The paper (Appendix B) computes Jaro-Winkler between author names and
discretizes to levels {1, 2, 3}.  We implement exact Jaro-Winkler on the
host for grounding the MLN, and hashed character-n-gram count profiles so
that *blocking* (canopies) runs as dense linear algebra on the TPU via the
``ngram_sim`` Pallas kernel (cosine over profiles).
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Jaro-Winkler (exact, scalar; vectorized drivers below)
# ---------------------------------------------------------------------------


def jaro(s1: str, s2: str) -> float:
    if s1 == s2:
        return 1.0
    len1, len2 = len(s1), len(s2)
    if len1 == 0 or len2 == 0:
        return 0.0
    match_dist = max(len1, len2) // 2 - 1
    match_dist = max(match_dist, 0)
    s1_matches = [False] * len1
    s2_matches = [False] * len2
    matches = 0
    for i, c1 in enumerate(s1):
        lo = max(0, i - match_dist)
        hi = min(len2, i + match_dist + 1)
        for j in range(lo, hi):
            if s2_matches[j] or s2[j] != c1:
                continue
            s1_matches[i] = True
            s2_matches[j] = True
            matches += 1
            break
    if matches == 0:
        return 0.0
    # transpositions
    t = 0
    j = 0
    for i in range(len1):
        if not s1_matches[i]:
            continue
        while not s2_matches[j]:
            j += 1
        if s1[i] != s2[j]:
            t += 1
        j += 1
    t //= 2
    m = float(matches)
    return (m / len1 + m / len2 + (m - t) / m) / 3.0


def jaro_winkler(s1: str, s2: str, p: float = 0.1, max_prefix: int = 4) -> float:
    j = jaro(s1, s2)
    prefix = 0
    for c1, c2 in zip(s1, s2):
        if c1 != c2 or prefix >= max_prefix:
            break
        prefix += 1
    return j + prefix * p * (1.0 - j)


def name_key(name: str) -> str:
    """Surname-first comparison form ("peter wesjor" -> "wesjor peter").

    Jaro-Winkler boosts common *prefixes*; on "first last" order that
    makes "hans quihom" ~ "hans mordin" score 0.8+ (same first name,
    different person).  Bibliographic matching compares surname-first,
    which puts the discriminating token in the prefix.
    """
    t = name.lower().split()
    if len(t) < 2:
        return name.lower()
    return " ".join([t[-1]] + t[:-1])


def block_key(name: str) -> str:
    """Canopy/blocking normal form: "surname first-initial".

    Abbreviated and full forms of one author map to the same key
    ("alessandro rossi" and "a. rossi" -> "rossi a"), so the canopy
    groups them; n-gram cosine on raw strings fails exactly there (the
    long first name dominates the profile).
    """
    t = name.lower().replace(".", "").split()
    if len(t) < 2:
        return name.lower()
    return f"{t[-1]} {t[0][0]}"


def first_name_conflict(a: str, b: str) -> bool:
    """Veto: two *full* (unabbreviated) first names that are genuinely
    different people ("james habsuni" vs "hans habsuni" — the surname
    prefix makes raw JW land at level 2, but no amount of coauthor
    evidence should merge them).  Typo variants ("david"/"davib") keep
    a high first-name JW and are not vetoed; abbreviated forms are
    handled by :func:`abbrev_compatible` instead.
    """
    ta, tb = a.lower().split(), b.lower().split()
    if len(ta) < 2 or len(tb) < 2:
        return False
    fa, fb = ta[0].rstrip("."), tb[0].rstrip(".")
    if not fa or not fb:
        return False
    if fa[0] != fb[0]:
        return True  # "j." can never abbreviate "hans"
    if len(fa) <= 1 or len(fb) <= 1:
        return False  # abbreviated, same initial: compatible
    # typo variants ("david"/"davib") sit at ~0.87+; unrelated first
    # names ("james"/"hans") at ~0.78 and below
    return jaro_winkler(fa, fb) < 0.84


def jw_matrix(names_a: list[str], names_b: list[str]) -> np.ndarray:
    out = np.zeros((len(names_a), len(names_b)), dtype=np.float32)
    for i, a in enumerate(names_a):
        for j, b in enumerate(names_b):
            out[i, j] = jaro_winkler(a, b)
    return out


# ---------------------------------------------------------------------------
# Discretization (paper: similarity in {1,2,3}, 3 = most similar)
# ---------------------------------------------------------------------------

# Levels are *candidate* thresholds: below LEVEL1 the pair is not a
# candidate at all (it never enters a Similar() tuple).
# Calibrated on the surname-first JW score distributions of the
# synthetic HEPTH/DBLP generators (true-pair 10%-quantile ~0.90; false-
# pair 99.5%-quantile ~0.95): level 3 = outright match, level 2 = needs
# two coauthor firings, level 1 = weak candidate (one coauthor).
DEFAULT_THRESHOLDS = (0.86, 0.93, 0.96)  # level >=1, >=2, >=3


def abbrev_compatible(a: str, b: str) -> bool:
    """Abbreviation-aware weak-candidate test ("j. doe" ~ "john doe").

    True iff one name is an initial form of the other: same surname,
    same first initial, and at least one side abbreviated.  Such pairs
    enter the Similar relation at level 1 only — a *weak* candidate
    (negative w_sim[1]) that matches only with coauthor support, which
    is exactly the disambiguation the collective matcher provides
    ("J. Doe" is ambiguous between "John Doe" and "Jane Doe" until a
    matching coauthor appears — paper App. D).
    """
    ta, tb = a.lower().split(), b.lower().split()
    if len(ta) < 2 or len(tb) < 2 or ta[-1] != tb[-1]:
        return False
    fa, fb = ta[0].rstrip("."), tb[0].rstrip(".")
    if not fa or not fb or fa[0] != fb[0]:
        return False
    abbrev = len(fa) == 1 or len(fb) == 1
    return abbrev and fa != fb


def discretize(sim: np.ndarray, thresholds=DEFAULT_THRESHOLDS) -> np.ndarray:
    t1, t2, t3 = thresholds
    lev = np.zeros(sim.shape, dtype=np.int8)
    lev[sim >= t1] = 1
    lev[sim >= t2] = 2
    lev[sim >= t3] = 3
    return lev


# ---------------------------------------------------------------------------
# Hashed character n-gram profiles (TPU-friendly blocking features)
# ---------------------------------------------------------------------------


def ngram_profiles(
    names: list[str], dim: int = 128, n: int = 3, seed: int = 0
) -> np.ndarray:
    """(N, dim) float32 L2-normalized hashed n-gram count vectors.

    Dense, fixed width => canopy similarity becomes A @ A.T on the MXU.
    ``dim`` is a multiple of 128 so kernel tiles are lane-aligned.
    """
    mask = (1 << 64) - 1
    rng_mix = 0x9E3779B97F4A7C15 ^ seed
    out = np.zeros((len(names), dim), dtype=np.float32)
    for idx, name in enumerate(names):
        s = "^" + name.lower() + "$"
        for i in range(max(1, len(s) - n + 1)):
            g = s[i : i + n]
            h = 1469598103934665603
            for ch in g.encode("utf-8"):
                h = ((h ^ ch) * 1099511628211) & mask  # FNV-1a, wrap at 64b
            h ^= rng_mix
            out[idx, h % dim] += 1.0
    norms = np.linalg.norm(out, axis=1, keepdims=True)
    norms[norms == 0.0] = 1.0
    return out / norms
