"""Black-box matcher abstractions (paper §3) + well-behavedness checks.

Type-I  (Def. 1): E(entities, V+, V-) -> matches, with idempotence
(Def. 2) and monotonicity (Def. 3) making it "well-behaved" (Def. 4).
Type-II (Def. 5): additionally exposes P_E; supermodular Type-II
matchers (Def. 6) are monotone Type-I (Prop. 2).

Concretely a matcher here operates on a padded :class:`NeighborhoodBatch`
with evidence masks over the pair axis, and returns a match mask.  The
checkers below verify the axioms *pointwise on given instances*; the
hypothesis property tests drive them across random instances.
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.types import NeighborhoodBatch


@runtime_checkable
class TypeIMatcher(Protocol):
    def run(
        self,
        batch: NeighborhoodBatch,
        ev_pos: np.ndarray | None = None,
        ev_neg: np.ndarray | None = None,
    ) -> np.ndarray: ...


@runtime_checkable
class TypeIIMatcher(TypeIMatcher, Protocol):
    def score(self, batch: NeighborhoodBatch, x: np.ndarray) -> np.ndarray: ...

    def run_with_messages(
        self,
        batch: NeighborhoodBatch,
        ev_pos: np.ndarray | None = None,
        ev_neg: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]: ...


# ---------------------------------------------------------------------------
# Axiom checkers (Def. 2/3/6) — return (ok, detail)
# ---------------------------------------------------------------------------


def check_idempotence(matcher: TypeIMatcher, batch, ev_pos=None, ev_neg=None):
    out = matcher.run(batch, ev_pos, ev_neg)
    out2 = matcher.run(batch, out, ev_neg)
    ok = bool(np.array_equal(out, out2))
    return ok, {"first": out, "second": out2}

def check_monotone_evidence(matcher: TypeIMatcher, batch, ev_pos, ev_pos_bigger):
    """Def. 3 (ii): V+ grows => output grows."""
    a = matcher.run(batch, ev_pos, None)
    b = matcher.run(batch, ev_pos_bigger, None)
    ok = bool(np.all(b | ~a))  # a subset of b
    return ok, {"small": a, "big": b}


def check_monotone_negative(matcher: TypeIMatcher, batch, ev_neg, ev_neg_bigger):
    """Def. 3 (iii): V- grows => output shrinks."""
    a = matcher.run(batch, None, ev_neg)
    b = matcher.run(batch, None, ev_neg_bigger)
    ok = bool(np.all(a | ~b))  # b subset of a
    return ok, {"small_neg": a, "big_neg": b}


def check_monotone_entities(matcher: TypeIMatcher, batch_small, batch_big, gid_map):
    """Def. 3 (i): E grows => output grows (compared via global pair gids)."""
    a = matcher.run(batch_small)
    b = matcher.run(batch_big)
    small_gids = set(batch_small.pair_gid[a].tolist()) - {-1}
    big_gids = set(batch_big.pair_gid[b].tolist()) - {-1}
    ok = small_gids <= big_gids
    return ok, {"small": small_gids, "big": big_gids}


def check_supermodular(matcher: TypeIIMatcher, batch, s_mask, t_mask, p_idx):
    """Def. 6 on one instance: S subset T, pair p:
    P(T u p)/P(T) >= P(S u p)/P(S) — in log space, delta(p|T) >= delta(p|S)."""
    assert np.all(t_mask | ~s_mask)
    B = batch.entity_ids.shape[0]
    sp = s_mask.copy()
    tp = t_mask.copy()
    sp[np.arange(B), p_idx] = True
    tp[np.arange(B), p_idx] = True
    ds = matcher.score(batch, sp) - matcher.score(batch, s_mask)
    dt = matcher.score(batch, tp) - matcher.score(batch, t_mask)
    ok = bool(np.all(dt >= ds - 1e-4))
    return ok, {"delta_S": ds, "delta_T": dt}
