"""The RULES matcher (paper Appendix B/C): declarative collective rules.

RULES is the paper's second matcher, modeled after the Dedupalog
framework [Arasu-Re-Suciu 2009].  It is a *Type-I* matcher — no
probability distribution — evaluated as a monotone fixpoint of the
Appendix-B rule set::

    1. similar(e1,e2,3)                                  => equals(e1,e2)
    2. similar(e1,e2,2) & one matched coauthor pair      => equals(e1,e2)
    3. similar(e1,e2,1) & two distinct matched co-pairs  => equals(e1,e2)

"Matched coauthor pair" counts both genuinely-matched candidate pairs
(``link @ x``) and shared coauthors ``d`` (the reflexive ``equals(d,d)``,
``n_shared``).  Per Prop. 5 this negation/transitivity-free fragment is
monotone, so SMP over RULES is sound (Thm. 2); the final transitive
closure (Appendix A) is applied by the caller via
:mod:`repro.core.closure` after message passing terminates.

TPU shape: the fixpoint body is ``n = n_shared + link @ x`` — the same
batched mat-vec as the MLN closure sweep, dispatched to the
``icm_sweep`` Pallas kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mln import ground_structure
from repro.core.types import NeighborhoodBatch
from repro.kernels.icm_sweep import ops as icm_ops


def _rules_fixpoint(lev, n_shared, link, ev_pos, ev_neg, valid):
    """Monotone rule fixpoint for one neighborhood. All (P,)-shaped."""
    x0 = ev_pos & valid & ~ev_neg

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        x, _ = state
        # matched coauthor-pair count per candidate pair
        n = icm_ops.sweep(n_shared, link, x.astype(jnp.float32))
        fire = (
            (lev == 3)
            | ((lev == 2) & (n >= 1.0 - 1e-6))
            | ((lev == 1) & (n >= 2.0 - 1e-6))
        )
        x2 = (fire & valid & ~ev_neg) | x0 | x
        return x2, jnp.any(x2 != x)

    x, _ = jax.lax.while_loop(cond, body, (x0, jnp.bool_(True)))
    return x


def rules_fixpoint_batch(lev, n_shared, link, ev_pos, ev_neg, valid):
    """Rule fixpoint for a whole bin in one ``while_loop``.

    Batched form of :func:`_rules_fixpoint` — one
    ``icm_ops.sweep_batch`` contraction per iteration, run until every
    neighborhood converges (idempotent for already-converged lanes, so
    the result equals the vmapped per-row loop).  Used by both the
    batched matcher below and the fused device-resident round engine.
    """
    x0 = ev_pos & valid & ~ev_neg

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        x, _ = state
        n = icm_ops.sweep_batch(n_shared, link, x.astype(jnp.float32))
        fire = (
            (lev == 3)
            | ((lev == 2) & (n >= 1.0 - 1e-6))
            | ((lev == 1) & (n >= 2.0 - 1e-6))
        )
        x2 = (fire & valid & ~ev_neg) | x0 | x
        return x2, jnp.any(x2 != x)

    x, _ = jax.lax.while_loop(cond, body, (x0, jnp.bool_(True)))
    return x


@functools.lru_cache(maxsize=None)
def _jitted_rules():
    return jax.jit(rules_fixpoint_batch)


class RulesMatcher:
    """Monotone Type-I matcher over padded neighborhood batches.

    Interface mirrors :class:`repro.core.mln.MLNMatcher` minus the
    Type-II ``score``; ``run_with_messages`` exists for driver symmetry
    but emits no maximal messages (labels = P everywhere) because
    maximality is a Type-II notion (Def. 8 + step 7 need ``P_E``).
    """

    is_probabilistic = False

    def parallel_backend(self) -> tuple[str, None]:
        """Grounding key for the round-parallel engine (core.parallel)."""
        return ("rules", None)

    def run(
        self,
        batch: NeighborhoodBatch,
        ev_pos: np.ndarray | None = None,
        ev_neg: np.ndarray | None = None,
    ) -> np.ndarray:
        lev, valid, n_shared, link = ground_structure(batch)
        B, P = lev.shape
        ev_pos = self._mask(ev_pos, (B, P))
        ev_neg = self._mask(ev_neg, (B, P))
        x = _jitted_rules()(lev, n_shared, link, ev_pos, ev_neg, valid)
        return np.asarray(x)

    def run_with_messages(self, batch, ev_pos=None, ev_neg=None):
        x = self.run(batch, ev_pos, ev_neg)
        B, P = x.shape
        lab = np.full((B, P), P, dtype=np.int32)
        return x, lab

    @staticmethod
    def _mask(m, shape) -> jax.Array:
        if m is None:
            return jnp.zeros(shape, dtype=bool)
        return jnp.asarray(m, dtype=bool)
