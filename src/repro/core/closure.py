"""Transitive closure of a match set (paper Appendix A).

The transitivity rule itself is not monotone, but "the transitive
closure of any monotonic matcher is monotonic" — the paper supports
transitivity by closing the match set after message passing terminates
(or at the end of each iteration).  We implement the host-side closure
(union-find over entity ids) plus cluster extraction used by the
evaluation metrics.
"""

from __future__ import annotations

import numpy as np

from repro.core import pairs as pairlib, txn
from repro.core.types import MatchStore


class UnionFind:
    """Union-find with path compression.

    Every ``parent``/``rank`` entry write is journaled into the active
    ingest transaction (including the compression writes inside
    ``find`` — an undo log that only covered ``union`` links would
    restore a parent chain that later ``find``s had already
    compressed *through* the rolled-back link)."""

    def __init__(self):
        self.parent: dict[int, int] = {}
        self.rank: dict[int, int] = {}

    def find(self, x: int) -> int:
        t = txn.active()
        if x not in self.parent:
            if t is not None:
                t.save_key(self.parent, x)
                t.save_key(self.rank, x)
            self.parent[x] = x
            self.rank[x] = 0
        p = self.parent[x]
        while p != self.parent[p]:
            if t is not None:
                t.save_key(self.parent, p)
            self.parent[p] = self.parent[self.parent[p]]
            p = self.parent[p]
        if t is not None:
            t.save_key(self.parent, x)
        self.parent[x] = p
        return p

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return
        if self.rank[ra] < self.rank[rb]:
            ra, rb = rb, ra
        t = txn.active()
        if t is not None:
            t.save_key(self.parent, rb)
            t.save_key(self.rank, ra)
        self.parent[rb] = ra
        if self.rank[ra] == self.rank[rb]:
            self.rank[ra] += 1

    def clusters(self) -> list[np.ndarray]:
        by_root: dict[int, list[int]] = {}
        for x in list(self.parent.keys()):
            by_root.setdefault(self.find(x), []).append(x)
        return [np.asarray(sorted(v), dtype=np.int64) for v in by_root.values()]


def clusters_of(store: MatchStore) -> list[np.ndarray]:
    """Connected components of the match graph (entity-id clusters)."""
    uf = UnionFind()
    a, b = pairlib.split_gid(store.gids)
    for x, y in zip(a.tolist(), b.tolist()):
        uf.union(int(x), int(y))
    return [c for c in uf.clusters() if len(c) >= 2]


def transitive_closure(store: MatchStore) -> MatchStore:
    """All intra-cluster pairs of the match graph's components."""
    gids: list[np.ndarray] = [store.gids]
    for c in clusters_of(store):
        n = len(c)
        if n <= 2:
            continue
        ii, jj = np.triu_indices(n, k=1)
        gids.append(pairlib.make_gid(c[ii], c[jj]))
    if len(gids) == 1:
        return store
    return MatchStore(np.concatenate(gids))
