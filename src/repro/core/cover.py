"""Covering (paper §4): canopies + relational boundary => total cover.

Pipeline (paper-faithful):

1. *Canopies* [McCallum-Nigam-Ungar 2000] over the ``Similar`` relation:
   entities are embedded as hashed n-gram profiles; a seed's canopy is
   every entity with cosine >= ``t_loose``; entities within ``t_tight``
   of the seed stop being seeds.  On TPU the seed-vs-pool similarity is
   the ``ngram_sim`` Pallas kernel (a tiled matmul).
2. *Boundary expansion*: each canopy is expanded with every entity that
   shares a relation tuple (Coauthor) with a member => the cover is
   **total** w.r.t. the relations (Def. 7): no tuple is lost.
3. *Packing*: neighborhoods are padded to fixed entity capacity and
   binned by size (k in ``k_bins``) so the batched matcher runs on
   dense, static shapes.  Size-binning is also our structural answer to
   the MapReduce skew the paper reports in §6.3 (see DESIGN §3).

Oversized canopies are split into overlapping windows (stride k/2) in
similarity-sorted order — the standard blocking trade-off; every split
window is boundary-expanded again, so totality is preserved.

The whole construction is a deterministic, locally-decomposable
function of its inputs, which is what the streaming path exploits:
:class:`CoverDelta` memoizes every stage and re-derives only the slice
an ingest touched, splicing the packed arrays in place — bit-for-bit
the scratch build at O(dirty) staging cost (see the class docstring).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import pairs as pairlib
from repro.core import similarity as simlib, txn
from repro.core.types import EntityTable, NeighborhoodBatch, Relations
from repro.kernels.ngram_sim import ops as sim_ops
from repro.obs.registry import get_registry

DEFAULT_BINS = (8, 16, 24, 32)


@dataclasses.dataclass
class Cover:
    """A total cover: per neighborhood, core members and full (core+boundary)."""

    core: list[np.ndarray]
    full: list[np.ndarray]
    _entity_index: dict[int, list[int]] | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def __len__(self) -> int:
        return len(self.full)

    def entity_index(self) -> dict[int, list[int]]:
        """entity id -> neighborhoods (by full membership).

        Memoized: a Cover is immutable once assembled, and the drivers
        consult this index on every evidence-driven re-activation — an
        O(n) rebuild per worklist step without the cache.
        """
        if self._entity_index is None:
            idx: dict[int, list[int]] = {}
            for n, members in enumerate(self.full):
                for e in members:
                    idx.setdefault(int(e), []).append(n)
            self._entity_index = idx
        return self._entity_index


def build_canopies(
    features: np.ndarray,
    t_loose: float,
    t_tight: float,
    *,
    chunk: int = 1024,
) -> list[np.ndarray]:
    """Deterministic canopy construction (seeds in id order).

    The paper picks random seeds; a fixed seed order is a valid draw and
    keeps the construction reproducible.  Order-invariance of the *match
    output* is the framework's consistency property, tested separately.
    """
    n = features.shape[0]
    remaining = np.ones(n, dtype=bool)
    canopies: list[np.ndarray] = []
    order = np.arange(n)
    for seed in order:
        if not remaining[seed]:
            continue
        sims = np.zeros(n, dtype=np.float32)
        q = features[seed : seed + 1]
        for lo in range(0, n, chunk):
            hi = min(lo + chunk, n)
            block = np.asarray(sim_ops.sim_above(q, features[lo:hi], 0.0))[0]
            sims[lo:hi] = block
        members = np.where(sims >= t_loose)[0]
        if len(members) == 0:
            members = np.array([seed])
        canopies.append(members.astype(np.int64))
        remaining[sims >= t_tight] = False
        remaining[seed] = False
    return canopies


def _split_oversized(members: np.ndarray, names: list[str], k_core: int) -> list[np.ndarray]:
    if len(members) <= k_core:
        return [members]
    order = np.argsort([names[int(e)] for e in members], kind="stable")
    sorted_members = members[order]
    out = []
    step = max(k_core // 2, 1)
    for lo in range(0, len(sorted_members), step):
        win = sorted_members[lo : lo + k_core]
        if len(win) == 0:
            break
        out.append(win)
        if lo + k_core >= len(sorted_members):
            break
    return out


def _expand_part(
    part: np.ndarray, adj: dict[int, set[int]], k_max: int
) -> tuple[np.ndarray, np.ndarray]:
    """Boundary-expand one split part -> (core, full), clipped to k_max.

    Shared by the scratch build and the incremental :class:`CoverDelta`
    path so the two produce byte-identical neighborhoods (including the
    set-iteration tie-break order of the boundary ranking).
    """
    boundary: set[int] = set()
    part_set = set(int(e) for e in part)
    for e in part:
        boundary |= adj.get(int(e), set())
    boundary -= part_set
    # clip boundary to capacity, preferring high-degree connectors
    room = k_max - len(part)
    if len(boundary) > room:
        ranked = sorted(
            boundary,
            key=lambda b: -len(adj.get(b, set()) & part_set),
        )
        boundary = set(ranked[:room])
    full = np.array(sorted(part_set | boundary), dtype=np.int64)
    core = np.asarray(sorted(part_set), dtype=np.int64)
    return core, full


def _pack_edge_groups(missing, k_max: int) -> list[np.ndarray]:
    """Greedily pack uncovered relation edges into supplementary
    neighborhoods (the Def. 7 totality sweep), a pure function of the
    missing-edge set."""
    out: list[np.ndarray] = []
    group: set[int] = set()
    for a, b in sorted(set(missing)):
        if len(group | {a, b}) > k_max:
            out.append(np.asarray(sorted(group), dtype=np.int64))
            group = set()
        group |= {a, b}
    if group:
        out.append(np.asarray(sorted(group), dtype=np.int64))
    return out


def _pack_leftover_chunks(leftovers: list[int], k_max: int) -> list[np.ndarray]:
    """Chunk uncovered entities (sorted) into k_max-sized neighborhoods."""
    return [
        np.asarray(leftovers[lo : lo + k_max], dtype=np.int64)
        for lo in range(0, len(leftovers), k_max)
    ]


def build_cover(
    entities: EntityTable,
    relations: Relations,
    *,
    t_loose: float = 0.70,
    t_tight: float = 0.90,
    k_max: int = 32,
    feature_dim: int = 128,
    boundary_relation: str = "coauthor",
) -> Cover:
    if entities.features is None:
        entities.features = simlib.ngram_profiles(
            [simlib.block_key(n) for n in entities.names], dim=feature_dim
        )
    canopies = build_canopies(entities.features, t_loose, t_tight)
    return assemble_cover(
        canopies,
        entities,
        relations,
        k_max=k_max,
        boundary_relation=boundary_relation,
    )


def assemble_cover(
    canopies: list[np.ndarray],
    entities: EntityTable,
    relations: Relations,
    *,
    k_max: int = 32,
    boundary_relation: str = "coauthor",
    present: set[int] | None = None,
    delta: "CoverDelta | None" = None,
    seeds: list[int] | None = None,
    touched: set[int] | None = None,
    new_ids: list[int] | None = None,
    new_edges: np.ndarray | None = None,
) -> Cover:
    """Deterministic canopies -> total cover assembly (split + boundary +
    totality sweep + leftovers).

    Shared by the batch path (:func:`build_cover`) and the streaming
    delta-maintenance path (:mod:`repro.stream.delta`): given the *same*
    canopies in the same order, both produce the identical Cover, which
    is what makes the streaming fixpoint bit-for-bit equal to the batch
    one.  ``present`` restricts the entity-coverage sweep to ids that
    actually exist (a streaming service ingesting batches out of id
    order has temporary holes in the id space).

    ``delta`` selects the incremental path: the persistent
    :class:`CoverDelta` re-derives only the neighborhoods reachable from
    ``touched`` entity ids (plus the edge/leftover bookkeeping deltas of
    ``new_ids``/``new_edges``) and reuses every other neighborhood from
    its memo — the same Cover as the scratch sweep, at O(dirty) cost.
    ``seeds`` aligns ``canopies`` with their canopy-cache seed ids.
    """
    if delta is not None:
        assert seeds is not None and touched is not None
        return delta.assemble(
            canopies,
            seeds,
            entities,
            relations,
            # the delta only reads len(present) (its O(1) universe
            # guard), so a range stands in for the full id set without
            # an O(n) materialization per ingest
            present=present if present is not None else range(len(entities)),
            touched=touched,
            new_ids=new_ids or [],
            new_edges=new_edges,
        )
    adj = relations.adjacency_sets(boundary_relation)
    core_sets: list[np.ndarray] = []
    full_sets: list[np.ndarray] = []
    seen: set[tuple] = set()
    # reserve boundary room: boundary can add up to k_max - k_core slots
    k_core = max(2, int(k_max * 0.6))
    for members in canopies:
        for part in _split_oversized(members, entities.names, k_core):
            key = tuple(sorted(int(e) for e in part))
            if key in seen or len(part) < 2:
                continue
            seen.add(key)
            core, full = _expand_part(part, adj, k_max)
            core_sets.append(core)
            full_sets.append(full)

    # Totality sweep (Def. 7): boundary clipping above can drop relation
    # tuples, and canopy singletons never enter a neighborhood.  Gather
    # every uncovered relation edge and pack the endpoints into
    # supplementary neighborhoods so that R(E) = U R(C_i) exactly.
    covered_edges: set[tuple[int, int]] = set()
    for members in full_sets:
        ms = [int(e) for e in members]
        mset = set(ms)
        for e in ms:
            for nb in adj.get(e, set()):
                if nb in mset:
                    covered_edges.add((min(e, nb), max(e, nb)))
    missing: list[tuple[int, int]] = []
    for edges in relations.edges.values():
        for a, b in edges:
            a, b = int(a), int(b)
            if a != b and (min(a, b), max(a, b)) not in covered_edges:
                missing.append((min(a, b), max(a, b)))
    for arr in _pack_edge_groups(missing, k_max):
        core_sets.append(arr)
        full_sets.append(arr)

    # Entity coverage (cover definition: union of neighborhoods == E):
    # canopy singletons with no relation edges still need a home.
    covered_entities: set[int] = set()
    for members in full_sets:
        covered_entities.update(int(e) for e in members)
    universe = set(range(len(entities))) if present is None else set(present)
    leftovers = sorted(universe - covered_entities)
    for arr in _pack_leftover_chunks(leftovers, k_max):
        core_sets.append(arr)
        full_sets.append(arr)
    return Cover(core=core_sets, full=full_sets)


def is_total(cover: Cover, relations: Relations, candidate_gids: np.ndarray) -> bool:
    """Check Def. 7 (relations) + blocking totality over candidate pairs."""
    covered = set()
    for members in cover.full:
        ms = set(int(e) for e in members)
        for a in ms:
            for b in ms:
                if a < b:
                    covered.add(int(pairlib.make_gid(a, b)))
    for edges in relations.edges.values():
        for a, b in edges:
            if a == b:
                continue
            if int(pairlib.make_gid(int(a), int(b))) not in covered:
                return False
    return all(int(g) in covered for g in candidate_gids)


# ---------------------------------------------------------------------------
# Packing into padded, size-binned NeighborhoodBatches
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedCover:
    """Size-binned padded tensors + host-side indices for message passing."""

    bins: dict[int, NeighborhoodBatch]  # k -> batch over neighborhoods
    bin_rows: dict[int, np.ndarray]  # k -> neighborhood index per row
    neighborhood_bin: np.ndarray  # (N,) bin k of each neighborhood
    neighborhood_row: np.ndarray  # (N,) row within its bin
    pair_levels: dict[int, int]  # global gid -> sim level (>=1)
    cover: Cover
    # per-neighborhood row keys (bin, members, intra-relation edges) —
    # populated when packing with a row_cache or via the CoverDelta
    # splice path; the streaming path diffs them across ingests to find
    # dirty neighborhoods, and the device GroundingCache fingerprints
    # bin rows with them.
    row_keys: list[tuple] | None = None
    # splice-maintained incidence lookup, attached by the CoverDelta
    # path: (gid -> {row key: refcount}, entity -> {row key: refcount},
    # row key -> neighborhood positions).  The first two dicts are the
    # delta's LIVE maps (maintained in the acquire/release refcount
    # loops, O(dirty) per ingest) and are only valid until the next
    # ingest repacks — exactly the window the engine queries them in;
    # the position map is rebuilt per pack (a dict append inside the
    # bin-sequence walk pack already does).  When absent (batch path),
    # queries fall back to the lazily built CSR / entity index below.
    slot_lookup: tuple[dict, dict, dict] | None = dataclasses.field(
        default=None, repr=False, compare=False
    )
    # memoized slot-incidence CSR (gid -> neighborhoods), see
    # slot_incidence(); a PackedCover is immutable once built.
    _slot_csr: tuple[np.ndarray, np.ndarray, np.ndarray] | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def num_neighborhoods(self) -> int:
        return len(self.neighborhood_bin)

    def rows_for(self, neighborhoods: list[int]) -> dict[int, np.ndarray]:
        """Group a set of neighborhood ids by bin -> row arrays."""
        out: dict[int, list[int]] = {}
        for n in neighborhoods:
            out.setdefault(int(self.neighborhood_bin[n]), []).append(
                int(self.neighborhood_row[n])
            )
        return {k: np.asarray(v, dtype=np.int64) for k, v in out.items()}

    def _positions_of_entity(self, e: int) -> set[int]:
        """Neighborhood positions whose full membership holds ``e``
        (splice-lookup path; callers guard on ``slot_lookup``)."""
        _, ent_rows, pos = self.slot_lookup
        out: set[int] = set()
        for rk in ent_rows.get(int(e), ()):
            out.update(pos.get(rk, ()))
        return out

    def neighborhoods_of_entities(self, ids) -> set[int]:
        """Neighborhoods whose full membership contains any of ``ids``.

        Resolved per query from the splice-maintained lookup when
        present (no per-ingest index rebuild); falls back to the
        memoized ``Cover.entity_index`` on the batch path.
        """
        out: set[int] = set()
        if self.slot_lookup is not None:
            for e in ids:
                out |= self._positions_of_entity(int(e))
            return out
        idx = self.cover.entity_index()
        for e in ids:
            out.update(idx.get(int(e), ()))
        return out

    def neighborhoods_of_pairs(self, gids: np.ndarray) -> list[int]:
        """Neighborhoods containing BOTH endpoints of any of the pairs."""
        if self.slot_lookup is not None:
            out: set[int] = set()
            for g in gids:
                a, b = pairlib.split_gid(np.int64(g))
                out |= self._positions_of_entity(int(a)) & \
                    self._positions_of_entity(int(b))
            return sorted(out)
        idx = self.cover.entity_index()
        out = set()
        for g in gids:
            a, b = pairlib.split_gid(np.int64(g))
            na = idx.get(int(a), [])
            nb = set(idx.get(int(b), []))
            for n in na:
                if n in nb:
                    out.add(n)
        return sorted(out)

    def slot_incidence(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR incidence: candidate pair gid -> neighborhoods holding it
        as a *candidate slot* (``pair_mask`` true).

        Returns ``(gids, indptr, nbhd)``: sorted unique gids, and for
        gid ``gids[i]`` the neighborhoods ``nbhd[indptr[i]:indptr[i+1]]``.
        This is the structure the round-parallel driver re-activates
        from: it is a subset of :meth:`neighborhoods_of_pairs`
        (endpoint incidence), and the difference is inert — a
        neighborhood holding both endpoints but not the candidate slot
        projects no evidence from that pair, so re-evaluating it can
        produce nothing new (its fixpoint contribution is unchanged).
        Built vectorized from the packed bins and memoized.
        """
        if self._slot_csr is None:
            gid_parts: list[np.ndarray] = []
            nb_parts: list[np.ndarray] = []
            for k, nb in self.bins.items():
                mask = nb.pair_mask & (nb.pair_gid >= 0)
                rows, _ = np.nonzero(mask)
                gid_parts.append(nb.pair_gid[mask])
                nb_parts.append(self.bin_rows[k][rows])
            if gid_parts:
                flat_gid = np.concatenate(gid_parts)
                flat_nb = np.concatenate(nb_parts)
                order = np.argsort(flat_gid, kind="stable")
                flat_gid, flat_nb = flat_gid[order], flat_nb[order]
                uniq, starts = np.unique(flat_gid, return_index=True)
                indptr = np.append(starts, len(flat_gid))
            else:
                uniq = np.zeros(0, dtype=np.int64)
                indptr = np.zeros(1, dtype=np.int64)
                flat_nb = np.zeros(0, dtype=np.int64)
            self._slot_csr = (uniq, indptr, flat_nb)
        return self._slot_csr

    def neighborhoods_of_slot_pairs(self, gids: np.ndarray) -> list[int]:
        """Neighborhoods with any of ``gids`` as a candidate slot (sorted).

        With a splice-maintained ``slot_lookup`` (streaming path) this
        resolves per query — gid -> row keys -> positions — without ever
        materializing the O(total candidate slots) CSR; rows with equal
        keys hold identical tensors, so their positions carry exactly
        the queried slot.
        """
        if self.slot_lookup is not None:
            gid_rows, _, pos = self.slot_lookup
            out: set[int] = set()
            for g in gids:
                for rk in gid_rows.get(int(g), ()):
                    out.update(pos.get(rk, ()))
            return sorted(out)
        uniq, indptr, nbhd = self.slot_incidence()
        if not len(gids) or not len(uniq):
            return []
        g = np.asarray(gids, dtype=np.int64)
        pos = np.searchsorted(uniq, g)
        pos = np.clip(pos, 0, len(uniq) - 1)
        pos = pos[uniq[pos] == g]
        if not len(pos):
            return []
        hits = np.concatenate([nbhd[indptr[i] : indptr[i + 1]] for i in pos])
        return [int(n) for n in np.unique(hits)]


def _bin_of(size: int, k_bins: tuple[int, ...]) -> int:
    return next((kb for kb in k_bins if size <= kb), k_bins[-1])


def _pair_level_fn(names: list[str], thresholds, level_cache: dict[int, int]):
    """Host-side Jaro-Winkler discretization, memoized per global pair.

    Levels are name-static, so a cached entry can never go stale; the
    streaming layer may bound the memo (``DeltaCover.level_cache_max``)
    because a miss just recomputes from the strings.
    """

    def pair_level(a: int, b: int) -> int:
        gid = int(pairlib.make_gid(a, b))
        lev = level_cache.get(gid)
        if lev is None:
            s = simlib.jaro_winkler(simlib.name_key(names[a]), simlib.name_key(names[b]))
            lev = int(simlib.discretize(np.asarray([s]), thresholds)[0])
            if lev == 0 and simlib.abbrev_compatible(names[a], names[b]):
                lev = 1  # abbreviation-aware weak candidate
            elif lev > 0 and simlib.first_name_conflict(names[a], names[b]):
                lev = 0  # full first names of different people: veto
            t = txn.active()
            if t is not None:
                # gids index into `names`: an aborted ingest's entry could
                # otherwise resolve to a *different* name pair after the
                # ids are reused, caching a wrong level forever
                t.save_key(level_cache, gid)
            level_cache[gid] = lev
        return lev

    return pair_level


def _row_key(members: np.ndarray, k: int, adj: dict[int, set[int]]) -> tuple:
    """``(k, members, intra-relation edges)`` — changes whenever anything
    that feeds the staged row tensors changes, so a cached row keyed by
    it can never be reused stale."""
    mkey = tuple(int(e) for e in members[:k])
    intra = tuple(
        (a, b)
        for ai, a in enumerate(mkey)
        for b in mkey[ai + 1 :]
        if b in adj.get(a, set())
    )
    return (k, mkey, intra)


def _stage_row(
    members: np.ndarray, k: int, adj: dict[int, set[int]], pair_level
) -> dict:
    """Stage one neighborhood's padded row tensors (the per-row work of
    :func:`pack_cover`, shared with the :class:`CoverDelta` splice path)."""
    members = members[:k]  # safety clip (build_cover respects k_max)
    P = pairlib.num_pairs(k)
    ii, jj = pairlib.triu_indices(k)

    ids = np.full(k, -1, dtype=np.int64)
    ids[: len(members)] = members
    emask = ids >= 0
    co = np.zeros((k, k), dtype=bool)
    for a_slot in range(len(members)):
        a = int(members[a_slot])
        nbrs = adj.get(a, set())
        for b_slot in range(a_slot + 1, len(members)):
            if int(members[b_slot]) in nbrs:
                co[a_slot, b_slot] = True
                co[b_slot, a_slot] = True

    lev = np.zeros(P, dtype=np.int8)
    gid = np.full(P, -1, dtype=np.int64)
    pmask = np.zeros(P, dtype=bool)
    for p in range(P):
        i, j = int(ii[p]), int(jj[p])
        if not (emask[i] and emask[j]):
            continue
        a, b = int(ids[i]), int(ids[j])
        lv = pair_level(a, b)
        if lv >= 1:
            lev[p] = lv
            gid[p] = pairlib.make_gid(a, b)
            pmask[p] = True
    return dict(ids=ids, emask=emask, co=co, lev=lev, gid=gid, pmask=pmask)


def _stack_rows(rows: list[dict]) -> NeighborhoodBatch:
    return NeighborhoodBatch(
        entity_ids=np.stack([r["ids"] for r in rows]),
        entity_mask=np.stack([r["emask"] for r in rows]),
        coauthor=np.stack([r["co"] for r in rows]),
        sim_level=np.stack([r["lev"] for r in rows]),
        pair_gid=np.stack([r["gid"] for r in rows]),
        pair_mask=np.stack([r["pmask"] for r in rows]),
    )


def pack_cover(
    cover: Cover,
    entities: EntityTable,
    relations: Relations,
    *,
    k_bins: tuple[int, ...] = DEFAULT_BINS,
    thresholds=simlib.DEFAULT_THRESHOLDS,
    boundary_relation: str = "coauthor",
    level_cache: dict[int, int] | None = None,
    row_cache: dict[tuple, dict] | None = None,
    delta: "CoverDelta | None" = None,
    prev: "PackedCover | None" = None,
) -> PackedCover:
    """Pack a cover into size-binned padded tensors.

    ``level_cache`` and ``row_cache`` are optional *persistent* caches
    for the streaming path: ``level_cache`` memoizes the host-side
    Jaro-Winkler discretization per global pair (a pure memo — the
    streaming layer may bound it, see ``DeltaCover.level_cache_max``),
    and ``row_cache`` memoizes fully staged neighborhood rows keyed by
    ``(k, members, intra-relation edges)`` — a key that changes whenever
    anything that feeds the row tensors changes, so stale entries can
    never be reused.  Batch callers omit both and get the original
    behavior; repacking after a micro-batch only stages rows for
    new/changed neighborhoods ("repack only affected bins").

    ``delta``/``prev`` select the incremental splice path: ``delta`` is
    the persistent :class:`CoverDelta` whose :meth:`CoverDelta.assemble`
    produced ``cover``, and ``prev`` is the previous :class:`PackedCover`
    whose per-bin arrays are reused wholesale (unchanged bins) or spliced
    (only freshly staged rows recomputed) — bit-for-bit equal to the
    scratch pack, at O(dirty) staging cost per ingest.
    """
    if delta is not None:
        return delta.pack(cover, prev=prev, level_cache=level_cache)
    adj = relations.adjacency_sets(boundary_relation)
    if level_cache is None:
        level_cache = {}
    pair_level = _pair_level_fn(entities.names, thresholds, level_cache)

    n_nb = len(cover)
    neighborhood_bin = np.zeros(n_nb, dtype=np.int64)
    neighborhood_row = np.zeros(n_nb, dtype=np.int64)
    staged: dict[int, list[dict]] = {k: [] for k in k_bins}
    row_keys: list[tuple] | None = [] if row_cache is not None else None

    for n, members in enumerate(cover.full):
        k = _bin_of(len(members), k_bins)

        row = None
        row_key = None
        if row_cache is not None:
            row_key = _row_key(members, k, adj)
            row_keys.append(row_key)
            row = row_cache.get(row_key)
        if row is None:
            row = _stage_row(members, k, adj, pair_level)
            if row_cache is not None:
                row_cache[row_key] = row

        neighborhood_bin[n] = k
        neighborhood_row[n] = len(staged[k])
        staged[k].append(row)

    bins: dict[int, NeighborhoodBatch] = {}
    bin_rows: dict[int, np.ndarray] = {}
    for k, rows in staged.items():
        if not rows:
            continue
        bins[k] = _stack_rows(rows)
        bin_rows[k] = np.where(neighborhood_bin == k)[0]

    # pair_levels must reflect pairs co-resident in *this* cover — not the
    # level cache, which on the streaming path persists across covers and
    # would leak retracted candidate pairs into the global grounding.
    pair_levels: dict[int, int] = {}
    for rows in staged.values():
        for r in rows:
            for g, lv in zip(r["gid"][r["pmask"]], r["lev"][r["pmask"]]):
                pair_levels[int(g)] = int(lv)
    return PackedCover(
        bins=bins,
        bin_rows=bin_rows,
        neighborhood_bin=neighborhood_bin,
        neighborhood_row=neighborhood_row,
        pair_levels=pair_levels,
        cover=cover,
        row_keys=row_keys,
    )


# ---------------------------------------------------------------------------
# Incremental cover assembly + packed-array splicing (the CoverDelta path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Part:
    """One memoized canopy part: a neighborhood candidate keyed by its
    sorted core-member tuple, shared by every canopy that emits it."""

    core: np.ndarray
    full: np.ndarray
    row_key: tuple
    emitters: set[int]  # seeds whose canopy emits this part


class CoverDelta:
    """Persistent incremental cover assembly + packed-array splice state.

    The scratch build (:func:`assemble_cover` + :func:`pack_cover`) is a
    deterministic function of ``(canopies, names, relations, present)``;
    every stage decomposes over a local neighborhood of the input, so a
    micro-batch that touches a small entity set can only change a small
    slice of the output.  This class memoizes each stage and re-derives
    only that slice:

    * **canopy parts** — split windows + boundary expansion are memoized
      per canopy seed; a canopy is re-derived only when a member is in
      ``touched`` (canopy re-swept, or a member gained a relation edge).
      Part content is keyed by the sorted core tuple, so the
      first-occurrence dedup of the scratch build becomes "owner =
      minimum emitting seed" (canopies arrive in seed order).
    * **totality sweep** (Def. 7) — per-edge cover counts are maintained
      under part adds/retires and new edges; the supplementary edge
      groups are re-packed only when the missing-edge set changes, and
      diffed by content so unchanged groups are never re-staged.
    * **leftover chunks** — per-entity cover counts maintain the
      uncovered set; chunks are re-packed on change and diffed likewise.
    * **row staging + packing** — rows are staged once per row key
      ``(k, members, intra-edges)`` and spliced into the per-bin padded
      arrays: an untouched bin is reused wholesale, an appended-to bin
      writes only the fresh tail into its capacity-doubling backing
      buffer (published arrays are views; growth copies are amortized
      O(1) per appended row — ``total_growth_copy_rows`` counts them),
      and only a bin whose row sequence changed mid-way is re-stacked
      into a fresh buffer (from memoized rows — no re-staging).
    * **incidence lookups** — ``gid -> row keys`` and ``entity -> row
      keys`` refcount maps are maintained in the same acquire/release
      loops and attached to the packed cover (``PackedCover.
      slot_lookup``), so evidence-driven re-activation queries
      (``neighborhoods_of_slot_pairs`` / ``neighborhoods_of_pairs`` /
      ``neighborhoods_of_entities``) resolve per query instead of
      rebuilding the O(total slots) CSR or the O(n) entity index per
      ingest.
    * **boundary adjacency** — maintained incrementally from
      ``new_edges`` with the same per-edge insertion sequence as
      ``Relations.adjacency_sets`` over the concatenated chunks
      (identical set iteration order, so boundary-ranking tie-breaks
      match the scratch build bit-for-bit) — no per-ingest O(E)
      rebuild.

    The result is bit-for-bit equal to the scratch build at every ingest
    (differential-tested in ``tests/test_stream.py``) with staging work
    proportional to the dirty set: ``last_splice_rows`` counts the rows
    actually (re)staged, the quantity asserted O(dirty) by the tests and
    gated in CI via ``benchmarks/check_bench.py``.

    Single boundary relation only: the totality bookkeeping tracks the
    relation whose edges arrive via ``new_edges``, matching the scratch
    build's use of one ``boundary_relation`` (the repo's corpora have
    exactly one relation).
    """

    def __init__(
        self,
        *,
        k_max: int = 32,
        k_bins: tuple[int, ...] = DEFAULT_BINS,
        thresholds=None,
        boundary_relation: str = "coauthor",
    ):
        self.k_max = k_max
        self.k_bins = k_bins
        self.thresholds = thresholds or simlib.DEFAULT_THRESHOLDS
        self.boundary_relation = boundary_relation
        # canopy-level memo
        self._seed_parts: dict[int, list[tuple]] = {}  # seed -> part keys
        self._seed_members: dict[int, np.ndarray] = {}
        self._member_seeds: dict[int, set[int]] = {}  # entity -> seeds
        # part-level memo
        self._parts: dict[tuple, _Part] = {}
        self._containers: dict[int, set[tuple]] = {}  # entity -> part keys
        # totality (Def. 7) bookkeeping
        self._all_edges: set[tuple[int, int]] = set()
        self._edge_cov: dict[tuple[int, int], int] = {}
        self._missing: set[tuple[int, int]] = set()
        self._groups: list[np.ndarray] = []
        self._group_keys: list[tuple] = []
        self._group_row_keys: list[tuple] = []
        self._group_containers: dict[int, set[tuple]] = {}
        # entity coverage / leftovers
        self._present: set[int] = set()
        self._cov_cnt: dict[int, int] = {}
        self._uncovered: set[int] = set()
        self._chunks: list[np.ndarray] = []
        self._chunk_keys: list[tuple] = []
        self._chunk_row_keys: list[tuple] = []
        # staged rows + reference counts
        self._rows: dict[tuple, dict] = {}
        self._row_ref: dict[tuple, int] = {}
        self._lev_ref: dict[int, int] = {}
        self._pair_levels: dict[int, int] = {}
        # splice-maintained incidence refcounts (candidate gid -> row
        # keys, entity -> row keys), updated in the same acquire/release
        # loops as _lev_ref — the query side of
        # PackedCover.neighborhoods_of_{slot_pairs,pairs,entities}.
        self._gid_rows: dict[int, dict[tuple, int]] = {}
        self._ent_rows: dict[int, dict[tuple, int]] = {}
        # per-bin packed splice state: published arrays are views into
        # capacity-doubling backing buffers (appends write only the
        # fresh tail; growth copies are amortized O(appended rows))
        self._bin_seq: dict[int, list[tuple]] = {}
        self._bin_arrays: dict[int, NeighborhoodBatch] = {}
        self._bin_buf: dict[int, dict[str, np.ndarray]] = {}
        # assemble -> pack handoff + per-ingest outputs
        self._pending: tuple | None = None
        self._adj: dict[int, set[int]] = {}
        self._names: list = []
        self.last_dirty: list[int] = []
        self.last_splice_rows = 0
        self.total_splice_rows = 0
        self.last_append_rows = 0
        self.total_append_rows = 0
        self.last_growth_copy_rows = 0
        self.total_growth_copy_rows = 0
        self.last_restack_rows = 0
        self.total_restack_rows = 0
        self.last_added_pairs: dict[int, int] = {}
        self.last_retracted_pairs: list[int] = []

    # -- count maintenance helpers ---------------------------------------

    def _cov_delta(self, e: int, d: int) -> None:
        t = txn.active()
        if t is not None:
            t.save_key(self._cov_cnt, e)
        c = self._cov_cnt.get(e, 0) + d
        if c:
            self._cov_cnt[e] = c
            if e in self._uncovered:
                if t is not None:
                    t.set_discard(self._uncovered, e)
                else:
                    self._uncovered.discard(e)
                self._chunks_stale = True
        else:
            self._cov_cnt.pop(e, None)
            if e in self._present and e not in self._uncovered:
                if t is not None:
                    t.set_add(self._uncovered, e)
                else:
                    self._uncovered.add(e)
                self._chunks_stale = True

    def _edge_delta(self, e: tuple[int, int], d: int) -> None:
        t = txn.active()
        if t is not None:
            t.save_key(self._edge_cov, e)
        c = self._edge_cov.get(e, 0) + d
        self._edge_cov[e] = c
        if c == 0 and e not in self._missing:
            if t is not None:
                t.set_add(self._missing, e)
            else:
                self._missing.add(e)
            self._missing_stale = True
        elif c > 0 and e in self._missing:
            if t is not None:
                t.set_discard(self._missing, e)
            else:
                self._missing.discard(e)
            self._missing_stale = True

    def _full_edges(self, full: np.ndarray):
        """Canonical relation edges with both endpoints in ``full``."""
        fset = set(int(e) for e in full)
        for a in fset:
            for b in self._adj.get(a, ()):
                if a < b and b in fset:
                    yield (a, b)

    @staticmethod
    def _ref_add(index: dict, key, rk: tuple) -> None:
        t = txn.active()
        if t is not None and key not in index:
            t.save_key(index, key)
        d = index.setdefault(key, {})
        if t is not None:
            t.save_key(d, rk)
        d[rk] = d.get(rk, 0) + 1

    @staticmethod
    def _ref_sub(index: dict, key, rk: tuple) -> None:
        t = txn.active()
        d = index[key]
        if t is not None:
            t.save_key(d, rk)
        c = d[rk] - 1
        if c:
            d[rk] = c
        else:
            del d[rk]
            if not d:
                if t is not None:
                    t.save_key(index, key)
                del index[key]

    def _add_part(self, key: tuple, window: np.ndarray, s: int) -> None:
        t = txn.active()
        part = self._parts.get(key)
        if part is not None:
            if t is not None:
                t.set_add(part.emitters, s)
            else:
                part.emitters.add(s)
            return
        core, full = _expand_part(window, self._adj, self.k_max)
        rk = _row_key(full, _bin_of(len(full), self.k_bins), self._adj)
        if t is not None:
            t.save_key(self._parts, key)
        self._parts[key] = _Part(core, full, rk, {s})
        for e in map(int, full):
            if t is not None:
                t.save_key(self._containers, e, copy=set)
            self._containers.setdefault(e, set()).add(key)
            self._cov_delta(e, +1)
        for edge in self._full_edges(full):
            self._edge_delta(edge, +1)
        self._acquires.append(rk)

    def _drop_part(self, key: tuple, s: int) -> None:
        t = txn.active()
        part = self._parts[key]
        if t is not None:
            t.set_discard(part.emitters, s)
        else:
            part.emitters.discard(s)
        if part.emitters:
            return
        for e in map(int, part.full):
            cs = self._containers.get(e)
            if cs is not None:
                if t is not None:
                    t.save_key(self._containers, e, copy=set)
                cs.discard(key)
                if not cs:
                    del self._containers[e]
            self._cov_delta(e, -1)
        for edge in self._full_edges(part.full):
            self._edge_delta(edge, -1)
        self._releases.append(part.row_key)
        if t is not None:
            t.save_key(self._parts, key)
        del self._parts[key]

    # -- assemble ---------------------------------------------------------

    def assemble(
        self,
        canopies: list[np.ndarray],
        seeds: list[int],
        entities: EntityTable,
        relations: Relations | None = None,
        *,
        present,  # any sized collection of the current ids (len-only use)
        touched: set[int],
        new_ids: list[int],
        new_edges: np.ndarray | None,
    ) -> Cover:
        """Incrementally re-derive the total cover after an ingest.

        ``canopies``/``seeds`` are the full current canopy list in seed
        order (clean entries are memo hits); ``touched`` is the set of
        entity ids whose similarity region was re-swept or that gained a
        relation edge this ingest.  Equal to the scratch
        :func:`assemble_cover` over the same inputs.

        ``relations`` is accepted for API symmetry with the scratch path
        but unused: the boundary adjacency is maintained incrementally
        from ``new_edges`` (every relation edge must arrive through it
        exactly once, like every id through ``new_ids``), inserted with
        the same per-edge ``a -> b, b -> a`` sequence in arrival order
        as ``Relations.adjacency_sets`` runs over the concatenated edge
        chunks — identical set insertion history, hence identical set
        iteration order, so the boundary-expansion tie-breaks stay
        bit-for-bit the scratch build's without the per-ingest O(E)
        adjacency rebuild.
        """
        t = txn.active()
        if t is not None:
            # wholesale attribute rebinds below (and in pack) — journal
            # the pre-ingest references once up front; entry-level
            # writes are journaled at their mutation sites
            for a in (
                "_names", "_pending", "_acquires", "_releases",
                "_missing_stale", "_chunks_stale",
                "_groups", "_group_keys", "_group_row_keys",
                "_chunks", "_chunk_keys", "_chunk_row_keys",
            ):
                t.save_attr(self, a)
        if new_edges is not None and len(new_edges):
            for x, y in np.asarray(new_edges, dtype=np.int64):
                x, y = int(x), int(y)
                if x == y:
                    continue  # rejected upstream; adjacency must not self-link
                if t is not None:
                    t.save_key(self._adj, x, copy=set)
                    t.save_key(self._adj, y, copy=set)
                self._adj.setdefault(x, set()).add(y)
                self._adj.setdefault(y, set()).add(x)
        self._names = entities.names
        k_core = max(2, int(self.k_max * 0.6))
        self._acquires: list[tuple] = []
        self._releases: list[tuple] = []
        self._missing_stale = False
        self._chunks_stale = False
        stale_parts: set[tuple] = set()
        stale_groups: set[tuple] = set()

        # 0. present growth: new ids start uncovered until a part/group
        # claims them.
        for e in new_ids:
            e = int(e)
            if t is not None:
                t.set_add(self._present, e)
            else:
                self._present.add(e)
            if self._cov_cnt.get(e, 0) == 0 and e not in self._uncovered:
                if t is not None:
                    t.set_add(self._uncovered, e)
                else:
                    self._uncovered.add(e)
                self._chunks_stale = True
        # the caller's universe must be exactly the accumulated new_ids:
        # this class supports growth only (no entity eviction), and the
        # leftover chunks are computed from the internal set.  The guard
        # is O(1) by design (an O(n) set comparison per ingest would
        # reintroduce the corpus-sized pass this class exists to remove),
        # so it catches shrinkage/extra ids by cardinality only — an
        # equal-cardinality divergence is on the caller (DeltaCover
        # passes the very set new_ids accumulated into).
        if len(present) != len(self._present):
            raise ValueError(
                f"present has {len(present)} ids but {len(self._present)} "
                "were accumulated via new_ids — CoverDelta tracks a "
                "grow-only universe"
            )

        # 1. new relation edges: initial cover counts from the container
        # index, and row-key staleness for neighborhoods that hold both
        # endpoints (their coauthor tensor changes even when membership
        # does not).
        if new_edges is not None and len(new_edges):
            for x, y in np.asarray(new_edges, dtype=np.int64):
                x, y = int(x), int(y)
                if x == y:
                    continue
                edge = (x, y) if x < y else (y, x)
                if edge in self._all_edges:
                    continue
                if t is not None:
                    t.set_add(self._all_edges, edge)
                    t.save_key(self._edge_cov, edge)
                else:
                    self._all_edges.add(edge)
                both = self._containers.get(x, set()) & self._containers.get(y, set())
                self._edge_cov[edge] = len(both)
                if not both:
                    if t is not None:
                        t.set_add(self._missing, edge)
                    else:
                        self._missing.add(edge)
                    self._missing_stale = True
                stale_parts |= both
                stale_groups |= self._group_containers.get(
                    x, set()
                ) & self._group_containers.get(y, set())

        # 2. dirty canopies: any canopy with a touched member (re-swept
        # region, or a member that gained an edge — boundary expansion
        # and clip ranking read members' adjacency only).
        seed_arr = np.asarray(seeds, dtype=np.int64)

        def _seed_pos(e: int) -> int:
            p = int(np.searchsorted(seed_arr, e))
            return p if p < len(seed_arr) and int(seed_arr[p]) == e else -1

        dirty_seeds: set[int] = set()
        for e in touched:
            dirty_seeds |= self._member_seeds.get(e, set())
            if _seed_pos(e) >= 0:
                dirty_seeds.add(e)

        # per-seed diff: windows whose core avoids `touched` and is kept
        # by the new split are reused without any churn.
        plans: list[tuple[int, list[tuple], list[tuple[tuple, np.ndarray]]]] = []
        for s in sorted(dirty_seeds):
            pos = _seed_pos(s)
            old_keys = self._seed_parts.get(s, [])
            new_parts: list[tuple[tuple, np.ndarray]] = []
            if pos >= 0:
                members = canopies[pos]
                for win in _split_oversized(members, self._names, k_core):
                    if len(win) < 2:
                        continue
                    new_parts.append((tuple(sorted(int(e) for e in win)), win))
            new_key_set = {k for k, _ in new_parts}
            kept = {
                k
                for k in old_keys
                if k in new_key_set and not any(e in touched for e in k)
            }
            # update the canopy-member index
            for e in map(int, self._seed_members.get(s, ())):
                ms = self._member_seeds.get(e)
                if ms is not None:
                    if t is not None:
                        t.save_key(self._member_seeds, e, copy=set)
                    ms.discard(s)
                    if not ms:
                        del self._member_seeds[e]
            if t is not None:
                t.save_key(self._seed_members, s)
                t.save_key(self._seed_parts, s)
            if pos >= 0:
                self._seed_members[s] = canopies[pos]
                for e in map(int, canopies[pos]):
                    if t is not None:
                        t.save_key(self._member_seeds, e, copy=set)
                    self._member_seeds.setdefault(e, set()).add(s)
                self._seed_parts[s] = [k for k, _ in new_parts]
            else:
                self._seed_members.pop(s, None)
                self._seed_parts.pop(s, None)
            plans.append((s, [k for k in old_keys if k not in kept],
                          [(k, w) for k, w in new_parts if k not in kept]))

        # two-phase apply: all drops, then all adds — a part key shared
        # by several dirty canopies is fully retired before any emitter
        # re-stages it against the current adjacency.
        for s, drops, _ in plans:
            for key in drops:
                self._drop_part(key, s)
        for s, _, adds in plans:
            for key, win in adds:
                self._add_part(key, win, s)

        # 3. stale row keys: surviving parts whose intra-edge set grew.
        for key in stale_parts:
            part = self._parts.get(key)
            if part is None:
                continue
            rk = _row_key(part.full, _bin_of(len(part.full), self.k_bins), self._adj)
            if rk != part.row_key:
                self._releases.append(part.row_key)
                self._acquires.append(rk)
                if t is not None:
                    t.save_attr(part, "row_key")
                part.row_key = rk

        # 4. totality groups (re-packed only when the missing set moved).
        if self._missing_stale:
            new_groups = _pack_edge_groups(self._missing, self.k_max)
            new_keys = [tuple(int(e) for e in g) for g in new_groups]
            old = dict(zip(self._group_keys, zip(self._groups, self._group_row_keys)))
            new_key_set = set(new_keys)
            for gk, (_, rk) in old.items():
                if gk not in new_key_set:
                    for e in gk:
                        gc = self._group_containers.get(e)
                        if gc is not None:
                            if t is not None:
                                t.save_key(self._group_containers, e, copy=set)
                            gc.discard(gk)
                            if not gc:
                                del self._group_containers[e]
                        self._cov_delta(e, -1)
                    self._releases.append(rk)
            groups: list[np.ndarray] = []
            group_row_keys: list[tuple] = []
            for gk, arr in zip(new_keys, new_groups):
                hit = old.get(gk)
                if hit is not None:
                    arr, rk = hit
                else:
                    rk = _row_key(arr, _bin_of(len(arr), self.k_bins), self._adj)
                    for e in gk:
                        if t is not None:
                            t.save_key(self._group_containers, e, copy=set)
                        self._group_containers.setdefault(e, set()).add(gk)
                        self._cov_delta(e, +1)
                    self._acquires.append(rk)
                groups.append(arr)
                group_row_keys.append(rk)
            self._groups, self._group_keys = groups, new_keys
            self._group_row_keys = group_row_keys
        for gk in stale_groups:
            try:
                i = self._group_keys.index(gk)
            except ValueError:
                continue
            rk = _row_key(
                self._groups[i], _bin_of(len(self._groups[i]), self.k_bins), self._adj
            )
            if rk != self._group_row_keys[i]:
                self._releases.append(self._group_row_keys[i])
                self._acquires.append(rk)
                if t is not None:
                    t.save_item(self._group_row_keys, i)
                self._group_row_keys[i] = rk

        # 5. leftover chunks.
        if self._chunks_stale:
            new_chunks = _pack_leftover_chunks(sorted(self._uncovered), self.k_max)
            new_keys = [tuple(int(e) for e in c) for c in new_chunks]
            old = dict(zip(self._chunk_keys, zip(self._chunks, self._chunk_row_keys)))
            new_key_set = set(new_keys)
            for ck, (_, rk) in old.items():
                if ck not in new_key_set:
                    self._releases.append(rk)
            chunks: list[np.ndarray] = []
            chunk_row_keys: list[tuple] = []
            for ck, arr in zip(new_keys, new_chunks):
                hit = old.get(ck)
                if hit is not None:
                    arr, rk = hit
                else:
                    rk = _row_key(arr, _bin_of(len(arr), self.k_bins), self._adj)
                    self._acquires.append(rk)
                chunks.append(arr)
                chunk_row_keys.append(rk)
            self._chunks, self._chunk_keys = chunks, new_keys
            self._chunk_row_keys = chunk_row_keys

        # 6. walk: first-occurrence order over canopies (owner = minimum
        # emitting seed), then totality groups, then leftover chunks —
        # exactly the scratch emission order.
        core_list: list[np.ndarray] = []
        full_list: list[np.ndarray] = []
        keys: list[tuple] = []
        for s in seeds:
            for key in self._seed_parts.get(int(s), ()):
                part = self._parts[key]
                if min(part.emitters) == s:
                    core_list.append(part.core)
                    full_list.append(part.full)
                    keys.append(part.row_key)
        for arr, rk in zip(self._groups, self._group_row_keys):
            core_list.append(arr)
            full_list.append(arr)
            keys.append(rk)
        for arr, rk in zip(self._chunks, self._chunk_row_keys):
            core_list.append(arr)
            full_list.append(arr)
            keys.append(rk)
        cover = Cover(core=core_list, full=full_list)
        self._pending = (cover, keys)
        return cover

    # -- packed-array backing buffers -------------------------------------

    _ROW_FIELDS = (
        ("entity_ids", "ids"), ("entity_mask", "emask"), ("coauthor", "co"),
        ("sim_level", "lev"), ("pair_gid", "gid"), ("pair_mask", "pmask"),
    )

    def _alloc_buf(self, proto_key: tuple, n: int) -> dict[str, np.ndarray]:
        """Fresh backing buffers shaped like ``proto_key``'s staged row,
        capacity = pow2 >= n."""
        proto = self._rows[proto_key]
        cap = 1 << max(n - 1, 0).bit_length()
        return {
            f: np.empty((cap,) + proto[rf].shape, proto[rf].dtype)
            for f, rf in self._ROW_FIELDS
        }

    def _publish(self, buf: dict[str, np.ndarray], n: int) -> NeighborhoodBatch:
        return NeighborhoodBatch(**{f: buf[f][:n] for f, _ in self._ROW_FIELDS})

    def _bin_append(self, k: int, seq: list[tuple], n0: int) -> NeighborhoodBatch:
        """Append ``seq[n0:]`` to bin ``k``'s buffer: O(fresh rows) writes.

        Rows ``[:n0]`` are already in the buffer (and published as views
        by the previous pack — append never touches them).  When the
        tail outgrows capacity the buffer doubles and the resident rows
        are copied once — amortized O(1) copies per appended row, vs the
        O(bin) memcpy of the former per-append ``np.concatenate``.

        Under an ingest transaction the tail writes themselves need no
        journal: rows ``>= n0`` sit beyond every published view, so a
        rollback (which restores ``_bin_seq``/``_bin_arrays``) leaves
        them unobservable, and the next append to this bin starts from
        the same ``n0`` and overwrites them.  Only the buffer *rebind*
        on growth is journaled.
        """
        t = txn.active()
        n1 = len(seq)
        buf = self._bin_buf[k]
        if next(iter(buf.values())).shape[0] < n1:
            new = self._alloc_buf(seq[0], n1)
            for f, _ in self._ROW_FIELDS:
                new[f][:n0] = buf[f][:n0]
            self.last_growth_copy_rows += n0
            if t is not None:
                t.save_key(self._bin_buf, k)
            self._bin_buf[k] = buf = new
        for i in range(n0, n1):
            row = self._rows[seq[i]]
            for f, rf in self._ROW_FIELDS:
                buf[f][i] = row[rf]
        self.last_append_rows += n1 - n0
        return self._publish(buf, n1)

    def _bin_restack(self, k: int, seq: list[tuple]) -> NeighborhoodBatch:
        """Rebuild bin ``k`` from memoized rows into a FRESH buffer (the
        row sequence changed mid-way, or the bin is new) — never in
        place, since a previous pack's views alias the old buffer."""
        t = txn.active()
        buf = self._alloc_buf(seq[0], len(seq))
        for i, rk in enumerate(seq):
            row = self._rows[rk]
            for f, rf in self._ROW_FIELDS:
                buf[f][i] = row[rf]
        if t is not None:
            t.save_key(self._bin_buf, k)
        self._bin_buf[k] = buf
        self.last_restack_rows += len(seq)
        return self._publish(buf, len(seq))

    # -- pack -------------------------------------------------------------

    def pack(
        self,
        cover: Cover,
        *,
        prev: PackedCover | None = None,
        level_cache: dict[int, int] | None = None,
    ) -> PackedCover:
        """Splice the packed arrays for the cover built by :meth:`assemble`.

        Only rows whose key is new this ingest are staged
        (``last_splice_rows``); per-bin arrays are reused outright when
        the bin's row sequence is unchanged, extended by one concatenate
        when rows were only appended, and re-stacked from memoized rows
        otherwise.  ``prev`` (the previous packed cover) is accepted for
        API symmetry — the splice state lives on this object.
        """
        assert self._pending is not None and self._pending[0] is cover, (
            "pack() must follow the assemble() that built this cover"
        )
        t = txn.active()
        if t is not None:
            for a in (
                "_pending", "_bin_seq", "_bin_arrays", "_bin_buf",
                "last_dirty", "last_splice_rows", "total_splice_rows",
                "last_append_rows", "total_append_rows",
                "last_growth_copy_rows", "total_growth_copy_rows",
                "last_restack_rows", "total_restack_rows",
                "last_added_pairs", "last_retracted_pairs",
            ):
                t.save_attr(self, a)
        _, keys = self._pending
        self._pending = None
        pair_level = _pair_level_fn(
            self._names, self.thresholds, level_cache if level_cache is not None else {}
        )

        # 1. stage rows for acquired keys not yet memoized (the O(dirty)
        # work) — members are recoverable from the row key itself.
        splice_rows = 0
        for rk in self._acquires:
            if rk not in self._rows:
                members = np.asarray(rk[1], dtype=np.int64)
                if t is not None:
                    t.save_key(self._rows, rk)
                self._rows[rk] = _stage_row(members, rk[0], self._adj, pair_level)
                splice_rows += 1

        # 2. reference counting: batch-apply releases then acquires; a
        # key is *fresh* (dirty) iff it was absent from the previous
        # cover, i.e. its refcount was zero and not because this very
        # ingest released it.
        released_to_zero: set[tuple] = set()
        gid_removed: set[int] = set()
        fresh_keys: set[tuple] = set()
        gid_fresh: set[int] = set()
        for rk in self._releases:
            if t is not None:
                t.save_key(self._row_ref, rk)
            self._row_ref[rk] -= 1
            if self._row_ref[rk] == 0:
                released_to_zero.add(rk)
            row = self._rows[rk]
            for g in row["gid"][row["pmask"]]:
                g = int(g)
                if t is not None:
                    t.save_key(self._lev_ref, g)
                self._lev_ref[g] -= 1
                if self._lev_ref[g] == 0:
                    gid_removed.add(g)
                self._ref_sub(self._gid_rows, g, rk)
            for e in rk[1]:
                self._ref_sub(self._ent_rows, e, rk)
        for rk in self._acquires:
            ref = self._row_ref.get(rk, 0)
            if ref == 0 and rk not in released_to_zero:
                fresh_keys.add(rk)
            if t is not None:
                t.save_key(self._row_ref, rk)
            self._row_ref[rk] = ref + 1
            row = self._rows[rk]
            for g, lv in zip(row["gid"][row["pmask"]], row["lev"][row["pmask"]]):
                g = int(g)
                ref_g = self._lev_ref.get(g, 0)
                if t is not None:
                    t.save_key(self._lev_ref, g)
                if ref_g == 0:
                    if t is not None:
                        t.save_key(self._pair_levels, g)
                    self._pair_levels[g] = int(lv)
                    if g not in gid_removed:
                        gid_fresh.add(g)
                self._lev_ref[g] = ref_g + 1
                self._ref_add(self._gid_rows, g, rk)
            for e in rk[1]:
                self._ref_add(self._ent_rows, e, rk)
        retracted = [g for g in gid_removed if self._lev_ref.get(g, 0) == 0]
        for g in retracted:
            if t is not None:
                t.save_key(self._pair_levels, g)
                t.save_key(self._lev_ref, g)
            del self._pair_levels[g]
            del self._lev_ref[g]
        added = {g: self._pair_levels[g] for g in gid_fresh}

        # 3. bin sequences + neighborhood indices (+ the row-key ->
        # positions map that resolves the splice-maintained incidence
        # lookups — built inside the walk pack already does).
        n_nb = len(keys)
        neighborhood_bin = np.zeros(n_nb, dtype=np.int64)
        neighborhood_row = np.zeros(n_nb, dtype=np.int64)
        bin_seqs: dict[int, list[tuple]] = {}
        pos_of_key: dict[tuple, list[int]] = {}
        for n, rk in enumerate(keys):
            k = rk[0]
            seq = bin_seqs.setdefault(k, [])
            neighborhood_bin[n] = k
            neighborhood_row[n] = len(seq)
            seq.append(rk)
            pos_of_key.setdefault(rk, []).append(n)

        # 4. per-bin splice: reuse / append / re-stack, against
        # capacity-doubling backing buffers (appends write only the
        # fresh tail rows; published arrays are views, so rows already
        # visible to a previous PackedCover are never overwritten).
        self.last_append_rows = 0
        self.last_growth_copy_rows = 0
        self.last_restack_rows = 0
        bins: dict[int, NeighborhoodBatch] = {}
        for k, seq in bin_seqs.items():
            old_seq = self._bin_seq.get(k)
            old_arr = self._bin_arrays.get(k)
            if old_arr is not None and old_seq == seq:
                bins[k] = old_arr
            elif (
                old_arr is not None
                and len(seq) > len(old_seq)
                and seq[: len(old_seq)] == old_seq
            ):
                bins[k] = self._bin_append(k, seq, len(old_seq))
            else:
                bins[k] = self._bin_restack(k, seq)
        self._bin_seq = bin_seqs
        self._bin_arrays = dict(bins)
        self._bin_buf = {k: b for k, b in self._bin_buf.items() if k in bins}
        self.total_append_rows += self.last_append_rows
        self.total_growth_copy_rows += self.last_growth_copy_rows
        self.total_restack_rows += self.last_restack_rows
        bin_rows = {k: np.where(neighborhood_bin == k)[0] for k in bins}

        # 5. evict rows that left the cover; publish per-ingest outputs.
        for rk in released_to_zero:
            if self._row_ref.get(rk, 0) == 0:
                if t is not None:
                    t.save_key(self._rows, rk)
                    t.save_key(self._row_ref, rk)
                self._rows.pop(rk, None)
                self._row_ref.pop(rk, None)
        self.last_dirty = [n for n, rk in enumerate(keys) if rk in fresh_keys]
        self.last_splice_rows = splice_rows
        self.total_splice_rows += splice_rows
        self.last_added_pairs = added
        self.last_retracted_pairs = retracted
        # registry-backed view of the splice accounting (cover.* family):
        # cumulative counterparts of the per-ingest last_* fields above
        reg = get_registry()
        reg.counter("cover.splice_rows").inc(splice_rows)
        reg.counter("cover.append_rows").inc(self.last_append_rows)
        reg.counter("cover.growth_copy_rows").inc(self.last_growth_copy_rows)
        reg.counter("cover.restack_rows").inc(self.last_restack_rows)
        self._acquires = []
        self._releases = []
        return PackedCover(
            bins=bins,
            bin_rows=bin_rows,
            neighborhood_bin=neighborhood_bin,
            neighborhood_row=neighborhood_row,
            pair_levels=dict(self._pair_levels),
            cover=cover,
            row_keys=list(keys),
            slot_lookup=(self._gid_rows, self._ent_rows, pos_of_key),
        )
