"""Covering (paper §4): canopies + relational boundary => total cover.

Pipeline (paper-faithful):

1. *Canopies* [McCallum-Nigam-Ungar 2000] over the ``Similar`` relation:
   entities are embedded as hashed n-gram profiles; a seed's canopy is
   every entity with cosine >= ``t_loose``; entities within ``t_tight``
   of the seed stop being seeds.  On TPU the seed-vs-pool similarity is
   the ``ngram_sim`` Pallas kernel (a tiled matmul).
2. *Boundary expansion*: each canopy is expanded with every entity that
   shares a relation tuple (Coauthor) with a member => the cover is
   **total** w.r.t. the relations (Def. 7): no tuple is lost.
3. *Packing*: neighborhoods are padded to fixed entity capacity and
   binned by size (k in ``k_bins``) so the batched matcher runs on
   dense, static shapes.  Size-binning is also our structural answer to
   the MapReduce skew the paper reports in §6.3 (see DESIGN §3).

Oversized canopies are split into overlapping windows (stride k/2) in
similarity-sorted order — the standard blocking trade-off; every split
window is boundary-expanded again, so totality is preserved.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import pairs as pairlib
from repro.core import similarity as simlib
from repro.core.types import EntityTable, NeighborhoodBatch, Relations
from repro.kernels.ngram_sim import ops as sim_ops

DEFAULT_BINS = (8, 16, 24, 32)


@dataclasses.dataclass
class Cover:
    """A total cover: per neighborhood, core members and full (core+boundary)."""

    core: list[np.ndarray]
    full: list[np.ndarray]
    _entity_index: dict[int, list[int]] | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def __len__(self) -> int:
        return len(self.full)

    def entity_index(self) -> dict[int, list[int]]:
        """entity id -> neighborhoods (by full membership).

        Memoized: a Cover is immutable once assembled, and the drivers
        consult this index on every evidence-driven re-activation — an
        O(n) rebuild per worklist step without the cache.
        """
        if self._entity_index is None:
            idx: dict[int, list[int]] = {}
            for n, members in enumerate(self.full):
                for e in members:
                    idx.setdefault(int(e), []).append(n)
            self._entity_index = idx
        return self._entity_index


def build_canopies(
    features: np.ndarray,
    t_loose: float,
    t_tight: float,
    *,
    chunk: int = 1024,
) -> list[np.ndarray]:
    """Deterministic canopy construction (seeds in id order).

    The paper picks random seeds; a fixed seed order is a valid draw and
    keeps the construction reproducible.  Order-invariance of the *match
    output* is the framework's consistency property, tested separately.
    """
    n = features.shape[0]
    remaining = np.ones(n, dtype=bool)
    canopies: list[np.ndarray] = []
    order = np.arange(n)
    for seed in order:
        if not remaining[seed]:
            continue
        sims = np.zeros(n, dtype=np.float32)
        q = features[seed : seed + 1]
        for lo in range(0, n, chunk):
            hi = min(lo + chunk, n)
            block = np.asarray(sim_ops.sim_above(q, features[lo:hi], 0.0))[0]
            sims[lo:hi] = block
        members = np.where(sims >= t_loose)[0]
        if len(members) == 0:
            members = np.array([seed])
        canopies.append(members.astype(np.int64))
        remaining[sims >= t_tight] = False
        remaining[seed] = False
    return canopies


def _split_oversized(members: np.ndarray, names: list[str], k_core: int) -> list[np.ndarray]:
    if len(members) <= k_core:
        return [members]
    order = np.argsort([names[int(e)] for e in members], kind="stable")
    sorted_members = members[order]
    out = []
    step = max(k_core // 2, 1)
    for lo in range(0, len(sorted_members), step):
        win = sorted_members[lo : lo + k_core]
        if len(win) == 0:
            break
        out.append(win)
        if lo + k_core >= len(sorted_members):
            break
    return out


def build_cover(
    entities: EntityTable,
    relations: Relations,
    *,
    t_loose: float = 0.70,
    t_tight: float = 0.90,
    k_max: int = 32,
    feature_dim: int = 128,
    boundary_relation: str = "coauthor",
) -> Cover:
    if entities.features is None:
        entities.features = simlib.ngram_profiles(
            [simlib.block_key(n) for n in entities.names], dim=feature_dim
        )
    canopies = build_canopies(entities.features, t_loose, t_tight)
    return assemble_cover(
        canopies,
        entities,
        relations,
        k_max=k_max,
        boundary_relation=boundary_relation,
    )


def assemble_cover(
    canopies: list[np.ndarray],
    entities: EntityTable,
    relations: Relations,
    *,
    k_max: int = 32,
    boundary_relation: str = "coauthor",
    present: set[int] | None = None,
) -> Cover:
    """Deterministic canopies -> total cover assembly (split + boundary +
    totality sweep + leftovers).

    Shared by the batch path (:func:`build_cover`) and the streaming
    delta-maintenance path (:mod:`repro.stream.delta`): given the *same*
    canopies in the same order, both produce the identical Cover, which
    is what makes the streaming fixpoint bit-for-bit equal to the batch
    one.  ``present`` restricts the entity-coverage sweep to ids that
    actually exist (a streaming service ingesting batches out of id
    order has temporary holes in the id space).
    """
    adj = relations.adjacency_sets(boundary_relation)
    core_sets: list[np.ndarray] = []
    full_sets: list[np.ndarray] = []
    seen: set[tuple] = set()
    # reserve boundary room: boundary can add up to k_max - k_core slots
    k_core = max(2, int(k_max * 0.6))
    for members in canopies:
        for part in _split_oversized(members, entities.names, k_core):
            key = tuple(sorted(int(e) for e in part))
            if key in seen or len(part) < 2:
                continue
            seen.add(key)
            boundary: set[int] = set()
            part_set = set(int(e) for e in part)
            for e in part:
                boundary |= adj.get(int(e), set())
            boundary -= part_set
            # clip boundary to capacity, preferring high-degree connectors
            room = k_max - len(part)
            if len(boundary) > room:
                ranked = sorted(
                    boundary,
                    key=lambda b: -len(adj.get(b, set()) & part_set),
                )
                boundary = set(ranked[:room])
            full = np.array(sorted(part_set | boundary), dtype=np.int64)
            core_sets.append(np.asarray(sorted(part_set), dtype=np.int64))
            full_sets.append(full)

    # Totality sweep (Def. 7): boundary clipping above can drop relation
    # tuples, and canopy singletons never enter a neighborhood.  Gather
    # every uncovered relation edge and pack the endpoints into
    # supplementary neighborhoods so that R(E) = U R(C_i) exactly.
    covered_edges: set[tuple[int, int]] = set()
    for members in full_sets:
        ms = [int(e) for e in members]
        mset = set(ms)
        for e in ms:
            for nb in adj.get(e, set()):
                if nb in mset:
                    covered_edges.add((min(e, nb), max(e, nb)))
    missing: list[tuple[int, int]] = []
    for edges in relations.edges.values():
        for a, b in edges:
            a, b = int(a), int(b)
            if a != b and (min(a, b), max(a, b)) not in covered_edges:
                missing.append((min(a, b), max(a, b)))
    if missing:
        group: set[int] = set()
        for a, b in sorted(set(missing)):
            if len(group | {a, b}) > k_max:
                arr = np.asarray(sorted(group), dtype=np.int64)
                core_sets.append(arr)
                full_sets.append(arr)
                group = set()
            group |= {a, b}
        if group:
            arr = np.asarray(sorted(group), dtype=np.int64)
            core_sets.append(arr)
            full_sets.append(arr)

    # Entity coverage (cover definition: union of neighborhoods == E):
    # canopy singletons with no relation edges still need a home.
    covered_entities: set[int] = set()
    for members in full_sets:
        covered_entities.update(int(e) for e in members)
    universe = set(range(len(entities))) if present is None else set(present)
    leftovers = sorted(universe - covered_entities)
    for lo in range(0, len(leftovers), k_max):
        arr = np.asarray(leftovers[lo : lo + k_max], dtype=np.int64)
        core_sets.append(arr)
        full_sets.append(arr)
    return Cover(core=core_sets, full=full_sets)


def is_total(cover: Cover, relations: Relations, candidate_gids: np.ndarray) -> bool:
    """Check Def. 7 (relations) + blocking totality over candidate pairs."""
    covered = set()
    for members in cover.full:
        ms = set(int(e) for e in members)
        for a in ms:
            for b in ms:
                if a < b:
                    covered.add(int(pairlib.make_gid(a, b)))
    for edges in relations.edges.values():
        for a, b in edges:
            if a == b:
                continue
            if int(pairlib.make_gid(int(a), int(b))) not in covered:
                return False
    return all(int(g) in covered for g in candidate_gids)


# ---------------------------------------------------------------------------
# Packing into padded, size-binned NeighborhoodBatches
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedCover:
    """Size-binned padded tensors + host-side indices for message passing."""

    bins: dict[int, NeighborhoodBatch]  # k -> batch over neighborhoods
    bin_rows: dict[int, np.ndarray]  # k -> neighborhood index per row
    neighborhood_bin: np.ndarray  # (N,) bin k of each neighborhood
    neighborhood_row: np.ndarray  # (N,) row within its bin
    pair_levels: dict[int, int]  # global gid -> sim level (>=1)
    cover: Cover
    # per-neighborhood row keys (bin, members, intra-relation edges) —
    # populated only when packing with a row_cache; the streaming path
    # diffs them across ingests to find dirty neighborhoods.
    row_keys: list[tuple] | None = None
    # memoized slot-incidence CSR (gid -> neighborhoods), see
    # slot_incidence(); a PackedCover is immutable once built.
    _slot_csr: tuple[np.ndarray, np.ndarray, np.ndarray] | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def num_neighborhoods(self) -> int:
        return len(self.neighborhood_bin)

    def rows_for(self, neighborhoods: list[int]) -> dict[int, np.ndarray]:
        """Group a set of neighborhood ids by bin -> row arrays."""
        out: dict[int, list[int]] = {}
        for n in neighborhoods:
            out.setdefault(int(self.neighborhood_bin[n]), []).append(
                int(self.neighborhood_row[n])
            )
        return {k: np.asarray(v, dtype=np.int64) for k, v in out.items()}

    def neighborhoods_of_pairs(self, gids: np.ndarray) -> list[int]:
        """Neighborhoods containing BOTH endpoints of any of the pairs."""
        idx = self.cover.entity_index()
        out: set[int] = set()
        for g in gids:
            a, b = pairlib.split_gid(np.int64(g))
            na = idx.get(int(a), [])
            nb = set(idx.get(int(b), []))
            for n in na:
                if n in nb:
                    out.add(n)
        return sorted(out)

    def slot_incidence(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR incidence: candidate pair gid -> neighborhoods holding it
        as a *candidate slot* (``pair_mask`` true).

        Returns ``(gids, indptr, nbhd)``: sorted unique gids, and for
        gid ``gids[i]`` the neighborhoods ``nbhd[indptr[i]:indptr[i+1]]``.
        This is the structure the round-parallel driver re-activates
        from: it is a subset of :meth:`neighborhoods_of_pairs`
        (endpoint incidence), and the difference is inert — a
        neighborhood holding both endpoints but not the candidate slot
        projects no evidence from that pair, so re-evaluating it can
        produce nothing new (its fixpoint contribution is unchanged).
        Built vectorized from the packed bins and memoized.
        """
        if self._slot_csr is None:
            gid_parts: list[np.ndarray] = []
            nb_parts: list[np.ndarray] = []
            for k, nb in self.bins.items():
                mask = nb.pair_mask & (nb.pair_gid >= 0)
                rows, _ = np.nonzero(mask)
                gid_parts.append(nb.pair_gid[mask])
                nb_parts.append(self.bin_rows[k][rows])
            if gid_parts:
                flat_gid = np.concatenate(gid_parts)
                flat_nb = np.concatenate(nb_parts)
                order = np.argsort(flat_gid, kind="stable")
                flat_gid, flat_nb = flat_gid[order], flat_nb[order]
                uniq, starts = np.unique(flat_gid, return_index=True)
                indptr = np.append(starts, len(flat_gid))
            else:
                uniq = np.zeros(0, dtype=np.int64)
                indptr = np.zeros(1, dtype=np.int64)
                flat_nb = np.zeros(0, dtype=np.int64)
            self._slot_csr = (uniq, indptr, flat_nb)
        return self._slot_csr

    def neighborhoods_of_slot_pairs(self, gids: np.ndarray) -> list[int]:
        """Neighborhoods with any of ``gids`` as a candidate slot (sorted)."""
        uniq, indptr, nbhd = self.slot_incidence()
        if not len(gids) or not len(uniq):
            return []
        g = np.asarray(gids, dtype=np.int64)
        pos = np.searchsorted(uniq, g)
        pos = np.clip(pos, 0, len(uniq) - 1)
        pos = pos[uniq[pos] == g]
        if not len(pos):
            return []
        hits = np.concatenate([nbhd[indptr[i] : indptr[i + 1]] for i in pos])
        return [int(n) for n in np.unique(hits)]


def pack_cover(
    cover: Cover,
    entities: EntityTable,
    relations: Relations,
    *,
    k_bins: tuple[int, ...] = DEFAULT_BINS,
    thresholds=simlib.DEFAULT_THRESHOLDS,
    boundary_relation: str = "coauthor",
    level_cache: dict[int, int] | None = None,
    row_cache: dict[tuple, dict] | None = None,
) -> PackedCover:
    """Pack a cover into size-binned padded tensors.

    ``level_cache`` and ``row_cache`` are optional *persistent* caches
    for the streaming path: ``level_cache`` memoizes the host-side
    Jaro-Winkler discretization per global pair (a pure memo — the
    streaming layer may bound it, see ``DeltaCover.level_cache_max``),
    and ``row_cache`` memoizes fully staged neighborhood rows keyed by
    ``(k, members, intra-relation edges)`` — a key that changes whenever
    anything that feeds the row tensors changes, so stale entries can
    never be reused.  Batch callers omit both and get the original
    behavior; repacking after a micro-batch only stages rows for
    new/changed neighborhoods ("repack only affected bins").
    """
    adj = relations.adjacency_sets(boundary_relation)
    names = entities.names
    if level_cache is None:
        level_cache = {}

    def pair_level(a: int, b: int) -> int:
        gid = int(pairlib.make_gid(a, b))
        lev = level_cache.get(gid)
        if lev is None:
            s = simlib.jaro_winkler(simlib.name_key(names[a]), simlib.name_key(names[b]))
            lev = int(simlib.discretize(np.asarray([s]), thresholds)[0])
            if lev == 0 and simlib.abbrev_compatible(names[a], names[b]):
                lev = 1  # abbreviation-aware weak candidate
            elif lev > 0 and simlib.first_name_conflict(names[a], names[b]):
                lev = 0  # full first names of different people: veto
            level_cache[gid] = lev
        return lev

    n_nb = len(cover)
    neighborhood_bin = np.zeros(n_nb, dtype=np.int64)
    neighborhood_row = np.zeros(n_nb, dtype=np.int64)
    staged: dict[int, list[dict]] = {k: [] for k in k_bins}
    row_keys: list[tuple] | None = [] if row_cache is not None else None

    for n, members in enumerate(cover.full):
        size = len(members)
        k = next((kb for kb in k_bins if size <= kb), k_bins[-1])
        members = members[:k]  # safety clip (build_cover respects k_max)
        k_eff = k

        row = None
        row_key = None
        if row_cache is not None:
            mkey = tuple(int(e) for e in members)
            intra = tuple(
                (a, b)
                for ai, a in enumerate(mkey)
                for b in mkey[ai + 1 :]
                if b in adj.get(a, set())
            )
            row_key = (k, mkey, intra)
            row_keys.append(row_key)
            row = row_cache.get(row_key)
        if row is None:
            P = pairlib.num_pairs(k_eff)
            ii, jj = pairlib.triu_indices(k_eff)

            ids = np.full(k_eff, -1, dtype=np.int64)
            ids[: len(members)] = members
            emask = ids >= 0
            co = np.zeros((k_eff, k_eff), dtype=bool)
            for a_slot in range(len(members)):
                a = int(members[a_slot])
                nbrs = adj.get(a, set())
                for b_slot in range(a_slot + 1, len(members)):
                    if int(members[b_slot]) in nbrs:
                        co[a_slot, b_slot] = True
                        co[b_slot, a_slot] = True

            lev = np.zeros(P, dtype=np.int8)
            gid = np.full(P, -1, dtype=np.int64)
            pmask = np.zeros(P, dtype=bool)
            for p in range(P):
                i, j = int(ii[p]), int(jj[p])
                if not (emask[i] and emask[j]):
                    continue
                a, b = int(ids[i]), int(ids[j])
                lv = pair_level(a, b)
                if lv >= 1:
                    lev[p] = lv
                    gid[p] = pairlib.make_gid(a, b)
                    pmask[p] = True
            row = dict(ids=ids, emask=emask, co=co, lev=lev, gid=gid, pmask=pmask)
            if row_cache is not None:
                row_cache[row_key] = row

        neighborhood_bin[n] = k
        neighborhood_row[n] = len(staged[k])
        staged[k].append(row)

    bins: dict[int, NeighborhoodBatch] = {}
    bin_rows: dict[int, np.ndarray] = {}
    for k, rows in staged.items():
        if not rows:
            continue
        bins[k] = NeighborhoodBatch(
            entity_ids=np.stack([r["ids"] for r in rows]),
            entity_mask=np.stack([r["emask"] for r in rows]),
            coauthor=np.stack([r["co"] for r in rows]),
            sim_level=np.stack([r["lev"] for r in rows]),
            pair_gid=np.stack([r["gid"] for r in rows]),
            pair_mask=np.stack([r["pmask"] for r in rows]),
        )
        rows_idx = np.where(neighborhood_bin == k)[0]
        bin_rows[k] = rows_idx

    # pair_levels must reflect pairs co-resident in *this* cover — not the
    # level cache, which on the streaming path persists across covers and
    # would leak retracted candidate pairs into the global grounding.
    pair_levels: dict[int, int] = {}
    for rows in staged.values():
        for r in rows:
            for g, lv in zip(r["gid"][r["pmask"]], r["lev"][r["pmask"]]):
                pair_levels[int(g)] = int(lv)
    return PackedCover(
        bins=bins,
        bin_rows=bin_rows,
        neighborhood_bin=neighborhood_bin,
        neighborhood_row=neighborhood_row,
        pair_levels=pair_levels,
        cover=cover,
        row_keys=row_keys,
    )
