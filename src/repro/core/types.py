"""Core data types for the collective entity-matching framework.

Everything the TPU sees is a *padded dense tensor*; everything kept on
the host between message-passing rounds is a plain numpy structure.

The paper's objects map as follows:

=====================  =========================================
Paper                  Here
=====================  =========================================
entity set E           :class:`EntityTable`
relations R            :class:`Relations` (Coauthor adjacency COO)
neighborhood C_i       one row of :class:`NeighborhoodBatch`
cover C                :class:`NeighborhoodBatch` (+ bins)
match set M+           :class:`MatchStore` (sorted int64 gids)
maximal message        one row of a message table (host)
=====================  =========================================
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from repro.core import pairs as pairlib


@dataclasses.dataclass
class EntityTable:
    """A set of entity references.

    names:     list of raw strings (author-reference surface forms).
    truth:     int64 ground-truth entity id per reference (-1 unknown).
    features:  optional hashed n-gram count profiles (N, F) float32,
               built lazily by repro.core.similarity.ngram_profiles.
    """

    names: list[str]
    truth: np.ndarray | None = None
    features: np.ndarray | None = None

    def __len__(self) -> int:
        return len(self.names)


@dataclasses.dataclass
class Relations:
    """Relational evidence (the paper's R). COO edge list over entity ids.

    For the bibliographic domain there is a single ``Coauthor`` relation;
    the framework supports any number of symmetric binary relations, each
    identified by name.
    """

    edges: dict[str, np.ndarray]  # name -> (E, 2) int64 (undirected)

    def adjacency_sets(self, name: str) -> dict[int, set[int]]:
        adj: dict[int, set[int]] = {}
        e = self.edges.get(name)
        if e is None:
            return adj
        for a, b in e:
            adj.setdefault(int(a), set()).add(int(b))
            adj.setdefault(int(b), set()).add(int(a))
        return adj

    def all_edges(self) -> np.ndarray:
        if not self.edges:
            return np.zeros((0, 2), dtype=np.int64)
        return np.concatenate(list(self.edges.values()), axis=0)


@dataclasses.dataclass
class NeighborhoodBatch:
    """A batch of ``B`` neighborhoods padded to ``k`` entity slots.

    entity_ids : (B, k) int64, -1 padding.
    entity_mask: (B, k) bool.
    coauthor   : (B, k, k) bool   relation adjacency restricted to slots.
    sim_level  : (B, P) int8      0 = not a candidate pair, else level 1..3.
    pair_gid   : (B, P) int64     global pair id (-1 where not a candidate).
    pair_mask  : (B, P) bool      candidate-pair validity.
    """

    entity_ids: np.ndarray
    entity_mask: np.ndarray
    coauthor: np.ndarray
    sim_level: np.ndarray
    pair_gid: np.ndarray
    pair_mask: np.ndarray

    @property
    def batch(self) -> int:
        return self.entity_ids.shape[0]

    @property
    def k(self) -> int:
        return self.entity_ids.shape[1]

    @property
    def num_pairs(self) -> int:
        return self.sim_level.shape[1]

    def row(self, b: int) -> "NeighborhoodBatch":
        return NeighborhoodBatch(
            self.entity_ids[b : b + 1],
            self.entity_mask[b : b + 1],
            self.coauthor[b : b + 1],
            self.sim_level[b : b + 1],
            self.pair_gid[b : b + 1],
            self.pair_mask[b : b + 1],
        )

    def select(self, idx: np.ndarray) -> "NeighborhoodBatch":
        return NeighborhoodBatch(
            self.entity_ids[idx],
            self.entity_mask[idx],
            self.coauthor[idx],
            self.sim_level[idx],
            self.pair_gid[idx],
            self.pair_mask[idx],
        )

    def pad_batch_to(self, n: int) -> "NeighborhoodBatch":
        """Pad the batch axis with empty neighborhoods (for SPMD shards)."""
        b = self.batch
        if b == n:
            return self
        assert n > b
        extra = n - b

        def _pad(x: np.ndarray, fill) -> np.ndarray:
            shape = (extra,) + x.shape[1:]
            return np.concatenate([x, np.full(shape, fill, dtype=x.dtype)])

        return NeighborhoodBatch(
            _pad(self.entity_ids, -1),
            _pad(self.entity_mask, False),
            _pad(self.coauthor, False),
            _pad(self.sim_level, 0),
            _pad(self.pair_gid, -1),
            _pad(self.pair_mask, False),
        )


class MatchStore:
    """Global set of matched pairs, kept as a sorted int64 gid array.

    Supports the three operations message passing needs: membership
    projection onto a neighborhood batch, union with new matches, and
    set difference (for "what is new this round").
    """

    def __init__(self, gids: np.ndarray | None = None):
        if gids is None:
            gids = np.zeros((0,), dtype=np.int64)
        self.gids = np.unique(np.asarray(gids, dtype=np.int64))

    def __len__(self) -> int:
        return int(self.gids.shape[0])

    def __contains__(self, gid: int) -> bool:
        i = np.searchsorted(self.gids, gid)
        return bool(i < len(self.gids) and self.gids[i] == gid)

    def copy(self) -> "MatchStore":
        return MatchStore(self.gids.copy())

    def union(self, new_gids: np.ndarray) -> "MatchStore":
        if len(new_gids) == 0:
            return self
        return MatchStore(np.concatenate([self.gids, new_gids]))

    def difference(self, other: "MatchStore") -> np.ndarray:
        return self.gids[~np.isin(self.gids, other.gids, assume_unique=True)]

    def mask_of(self, pair_gid: np.ndarray) -> np.ndarray:
        """Boolean mask of same shape as pair_gid: which pairs are in here."""
        if len(self.gids) == 0:
            return np.zeros(pair_gid.shape, dtype=bool)
        flat = pair_gid.reshape(-1)
        out = np.isin(flat, self.gids)
        out &= flat >= 0
        return out.reshape(pair_gid.shape)

    def as_set(self) -> set[int]:
        return set(int(g) for g in self.gids)

    @staticmethod
    def from_pairs(a: Iterable[int], b: Iterable[int]) -> "MatchStore":
        a = np.asarray(list(a), dtype=np.int64)
        b = np.asarray(list(b), dtype=np.int64)
        if len(a) == 0:
            return MatchStore()
        return MatchStore(pairlib.make_gid(a, b))
