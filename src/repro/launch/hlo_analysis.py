"""Loop-aware static analysis of partitioned HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so any
program built from ``lax.scan`` (every layer stack here) is massively
under-counted.  This module re-derives the three roofline inputs from
``compiled.as_text()`` with loop trip counts applied:

* **flops** — ``dot`` ops: ``2 x result_elems x contracted_elems``;
  convolutions ``2 x result x window``; elementwise/reduce ops 1 flop
  per element.  ``while`` bodies are multiplied by their trip count
  (recovered from the scan-induction-variable ``compare(iv, C)`` in the
  loop condition); fusions/calls are recursed.
* **bytes** — HBM traffic proxy: for every top-level op of every
  executed computation, result bytes + operand bytes (fusion interiors
  excluded — the fusion boundary is what touches HBM), times the
  enclosing trip counts.
* **collectives** — every all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute with per-device operand bytes, a wire
  traffic model, replica-group size, cross-pod (DCN) classification, and
  the enclosing loop multiplier.

Shapes in a partitioned module are per-device, so every number this
module reports is *per chip*.

Fixed-point ``lax.while_loop``s (the EM matcher's convergence loops)
have data-dependent trip counts; they are reported with trip=1 and
flagged in ``unknown_whiles`` so callers can scale by an assumed sweep
count.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|u4|s4|pred|c64|c128|token)\[([0-9,]*)\]"
)
COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.-]+)\s*=\s*")
ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "power", "negate", "abs", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "compare", "select",
    "and", "or", "xor", "not", "clamp", "atan2", "remainder", "sine",
    "cosine", "tan", "erf", "logistic", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "clz", "popcnt",
}
SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id",
}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}
WIRE_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0, "ragged-all-to-all": 1.0,
}


def type_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for m in SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attributes (raw tail of the line)

    @property
    def result_elems(self) -> int:
        return type_elems_bytes(self.type_str)[0]

    @property
    def result_bytes(self) -> int:
        return type_elems_bytes(self.type_str)[1]

    def operand_names(self) -> list[str]:
        # operands live before the closing paren that starts the attr list
        depth, i = 1, 0
        while i < len(self.rest) and depth:
            if self.rest[i] == "(":
                depth += 1
            elif self.rest[i] == ")":
                depth -= 1
            i += 1
        return re.findall(r"%[\w.-]+", self.rest[: i])

    def attr(self, key: str) -> str | None:
        m = re.search(rf"{key}=([^,]+(?:\{{[^}}]*\}})?)", self.rest)
        return m.group(1) if m else None

    def called_computations(self) -> list[str]:
        names: list[str] = []
        for key in ("calls", "to_apply", "condition", "body",
                    "true_computation", "false_computation"):
            m = re.search(rf"{key}=(%[\w.-]+)", self.rest)
            if m:
                names.append(m.group(1))
        m = re.search(r"branch_computations=\{([^}]*)\}", self.rest)
        if m:
            names.extend(re.findall(r"%[\w.-]+", m.group(1)))
        return names


def _parse_instr(line: str) -> Instr | None:
    """Split one HLO line into (name, result type, opcode, tail).

    Result types can be tuples containing ``/*index=N*/`` comments, so
    the type is scanned with a paren balance instead of a regex.
    """
    m = NAME_RE.match(line)
    if m is None:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":  # tuple type
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i : j + 1]
        i = j + 1
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        type_str = line[i:j]
        i = j
    # opcode token, then the '(' that opens the operand list
    rest = line[i:].lstrip()
    p = rest.find("(")
    if p < 0:
        return None
    opcode = rest[:p].strip()
    if not re.fullmatch(r"[\w-]+", opcode):
        return None
    return Instr(name, type_str, opcode, rest[p + 1 :])


def parse_module(text: str) -> tuple[dict[str, list[Instr]], str | None]:
    comps: dict[str, list[Instr]] = {}
    entry: str | None = None
    cur: list[Instr] | None = None
    for line in text.splitlines():
        h = COMP_HEADER_RE.match(line.strip()) if "{" in line else None
        if h and ("->" in line):
            name = h.group(1)
            comps[name] = []
            cur = comps[name]
            if line.lstrip().startswith("ENTRY"):
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.append(ins)
    return comps, entry


# ---------------------------------------------------------------------------
# Per-computation analysis
# ---------------------------------------------------------------------------


def _shape_env(instrs: list[Instr]) -> dict[str, str]:
    return {i.name: i.type_str for i in instrs}


def _dot_flops(instr: Instr, env: dict[str, str]) -> float:
    ops = instr.operand_names()
    if not ops:
        return 0.0
    lhs_type = env.get(ops[0], "")
    dims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    contracted = 1
    if dims_m and lhs_type:
        sm = SHAPE_RE.search(lhs_type)
        if sm:
            shape = [int(d) for d in sm.group(2).split(",") if d]
            for di in dims_m.group(1).split(","):
                if di:
                    contracted *= shape[int(di)] if int(di) < len(shape) else 1
    return 2.0 * instr.result_elems * contracted


def _conv_flops(instr: Instr) -> float:
    m = re.search(r"window=\{size=([0-9x]+)", instr.rest)
    window = 1
    if m:
        for d in m.group(1).split("x"):
            window *= int(d)
    return 2.0 * instr.result_elems * window


def _trip_count(comps: dict[str, list[Instr]], cond_name: str) -> int | None:
    """Recover the scan trip count from the loop condition computation."""
    seen: list[int] = []
    stack = [cond_name]
    visited = set()
    while stack:
        cn = stack.pop()
        if cn in visited or cn not in comps:
            continue
        visited.add(cn)
        for ins in comps[cn]:
            if ins.opcode == "constant" and ins.type_str.strip() in ("s32[]", "u32[]", "s64[]", "u64[]"):
                m = re.match(r"([0-9-]+)", ins.rest.rstrip(") "))
                if m:
                    seen.append(int(m.group(1)))
            for c in ins.called_computations():
                stack.append(c)
    pos = [c for c in seen if c > 0]
    return max(pos) if pos else None


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: list[dict] = dataclasses.field(default_factory=list)
    unknown_whiles: int = 0
    bf16_upcast_bytes: float = 0.0  # CPU-backend bf16 legalization copies

    def add(self, other: "Analysis", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for c in other.collectives:
            c2 = dict(c)
            c2["mult"] = c.get("mult", 1.0) * mult
            self.collectives.append(c2)
        self.unknown_whiles += other.unknown_whiles
        # buffer-space estimate: count each conversion site once, not
        # per loop trip (the f32 buffer is reused across iterations)
        self.bf16_upcast_bytes += other.bf16_upcast_bytes


def _replica_groups(instr: Instr, n_devices: int, pod_boundary: int):
    """(group_size, cross_pod) from either explicit or iota group syntax."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?", instr.rest)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        n = int(np.prod(dims))
        ids = np.arange(n).reshape(dims)
        if m.group(4):
            perm = [int(d) for d in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(g, s)
        cross = bool(np.any(groups // pod_boundary
                            != groups[:, :1] // pod_boundary))
        return s, cross
    m = re.search(r"replica_groups=\{(\{[0-9, ]+\}(?:,\{[0-9, ]+\})*)\}", instr.rest)
    if m:
        groups = [
            [int(x) for x in re.findall(r"\d+", grp)]
            for grp in re.findall(r"\{([0-9, ]+)\}", m.group(1))
        ]
        size = max(len(g) for g in groups)
        cross = any(
            (max(g) // pod_boundary) != (min(g) // pod_boundary) for g in groups
        )
        return size, cross
    return n_devices, False


def analyze_computation(
    comps: dict[str, list[Instr]],
    name: str,
    cache: dict[str, Analysis],
    *,
    n_devices: int,
    pod_boundary: int,
    inside_fusion: bool = False,
) -> Analysis:
    if name in cache:
        return cache[name]
    cache[name] = Analysis()  # cycle guard
    instrs = comps.get(name, [])
    env = _shape_env(instrs)
    out = Analysis()
    for ins in instrs:
        op = ins.opcode
        if op == "dot":
            out.flops += _dot_flops(ins, env)
        elif op == "convolution":
            out.flops += _conv_flops(ins)
        elif op in ELEMWISE:
            out.flops += ins.result_elems
        elif op in ("reduce", "reduce-window"):
            ops = ins.operand_names()
            if ops and ops[0] in env:
                out.flops += type_elems_bytes(env[ops[0]])[0]
        elif op == "convert" and "f32[" in ins.type_str:
            # XLA *CPU* legalizes bf16 by inserting f32 round-trips of
            # whole buffers (TPU executes bf16 natively).  Track large
            # bf16->f32 converts so memory reports can be TPU-adjusted.
            srcs = ins.operand_names()
            if srcs and "bf16[" in env.get(srcs[0], ""):
                if ins.result_bytes >= 32 * 2**20:
                    out.bf16_upcast_bytes += ins.result_bytes
        elif op in COLLECTIVES:
            kind = op.replace("-start", "")
            gsize, cross = _replica_groups(ins, n_devices, pod_boundary)
            nbytes = ins.result_bytes
            out.collectives.append({
                "kind": kind, "bytes": nbytes,
                "wire_bytes": nbytes * WIRE_FACTOR.get(kind, 1.0),
                "group_size": gsize, "cross_pod": cross, "mult": 1.0,
            })

        if op == "while":
            cond = re.search(r"condition=(%[\w.-]+)", ins.rest)
            body = re.search(r"body=(%[\w.-]+)", ins.rest)
            # XLA annotates statically known trip counts (scan loops)
            ktc = re.search(r'known_trip_count[":{\s]+n[":\s]+(\d+)', ins.rest)
            trip = int(ktc.group(1)) if ktc else (
                _trip_count(comps, cond.group(1)) if cond else None
            )
            if trip is None:
                trip = 1
                out.unknown_whiles += 1
            if body:
                sub = analyze_computation(
                    comps, body.group(1), cache,
                    n_devices=n_devices, pod_boundary=pod_boundary,
                )
                out.add(sub, mult=float(trip))
            if cond:
                subc = analyze_computation(
                    comps, cond.group(1), cache,
                    n_devices=n_devices, pod_boundary=pod_boundary,
                )
                out.add(subc, mult=float(trip))
        elif op == "fusion":
            called = ins.called_computations()
            if called:
                sub = analyze_computation(
                    comps, called[0], cache,
                    n_devices=n_devices, pod_boundary=pod_boundary,
                    inside_fusion=True,
                )
                # flops from the interior; bytes only at the boundary
                out.flops += sub.flops
                out.collectives.extend(dict(c) for c in sub.collectives)
                out.unknown_whiles += sub.unknown_whiles
                out.bf16_upcast_bytes += sub.bf16_upcast_bytes
        elif op in ("call", "async-start", "custom-call"):
            for cn in ins.called_computations():
                sub = analyze_computation(
                    comps, cn, cache,
                    n_devices=n_devices, pod_boundary=pod_boundary,
                )
                out.add(sub)
        elif op == "conditional":
            branches = ins.called_computations()
            if branches:
                subs = [
                    analyze_computation(
                        comps, b, cache,
                        n_devices=n_devices, pod_boundary=pod_boundary,
                    )
                    for b in branches
                ]
                out.add(max(subs, key=lambda a: a.flops))
        elif op == "reduce" and not inside_fusion:
            pass  # to_apply is a scalar computation; already counted above

        # HBM-traffic proxy (fusion interiors excluded).  Elementwise /
        # shape ops count result bytes only: a TPU build fuses the
        # producer chain, so their operands never round-trip HBM (the
        # CPU backend fuses far less; counting its op boundaries
        # verbatim would inflate the memory term ~3x).
        if not inside_fusion and op not in SKIP_BYTES and op != "while":
            nbytes = ins.result_bytes
            if op not in ELEMWISE and op not in (
                "broadcast", "iota", "reshape", "transpose", "convert",
                "reduce", "copy", "slice", "pad", "reverse", "concatenate",
            ):
                for o in ins.operand_names():
                    if o in env:
                        nbytes += type_elems_bytes(env[o])[1]
            out.bytes += nbytes

    cache[name] = out
    return out


def analyze(text: str, *, n_devices: int = 256, pod_boundary: int = 256) -> dict:
    """Full-module analysis. All numbers are per device."""
    comps, entry = parse_module(text)
    if entry is None:
        # fall back: the last computation is usually the entry
        entry = list(comps)[-1] if comps else None
    cache: dict[str, Analysis] = {}
    res = analyze_computation(
        comps, entry, cache, n_devices=n_devices, pod_boundary=pod_boundary
    ) if entry else Analysis()

    colls = res.collectives
    def wsum(pred):
        return float(sum(c["wire_bytes"] * c.get("mult", 1.0) for c in colls if pred(c)))

    by_kind = {}
    for c in colls:
        k = c["kind"]
        by_kind[k] = by_kind.get(k, 0.0) + c["wire_bytes"] * c.get("mult", 1.0)
    return {
        "flops": float(res.flops),
        "bytes": float(res.bytes),
        "collective_bytes": float(
            sum(c["bytes"] * c.get("mult", 1.0) for c in colls)
        ),
        "collective_wire_bytes": wsum(lambda c: True),
        "collective_cross_pod_bytes": wsum(lambda c: c["cross_pod"]),
        "collectives_by_kind": by_kind,
        "n_collective_sites": len(colls),
        "unknown_whiles": int(res.unknown_whiles),
        "bf16_upcast_bytes": float(res.bf16_upcast_bytes),
    }
