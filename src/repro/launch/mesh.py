"""Production meshes + logical->physical spec mapping.

Single pod: ``(data=16, model=16)`` — 256 chips (TPU v5e pod).
Multi-pod: ``(pod=2, data=16, model=16)`` — 512 chips; the ``pod`` axis
is pure data parallelism (params replicated across pods, gradients
all-reduced hierarchically: reduce-scatter on ICI inside the pod, then
cross-pod on DCN).  Designed so ``pod`` scales to O(100) with no spec
changes — nothing but the batch is sharded over it.

Model code declares *logical* specs over ``("data", "model")``;
:func:`pod_spec` rewrites batch-bearing specs so that on a multi-pod
mesh the batch additionally shards over ``pod``.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def pod_spec(spec: P, mesh: Mesh) -> P:
    """Rewrite 'data' -> ('pod', 'data') when the mesh has a pod axis."""
    if "pod" not in mesh.axis_names:
        return spec

    def fix(entry):
        if entry == "data":
            return ("pod", "data")
        if isinstance(entry, (tuple, list)):
            out = []
            for e in entry:
                out.extend(["pod", "data"] if e == "data" else [e])
            return tuple(out)
        return entry

    return P(*(fix(e) for e in spec))


def data_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    """NamedSharding for an *input/state* spec (batch shards over pod)."""
    return NamedSharding(mesh, pod_spec(spec, mesh))


def param_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    """NamedSharding for a *parameter* spec (pod-replicated by design)."""
    return NamedSharding(mesh, spec)
