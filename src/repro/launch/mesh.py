"""Production meshes + logical->physical spec mapping.

Single pod: ``(data=16, model=16)`` — 256 chips (TPU v5e pod).
Multi-pod: ``(pod=2, data=16, model=16)`` — 512 chips; the ``pod`` axis
is pure data parallelism (params replicated across pods, gradients
all-reduced hierarchically: reduce-scatter on ICI inside the pod, then
cross-pod on DCN).  Designed so ``pod`` scales to O(100) with no spec
changes — nothing but the batch is sharded over it.

Model code declares *logical* specs over ``("data", "model")``;
:func:`pod_spec` rewrites batch-bearing specs so that on a multi-pod
mesh the batch additionally shards over ``pod``.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def pod_spec(spec: P, mesh: Mesh) -> P:
    """Rewrite 'data' -> ('pod', 'data') when the mesh has a pod axis."""
    if "pod" not in mesh.axis_names:
        return spec

    def fix(entry):
        if entry == "data":
            return ("pod", "data")
        if isinstance(entry, (tuple, list)):
            out = []
            for e in entry:
                out.extend(["pod", "data"] if e == "data" else [e])
            return tuple(out)
        return entry

    return P(*(fix(e) for e in spec))


def data_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    """NamedSharding for an *input/state* spec (batch shards over pod)."""
    return NamedSharding(mesh, pod_spec(spec, mesh))


def param_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    """NamedSharding for a *parameter* spec (pod-replicated by design)."""
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# EM serving meshes (multi-process CPU/TPU sharded resolution)
# ---------------------------------------------------------------------------

_distributed_initialized = False


def init_em_distributed(
    coordinator: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
) -> bool:
    """Join (or skip) a ``jax.distributed`` service for sharded serving.

    Arguments default to the ``REPRO_SHARD_COORD`` / ``REPRO_SHARD_N`` /
    ``REPRO_SHARD_ID`` environment variables so subprocess workers (the
    CI mesh leg and ``benchmarks/shard_scaling.py``) need no plumbing.
    Returns False — without touching jax — when no coordinator is
    configured, so single-process callers can call this unconditionally.

    On CPU backends the cross-process collective client must be selected
    *before* ``jax.distributed.initialize``; jaxlib builds that predate
    the gloo client (or name the option differently) raise, and the
    caller is expected to skip the distributed path in that case.
    """
    global _distributed_initialized
    import os

    coordinator = coordinator or os.environ.get("REPRO_SHARD_COORD")
    if not coordinator:
        return False
    if _distributed_initialized:
        return True
    if num_processes is None:
        num_processes = int(os.environ.get("REPRO_SHARD_N", "1"))
    if process_id is None:
        process_id = int(os.environ.get("REPRO_SHARD_ID", "0"))
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass  # non-CPU backend or pre-gloo jax: initialize decides
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
    )
    _distributed_initialized = True
    return True


def em_service_mesh(n_shards: int | None = None) -> Mesh:
    """1-D ``("data",)`` mesh over the global device list.

    With ``jax.distributed`` initialized this spans every process
    (``process_count x local_devices`` shards); otherwise it is the
    local multi-device mesh ``core.parallel.make_em_mesh`` builds — the
    two entry points stay interchangeable so the serving stack can hand
    either to ``run_parallel``.
    """
    from repro.core.parallel import make_em_mesh

    return make_em_mesh(n_shards)
