"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Builds the mesh from whatever devices exist (1 CPU here; a pod slice in
production), applies the launch sharding policies, and drives the
restartable Trainer.  ``--dry`` lowers/compiles the step and prints the
memory analysis instead of training (the single-cell analogue of
``repro.launch.dryrun``).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.base import ARCH_IDS, get_config, smoke_config
from repro.data.corpus import CorpusConfig
from repro.launch import sharding as shardlib
from repro.models.registry import get_model
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_layers = cfg.n_layers
    cfg = dataclasses.replace(
        cfg, remat_group=shardlib.default_remat_group(n_layers)
    )
    api = get_model(cfg)

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    print(f"arch={cfg.name} devices={n_dev} steps={args.steps}")

    data = CorpusConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                        global_batch=args.batch, seed=args.seed)
    tcfg = TrainerConfig(steps=args.steps, microbatches=args.microbatches,
                         ckpt_dir=args.ckpt_dir, seed=args.seed)
    trainer = Trainer(api, data, OptConfig(lr=args.lr, total_steps=args.steps),
                      tcfg, mesh=mesh)
    out = trainer.run()
    for step, loss in out["losses"]:
        print(f"step {step:5d}  loss {loss:.4f}")
    print(f"done: {out['steps_done']} steps in {out['wall_time_s']:.1f}s")


if __name__ == "__main__":
    main()
