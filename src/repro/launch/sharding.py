"""Launch-level sharding policy.

Models declare *logical* shardings over ``("data", "model")`` in their
PSpec trees; this module applies the launch policies on top:

* **FSDP** (``fsdp_params``): additionally shard every large parameter
  over the ``data`` axis (ZeRO-3 style).  GSPMD all-gathers the weight
  just-in-time per layer and reduce-scatters its gradient; optimizer
  state inherits the layout, so params+grads+Adam state are fully
  sharded over data×model.  Required to fit the 52B/72B/~100B configs
  on 16 GB v5e chips.
* **pod rewriting**: on a multi-pod mesh, batch-bearing dims shard over
  ``("pod", "data")``; parameters never shard over ``pod`` (pure DP,
  hierarchical gradient reduction: ICI reduce-scatter inside the pod,
  DCN all-reduce across pods).
* **divisibility guard** (``drop_indivisible``): axes whose shard count
  does not divide the dim are dropped (e.g. the ``long_500k`` batch of
  1 never shards over ``data``); GSPMD could pad, but explicit is
  cheaper and keeps the dry-run memory analysis honest.
* **launch heuristics**: microbatch count and remat group size per
  (arch × shape × mesh) cell.
"""

from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import pod_spec
from repro.models.param import PSpec, filter_spec, spec_tree_map

FSDP_MIN_SIZE = 1 << 20  # params below 1M elements stay replicated over data


def _entry_axes(e):
    if e is None:
        return ()
    return tuple(e) if isinstance(e, (tuple, list)) else (e,)


def data_axis_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("data", 1))


def fsdp_spec(ps: PSpec, data_size: int) -> PSpec:
    """Shard one more dim of a large param over ``data`` (ZeRO-3)."""
    if ps.size < FSDP_MIN_SIZE or len(ps.shape) < 2:
        return ps
    if ps.init == "embed":
        # embedding tables stay out of FSDP: model-sharded tables break
        # the gather's propagation with an extra `data` axis; pure-DP
        # tables were tried vocab-sharded (hillclimb iter. 3) and
        # REFUTED — the unembed all-gathers cost more than the grad
        # all-reduce they save (EXPERIMENTS.md §Perf).
        return ps
    entries = list(ps.spec) + [None] * (len(ps.shape) - len(ps.spec))
    used = {a for e in entries for a in _entry_axes(e)}
    if "data" in used:
        return ps
    # Prefer the fan-in dim, then fan-out, then interior dims.  The
    # leading stacked-layer dim is skipped: lax.scan slices it per
    # iteration and a sharded slice axis would force a gather per layer.
    nd = len(ps.shape)
    order = [nd - 2, nd - 1] + list(range(1, nd - 2))
    for d in order:
        if entries[d] is None and ps.shape[d] % data_size == 0 and ps.shape[d] >= data_size:
            entries[d] = "data"
            return dataclasses.replace(ps, spec=P(*entries))
    return ps


def strip_model(tree):
    """Remove the `model` axis from every param spec (pure-DP layout).

    For small models TP-16 is the wrong point on the roofline: the
    megatron activation all-reduces dwarf the matmuls.  With `model`
    stripped, the launcher reuses the tensor axis as extra data
    parallelism (batch shards over ('data','model')) and params are
    FSDP-sharded over `data` only.
    """

    def fix_entry(e):
        if e == "model":
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a != "model")
            return kept if kept else None
        return e

    def f(ps: PSpec) -> PSpec:
        return dataclasses.replace(ps, spec=P(*(fix_entry(e) for e in ps.spec)))

    return spec_tree_map(f, tree)


def dp_over_model_spec(spec: P) -> P:
    """Rewrite batch specs 'data' -> ('data','model') (pure-DP layout)."""

    def fix(e):
        if e == "data":
            return ("data", "model")
        if isinstance(e, (tuple, list)):
            out = []
            for a in e:
                out.extend(["data", "model"] if a == "data" else [a])
            return tuple(out)
        return e

    return P(*(fix(e) for e in spec))


def fsdp_params(tree, mesh: Mesh):
    n = data_axis_size(mesh)
    return spec_tree_map(lambda ps: fsdp_spec(ps, n), tree)


def cast_params(tree, dtype):
    """Serve-time dtype override (params held in bf16 for decode)."""
    import jax.numpy as jnp

    def f(ps: PSpec) -> PSpec:
        if ps.dtype == jnp.float32:
            return dataclasses.replace(ps, dtype=dtype)
        return ps

    return spec_tree_map(f, tree)


def drop_indivisible(spec: P, shape, mesh: Mesh) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        n = int(np.prod([sizes.get(a, 1) for a in _entry_axes(e)])) if e else 1
        out.append(e if (n == 1 or dim % n == 0) else None)
    return P(*out)


def input_shardings(api, shape, mesh: Mesh) -> dict:
    """NamedShardings for the input batch (pod-aware, divisibility-safe)."""
    sds = api.input_specs(shape)
    psp = api.input_pspecs(shape)
    out = {}
    for name, s in sds.items():
        sp = pod_spec(psp[name], mesh)
        sp = filter_spec(sp, mesh)
        sp = drop_indivisible(sp, s.shape, mesh)
        out[name] = NamedSharding(mesh, sp)
    return out


def state_shardings(tree, mesh: Mesh, *, pod_batch: bool = True):
    """NamedShardings for a PSpec state tree (e.g. the KV cache).

    ``pod_batch=True`` additionally shards 'data'-bearing dims over the
    pod axis (decode state is per-request, hence pure DP over pods).
    """

    def f(ps: PSpec):
        sp = pod_spec(ps.spec, mesh) if pod_batch else ps.spec
        sp = filter_spec(sp, mesh)
        sp = drop_indivisible(sp, ps.shape, mesh)
        return NamedSharding(mesh, sp)

    return spec_tree_map(f, tree)


def param_shardings(tree, mesh: Mesh):
    """NamedShardings for params (never sharded over pod)."""

    def f(ps: PSpec):
        sp = filter_spec(ps.spec, mesh)
        sp = drop_indivisible(sp, ps.shape, mesh)
        return NamedSharding(mesh, sp)

    return spec_tree_map(f, tree)


# ---------------------------------------------------------------------------
# Launch heuristics
# ---------------------------------------------------------------------------


def pick_microbatches(global_batch: int, data_shards: int, seq_len: int,
                      target_tokens: int = 8192) -> int:
    """Largest microbatch count keeping >= target tokens/device/microbatch.

    More microbatches => less live activation memory per grad-accum step
    but shorter matmuls; ~8k tokens per device per microbatch keeps the
    MXU well fed while bounding the remat working set.
    """
    b_loc = max(global_batch // max(data_shards, 1), 1)
    best = 1
    for mb in range(1, b_loc + 1):
        if b_loc % mb:
            continue
        if (b_loc // mb) * seq_len >= target_tokens:
            best = mb
    return best


def default_remat_group(n_layers: int) -> int:
    """Largest divisor of L that is <= ceil(sqrt(L)) (O(sqrt L) schedule)."""
    top = int(np.ceil(np.sqrt(n_layers)))
    for g in range(top, 1, -1):
        if n_layers % g == 0:
            return g
    return 1


# ---------------------------------------------------------------------------
# EM serving shards (LSH bucket-map partitioning)
# ---------------------------------------------------------------------------

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3


def bucket_shard(band: int, key: tuple[int, ...], n_shards: int) -> int:
    """Deterministic owner shard of one LSH bucket ``(band, key)``.

    FNV-1a over the band index and the key's minhash values — NOT
    Python's ``hash`` (salted per interpreter), so every process of a
    sharded service and every re-run of a test computes the same
    partition.  The partition is exhaustive and disjoint by
    construction: exactly one shard owns each bucket.
    """
    h = _FNV_OFFSET
    for v in (band, *key):
        v = int(v) & 0xFFFFFFFFFFFFFFFF
        for _ in range(8):
            h ^= v & 0xFF
            h = (h * _FNV_PRIME) & 0xFFFFFFFFFFFFFFFF
            v >>= 8
    return h % int(n_shards)


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """This process's slice of the sharded serving partition.

    ``n_shards`` is the process count of the serving mesh and
    ``shard_id`` this process's index; the LSH index stores and probes
    only the buckets :func:`bucket_shard` assigns to ``shard_id``, and
    per-probe candidate sets are merged by a cross-process union (the
    boundary-message merge at ingest quiescence points).
    """

    n_shards: int
    shard_id: int

    def __post_init__(self):
        if self.n_shards < 1 or not (0 <= self.shard_id < self.n_shards):
            raise ValueError(
                f"invalid shard spec: id {self.shard_id} of {self.n_shards}"
            )

    def owns(self, band: int, key: tuple[int, ...]) -> bool:
        return bucket_shard(band, key, self.n_shards) == self.shard_id


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n else 1


@dataclasses.dataclass
class ShardMerger:
    """Cross-process union of per-shard candidate-id sets.

    Callable hook for :class:`repro.stream.index.MinHashLSHIndex`: each
    process probes only its owned buckets, then the probe results are
    united over the mesh so every process sees the same candidate set
    the unsharded index would have produced (the partition is
    exhaustive, so the union is exact — and the caller sorts, so set
    order never leaks into downstream state).
    """

    mesh: Mesh

    def __post_init__(self):
        self._gather_fns: dict = {}
        self.merges = 0

    def _spans(self) -> bool:
        from repro.kernels.common import mesh_spans_processes

        return mesh_spans_processes(self.mesh)

    def _gather(self, local: np.ndarray, fill) -> np.ndarray:
        """All-gather equal-shape per-process row blocks (process order)."""
        import jax

        from repro.kernels import common as kcommon

        mesh = self.mesh
        axis = mesh.axis_names[0]
        devs_here = [
            d for d in mesh.devices.flat
            if d.process_index == jax.process_index()
        ]
        k = len(devs_here)
        pad = (-len(local)) % k
        if pad:
            local = np.concatenate(
                [local, np.full((pad,) + local.shape[1:], fill, local.dtype)]
            )
        per_dev = len(local) // k
        sharding = NamedSharding(mesh, P(axis))
        global_shape = (len(local) * (mesh.devices.size // k),) + local.shape[1:]
        shards = [
            jax.device_put(local[i * per_dev : (i + 1) * per_dev], d)
            for i, d in enumerate(devs_here)
        ]
        garr = jax.make_array_from_single_device_arrays(
            global_shape, sharding, shards
        )
        key = (global_shape, local.dtype.str)
        fn = self._gather_fns.get(key)
        if fn is None:
            import jax.numpy as jnp  # noqa: F401 - jitted body below

            fn = self._gather_fns[key] = jax.jit(
                kcommon.shard_map(
                    lambda x: jax.lax.all_gather(x, axis, axis=0, tiled=True),
                    mesh, (P(axis),), P(),
                )
            )
        return np.asarray(fn(garr))

    def union(self, ids: set[int]) -> set[int]:
        """Union this shard's candidate ids across every process."""
        if not self._spans():
            return ids
        self.merges += 1
        local = np.fromiter(sorted(ids), np.int64, len(ids))
        counts = self._gather(np.array([len(local)], np.int64), 0)
        cap = _pow2(int(counts.max())) if counts.size else 1
        padded = np.full(cap, -1, np.int64)
        padded[: len(local)] = local
        merged = self._gather(padded, -1)
        return set(merged[merged >= 0].tolist())
