"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Random-weight serving driver around :class:`repro.serve.engine.Engine`
(the jitted decode step is the same ``serve_step`` the multi-pod
dry-run lowers at 32k/500k context).

``--em`` switches to the sharded entity-resolution service instead: one
:class:`repro.stream.shard.ShardCoordinator` replica per process.  Run
it once per shard with ``REPRO_SHARD_COORD`` / ``REPRO_SHARD_N`` /
``REPRO_SHARD_ID`` set (see ``docs/SHARDING.md``); a bare single-process
invocation serves the unsharded 1-shard degenerate case.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def em_main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--em", action="store_true")
    ap.add_argument("--scheme", default="smp", choices=["smp", "mmp"])
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--shards", type=int, default=None)
    args = ap.parse_args(argv)

    from repro.data.synthetic import SynthConfig, arrival_stream, make_dataset
    from repro.stream.shard import ShardContext, ShardCoordinator

    ctx = ShardContext.create(args.shards)
    coord = ShardCoordinator(ctx, scheme=args.scheme, parallel=True)
    ds = make_dataset(SynthConfig.hepth(scale=args.scale, seed=7))
    t0 = time.perf_counter()
    n_refs = 0
    for b in arrival_stream(ds, n_batches=args.batches):
        coord.ingest(list(b.names), b.edges)
        n_refs += len(b.names)
    dt = time.perf_counter() - t0
    agree = coord.digests_agree()
    print(
        f"shard {ctx.shard_id}/{ctx.n_shards}: {n_refs} refs in {dt:.2f}s "
        f"({n_refs / dt:.1f} refs/s), "
        f"{len(coord.snapshot().clusters())} clusters, "
        f"digest {coord.digest()[:12]} "
        f"({'replicas agree' if agree else 'REPLICA DIVERGENCE'})"
    )
    if not agree:
        raise SystemExit(1)


def main():
    if "--em" in sys.argv[1:]:
        return em_main()

    from repro.configs.base import ARCH_IDS, get_config, smoke_config
    from repro.models.registry import get_model
    from repro.serve.engine import demo_engine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = get_model(cfg)
    if api.prefill is None:
        raise SystemExit(f"{cfg.name} ({cfg.family}) has no prefill path")
    engine = demo_engine(api, batch=args.batch, s_max=args.s_max)

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, cfg.vocab_size - 1, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"{cfg.name}: {len(prompts)} requests, {total} tokens, "
          f"{dt:.2f}s ({total/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
