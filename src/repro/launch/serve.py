"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Random-weight serving driver around :class:`repro.serve.engine.Engine`
(the jitted decode step is the same ``serve_step`` the multi-pod
dry-run lowers at 32k/500k context).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.base import ARCH_IDS, get_config, smoke_config
from repro.models.registry import get_model
from repro.serve.engine import demo_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = get_model(cfg)
    if api.prefill is None:
        raise SystemExit(f"{cfg.name} ({cfg.family}) has no prefill path")
    engine = demo_engine(api, batch=args.batch, s_max=args.s_max)

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, cfg.vocab_size - 1, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"{cfg.name}: {len(prompts)} requests, {total} tokens, "
          f"{dt:.2f}s ({total/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
