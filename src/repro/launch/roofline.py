"""Roofline report: three terms per (arch × shape × mesh) cell.

Consumes the dry-run JSONs (``experiments/dryrun/*.json``) and emits the
§Roofline table:

    compute term    = per-chip HLO flops / 197 TFLOP/s (bf16, v5e)
    memory term     = per-chip HBM bytes / 819 GB/s
    collective term = per-chip wire bytes / 50 GB/s per ICI link
                      (+ cross-pod DCN bytes / 25 GB/s, reported apart)

All three in seconds per step; the max is the bound.  ``MFU`` is
MODEL_FLOPS / (chips x peak x bound-term): the roofline fraction the
cell would reach if it hits its dominant bound.

Usage::

    PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip, TPU v5e
HBM_BW = 819e9       # bytes/s / chip
ICI_BW = 50e9        # bytes/s / link
DCN_BW = 25e9        # bytes/s / chip cross-pod (assumed)
HBM_GB = 16          # v5e HBM capacity


def load(dirname: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def terms(rec: dict) -> dict:
    ct = rec["hlo_flops"] / PEAK_FLOPS
    mt = rec["hlo_bytes"] / HBM_BW
    ici = (rec["collective_wire_bytes"] - rec["collective_cross_pod_bytes"]) / ICI_BW
    dcn = rec["collective_cross_pod_bytes"] / DCN_BW
    lt = ici + dcn
    bound = max(ct, mt, lt)
    dom = {ct: "compute", mt: "memory", lt: "collective"}[bound]
    n = rec["n_chips"]
    useful = rec["model_flops"] / n / PEAK_FLOPS  # s of pure model math/chip
    mfu = useful / bound if bound > 0 else 0.0
    mem = rec.get("mem", {})
    hbm = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
           + mem.get("output_bytes", 0) - mem.get("alias_bytes", 0))
    return {
        "compute_s": ct, "memory_s": mt, "collective_s": lt, "dcn_s": dcn,
        "bound": dom, "mfu": mfu,
        "flops_ratio": rec["model_flops"] / max(rec["hlo_flops"] * n, 1),
        "hbm_gib": hbm / 2**30,
        "upcast_gib": rec.get("bf16_upcast_bytes", 0) / 2**30,
    }


def advice(rec: dict, t: dict) -> str:
    if rec.get("kind") == "em_round":
        return ("matcher-dominated, as the paper's framework predicts: "
                "the bitset exchange is structurally cheap; fast greedy "
                "re-activation rounds are the lever (EXPERIMENTS §Perf)")
    if t["bound"] == "collective":
        if rec.get("kind") == "train" and rec["params"] < 2e9:
            return "TP-16 too wide for this size: drop `model` use (pure DP/FSDP)"
        if rec.get("arch", "").startswith(("moonshot", "llama4", "jamba")):
            return "EP all-to-all + megatron ARs dominate: larger MoE groups / fewer AR hops"
        return "overlap ARs with compute (XLA latency hiding), reduce-scatter grads"
    if t["bound"] == "memory":
        if rec.get("kind") != "train":
            return "decode is KV-bandwidth bound (expected): bigger batch amortizes weights"
        return "fuse/remat to cut activation traffic; bf16 everywhere"
    return "compute-bound: at roofline when MFU -> 1; cut remat/causal waste"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16", help="mesh to tabulate (roofline is single-pod)")
    ap.add_argument("--md", action="store_true", help="emit markdown")
    args = ap.parse_args()

    recs = [r for r in load(args.dir) if r.get("status") == "ok"]
    recs = [r for r in recs if r["mesh"] == args.mesh]
    recs.sort(key=lambda r: (r["arch"], r["shape"]))

    sep = "|" if args.md else " "
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "bound", "MFU", "model/hlo", "HBM_GiB"]
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(f"{'arch':24s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
              f"{'coll_s':>9s} {'bound':>10s} {'MFU':>6s} {'m/h':>5s} {'GiB':>6s}")
    for r in recs:
        t = terms(r)
        row = [r["arch"], r["shape"], f"{t['compute_s']:.4f}",
               f"{t['memory_s']:.4f}", f"{t['collective_s']:.4f}",
               t["bound"], f"{t['mfu']:.3f}", f"{t['flops_ratio']:.2f}",
               f"{t['hbm_gib']:.1f}"]
        if args.md:
            print("| " + " | ".join(row) + " |")
        else:
            print(f"{row[0]:24s} {row[1]:12s} {row[2]:>9s} {row[3]:>9s} "
                  f"{row[4]:>9s} {row[5]:>10s} {row[6]:>6s} {row[7]:>5s} {row[8]:>6s}")
    print()
    for r in recs:
        t = terms(r)
        print(f"- {r['arch']} × {r['shape']}: {t['bound']}-bound — {advice(r, t)}")


if __name__ == "__main__":
    main()
