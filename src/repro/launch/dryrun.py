import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_MIXED_DOT"] = "preferred"  # TPU math: bf16 dots, f32 accum

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without real
hardware: 512 placeholder host devices stand in for 2 TPU v5e pods, and
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed for

  * the single-pod mesh  (data=16, model=16)      — 256 chips, and
  * the multi-pod mesh   (pod=2, data=16, model=16) — 512 chips,

for every assigned architecture × input-shape cell, plus the EM-round
cell (the paper's technique on the production mesh).  Each compile's
``memory_analysis()`` (fits in HBM?) and ``cost_analysis()`` (FLOPs /
bytes for the roofline) are captured to JSON under ``experiments/``;
``repro.launch.roofline`` consumes them.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both            # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --em                   # EM round cell
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis
from repro.launch import sharding as shardlib
from repro.launch.mesh import make_production_mesh, pod_spec
from repro.models.param import abstract_params, filter_spec, param_count
from repro.models.registry import get_model
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step, microbatched_specs

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def _mesh(multi_pod: bool):
    return make_production_mesh(multi_pod=multi_pod)


def _batch_abstract(api, shape):
    return dict(api.input_specs(shape))


def active_param_count(cfg, specs) -> int:
    """Params touched per token: total minus the (1 - k/E) unused experts."""
    total = param_count(specs)
    if not cfg.n_experts:
        return total
    f = cfg.moe_d_ff or cfg.d_ff
    per_expert = cfg.d_model * 2 * f + f * cfg.d_model
    if cfg.family == "hybrid":
        from repro.models.hybrid import _is_moe

        n_moe = sum(_is_moe(cfg, i) for i in range(cfg.n_layers))
    else:
        n_moe = cfg.n_layers
    unused = n_moe * (cfg.n_experts - cfg.experts_per_token) * per_expert
    return total - unused


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               fsdp: str = "auto", microbatches: int | None = None,
               remat_group: int | None = None, donate: bool = True,
               tp: str = "on"):
    """Lower + compile one cell; return the metrics dict."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = _mesh(multi_pod)
    dsz = shardlib.data_axis_size(mesh) * (2 if multi_pod else 1)
    kind = shape.kind
    t0 = time.perf_counter()

    if kind == "train":
        from repro.models import layers as layerslib

        # Megatron-SP at layer boundaries was tried for the 32k-token
        # cells and REFUTED: GSPMD round-trips the resharding inside
        # every sublayer (flops x2, memory up for jamba) — see
        # EXPERIMENTS.md §Perf.  Off by default; kept as a knob.
        layerslib.SEQ_SHARD_BOUNDARY = os.environ.get("REPRO_SEQ_SHARD", "0") == "1"
        layerslib.DP_OVER_MODEL = tp == "off"
        rg = remat_group if remat_group is not None else shardlib.default_remat_group(cfg.n_layers)
        cfg = dataclasses.replace(cfg, remat_group=rg)
        api = get_model(cfg)
        specs = api.param_specs()
        if tp == "off":  # pure-DP layout: tensor axis becomes batch
            specs = shardlib.strip_model(specs)
        use_fsdp = fsdp == "on" or (fsdp == "auto")
        if use_fsdp:
            specs = shardlib.fsdp_params(specs, mesh)
        pshard = shardlib.param_shardings(specs, mesh)
        oshard = {"m": pshard, "v": pshard,
                  "step": NamedSharding(mesh, P())}
        params_abs = abstract_params(specs)
        opt_abs = {"m": params_abs, "v": params_abs,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        if tp == "off":
            dsz *= dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        mb = microbatches if microbatches is not None else shardlib.pick_microbatches(
            shape.global_batch, dsz, shape.seq_len
        )
        batch_abs, batch_psp = microbatched_specs(
            _batch_abstract(api, shape), api.input_pspecs(shape), mb
        )
        if tp == "off":
            batch_psp = {k: shardlib.dp_over_model_spec(v) for k, v in batch_psp.items()}
        bshard = {
            name: NamedSharding(
                mesh,
                shardlib.drop_indivisible(
                    filter_spec(pod_spec(batch_psp[name], mesh), mesh),
                    batch_abs[name].shape,
                    mesh,
                ),
            )
            for name in batch_abs
        }
        step = make_train_step(api, OptConfig(), microbatches=mb)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1) if donate else (),
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        extra = {"microbatches": mb, "remat_group": rg, "fsdp": use_fsdp,
                 "tp": tp}
    else:  # decode: single-token serve step against a seq_len KV cache
        api = get_model(cfg)
        specs = shardlib.cast_params(api.param_specs(), jnp.bfloat16)
        # big checkpoints must also shard weights over data to fit HBM
        use_fsdp = fsdp == "on" or (
            fsdp == "auto"
            and param_count(specs) * 2 / 16 > 8e9  # >8GB/chip at TP-16
        )
        if use_fsdp:
            specs = shardlib.fsdp_params(specs, mesh)
        pshard = shardlib.param_shardings(specs, mesh)
        cache_specs = api.cache_specs(shape.global_batch, shape.seq_len)
        cshard = shardlib.state_shardings(cache_specs, mesh)
        bshard = shardlib.input_shardings(api, shape, mesh)
        params_abs = abstract_params(specs)
        cache_abs = abstract_params(cache_specs)
        batch_abs = _batch_abstract(api, shape)
        jitted = jax.jit(
            api.decode,
            in_shardings=(pshard, cshard, bshard),
            out_shardings=(None, cshard),
            donate_argnums=(1,) if donate else (),
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(params_abs, cache_abs, batch_abs)
        extra = {"fsdp": use_fsdp}

    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    n_chips = int(np.prod(mesh.devices.shape))
    mem = compiled.memory_analysis()
    ana = hlo_analysis.analyze(
        compiled.as_text(), n_devices=n_chips, pod_boundary=256
    )

    n_params = param_count(specs)
    n_active = active_param_count(cfg, specs)
    tokens = shape.global_batch * (shape.seq_len if kind == "train" else 1)
    model_flops = (6 if kind == "train" else 2) * n_active * tokens

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok", "kind": kind, "n_chips": n_chips,
        "params": int(n_params), "active_params": int(n_active),
        "tokens_per_step": int(tokens), "model_flops": float(model_flops),
        # per-chip numbers from the loop-aware HLO analysis
        "hlo_flops": ana["flops"],
        "hlo_bytes": ana["bytes"],
        "mem": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        },
        "collective_bytes": ana["collective_bytes"],
        "collective_wire_bytes": ana["collective_wire_bytes"],
        "collective_cross_pod_bytes": ana["collective_cross_pod_bytes"],
        "n_collectives": ana["n_collective_sites"],
        "collectives_by_kind": ana["collectives_by_kind"],
        "unknown_whiles": ana["unknown_whiles"],
        # f32 round-trips of bf16 buffers: a CPU-backend legalization
        # artifact absent on TPU; see EXPERIMENTS.md §Dry-run.
        "bf16_upcast_bytes": ana["bf16_upcast_bytes"],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        **extra,
    }
    return rec


# ---------------------------------------------------------------------------
# The EM-round cell (the paper's technique on the production mesh)
# ---------------------------------------------------------------------------


def lower_em_cell(multi_pod: bool, *, k: int = 32, neighborhoods: int = 8192,
                  universe: int = 1 << 20, matcher_kind: str = "mln"):
    """Lower one SPMD message-passing round at production scale.

    One round = batched MLN MAP inference on every active neighborhood
    (sharded over all mesh axes) + the match-bitset all-reduce.  8192
    neighborhoods of k=32 is a DBLP-BIG-scale round (§6.3).
    """
    from repro.core import pairs as pairlib
    from repro.core.mln import PAPER_LEARNED
    from repro.core.parallel import RoundSpec, build_round_fn

    mesh = _mesh(multi_pod)
    axes = tuple(mesh.axis_names)
    n_chips = int(np.prod(mesh.devices.shape))
    B = max(neighborhoods, n_chips)
    Pn = pairlib.num_pairs(k)
    spec = RoundSpec(k=k, num_pairs=Pn, universe_size=universe,
                     matcher_kind=matcher_kind, weights=PAPER_LEARNED)
    fn = build_round_fn(spec, mesh, axes)

    def sds(shape, dt):
        return jax.ShapeDtypeStruct(shape, dt)

    args = (
        sds((B, k), jnp.bool_),         # entity_mask
        sds((B, k, k), jnp.bool_),      # coauthor
        sds((B, Pn), jnp.int8),         # sim_level
        sds((B, Pn), jnp.bool_),        # pair_mask
        sds((B, Pn), jnp.int32),        # uidx
        sds((universe,), jnp.bool_),    # m_bits
    )
    t0 = time.perf_counter()
    lowered = fn.lower(*args)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    ana = hlo_analysis.analyze(
        compiled.as_text(), n_devices=n_chips, pod_boundary=256
    )
    rec = {
        "arch": f"em_round_{matcher_kind}", "shape": f"k{k}_B{B}",
        "mesh": "2x16x16" if multi_pod else "16x16", "status": "ok",
        "kind": "em_round", "n_chips": n_chips,
        "params": 0, "active_params": 0, "tokens_per_step": B,
        # useful work: one (P,P)@(P,P) entailment matmul + sweeps per nb
        "model_flops": float(B * 2 * Pn * Pn * Pn),
        "hlo_flops": ana["flops"],
        "hlo_bytes": ana["bytes"],
        "mem": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        },
        "collective_bytes": ana["collective_bytes"],
        "collective_wire_bytes": ana["collective_wire_bytes"],
        "collective_cross_pod_bytes": ana["collective_cross_pod_bytes"],
        "n_collectives": ana["n_collective_sites"],
        "collectives_by_kind": ana["collectives_by_kind"],
        "unknown_whiles": ana["unknown_whiles"],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    return rec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _save(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--em", action="store_true", help="run the EM-round cell")
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--tp", default="on", choices=["on", "off"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat-group", type=int, default=None)
    ap.add_argument("--out", default=os.environ.get("DRYRUN_OUT", "experiments/dryrun"))
    args = ap.parse_args()

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    n_ok = n_skip = n_fail = 0
    for multi_pod in meshes:
        if args.em:
            rec = lower_em_cell(multi_pod)
            _save(rec, args.out)
            print(f"[em_round {rec['mesh']}] ok "
                  f"flops={rec['hlo_flops']:.3e} coll={rec['collective_wire_bytes']:.3e}B "
                  f"compile={rec['compile_s']}s")
            n_ok += 1
            continue
        for arch in archs:
            for shape in shapes:
                tag = f"{arch} × {shape} × {'2x16x16' if multi_pod else '16x16'}"
                try:
                    rec = lower_cell(arch, shape, multi_pod, fsdp=args.fsdp,
                                     microbatches=args.microbatches,
                                     remat_group=args.remat_group, tp=args.tp)
                except Exception:
                    n_fail += 1
                    print(f"[{tag}] FAIL")
                    traceback.print_exc()
                    continue
                _save(rec, args.out)
                if rec["status"] == "skipped":
                    n_skip += 1
                    print(f"[{tag}] skipped: {rec['reason']}")
                else:
                    n_ok += 1
                    hbm = rec["mem"]["argument_bytes"] + rec["mem"]["temp_bytes"] + rec["mem"]["output_bytes"] - rec["mem"]["alias_bytes"]
                    print(f"[{tag}] ok mem/dev={hbm/2**30:.2f}GiB "
                          f"flops={rec['hlo_flops']:.3e} "
                          f"coll={rec['collective_wire_bytes']:.3e}B "
                          f"compile={rec['compile_s']}s")
    print(f"dry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
