"""Train-step factory: microbatched grad accumulation, remat, sharding,
optional cross-pod compressed gradient exchange.

The produced step is a pure jittable function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable
for ``jax.jit(..., in_shardings=..., out_shardings=...)`` and for
``.lower().compile()`` in the multi-pod dry-run.

Microbatching splits the per-step batch into ``microbatches`` chunks
accumulated with a ``lax.scan``.  The split happens **on the host**
(:func:`split_microbatches`): every batch leaf arrives with a leading
``(n_mb, B/n_mb, ...)`` axis and the scan consumes it directly.  An
in-graph ``reshape`` of a batch-sharded tensor would force GSPMD to
reshard (the microbatch groups interleave across devices); pre-split
input keeps every microbatch an evenly-sharded ``B/n_mb`` batch and the
step free of layout churn.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelAPI
from repro.train import compress as complib
from repro.train.optimizer import OptConfig, adamw_update


def _batch_dim(x) -> int:
    """The global-batch dim of a batch leaf (positions are (3, B, S))."""
    return 1 if (x.ndim >= 2 and x.shape[0] == 3) else 0


def split_microbatches(batch: dict, n: int) -> dict:
    """Host-side (B, ...) -> (n, B/n, ...) split, microbatch axis leading."""
    if n <= 1:
        return batch

    def f(x):
        x = np.asarray(x)
        d = _batch_dim(x)
        B = x.shape[d]
        assert B % n == 0, f"batch {B} not divisible by microbatches {n}"
        y = x.reshape(*x.shape[:d], n, B // n, *x.shape[d + 1 :])
        return np.moveaxis(y, d, 0)

    return jax.tree.map(f, batch)


def microbatched_specs(batch_specs: dict, pspecs: dict, n: int):
    """Abstract (ShapeDtypeStruct, PartitionSpec) trees for a pre-split batch.

    Used by the dry-run: shape (B, ...) -> (n, B/n, ...) with the batch
    sharding entries shifted right by the new leading (unsharded) axis.
    """
    from jax.sharding import PartitionSpec as P

    if n <= 1:
        return batch_specs, pspecs
    out_s, out_p = {}, {}
    for name, sds in batch_specs.items():
        d = _batch_dim(sds)
        shape = list(sds.shape)
        assert shape[d] % n == 0
        shape[d] //= n
        out_s[name] = jax.ShapeDtypeStruct((n, *shape), sds.dtype)
        out_p[name] = P(None, *pspecs[name])
    return out_s, out_p


def make_train_step(
    api: ModelAPI,
    opt_cfg: OptConfig,
    *,
    microbatches: int = 1,
    compress_pods: bool = False,
    mesh=None,
):
    """Build the jittable train step for this model.

    With ``microbatches > 1`` the batch must be pre-split on the host
    (see :func:`split_microbatches`): every leaf has a leading
    microbatch axis that the grad-accumulation scan consumes.
    """

    def loss_fn(params, mb):
        loss, metrics = api.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def body(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches, acc, grads
            )
            return acc, (loss, metrics)

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, (losses, metricses) = jax.lax.scan(body, zero, batch)
        loss = jnp.mean(losses)
        metrics = jax.tree.map(jnp.mean, metricses)
        return loss, metrics, grads

    if not compress_pods:

        def train_step(params, opt_state, batch):
            loss, metrics, grads = compute_grads(params, batch)
            params, opt_state, opt_metrics = adamw_update(
                opt_cfg, params, grads, opt_state
            )
            return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

        return train_step

    # ---- compressed cross-pod DP: shard_map manual over 'pod' ------------
    assert mesh is not None and "pod" in mesh.axis_names
    from jax.sharding import PartitionSpec as P

    def _pod_spec(v):
        # batch leaves: pod shards the batch dim; a leading microbatch
        # axis (and the (3, B, S) positions layout) shift it right.
        d = _batch_dim(v) + (1 if microbatches > 1 else 0)
        entries = [None] * v.ndim
        entries[d] = "pod"
        return P(*entries)

    def pod_body(params, opt_state, err, batch):
        # per-pod gradient (batch is this pod's shard; inner axes auto)
        loss, metrics, grads = compute_grads(params, batch)
        grads, err = complib.tree_compressed_psum(grads, "pod", err)
        params, opt_state, opt_metrics = adamw_update(opt_cfg, params, grads, opt_state)
        loss = jax.lax.pmean(loss, "pod")
        metrics = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), metrics)
        return params, opt_state, err, {"loss": loss, **metrics, **opt_metrics}

    def train_step(params, opt_state, err, batch):
        batch_specs = {k: _pod_spec(v) for k, v in batch.items()}
        fn = jax.shard_map(
            pod_body,
            mesh=mesh,
            in_specs=(P(), P(), P(), batch_specs),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
            axis_names=frozenset({"pod"}),
        )
        return fn(params, opt_state, err, batch)

    return train_step
