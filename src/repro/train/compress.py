"""Cross-pod gradient compression: int8 quantized all-reduce + error feedback.

Inside one pod, gradients reduce over the ICI mesh at full precision
(cheap, fast links).  *Across* pods the links are DCN-class, so we
compress: per-tensor symmetric int8 quantization, psum over the ``pod``
axis in int32, dequantize, with an *error-feedback* buffer carrying the
quantization residual into the next step (Seide et al. / EF-SGD — keeps
convergence unbiased to first order).

Implementation note: the compressed exchange must be an *explicit*
collective (GSPMD's automatic gradient all-reduce can't be intercepted),
so the train step wraps the grad computation in ``shard_map`` manual
over ``pod`` with the intra-pod axes left on auto — see
``repro.train.train_step.make_train_step(compress_pods=True)``.

4x traffic reduction on the cross-pod hop (f32 -> int8), at the cost of
one extra all-reduce of the per-tensor scales (negligible: 1 scalar per
tensor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g, axis_name: str | None):
    """Symmetric int8 quantization; scale is the cross-pod max |g|
    (``axis_name=None``: local scale — single-shard / test use)."""
    amax = jnp.max(jnp.abs(g))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)  # shared scale -> psum exact
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(g, axis_name: str, err):
    """Error-feedback int8 psum over ``axis_name``.

    g, err: f32 tensors (local gradient shard + carried residual).
    Returns (mean-reduced gradient, new residual).
    """
    g = g.astype(jnp.float32) + err
    q, scale = quantize(g, axis_name)
    deq_local = q.astype(jnp.float32) * scale
    new_err = g - deq_local
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n, new_err


def tree_compressed_psum(grads, axis_name: str, err_tree):
    leaves, treedef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_tree)
    outs, new_errs = [], []
    for g, e in zip(leaves, errs):
        o, ne = compressed_psum(g, axis_name, e)
        outs.append(o)
        new_errs.append(ne)
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, new_errs)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
