"""Training loop: restartable, preemption-aware, checkpointed.

Responsibilities:
  * build params/opt-state (or restore the latest checkpoint —
    including after an *elastic* device-count change, since restore
    re-places arrays under the current mesh's shardings);
  * drive the jitted train step over the deterministic data stream
    (batch ``i`` is a pure function of the seed, so restart at step N
    replays the exact schedule);
  * periodic + preemption-triggered checkpointing (a SIGTERM-style
    flag calls one synchronous save before exit — the launcher
    restarts the job, which resumes from that step);
  * straggler note: steps are bulk-synchronous SPMD — a slow host
    costs its step, not a cascade; the EM side gets the same property
    from round-based message passing.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.corpus import CorpusConfig, TokenStream
from repro.models.param import init_params, shardings as make_shardings
from repro.models.registry import ModelAPI
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step, split_microbatches


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    microbatches: int = 1
    seed: int = 0
    ckpt_dir: str | None = None
    keep_ckpts: int = 3
    async_ckpt: bool = True


class Trainer:
    def __init__(
        self,
        api: ModelAPI,
        data_cfg: CorpusConfig,
        opt_cfg: OptConfig,
        cfg: TrainerConfig,
        mesh=None,
    ):
        self.api = api
        self.data = TokenStream(data_cfg)
        self.opt_cfg = opt_cfg
        self.cfg = cfg
        self.mesh = mesh
        self.preempted = False  # set by a signal handler in production
        self.ckpt = (
            Checkpointer(cfg.ckpt_dir, keep=cfg.keep_ckpts, async_save=cfg.async_ckpt)
            if cfg.ckpt_dir
            else None
        )
        self._step_fn = jax.jit(
            make_train_step(api, opt_cfg, microbatches=cfg.microbatches)
        )

    # -- state ---------------------------------------------------------------
    def init_state(self):
        specs = self.api.param_specs()
        params = init_params(specs, seed=self.cfg.seed)
        if self.mesh is not None:
            shard = make_shardings(specs, self.mesh)
            params = jax.tree.map(jax.device_put, params, shard)
        opt_state = init_opt_state(params)
        return {"params": params, "opt": opt_state}, 0

    def restore_or_init(self):
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                state, _ = self.init_state()
                shard = None
                if self.mesh is not None:
                    specs = self.api.param_specs()
                    pshard = make_shardings(specs, self.mesh)
                    shard = {
                        "params": pshard,
                        "opt": {
                            "m": pshard,
                            "v": pshard,
                            "step": jax.tree.map(lambda _: None, jnp.zeros(())),
                        },
                    }
                    shard = None  # re-placement handled by device_put below
                restored = self.ckpt.restore(latest, state)
                return restored, latest
        return self.init_state()[0], 0

    # -- loop ----------------------------------------------------------------
    def run(self) -> dict:
        state, start = self.restore_or_init()
        params, opt = state["params"], state["opt"]
        losses = []
        t0 = time.perf_counter()
        step = start
        for step in range(start, self.cfg.steps):
            batch = split_microbatches(self.data.batch(step), self.cfg.microbatches)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, metrics = self._step_fn(params, opt, batch)
            if (step + 1) % self.cfg.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                losses.append((step + 1, loss))
            if self.ckpt and (
                (step + 1) % self.cfg.ckpt_every == 0 or self.preempted
            ):
                self.ckpt.save(step + 1, {"params": params, "opt": opt})
                if self.preempted:
                    self.ckpt.wait()
                    break
        if self.ckpt:
            self.ckpt.save(self.cfg.steps, {"params": params, "opt": opt})
            self.ckpt.wait()
        wall = time.perf_counter() - t0
        return {
            "params": params,
            "opt": opt,
            "losses": losses,
            "steps_done": step + 1,
            "wall_time_s": wall,
        }
