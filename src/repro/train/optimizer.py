"""AdamW + cosine schedule + global-norm clipping (no external deps).

Optimizer state is a pytree congruent with the params (m, v in f32),
so GSPMD shards it exactly like the FSDP'd parameters — the ZeRO
property falls out of the sharding specs for free.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    decayed = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decayed


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: OptConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1.0 - cfg.b1) * g
        v2 = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
