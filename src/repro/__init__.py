"""Large-scale collective entity matching — curated public surface.

The supported API, re-exported lazily (PEP 562) so ``import repro``
stays cheap and heavy stacks (jax, the serving engine) load only when
first touched:

* streaming service — :class:`ResolveService`, :class:`ServiceConfig`,
  :class:`ResolveSnapshot`, the :class:`ServingFrontend` traffic
  front-end with :class:`ServingConfig`, and the sharded
  :class:`ShardCoordinator`;
* matcher plug-in registry — :func:`get_matcher`,
  :func:`register_matcher`, :func:`list_matchers`, :func:`matcher_info`,
  :class:`MatcherInfo` (see :mod:`repro.core.matchers`);
* observability — :func:`get_registry` (metrics snapshot via
  ``get_registry().snapshot()``) and :func:`write_snapshot`.

Everything else under ``repro.*`` is implementation detail with no
stability promise; the docs reference only the names above.
"""

from __future__ import annotations

_EXPORTS = {
    "IngestReport": "repro.stream.service",
    "ResolveService": "repro.stream.service",
    "ResolveSnapshot": "repro.stream.service",
    "ServiceConfig": "repro.stream.service",
    "ServingConfig": "repro.stream.serving",
    "ServingFrontend": "repro.stream.serving",
    "ShardContext": "repro.stream.shard",
    "ShardCoordinator": "repro.stream.shard",
    "MatcherInfo": "repro.core.matchers",
    "get_matcher": "repro.core.matchers",
    "list_matchers": "repro.core.matchers",
    "matcher_info": "repro.core.matchers",
    "register_matcher": "repro.core.matchers",
    "get_registry": "repro.obs",
    "write_snapshot": "repro.obs",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(target), name)


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
