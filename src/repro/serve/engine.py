"""Batched serving engine: prefill + greedy decode over a KV cache.

A deliberately small but real engine: fixed decode batch, a request
queue filled into free slots after each generation completes (static-
shape continuous batching), greedy sampling.  The decode step is the
same jitted ``serve_step`` the dry-run lowers at scale.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.param import init_params
from repro.models.registry import ModelAPI


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list[int] = dataclasses.field(default_factory=list)


class Engine:
    def __init__(self, api: ModelAPI, params, batch: int, s_max: int):
        assert api.prefill is not None, f"{api.cfg.family} has no prefill"
        self.api = api
        self.params = params
        self.batch = batch
        self.s_max = s_max
        self._decode = jax.jit(api.decode)
        self._prefill = jax.jit(
            lambda p, t: api.prefill(p, t, s_max), static_argnums=()
        )

    def generate(self, prompts: list[np.ndarray], max_new: int = 16) -> list[list[int]]:
        """Serve a list of equal-length prompts in batches."""
        outs: list[list[int]] = []
        for lo in range(0, len(prompts), self.batch):
            group = prompts[lo : lo + self.batch]
            pad = self.batch - len(group)
            toks = np.stack(list(group) + [group[-1]] * pad)
            outs.extend(self._generate_batch(toks, max_new)[: len(group)])
        return outs

    def encode(self, prompts: list[np.ndarray]) -> np.ndarray:
        """Embed token sequences: one prefill per padded batch, mean-pool
        the logits over real positions, L2-normalize.  Returns (N, vocab)
        float32 — the encoder forward pass behind the embedding matcher.
        """
        out = []
        for lo in range(0, len(prompts), self.batch):
            group = prompts[lo : lo + self.batch]
            pad = self.batch - len(group)
            lens = np.array(
                [len(p) for p in group] + [len(group[-1])] * pad, np.int32
            )
            toks = np.zeros((self.batch, self.s_max), np.int32)
            for i, p in enumerate(list(group) + [group[-1]] * pad):
                toks[i, : len(p)] = p[: self.s_max]
            logits, _cache = self._prefill(self.params, jnp.asarray(toks))
            mask = np.arange(self.s_max)[None, :] < np.minimum(
                lens, self.s_max
            )[:, None]
            pooled = np.asarray(logits) * mask[:, :, None]
            pooled = pooled.sum(axis=1) / np.maximum(
                mask.sum(axis=1, keepdims=True), 1
            )
            norm = np.linalg.norm(pooled, axis=-1, keepdims=True)
            pooled = pooled / np.maximum(norm, 1e-9)
            out.append(pooled[: len(group)].astype(np.float32))
        return np.concatenate(out, axis=0)

    def _generate_batch(self, tokens: np.ndarray, max_new: int) -> list[list[int]]:
        B, S = tokens.shape
        logits, cache = self._prefill(self.params, jnp.asarray(tokens))
        seqs: list[list[int]] = [[] for _ in range(B)]
        cur = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        for t in range(max_new):
            for b in range(B):
                seqs[b].append(int(cur[b]))
            batch = {
                "tokens": cur[:, None],
                "pos": jnp.full((B,), S + t, jnp.int32),
            }
            logits, cache = self._decode(self.params, cache, batch)
            cur = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        return seqs


def demo_engine(api: ModelAPI, batch: int = 2, s_max: int = 64, seed: int = 0):
    params = init_params(api.param_specs(), seed=seed)
    return Engine(api, params, batch, s_max)
