"""Paper *quality* metrics, re-exported under the observability roof.

The runtime registry (:mod:`repro.obs.registry`) measures *how fast and
at what cost* the system runs; this module is the other axis — *how
well it matches*: precision/recall/F1 against ground truth and the
§2.2.1 soundness/completeness framework properties.  The
implementation lives in :mod:`repro.core.metrics` (see its docstring
for the paper mapping); this alias exists so quality numbers are
reported through the same ``repro.obs`` surface as the runtime ones —
e.g. a benchmark snapshot can carry ``obs.quality.prf(...)`` next to a
registry snapshot — and so ``metrics`` no longer names two different
things at one import depth.
"""

from repro.core.metrics import (  # noqa: F401
    PRF,
    completeness,
    prf,
    soundness,
    true_pair_gids,
)

__all__ = ["PRF", "completeness", "prf", "soundness", "true_pair_gids"]
