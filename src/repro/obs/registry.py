"""Process-wide runtime metrics registry: counters, gauges, histograms.

This is the *runtime* observability substrate — wall-clock, dispatch,
transfer-byte and latency accounting for the serving/engine stack.  It
is deliberately distinct from :mod:`repro.core.metrics`, which holds the
paper's *quality* metrics (precision/recall/F1, soundness/completeness);
that family is re-exported as :mod:`repro.obs.quality` so "metrics"
stops meaning two things.

Design:

* One process-wide :class:`MetricsRegistry` singleton
  (:func:`get_registry`), matching how the engine objects that record
  into it (``GroundingCache``, ``DevicePromoter``, ``ResolveService``)
  are themselves long-lived.  :func:`reset` clears contents *in place*
  so module-level references held by hot paths stay valid — the pattern
  benchmarks use between cells.
* Every mutation takes the registry lock; instruments are created on
  first touch (``registry.counter("x").inc()``), so call sites never
  pre-register.  Reads (:meth:`MetricsRegistry.snapshot`) take the same
  lock, so a snapshot is internally consistent even under concurrent
  writers — the property ``tests/test_obs.py`` hammers with
  ``ResolveService`` reader threads.
* Histograms keep the **raw samples**, so percentile extraction is
  exact (nearest-rank), not an approximation over fixed buckets —
  ``p50``/``p90``/``p99`` of a resolve-latency histogram are real
  observed latencies.  A ``max_samples`` cap (default 1 << 20) guards a
  long-lived service: past it the histogram degrades gracefully by
  keeping a uniform random reservoir (sum/count/min/max stay exact).

Naming convention (the counter catalog lives in
``docs/ARCHITECTURE.md``): dotted lowercase families —
``ingest.*`` (per-ingest work counters mirroring ``IngestReport``),
``em.*`` (per-run engine counters mirroring ``EMResult``),
``transfer.*`` (host→device upload bytes), ``resolve.*`` (query-path
counters and the latency histogram), ``cover.*`` (packed-array splice
accounting).
"""

from __future__ import annotations

import math
import random
import threading
import time

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "reset",
]


class Counter:
    """Monotonically increasing integer; lock provided by the registry."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self.value = 0
        self._lock = lock

    def inc(self, n: int | float = 1) -> None:
        with self._lock:
            self.value += int(n)


class Gauge:
    """Last-write-wins scalar (e.g. a high-water mark or a config knob)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def max(self, v: float) -> None:
        """Raise the gauge to ``v`` if larger (high-water-mark updates)."""
        with self._lock:
            if v > self.value:
                self.value = float(v)


class Histogram:
    """Exact-percentile histogram over raw float samples.

    Percentiles are nearest-rank over the sorted samples — an observed
    value, never an interpolation.  Beyond ``max_samples`` the sample
    set becomes a uniform reservoir (Vitter's algorithm R) so memory is
    bounded; ``count``/``sum``/``min``/``max`` stay exact regardless.
    """

    __slots__ = ("name", "count", "total", "vmin", "vmax", "samples",
                 "max_samples", "_rng", "_lock")

    def __init__(self, name: str, lock: threading.Lock,
                 max_samples: int = 1 << 20):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.samples: list[float] = []
        self.max_samples = max_samples
        self._rng = random.Random(0x0B5)
        self._lock = lock

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
            if len(self.samples) < self.max_samples:
                self.samples.append(v)
            else:  # reservoir: each sample kept with probability n/count
                j = self._rng.randrange(self.count)
                if j < self.max_samples:
                    self.samples[j] = v

    def percentile(self, q: float) -> float:
        """Exact nearest-rank percentile, ``q`` in [0, 100]."""
        with self._lock:
            if not self.samples:
                return 0.0
            s = sorted(self.samples)
            rank = max(int(math.ceil(q / 100.0 * len(s))), 1)
            return s[min(rank, len(s)) - 1]

    def summary(self) -> dict:
        with self._lock:
            n = self.count
            s = sorted(self.samples)

        def pct(q: float) -> float:
            if not s:
                return 0.0
            rank = max(int(math.ceil(q / 100.0 * len(s))), 1)
            return s[min(rank, len(s)) - 1]

        return {
            "count": n,
            "sum": self.total,
            "mean": self.total / n if n else 0.0,
            "min": self.vmin if n else 0.0,
            "max": self.vmax if n else 0.0,
            "p50": pct(50.0),
            "p90": pct(90.0),
            "p99": pct(99.0),
        }


class MetricsRegistry:
    """Thread-safe instrument store + the span log tracing writes into.

    ``spans`` is an append-only list of
    :class:`repro.obs.tracing.SpanRecord`, capped at ``max_spans``
    (oldest dropped, ``spans_dropped`` counts them) so a long-lived
    service cannot grow the trace without bound.  ``t0`` anchors the
    Chrome-trace timebase: span timestamps are ``perf_counter`` values,
    exported relative to it.
    """

    def __init__(self, max_spans: int = 1 << 16):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}
        self.spans: list = []
        self.max_spans = max_spans
        self.spans_dropped = 0
        self.tracing = True
        self.t0 = time.perf_counter()

    # -- instrument accessors (create on first touch) ---------------------

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name, self._lock))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name, self._lock))
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, Histogram(name, self._lock))
        return h

    def value(self, name: str) -> int:
        """Current value of a counter (0 if never touched)."""
        c = self._counters.get(name)
        return c.value if c is not None else 0

    # -- span log (written by repro.obs.tracing) --------------------------

    def record_span(self, rec) -> None:
        with self._lock:
            if len(self.spans) >= self.max_spans:
                drop = len(self.spans) - self.max_spans + 1
                del self.spans[:drop]
                self.spans_dropped += drop
            self.spans.append(rec)

    def set_tracing(self, enabled: bool) -> None:
        self.tracing = bool(enabled)

    # -- lifecycle --------------------------------------------------------

    def reset(self) -> None:
        """Clear contents in place; instrument objects and the registry
        identity survive, so cached references in hot paths stay valid."""
        with self._lock:
            for c in self._counters.values():
                c.value = 0
            for g in self._gauges.values():
                g.value = 0.0
            for h in self._hists.values():
                h.count = 0
                h.total = 0.0
                h.vmin = math.inf
                h.vmax = -math.inf
                h.samples.clear()
            self.spans.clear()
            self.spans_dropped = 0
            self.t0 = time.perf_counter()

    def snapshot(self) -> dict:
        """One consistent JSON-ready view of everything.

        ``{"counters": {name: int}, "gauges": {name: float},
        "histograms": {name: {count,sum,mean,min,max,p50,p90,p99}},
        "spans": {name: {count, total_s}}, "spans_dropped": int}``

        The per-name span rollup gives stage timings without shipping
        the raw span log; the log itself is exported by
        :func:`repro.obs.export.write_chrome_trace`.
        """
        with self._lock:
            counters = {n: c.value for n, c in self._counters.items()}
            gauges = {n: g.value for n, g in self._gauges.items()}
            spans = list(self.spans)
            dropped = self.spans_dropped
        hists = {n: h.summary() for n, h in list(self._hists.items())}
        rollup: dict[str, dict] = {}
        for rec in spans:
            agg = rollup.setdefault(rec.name, {"count": 0, "total_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += rec.dur_s
        for agg in rollup.values():
            agg["total_s"] = round(agg["total_s"], 6)
        return {
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
            "spans": rollup,
            "spans_dropped": dropped,
        }


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry every engine component records into."""
    return _REGISTRY


def reset() -> None:
    """Clear the process-wide registry in place (see ``reset`` method)."""
    _REGISTRY.reset()
