"""Exporters: JSON snapshots, Chrome-trace files, jax.profiler sessions.

Three ways out of the registry:

* :func:`write_snapshot` — ``MetricsRegistry.snapshot()`` as a JSON
  file; what the benchmarks commit into ``BENCH_*.json`` blocks.
* :func:`write_chrome_trace` — the span log as a Chrome
  ``trace_event`` file (``{"traceEvents": [...]}``, complete ``"X"``
  events in microseconds).  Loads in ``chrome://tracing`` and
  `Perfetto <https://ui.perfetto.dev>`_; CI exports one per push from a
  hepth ingest and uploads it as a workflow artifact.
* :func:`profiler_session` — an opt-in ``jax.profiler`` trace around a
  region (``run_parallel`` wraps itself in one).  Enabled by passing a
  ``logdir`` or setting ``REPRO_JAX_PROFILE_DIR``; a no-op otherwise,
  so the hot path never pays for it.
"""

from __future__ import annotations

import contextlib
import json
import os

from repro.obs.registry import MetricsRegistry, get_registry

__all__ = ["profiler_session", "write_chrome_trace", "write_snapshot"]

PROFILE_ENV = "REPRO_JAX_PROFILE_DIR"


def write_snapshot(path: str, registry: MetricsRegistry | None = None) -> dict:
    """Dump ``registry.snapshot()`` to ``path`` as JSON; returns it."""
    reg = registry if registry is not None else get_registry()
    snap = reg.snapshot()
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return snap


def chrome_trace_events(registry: MetricsRegistry | None = None) -> list[dict]:
    """The span log as Chrome ``trace_event`` dicts (phase ``X``).

    Timestamps are microseconds relative to the registry's ``t0`` (its
    creation or last reset), one ``tid`` per recording thread, so the
    viewer reconstructs the nesting of concurrent ingests and readers.
    """
    reg = registry if registry is not None else get_registry()
    t0 = reg.t0
    events: list[dict] = [{
        "name": "process_name",
        "ph": "M",
        "pid": 0,
        "tid": 0,
        "args": {"name": "repro"},
    }]
    with reg._lock:
        spans = list(reg.spans)
    for rec in spans:
        ev = {
            "name": rec.name,
            "ph": "X",
            "ts": round((rec.t_start - t0) * 1e6, 3),
            "dur": round(rec.dur_s * 1e6, 3),
            "pid": 0,
            "tid": rec.thread_id % (1 << 31),
        }
        args = dict(rec.args) if rec.args else {}
        if rec.parent:
            args["parent"] = rec.parent
        if args:
            ev["args"] = args
        events.append(ev)
    return events


def write_chrome_trace(path: str,
                       registry: MetricsRegistry | None = None) -> int:
    """Write the span log as a Chrome-trace/Perfetto JSON file.

    Returns the number of span events written (excluding metadata).
    Open the file at ``chrome://tracing`` or https://ui.perfetto.dev.
    """
    events = chrome_trace_events(registry)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        f.write("\n")
    return len(events) - 1


@contextlib.contextmanager
def profiler_session(logdir: str | None = None):
    """Opt-in ``jax.profiler`` trace around a region.

    Activates when ``logdir`` is given or ``REPRO_JAX_PROFILE_DIR`` is
    set; yields True when a trace is running, False when it is a no-op.
    Sessions do not nest: if one is already active (jax raises), the
    inner region silently runs untraced — the outer session owns the
    trace.
    """
    logdir = logdir or os.environ.get(PROFILE_ENV)
    if not logdir:
        yield False
        return
    import jax

    try:
        jax.profiler.start_trace(logdir)
    except Exception:
        yield False  # an outer session is already tracing
        return
    try:
        yield True
    finally:
        jax.profiler.stop_trace()
