"""Structured tracing spans: nestable context managers over the registry.

A span measures one stage of work — wall-clock by default, with optional
device fencing (:meth:`Span.fence`) so asynchronously dispatched JAX
work is attributed to the span that launched it instead of whichever
later host sync happens to absorb it.

Spans nest per thread: a thread-local stack tracks the open span, and
each record carries its parent's name and depth, so both the in-process
nesting tests and the Chrome-trace export (which reconstructs nesting
from timestamps within a ``tid``) see the same tree.  The span taxonomy
used by the serving stack is documented in ``docs/ARCHITECTURE.md``
(Observability section); the stable stage names are:

    ingest                      one ResolveService.ingest call
      ingest.lsh                MinHash/LSH probe (stream/delta._probe)
      ingest.replay             localized canopy replay
      ingest.cover_splice       incremental assemble + packed splice
      ingest.grounding_splice   GroundingMaintainer delta + array splice
      ingest.rounds             fixpoint advance (engine.advance)
        rounds.ground           bin grounding dispatches (GroundingCache)
        rounds.fused            fused multi-round while_loop dispatches
        rounds.full             per-bin full-round dispatches
        rounds.promote          step-7 promotion (device or host)
      ingest.commit             atomic cluster/fixpoint publish

Disabling (``registry.set_tracing(False)``) makes :func:`span` yield a
shared no-op whose every method is a pass — the hot path pays one
attribute read.  With tracing ON the cost is two ``perf_counter`` calls
and one locked list append per span; the <5% ingest-overhead guard in
``tests/test_obs.py`` holds the bill.
"""

from __future__ import annotations

import dataclasses
import threading
import time

from repro.obs.registry import MetricsRegistry, get_registry

__all__ = ["Span", "SpanRecord", "span"]


@dataclasses.dataclass
class SpanRecord:
    """One closed span, as stored in the registry's span log."""

    name: str
    t_start: float  # perf_counter at enter
    dur_s: float
    thread_id: int
    parent: str | None
    depth: int
    args: dict | None = None


_local = threading.local()


def _stack() -> list:
    st = getattr(_local, "stack", None)
    if st is None:
        st = _local.stack = []
    return st


class Span:
    """An open span; created by :func:`span`, closed by ``__exit__``."""

    __slots__ = ("name", "registry", "args", "t_start", "parent", "depth")

    def __init__(self, name: str, registry: MetricsRegistry,
                 args: dict | None):
        self.name = name
        self.registry = registry
        self.args = args
        self.t_start = 0.0
        self.parent: str | None = None
        self.depth = 0

    def __enter__(self) -> Span:
        st = _stack()
        self.parent = st[-1].name if st else None
        self.depth = len(st)
        st.append(self)
        self.t_start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        dur = time.perf_counter() - self.t_start
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        self.registry.record_span(SpanRecord(
            name=self.name,
            t_start=self.t_start,
            dur_s=dur,
            thread_id=threading.get_ident(),
            parent=self.parent,
            depth=self.depth,
            args=self.args,
        ))

    def fence(self, value):
        """Block until ``value``'s device buffers are ready, inside the
        span — attributes in-flight device work to this span rather than
        to the next host sync.  Returns ``value`` for chaining.  A no-op
        for host values (``block_until_ready`` ignores non-arrays)."""
        import jax

        return jax.block_until_ready(value)

    def set(self, **kv) -> None:
        """Attach args to the record (shown in the Chrome-trace UI)."""
        if self.args is None:
            self.args = {}
        self.args.update(kv)


class _NoopSpan:
    """Shared do-nothing span returned when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return None

    def fence(self, value):
        return value

    def set(self, **kv):
        return None


_NOOP = _NoopSpan()


def span(name: str, registry: MetricsRegistry | None = None, **args):
    """Open a tracing span: ``with span("ingest.replay"): ...``.

    ``args`` become Chrome-trace event args.  When tracing is disabled
    on the registry this returns a shared no-op object.
    """
    reg = registry if registry is not None else get_registry()
    if not reg.tracing:
        return _NOOP
    return Span(name, reg, args or None)
