"""Device-transfer accounting: host→device upload bytes, by site.

The serving path's transfer story is exactly three sites, each with a
named counter (the quantities ROADMAP Open item 3 gates):

* ``transfer.gcache_bytes`` — raw row tensors shipped to the grounding
  dispatches (:meth:`repro.core.parallel.GroundingCache` ``_ground_rows``,
  which backs both cold grounds and :meth:`~repro.core.parallel.
  GroundingCache.splice`).  O(rows re-ground), i.e. O(dirty) on the
  streaming path.
* ``transfer.promoter_bytes`` — ``DevicePromoter`` uploads: the global
  grounding's ``u``/coupling COO (once per grounding *version* — today
  O(pairs) per ingest, the known residue item 3 retires), the pool
  group CSR (once per ``MessagePool.groups()`` snapshot), and the base
  bitset per promotion call.
* ``transfer.prepare_bytes`` — ``_prepare_bins`` staging: the padded
  per-bin host copies (the bytes later dispatches upload, counted once
  at staging time), paid once per ``run_parallel`` call.

``record_transfer`` is the single write path so the byte arithmetic
(`sum of .nbytes`) cannot drift between sites; per-ingest deltas are
read back by ``ResolveService`` (``IngestReport.upload_bytes``) and
gated by ``benchmarks/check_bench.py --gate=transfer``.
"""

from __future__ import annotations

from repro.obs.registry import get_registry

__all__ = ["SITES", "record_transfer", "total_upload_bytes"]

SITES = ("gcache", "promoter", "prepare")


def record_transfer(site: str, *arrays) -> int:
    """Count host→device upload bytes against ``transfer.<site>_bytes``.

    ``arrays`` are the staged/uploaded buffers (anything with
    ``.nbytes``); returns the byte total for callers that also track
    locally.
    """
    n = sum(int(a.nbytes) for a in arrays if a is not None)
    if n:
        get_registry().counter(f"transfer.{site}_bytes").inc(n)
    return n


def total_upload_bytes() -> int:
    """Current sum over every transfer site's counter."""
    reg = get_registry()
    return sum(reg.value(f"transfer.{s}_bytes") for s in SITES)
