"""Unified runtime observability: metrics registry, tracing, exporters.

The paper's framework decomposes EM into per-stage work (blocking,
replay, cover splice, grounding splice, message-passing rounds,
promotion, commit — §4/§5 of 1103.2410); this package is where that
story is *measured*, in one substrate instead of counters smeared over
report dataclasses and benchmark plumbing:

* :mod:`repro.obs.registry` — process-wide, thread-safe counters /
  gauges / histograms (exact p50/p90/p99); ``get_registry()`` /
  ``reset()``.
* :mod:`repro.obs.tracing` — nestable ``span()`` context managers with
  optional device fencing; the serving span taxonomy is in the module
  docstring and ``docs/ARCHITECTURE.md``.
* :mod:`repro.obs.transfer` — host→device upload-byte accounting for
  the three transfer sites (grounding cache, promoter, bin staging).
* :mod:`repro.obs.export` — JSON snapshots, Chrome-trace/Perfetto
  ``trace_event`` files, opt-in ``jax.profiler`` sessions.
* :mod:`repro.obs.quality` — the paper's quality metrics
  (:mod:`repro.core.metrics`), re-exported so runtime and quality
  numbers report through one surface.

``IngestReport`` and ``EMResult`` remain the public per-call dataclass
views; their counters are registry-backed (``ingest.*`` / ``em.*``
counter families, published at the end of each ingest/run), which is
what ``benchmarks/stream_throughput.py`` and ``table1_parallel.py``
consume via ``snapshot()``.

The fault-tolerance plane reports through the same registry: the
durability families ``wal.*`` (``appends``/``bytes`` counters,
``append_ms`` histogram), ``ckpt.*`` (``saves`` counter, ``last_seq``
gauge), ``recover.*`` (``replayed`` counter, ``wall_ms`` histogram),
``ingest.aborts`` (rolled-back ingests), and the serving degradation
counters ``serve.retries`` / ``serve.quarantined`` /
``serve.faults.flush`` / ``serve.faults.bisections`` plus the
``serve.backoff_ms`` histogram — the taxonomy
``docs/ARCHITECTURE.md`` catalogs and ``tests/test_faults.py``
exercises under injected faults.
"""

from repro.obs.export import (  # noqa: F401
    profiler_session,
    write_chrome_trace,
    write_snapshot,
)
from repro.obs.registry import (  # noqa: F401
    MetricsRegistry,
    get_registry,
    reset,
)
from repro.obs.tracing import Span, SpanRecord, span  # noqa: F401
from repro.obs.transfer import record_transfer, total_upload_bytes  # noqa: F401

__all__ = [
    "MetricsRegistry",
    "Span",
    "SpanRecord",
    "get_registry",
    "profiler_session",
    "record_transfer",
    "reset",
    "span",
    "total_upload_bytes",
    "write_chrome_trace",
    "write_snapshot",
]
