"""qwen2-vl-7b — Qwen2-VL-7B backbone (M-RoPE, dynamic resolution).

[arXiv:2409.12191; hf] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152,064.  The vision tower is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings (ViT hidden
size 1280) merged into the token stream at given positions; positions
are 3-stream M-RoPE ids (temporal/height/width, sections 16/24/24).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    mrope=True,
    mrope_sections=(16, 24, 24),
    vision_patches=1024,
    vision_dim=1280,
    norm_eps=1e-6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        mrope=True,
        mrope_sections=(2, 3, 3),
        vision_patches=8,
        vision_dim=48,
    )
