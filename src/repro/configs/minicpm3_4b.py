"""minicpm3-4b — dense decoder with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf] 62L d_model=2560 40H (kv=40: MLA)
d_ff=6400 vocab=73,448.  MLA ranks: q_lora 768, kv_lora 256,
qk_nope 64, qk_rope 32, v_head 64 — the decode cache stores the
compressed latent (256+32 per token instead of 2·40·96).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    d_head=96,
    mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    rope_theta=1e4,
    norm_eps=1e-5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        mla=True,
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        rope_theta=1e4,
    )
