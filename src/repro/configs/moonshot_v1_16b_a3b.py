"""moonshot-v1-16b-a3b — Moonlight-16B-A3B (Kimi/Moonshot MoE).

[hf:moonshotai/Moonlight-16B-A3B; hf] 48L d_model=2048 16H (kv=16)
MoE 64 experts top-6, expert d_ff=1408, vocab 163,840.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    experts_per_token=6,
    rope_theta=50000.0,
    norm_eps=1e-5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=48,
        moe_d_ff=48,
        vocab_size=512,
        n_experts=8,
        experts_per_token=2,
        rope_theta=50000.0,
    )
