"""Architecture + shape configuration schema.

One module per assigned architecture lives next to this file; each
exports ``CONFIG`` (the exact literature configuration) and
``smoke_config()`` (a reduced same-family variant for CPU tests).

Shapes are the assignment's four input-shape cells; ``decode_*`` /
``long_*`` lower ``serve_step`` (single-token decode against a KV cache
of ``seq_len``), the others lower ``train_step``.
"""

from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    act: str = "silu"  # 'silu' (gated) | 'gelu'
    norm_eps: float = 1e-5
    qkv_bias: bool = False
    rope_theta: float = 1e6
    use_rope: bool = True  # False: learned absolute positions (Whisper)
    tie_embeddings: bool = False
    max_position_embeddings: int = 32768

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # expert hidden size (0 -> d_ff)
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    router_aux_weight: float = 0.01

    # MLA (multi-head latent attention, MiniCPM3/DeepSeek style)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # M-RoPE (Qwen2-VL)
    mrope: bool = False
    mrope_sections: tuple[int, int, int] = (16, 24, 24)

    # SSM (Mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    # Hybrid (Jamba): period structure
    period: int = 0  # layers per period (0 = homogeneous stack)
    attn_layer_offset: int = 4  # index of the attention layer in a period
    attn_layer_period: int = 8
    expert_layer_offset: int = 1  # MoE FFN on odd layers (period 2)
    expert_layer_period: int = 2

    # Encoder-decoder (Whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500  # post-conv source positions (stubbed frontend)
    learned_pos: bool = False

    # VLM (vision frontend stub)
    vision_patches: int = 0  # patches provided by input_specs
    vision_dim: int = 0  # incoming patch-embedding dim (stub projector input)

    # Activation-checkpoint policy: layers per remat group (two-level
    # scan: only group-boundary activations are saved; groups recompute
    # in backward). 0 = one group per layer (save every layer input).
    remat_group: int = 0

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper via its decoder)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "train"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "moonshot_v1_16b_a3b",
    "llama4_scout_17b_a16e",
    "qwen2_vl_7b",
    "falcon_mamba_7b",
    "jamba_v0_1_52b",
    "whisper_medium",
    "yi_6b",
    "qwen2_72b",
    "minicpm3_4b",
    "qwen1_5_0_5b",
]


def _module(arch: str):
    arch = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and why not if it doesn't.

    `long_500k` needs sub-quadratic sequence mixing — run for SSM/hybrid,
    skip for pure full-attention archs (noted in DESIGN.md §5).
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: 500k dense decode out of scope"
    return True, ""
