"""llama4-scout-17b-a16e — Llama-4 Scout (MoE, early fusion).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202,048, MoE 16 experts top-1.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    moe_d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    experts_per_token=1,
    rope_theta=500000.0,
    norm_eps=1e-5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        moe_d_ff=96,
        vocab_size=512,
        n_experts=4,
        experts_per_token=1,
        rope_theta=500000.0,
    )
