"""falcon-mamba-7b — attention-free Mamba-1 LM.

[arXiv:2410.05355; unverified] 64L d_model=4096 (attn-free) vocab=65,024,
ssm_state=16, expand 2 (d_inner 8192), conv 4, dt_rank 256.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    norm_eps=1e-5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=512,
        ssm_state=8,
        ssm_conv=4,
        ssm_expand=2,
    )
