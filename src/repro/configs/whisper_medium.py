"""whisper-medium — encoder-decoder ASR backbone (conv frontend stubbed).

[arXiv:2212.04356; unverified] 24L enc + 24L dec, d_model=1024 16H
(kv=16) d_ff=4096 vocab=51,865; GELU MLPs, LayerNorm, learned absolute
positions, QKV bias.  The audio conv frontend is a STUB: inputs are
precomputed frame embeddings (B, 1500, d_model).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    qkv_bias=True,
    use_rope=False,
    learned_pos=True,
    encoder_frames=1500,
    max_position_embeddings=32768,
    norm_eps=1e-5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="encdec",
        n_layers=2,
        encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        act="gelu",
        qkv_bias=True,
        use_rope=False,
        learned_pos=True,
        encoder_frames=30,
        max_position_embeddings=128,
    )
