"""jamba-v0.1-52b — hybrid Mamba/attention 7:1 + MoE.

[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65,536, MoE 16e top-2.  Attention every 8th layer (offset 4),
MoE FFN every 2nd layer (offset 1); Jamba uses no positional encoding
(the Mamba mixers carry position).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    moe_d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    use_rope=False,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    attn_layer_period=8,
    attn_layer_offset=4,
    expert_layer_period=2,
    expert_layer_offset=1,
    norm_eps=1e-6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        moe_d_ff=96,
        vocab_size=512,
        n_experts=4,
        experts_per_token=2,
        use_rope=False,
        ssm_state=8,
        attn_layer_period=4,
        attn_layer_offset=2,
        expert_layer_period=2,
        expert_layer_offset=1,
    )
