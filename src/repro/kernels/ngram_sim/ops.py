"""Jit'd dispatch for n-gram similarity: Pallas on TPU, jnp elsewhere.

Blocked cosine similarity over L2-normalized hashed n-gram profiles —
the canopy construction's seed-vs-pool probe (a tiled matmul on TPU).

Shapes/dtypes:
    ``sim_matrix(A, B)``:  A (M, F) f32, B (N, F) f32 -> (M, N) f32.
    ``sim_above(A, B, t)``: same, entries < ``t`` zeroed (sparse-ish).

Dispatch rule (``kernels.common.pallas_mode``): the compiled Pallas
kernel on TPU; ``REPRO_PALLAS=interpret`` forces the Pallas body in
interpret mode (how CPU CI validates it); anywhere else the pure-jnp
oracle in ``ref.py`` — identical math, so callers never branch.
"""

from __future__ import annotations

from repro.kernels import common
from repro.kernels.ngram_sim import kernel, ref


def sim_above(A, B, threshold: float):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sim_above(A, B, threshold)
    if mode == "interpret":
        return kernel.sim_above(A, B, threshold, interpret=True)
    return ref.sim_above(A, B, threshold)


def sim_matrix(A, B):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sim_matrix(A, B)
    if mode == "interpret":
        return kernel.sim_matrix(A, B, interpret=True)
    return ref.sim_matrix(A, B)
