"""Jit'd dispatch for n-gram similarity: Pallas on TPU, jnp elsewhere."""

from __future__ import annotations

from repro.kernels import common
from repro.kernels.ngram_sim import kernel, ref


def sim_above(A, B, threshold: float):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sim_above(A, B, threshold)
    if mode == "interpret":
        return kernel.sim_above(A, B, threshold, interpret=True)
    return ref.sim_above(A, B, threshold)


def sim_matrix(A, B):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sim_matrix(A, B)
    if mode == "interpret":
        return kernel.sim_matrix(A, B, interpret=True)
    return ref.sim_matrix(A, B)
