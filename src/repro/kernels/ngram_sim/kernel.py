"""Pallas TPU kernel: blocked n-gram cosine similarity with fused threshold.

Canopy blocking (§4, [McCallum et al. 2000]) needs all-pairs similarity
between candidate entities.  With entities embedded as L2-normalized
hashed n-gram profiles (see ``repro.core.similarity``), similarity is a
dense ``A @ B^T`` — we tile it over the MXU and fuse the loose-threshold
cut in the epilogue so sub-threshold lanes are zeroed before leaving
VMEM (the host then only materializes the sparse survivors).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params, pad_axis, pick_tile, round_up


def _sim_kernel(a_ref, b_ref, o_ref, acc_ref, *, threshold: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        s = acc_ref[...]
        o_ref[...] = jnp.where(s >= threshold, s, 0.0)


@functools.partial(
    jax.jit, static_argnames=("threshold", "interpret", "bm", "bn", "bf")
)
def sim_above(
    A, B, threshold: float = 0.0, *, interpret: bool = False, bm=128, bn=128, bf=128
):
    """A (M,F), B (N,F) -> (M,N) f32, entries < threshold zeroed."""
    M, F = A.shape
    N, _ = B.shape
    bm = pick_tile(M, bm)
    bn = pick_tile(N, bn)
    bf = pick_tile(F, bf)
    Mp, Np, Fp = round_up(M, bm), round_up(N, bn), round_up(F, bf)
    Ap = pad_axis(pad_axis(A.astype(jnp.float32), 0, Mp), 1, Fp)
    Bp = pad_axis(pad_axis(B.astype(jnp.float32), 0, Np), 1, Fp)

    grid = (Mp // bm, Np // bn, Fp // bf)
    out = pl.pallas_call(
        functools.partial(_sim_kernel, threshold=threshold),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bf), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bf), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(Ap, Bp)
    return out[:M, :N]


def sim_matrix(A, B, *, interpret: bool = False):
    return sim_above(A, B, threshold=-2.0, interpret=interpret)
