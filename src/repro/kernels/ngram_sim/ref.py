"""Pure-jnp oracle for blocked n-gram cosine similarity."""

from __future__ import annotations

import jax.numpy as jnp


def sim_matrix(A, B):
    """A (M, F), B (N, F) L2-normalized -> (M, N) cosine sims, f32."""
    return jnp.dot(A.astype(jnp.float32), B.astype(jnp.float32).T)


def sim_above(A, B, threshold: float):
    """Thresholded similarity: sim where >= threshold else 0 (sparse-ish)."""
    s = sim_matrix(A, B)
    return jnp.where(s >= threshold, s, 0.0)
