"""Pure-jnp oracle for batched MinHash signatures.

``X`` is a (N, D) shingle-presence matrix (nonzero = shingle present),
``A`` is an (H, D) table of per-hash-function values for every shingle
slot (one draw of H random permutations of the shingle vocabulary,
tabulated).  The MinHash signature of row ``n`` under hash function
``h`` is the minimum of ``A[h, d]`` over the present shingles ``d``.
Rows with no shingles get the ``EMPTY`` sentinel.
"""

from __future__ import annotations

import jax.numpy as jnp

# Hash values live in [0, EMPTY); EMPTY marks "no shingle present".
# Kept a plain int so kernels can close over it as a literal.
EMPTY = 2**30


def minhash(X, A):
    """X (N, D) presence, A (H, D) int32 -> (N, H) int32 signatures."""
    present = (X > 0)[:, None, :]  # (N, 1, D)
    vals = jnp.where(present, A[None, :, :], EMPTY)  # (N, H, D)
    return vals.min(axis=2).astype(jnp.int32)
