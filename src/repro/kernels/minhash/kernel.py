"""Pallas TPU kernel: batched MinHash signatures for streaming ingest.

The streaming LSH index (``repro.stream.index``) needs MinHash
signatures for every arriving micro-batch.  A signature is a masked min
reduction: ``sig[n, h] = min_d { A[h, d] : X[n, d] > 0 }`` over the
shingle axis ``d`` — a "min-plus matmul" shape, so we tile it like the
``ngram_sim`` matmul but with the VPU's elementwise min instead of the
MXU.  The reduction axis is placed in the *middle* of the broadcast
intermediate ``(bn, bd, bh)`` so both operand blocks and the (bn, bh)
accumulator keep the 128-lane minor dimension.

Inputs are fed transposed — ``Xt (D, N)`` and ``At (D, H)`` — so every
block is (bd, 128)-shaped with the lane axis on N/H.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params, pad_axis, pick_tile, round_up
from repro.kernels.minhash.ref import EMPTY


def _minhash_kernel(xt_ref, at_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, EMPTY)

    present = xt_ref[...].T > 0  # (bn, bd)
    vals = jnp.where(
        present[:, :, None], at_ref[...][None, :, :], EMPTY
    )  # (bn, bd, bh)
    acc_ref[...] = jnp.minimum(acc_ref[...], vals.min(axis=1))

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "bn", "bh", "bd"))
def minhash(X, A, *, interpret: bool = False, bn=128, bh=128, bd=32):
    """X (N, D) presence, A (H, D) int32 -> (N, H) int32 signatures."""
    N, D = X.shape
    H, _ = A.shape
    bn = pick_tile(N, bn)
    bh = pick_tile(H, bh)
    bd = pick_tile(D, bd)
    Np, Hp, Dp = round_up(N, bn), round_up(H, bh), round_up(D, bd)
    # Transposed layout: minor dim is N/H (128 lanes), D is the grid axis.
    Xt = pad_axis(pad_axis((X > 0).astype(jnp.int32).T, 0, Dp), 1, Np)
    At = pad_axis(pad_axis(A.astype(jnp.int32).T, 0, Dp, fill=EMPTY), 1, Hp)

    grid = (Np // bn, Hp // bh, Dp // bd)
    out = pl.pallas_call(
        _minhash_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bn), lambda i, j, k: (k, i)),
            pl.BlockSpec((bd, bh), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bh), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Hp), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bn, bh), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(Xt, At)
    return out[:N, :H]
