"""Jit'd dispatch for MinHash signatures: Pallas on TPU, jnp elsewhere."""

from __future__ import annotations

import numpy as np

from repro.kernels import common
from repro.kernels.minhash import kernel, ref


def minhash(X, A):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.minhash(X, A)
    if mode == "interpret":
        return kernel.minhash(X, A, interpret=True)
    return ref.minhash(X, A)


def hash_table(num_hashes: int, dim: int, seed: int = 0) -> np.ndarray:
    """(H, D) int32 table of independent random hash values in [0, EMPTY).

    One tabulated draw of ``num_hashes`` random orderings of the shingle
    vocabulary; collisions across slots are harmless (MinHash only needs
    the argmin distribution to be uniform-ish).
    """
    rng = np.random.default_rng(seed)
    return rng.integers(0, int(ref.EMPTY), size=(num_hashes, dim), dtype=np.int32)
