"""Jit'd dispatch for MinHash signatures: Pallas on TPU, jnp elsewhere.

Batched MinHash over shingle-presence vectors — the streaming LSH
index's on-device signature computation (``repro.stream.index``).

Shapes/dtypes:
    ``minhash(X, A)``: X (N, D) f32 presence (nonzero = shingle
    present), A (H, D) int32 hash table -> (N, H) int32 signatures;
    rows with no shingles get the ``ref.EMPTY`` sentinel.
    ``hash_table(H, D, seed)``: (H, D) int32 in ``[0, EMPTY)``.

Dispatch rule (``kernels.common.pallas_mode``): compiled Pallas kernel
on TPU, interpret mode under ``REPRO_PALLAS=interpret`` (CPU CI), and
the pure-jnp oracle in ``ref.py`` everywhere else — identical results
on every backend.
"""

from __future__ import annotations

import numpy as np

from repro.kernels import common
from repro.kernels.minhash import kernel, ref


def minhash(X, A):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.minhash(X, A)
    if mode == "interpret":
        return kernel.minhash(X, A, interpret=True)
    return ref.minhash(X, A)


def hash_table(num_hashes: int, dim: int, seed: int = 0) -> np.ndarray:
    """(H, D) int32 table of independent random hash values in [0, EMPTY).

    One tabulated draw of ``num_hashes`` random orderings of the shingle
    vocabulary; collisions across slots are harmless (MinHash only needs
    the argmin distribution to be uniform-ish).
    """
    rng = np.random.default_rng(seed)
    return rng.integers(0, int(ref.EMPTY), size=(num_hashes, dim), dtype=np.int32)
