"""Jit'd dispatch: Pallas flash attention on TPU, oracles elsewhere.

Grouped-query attention for the LM stack (``repro.models``), online-
softmax tiled on TPU; the jnp oracle materializes the full (S, T)
score matrix.

Shapes/dtypes:
    ``attention(q, k, v, scale, causal=True)``:
    q (B, S, H, hd), k/v (B, T, Hkv, hd) with H a multiple of Hkv
    (GQA groups of H // Hkv query heads per KV head) -> (B, S, H*hd)
    f32; inputs may be lower precision, accumulation is f32.

Dispatch rule (``kernels.common.pallas_mode``): compiled Pallas kernel
on TPU, interpret mode under ``REPRO_PALLAS=interpret`` (CPU CI), else
the jnp oracle in ``ref.py``.
"""

from __future__ import annotations

from repro.kernels import common
from repro.kernels.flash_attn import kernel, ref


def attention(q, k, v, scale, *, causal: bool = True):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.flash_attention(q, k, v, scale, causal=causal)
    if mode == "interpret":
        return kernel.flash_attention(q, k, v, scale, causal=causal, interpret=True)
    return ref.attention(q, k, v, scale, causal=causal)
