"""Jit'd dispatch: Pallas flash attention on TPU, oracles elsewhere."""

from __future__ import annotations

from repro.kernels import common
from repro.kernels.flash_attn import kernel, ref


def attention(q, k, v, scale, *, causal: bool = True):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.flash_attention(q, k, v, scale, causal=causal)
    if mode == "interpret":
        return kernel.flash_attention(q, k, v, scale, causal=causal, interpret=True)
    return ref.attention(q, k, v, scale, causal=causal)
