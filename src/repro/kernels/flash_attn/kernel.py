"""Pallas TPU flash attention (GQA, causal, online softmax).

The TPU-native replacement for the XLA query-chunked path
(``repro.models.layers.chunked_attention``): one fused kernel holding a
``(bq, hd)`` output accumulator and running (max, sum) statistics in
VMEM while streaming ``(bk, hd)`` key/value tiles from HBM — the
``(S, T)`` score matrix never exists, and *fully-masked causal tiles
are skipped* (`pl.when` over the whole tile body), which removes the
2x causal-compute waste the XLA path pays.

Adaptation note (DESIGN §3): FlashAttention's CUDA formulation tunes
shared-memory banking and warp occupancy; on TPU the same insight maps
to VMEM block residency + MXU-aligned (128) tiles, with the grid's
innermost axis ("arbitrary" semantics) carrying the kv stream.

Grid: (B * H, S/bq, T/bk); q/k/v are reshaped to head-major 3-D outside
the kernel, and the GQA group maps query-head -> kv-head in the index
map (no materialized head repetition).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params, pad_axis, pick_tile, round_up

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, bq, bk, t_valid):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal skip: tile is dead when every key index > every query index —
    # the whole body is predicated off, removing the 2x causal waste.
    q_last = qi * bq + bq - 1
    k_first = ki * bk
    live = (k_first <= q_last) if causal else (ki >= 0)

    @pl.when(live)
    def _tile():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                  # (bq, bk)

        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < t_valid                      # key padding
        if causal:
            mask &= rows >= cols
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                       # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _done():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "causal", "interpret", "bq", "bk")
)
def flash_attention(q, k, v, scale, *, causal: bool = True,
                    interpret: bool = False, bq: int = 128, bk: int = 128):
    """q (B,S,H,hd), k/v (B,T,Hkv,hd) -> (B,S,H*hd) f32."""
    B, S, H, hd = q.shape
    T, hkv = k.shape[1], k.shape[2]
    g = H // hkv
    bq = pick_tile(S, bq)
    bk = pick_tile(T, bk)
    Sp, Tp = round_up(S, bq), round_up(T, bk)

    # head-major layout: (B*H, S, hd) / (B*Hkv, T, hd)
    qh = pad_axis(q.transpose(0, 2, 1, 3).reshape(B * H, S, hd), 1, Sp)
    kh = pad_axis(k.transpose(0, 2, 1, 3).reshape(B * hkv, T, hd), 1, Tp)
    vh = pad_axis(v.transpose(0, 2, 1, 3).reshape(B * hkv, T, hd), 1, Tp)

    grid = (B * H, Sp // bq, Tp // bk)
    kernel = functools.partial(
        _flash_kernel, scale=float(scale), causal=causal,
        bq=bq, bk=bk, t_valid=T,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :S, :]  # strip seq padding
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, H * hd)
