"""Pure-jnp oracle: naive GQA attention with full (S, T) scores."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention(q, k, v, scale, *, causal: bool = True):
    """q (B,S,H,hd), k/v (B,T,Hkv,hd) -> (B,S,H*hd) f32."""
    B, S, H, hd = q.shape
    T, hkv = k.shape[1], k.shape[2]
    g = H // hkv
    qg = q.reshape(B, S, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H * hd)
