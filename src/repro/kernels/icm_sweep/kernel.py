"""Pallas TPU kernel for the batched ICM conditional-delta sweep.

Computes ``delta[s, p] = u[p] + sum_q X[s, q] * C[q, p]`` — the inner
loop of both greedy closure and the entailment-matrix construction
(DESIGN §3).  On TPU this is a tiled MXU matmul with the unary add fused
into the epilogue, so the sweep never round-trips the (S, P) delta
through HBM between the matmul and the bias.

Tiling: output tiles (bs, bp) held in a VMEM f32 scratch accumulator;
the contraction dim is the innermost ("arbitrary") grid axis.  Tiles are
multiples of (8, 128) to match the VPU/MXU lane layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params, pad_axis, pick_tile, round_up


def _sweep_kernel(u_ref, x_ref, c_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], c_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...] + u_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "bs", "bp", "bk"))
def sweep_matrix(u, C, X, *, interpret: bool = False, bs=128, bp=128, bk=128):
    """u (P,), C (P, P), X (S, P) -> (S, P) f32 via pallas_call."""
    S, P = X.shape
    bs = pick_tile(S, bs)
    bp = pick_tile(P, bp)
    bk = pick_tile(P, bk)
    Sp, Pp = round_up(S, bs), round_up(P, bp)
    Kp = round_up(P, bk)

    u2 = pad_axis(u.astype(jnp.float32)[None, :], 1, Pp)
    Xp = pad_axis(pad_axis(X.astype(jnp.float32), 0, Sp), 1, Kp)
    Cp = pad_axis(pad_axis(C.astype(jnp.float32), 0, Kp), 1, Pp)

    grid = (Sp // bs, Pp // bp, Kp // bk)
    out = pl.pallas_call(
        _sweep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bp), lambda i, j, k: (0, j)),
            pl.BlockSpec((bs, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bp), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bs, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Sp, Pp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bs, bp), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(u2, Xp, Cp)
    return out[:S, :P]


def sweep(u, C, x, *, interpret: bool = False):
    return sweep_matrix(u, C, x[None, :], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sweep_batch(u, C, X, *, interpret: bool = False):
    """u (B, P), C (B, P, P), X (B, P) -> (B, P) f32.

    Batched over the bin axis via the pallas_call batching rule — each
    lane is one neighborhood's conditional-delta sweep.
    """
    return jax.vmap(
        lambda ub, Cb, xb: sweep(ub, Cb, xb, interpret=interpret)
    )(u, C, X)
