"""Pure-jnp oracle for the ICM sweep: delta = u + X @ C."""

from __future__ import annotations

import jax.numpy as jnp


def sweep_matrix(u, C, X):
    """u (P,), C (P, P) symmetric, X (S, P) -> (S, P) f32."""
    return u[None, :].astype(jnp.float32) + jnp.dot(
        X.astype(jnp.float32), C.astype(jnp.float32)
    )


def sweep(u, C, x):
    """u (P,), C (P, P), x (P,) -> (P,)."""
    return sweep_matrix(u, C, x[None, :])[0]
