"""Pure-jnp oracle for the ICM sweep: delta = u + X @ C."""

from __future__ import annotations

import jax.numpy as jnp


def sweep_matrix(u, C, X):
    """u (P,), C (P, P) symmetric, X (S, P) -> (S, P) f32."""
    return u[None, :].astype(jnp.float32) + jnp.dot(
        X.astype(jnp.float32), C.astype(jnp.float32)
    )


def sweep(u, C, x):
    """u (P,), C (P, P), x (P,) -> (P,)."""
    return sweep_matrix(u, C, x[None, :])[0]


def sweep_batch(u, C, X):
    """u (B, P), C (B, P, P) symmetric, X (B, P) -> (B, P) f32.

    One conditional-delta sweep per neighborhood of a bin — the batched
    form of :func:`sweep` used by the fused round engine so a whole bin
    advances in a single batched contraction instead of B vmapped ones.
    """
    return u.astype(jnp.float32) + jnp.einsum(
        "bp,bpq->bq", X.astype(jnp.float32), C.astype(jnp.float32)
    )
