"""Jit'd dispatch for the ICM sweep: Pallas on TPU, jnp oracle elsewhere.

The conditional-delta sweep of greedy/ICM MAP inference over a
neighborhood's pair variables: ``delta = u + X @ C`` (u unary, C
coupling, X the current assignment) — the inner step of the MLN
matcher's closure and of the fused round engine.

Shapes/dtypes (all f32 outputs):
    ``sweep(u, C, x)``:        u (P,), C (P, P) symmetric, x (P,) -> (P,).
    ``sweep_matrix(u, C, X)``: X (S, P) assignment rows -> (S, P).
    ``sweep_batch(u, C, X)``:  u (B, P), C (B, P, P), X (B, P) -> (B, P)
    — one sweep per neighborhood of a whole size-bin in a single
    batched contraction (what the fused ``while_loop`` engine calls).

Dispatch rule (``kernels.common.pallas_mode``): compiled Pallas on TPU,
interpret mode under ``REPRO_PALLAS=interpret``, else the jnp oracle in
``ref.py`` — same math everywhere.
"""

from __future__ import annotations

from repro.kernels import common
from repro.kernels.icm_sweep import kernel, ref


def sweep_matrix(u, C, X):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sweep_matrix(u, C, X)
    if mode == "interpret":
        return kernel.sweep_matrix(u, C, X, interpret=True)
    return ref.sweep_matrix(u, C, X)


def sweep(u, C, x):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sweep(u, C, x)
    if mode == "interpret":
        return kernel.sweep(u, C, x, interpret=True)
    return ref.sweep(u, C, x)


def sweep_batch(u, C, X):
    """Per-neighborhood sweep over a whole bin: (B, P) -> (B, P).

    The fused round engine advances every neighborhood of a bin in one
    batched contraction per closure iteration instead of B vmapped
    per-row sweeps, so the multi-round ``lax.while_loop`` body is a
    single MXU-shaped op.
    """
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sweep_batch(u, C, X)
    if mode == "interpret":
        return kernel.sweep_batch(u, C, X, interpret=True)
    return ref.sweep_batch(u, C, X)
