"""Jit'd dispatch for the ICM sweep: Pallas on TPU, jnp oracle elsewhere."""

from __future__ import annotations

from repro.kernels import common
from repro.kernels.icm_sweep import kernel, ref


def sweep_matrix(u, C, X):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sweep_matrix(u, C, X)
    if mode == "interpret":
        return kernel.sweep_matrix(u, C, X, interpret=True)
    return ref.sweep_matrix(u, C, X)


def sweep(u, C, x):
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sweep(u, C, x)
    if mode == "interpret":
        return kernel.sweep(u, C, x, interpret=True)
    return ref.sweep(u, C, x)


def sweep_batch(u, C, X):
    """Per-neighborhood sweep over a whole bin: (B, P) -> (B, P).

    The fused round engine advances every neighborhood of a bin in one
    batched contraction per closure iteration instead of B vmapped
    per-row sweeps, so the multi-round ``lax.while_loop`` body is a
    single MXU-shaped op.
    """
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.sweep_batch(u, C, X)
    if mode == "interpret":
        return kernel.sweep_batch(u, C, X, interpret=True)
    return ref.sweep_batch(u, C, X)
