"""Jit'd dispatch for MLN set scoring: Pallas on TPU, jnp oracle elsewhere."""

from __future__ import annotations

from repro.kernels import common
from repro.kernels.mln_score import kernel, ref


def score_sets(u, C, X):
    """u (B,P), C (B,P,P), X (B,S,P) -> (B,S) unnormalized log P."""
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.score_sets(u, C, X)
    if mode == "interpret":
        return kernel.score_sets(u, C, X, interpret=True)
    return ref.score_sets(u, C, X)
