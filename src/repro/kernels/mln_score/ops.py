"""Jit'd dispatch for MLN set scoring: Pallas on TPU, jnp oracle elsewhere.

Batched unnormalized log-probability of candidate match sets under the
grounded MLN: ``f(x) = x . u + 1/2 x^T C x`` per (neighborhood, set) —
the matcher's set-comparison primitive (maximal-message enumeration).

Shapes/dtypes:
    ``score_sets(u, C, X)``: u (B, P) f32 unaries, C (B, P, P) f32
    symmetric couplings, X (B, S, P) candidate-set indicators ->
    (B, S) f32 scores.

Dispatch rule (``kernels.common.pallas_mode``): compiled Pallas on TPU,
interpret mode under ``REPRO_PALLAS=interpret`` (CPU CI), else the
pure-jnp oracle in ``ref.py`` — identical math on every backend.
"""

from __future__ import annotations

from repro.kernels import common
from repro.kernels.mln_score import kernel, ref


def score_sets(u, C, X):
    """u (B,P), C (B,P,P), X (B,S,P) -> (B,S) unnormalized log P."""
    mode = common.pallas_mode()
    if mode == "compiled":
        return kernel.score_sets(u, C, X)
    if mode == "interpret":
        return kernel.score_sets(u, C, X, interpret=True)
    return ref.score_sets(u, C, X)
