"""Pure-jnp oracle for batched MLN set scoring.

f(x) = x . u + 1/2 * x^T C x, per (neighborhood b, candidate set s).
"""

from __future__ import annotations

import jax.numpy as jnp


def score_sets(u, C, X):
    """u (B, P), C (B, P, P), X (B, S, P) -> (B, S) f32."""
    u = u.astype(jnp.float32)
    C = C.astype(jnp.float32)
    X = X.astype(jnp.float32)
    lin = jnp.einsum("bsp,bp->bs", X, u)
    quad = 0.5 * jnp.einsum("bsp,bpq,bsq->bs", X, C, X)
    return lin + quad
