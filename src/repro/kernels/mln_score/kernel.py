"""Pallas TPU kernel: batched supermodular set scoring.

``f[b, s] = X[b,s,:] . u[b,:] + 1/2 * X[b,s,:] (C[b] X[b,s,:]^T)``

This powers (i) the Type-II probability checks of MMP step 7, (ii) the
UB upper-bound scheme of §6.1, and (iii) exact subset enumeration over
small entailment components, where ``S = 2^m`` candidate sets are scored
in one launch (the MXU-native replacement for per-set Alchemy calls).

Strategy per (b, s-tile): loop P-tiles twice —
  pass k: Y_tile = X_tile @ C[:, ktile]   (accumulated in VMEM scratch)
  epilogue: lin = X @ u, quad = 1/2 rowsum(Y * X), out = lin + quad.

We fuse by computing, for each contraction tile k:
  acc[s] += X[s, ktile] . u[ktile]                 (linear part)
  acc[s] += 1/2 * rowsum((X[s,:] @ C[:, ktile]) * X[s, ktile])
where the inner matmul loops over the *other* P axis with its own grid
dim, giving grid (B, S/bs, P/bp, P/bk): the quad term accumulates the
full X @ C product restricted to the output ktile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params, pad_axis, pick_tile, round_up


def _score_kernel(u_ref, x_ref, xj_ref, c_ref, o_ref, y_acc, f_acc):
    # grid = (B, S/bs, P/bj, P/bk); for fixed (b, s-tile, j-tile):
    #   y_acc (bs, bj) accumulates (X @ C)[:, jtile] over k
    #   at last k: f_acc += rowsum(0.5 * y * xj) + (j==0 ? X@u : 0)
    j = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when((j == 0) & (k == 0))
    def _init_f():
        f_acc[...] = jnp.zeros_like(f_acc)

    @pl.when(k == 0)
    def _init_y():
        y_acc[...] = jnp.zeros_like(y_acc)

    y_acc[0] += jnp.dot(
        x_ref[0], c_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(3) - 1)
    def _epilogue():
        xj = xj_ref[0]  # (bs, bj)
        f_acc[0] += jnp.sum(0.5 * y_acc[0] * xj, axis=1, keepdims=True)
        f_acc[0] += jnp.dot(xj, u_ref[0].T, preferred_element_type=jnp.float32)

    @pl.when(
        (j == pl.num_programs(2) - 1) & (k == pl.num_programs(3) - 1)
    )
    def _done():
        o_ref[0] = f_acc[0]


@functools.partial(jax.jit, static_argnames=("interpret", "bs", "bj", "bk"))
def score_sets(u, C, X, *, interpret: bool = False, bs=128, bj=128, bk=128):
    """u (B,P), C (B,P,P), X (B,S,P) -> (B,S) f32."""
    B, S, P = X.shape
    bs = pick_tile(S, bs)
    bj = pick_tile(P, bj)
    bk = pick_tile(P, bk)
    Sp, Pj, Pk = round_up(S, bs), round_up(P, bj), round_up(P, bk)

    u_p = pad_axis(u.astype(jnp.float32), 1, Pj)[:, None, :]  # (B,1,Pj)
    X_k = pad_axis(pad_axis(X.astype(jnp.float32), 1, Sp), 2, Pk)
    X_j = pad_axis(pad_axis(X.astype(jnp.float32), 1, Sp), 2, Pj)
    C_p = pad_axis(pad_axis(C.astype(jnp.float32), 1, Pk), 2, Pj)

    grid = (B, Sp // bs, Pj // bj, Pk // bk)
    out = pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bj), lambda b, s, j, k: (b, 0, j)),
            pl.BlockSpec((1, bs, bk), lambda b, s, j, k: (b, s, k)),
            pl.BlockSpec((1, bs, bj), lambda b, s, j, k: (b, s, j)),
            pl.BlockSpec((1, bk, bj), lambda b, s, j, k: (b, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bs, 1), lambda b, s, j, k: (b, s, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, bs, bj), jnp.float32),
            pltpu.VMEM((1, bs, 1), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(u_p, X_k, X_j, C_p)
    return out[:, :S, 0]
