"""Shared helpers for the Pallas TPU kernels.

Routing policy (``ops.py`` of every kernel):

* On TPU, run the compiled Pallas kernel.
* On CPU/GPU, run the pure-jnp reference (identical math) so the whole
  framework works everywhere.
* ``REPRO_PALLAS=interpret`` forces the Pallas kernel in interpret mode
  (kernel body executed in Python) — this is how the CPU CI validates
  the kernels against the oracles in ``ref.py``.
"""

from __future__ import annotations

import os

import jax
import numpy as np


def compiler_params(**kwargs):
    """Version-compat constructor for Pallas TPU compiler params.

    jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
    depending on the installed version exactly one of the two exists.
    Every kernel builds its params through this helper so the repo works
    on either side of the rename.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def shard_map(fn, mesh, in_specs, out_specs):
    """Version-compat ``shard_map``: ``jax.shard_map`` (new) falls back to
    ``jax.experimental.shard_map.shard_map`` (<= 0.4.x), and the disabled
    replication check is passed under whichever kwarg the version takes
    (``check_vma`` post-rename, ``check_rep`` before)."""
    if hasattr(jax, "shard_map"):
        _shard_map = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as _shard_map
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return _shard_map(fn, **kw, check_vma=False)
    except TypeError:
        return _shard_map(fn, **kw, check_rep=False)


def pallas_mode() -> str:
    """'compiled' | 'interpret' | 'off'."""
    env = os.environ.get("REPRO_PALLAS", "").lower()
    if env == "interpret":
        return "interpret"
    if env == "off":
        return "off"
    try:
        platform = jax.default_backend()
    except Exception:  # pragma: no cover
        platform = "cpu"
    return "compiled" if platform == "tpu" else "off"


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pad_axis(x, axis: int, to: int, fill=0.0):
    """Pad jnp/np array along axis to length `to`."""
    import jax.numpy as jnp

    cur = x.shape[axis]
    if cur == to:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, to - cur)
    return jnp.pad(x, pad, constant_values=fill)


def pick_tile(n: int, preferred: int = 128, floor: int = 8) -> int:
    """Largest hardware-aligned tile <= preferred that keeps padding sane."""
    if n >= preferred:
        return preferred
    t = floor
    while t * 2 <= max(n, floor):
        t *= 2
    return max(t, floor)


def assert_allclose(a, b, rtol=1e-5, atol=1e-5, msg=""):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol, err_msg=msg)


# ---------------------------------------------------------------------------
# Multi-process mesh helpers (CPU-mesh sharded serving)
# ---------------------------------------------------------------------------


def mesh_spans_processes(mesh) -> bool:
    """True when the mesh covers devices from more than one JAX process.

    On a single-process mesh (the normal case, including
    ``--xla_force_host_platform_device_count`` multi-device CPU), plain
    ``jnp.asarray`` uploads are valid global arrays for ``shard_map``.
    Across processes they are not: every input to a global-mesh
    computation must be built with an explicit ``NamedSharding`` so all
    processes agree on the layout.
    """
    if mesh is None:
        return False
    try:
        return len({d.process_index for d in mesh.devices.flat}) > 1
    except Exception:  # pragma: no cover - exotic mesh types
        return False


def put_replicated(x, mesh):
    """Upload a host array fully replicated over ``mesh``.

    Single-process meshes take the cheap ``jnp.asarray`` path (committed
    to the default device, exactly what the pre-distributed code did);
    multi-process meshes need a real replicated ``NamedSharding`` so the
    array is addressable as one global value on every host.
    """
    import jax.numpy as jnp

    if not mesh_spans_processes(mesh):
        return jnp.asarray(x)
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.device_put(np.asarray(x), NamedSharding(mesh, PartitionSpec()))


def put_sharded(x, mesh, axis):
    """Upload a host array sharded over ``mesh`` along its leading dim.

    The leading dimension must be divisible by the mesh size (callers
    pad batches with ``pad_mult``).  Single-process meshes fall back to
    ``jnp.asarray`` — ``shard_map`` reshards the committed array itself,
    which is what the existing single-host dispatch relies on.
    """
    import jax.numpy as jnp

    if not mesh_spans_processes(mesh):
        return jnp.asarray(x)
    from jax.sharding import NamedSharding, PartitionSpec

    spec = PartitionSpec(axis, *([None] * (np.ndim(x) - 1)))
    return jax.device_put(np.asarray(x), NamedSharding(mesh, spec))


def host_array(x) -> np.ndarray:
    """Bring a (replicated) device array back to the host as numpy."""
    return np.asarray(x)
