"""EM-based corpus dedup: the paper's technique in the LM data path.

Web-scale LM training requires document dedup.  Exact-hash dedup misses
near-duplicates; pairwise MinHash misses *transitive* duplicate families
(A~B, B~C but A!~C on surface similarity).  That is precisely the
collective-EM problem, so we run the paper's machinery over documents:

* entities  = documents (hashed shingle profiles as "names");
* Similar   = shingle-profile cosine, discretized to levels 1..3;
* relation  = ``SameSource`` (documents from one crawl/source cluster —
  the analogue of Coauthor: relational, not textual, evidence);
* matcher   = the same supermodular MLN, weights re-interpreted for the
  document domain; SMP/MMP message passing across canopy neighborhoods.

The output clusters drive `filter_corpus`, keeping one representative
per duplicate family.  This is deliberately the *same code path* as the
bibliographic pipeline — the black-box abstraction (paper §3) is what
makes the matcher domain-agnostic.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.closure import clusters_of
from repro.core.mln import MLNMatcher, MLNWeights
from repro.core.pipeline import resolve
from repro.core.types import EntityTable, Relations

# Weights tuned for the document domain: level-3 shingle similarity is
# near-duplication; one shared-source link plus level-2 is enough.
DOC_WEIGHTS = MLNWeights(w_sim=(0.0, -2.0, -1.0, 8.0), w_co=1.6)
# MinHash-signature JW levels (near-dups land at ~0.84-0.95; random doc
# signatures over a 26-letter alphabet have a ~0.6-0.75 JW baseline).
DOC_THRESHOLDS = (0.78, 0.82, 0.875)


def _doc_signature(doc: np.ndarray, n: int = 3, chars: int = 32) -> str:
    """MinHash shingle signature rendered as a string.

    Hash every ``n``-token shingle, keep the ``chars`` smallest hashes
    (order-invariant, robust to local edits — classic MinHash), and
    render them as letters so the existing name/profile machinery
    (n-gram profiles + Jaro-Winkler levels) applies unchanged.
    """
    d = np.asarray(doc, dtype=np.int64)
    if len(d) < n:
        d = np.pad(d, (0, n - len(d)), constant_values=1)
    # rolling polynomial hash of shingles, vectorized
    h = np.zeros(len(d) - n + 1, dtype=np.uint64)
    for i in range(n):
        h = h * np.uint64(1099511628211) + d[i : len(d) - n + 1 + i].astype(np.uint64)
        h ^= h >> np.uint64(29)
    mins = np.sort(np.unique(h))[:chars]
    return "".join(chr(ord("a") + int(m % np.uint64(26))) for m in mins)


@dataclasses.dataclass
class DedupReport:
    n_docs: int
    n_clusters: int
    n_removed: int
    keep_mask: np.ndarray
    clusters: list[np.ndarray]


def dedup_documents(
    docs: list[np.ndarray],
    source_of: np.ndarray | None = None,
    *,
    weights: MLNWeights = DOC_WEIGHTS,
    scheme: str = "smp",
    k_max: int = 24,
) -> DedupReport:
    """Run collective EM over documents, return duplicate clusters."""
    names = [_doc_signature(d) for d in docs]
    entities = EntityTable(names=names, truth=None)

    if source_of is None:
        source_of = np.zeros(len(docs), dtype=np.int64)
    # SameSource relation: windowed clique per source.  A chain would
    # give a candidate pair no *shared* neighbor, and the MLN's
    # relational rule needs one (coauthor(e1,c) & coauthor(e2,c)); a
    # window-4 clique keeps the relation sparse while giving every
    # nearby same-source pair common neighbors.
    edges = []
    recent: dict[int, list[int]] = {}
    window = 4
    for i, s in enumerate(np.asarray(source_of).tolist()):
        for j in recent.get(s, []):
            edges.append((j, i))
        recent.setdefault(s, []).append(i)
        recent[s] = recent[s][-window:]
    rel = Relations(
        edges={
            "coauthor": np.asarray(edges, dtype=np.int64)
            if edges
            else np.zeros((0, 2), dtype=np.int64)
        }
    )

    matcher = MLNMatcher(weights)
    res = resolve(
        entities,
        rel,
        scheme=scheme,
        matcher=matcher,
        weights=weights,
        k_max=k_max,
        thresholds=DOC_THRESHOLDS,
        t_loose=0.60,
    )
    clusters = clusters_of(res.closed)

    keep = np.ones(len(docs), dtype=bool)
    removed = 0
    for c in clusters:
        for dup in c[1:]:  # keep the first member as representative
            keep[int(dup)] = False
            removed += 1
    return DedupReport(
        n_docs=len(docs),
        n_clusters=len(clusters),
        n_removed=removed,
        keep_mask=keep,
        clusters=clusters,
    )


def filter_corpus(docs: list[np.ndarray], report: DedupReport) -> list[np.ndarray]:
    return [d for d, k in zip(docs, report.keep_mask) if k]
