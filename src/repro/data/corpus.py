"""LM training data pipeline: synthetic corpus + sharded loader.

The training substrate needs a deterministic, infinite, restartable
token stream.  Documents are synthesized from a power-law unigram model
(Zipfian token frequencies, like natural text) with a controllable rate
of *near-duplicate* documents — the workload for the EM-based corpus
dedup (:mod:`repro.data.dedup`), which is the paper's technique applied
at the LM data layer.

Determinism + restartability: batch ``i`` is a pure function of
``(seed, i)`` (counter-based RNG), so checkpoint restore just resumes at
``step`` with no loader state to persist — a requirement for preemption
recovery on large fleets.
"""

from __future__ import annotations

import dataclasses

import numpy as np

try:  # jax only needed for device placement helpers
    import jax
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
except Exception:  # pragma: no cover
    jax = None


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    # document model
    doc_len_mean: int = 512
    dup_rate: float = 0.15  # fraction of near-duplicate docs
    zipf_a: float = 1.2


class TokenStream:
    """Deterministic (seed, step) -> batch of token ids + targets."""

    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, 0xC0FFEE])
        )
        # Zipf over vocab, shifted so token 0 is reserved for padding/BOS
        z = rng.zipf(cfg.zipf_a, size=(cfg.global_batch, cfg.seq_len + 1))
        toks = (z % (cfg.vocab_size - 1)).astype(np.int32) + 1
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }

    def batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def make_documents(
    cfg: CorpusConfig, n_docs: int
) -> tuple[list[np.ndarray], np.ndarray]:
    """Document collection with injected near-duplicates (for dedup).

    Returns (docs, dup_of) where ``dup_of[i]`` is the index of the
    original document i duplicates, or -1 for originals — ground truth
    for evaluating the dedup pipeline.
    """
    rng = np.random.default_rng(cfg.seed)
    docs: list[np.ndarray] = []
    dup_of = np.full(n_docs, -1, dtype=np.int64)
    for d in range(n_docs):
        if docs and rng.random() < cfg.dup_rate:
            # near-duplicate of an earlier doc: token dropout + noise
            j = int(rng.integers(0, len(docs)))
            src = docs[j]
            keep = rng.random(len(src)) > 0.03
            dup = src[keep].copy()
            flips = rng.random(len(dup)) < 0.01
            dup[flips] = rng.integers(1, cfg.vocab_size, size=int(flips.sum()))
            docs.append(dup)
            dup_of[d] = dup_of[j] if dup_of[j] >= 0 else j
        else:
            n = max(16, int(rng.normal(cfg.doc_len_mean, cfg.doc_len_mean / 4)))
            z = rng.zipf(cfg.zipf_a, size=n)
            docs.append((z % (cfg.vocab_size - 1)).astype(np.int32) + 1)
    return docs, dup_of


def shard_batch(batch: dict[str, np.ndarray], mesh, data_axes=("data",)):
    """Place a host batch onto the mesh, sharded along the batch axis."""
    assert jax is not None
    sharding = NamedSharding(mesh, P(data_axes))
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}


class Loader:
    """Prefetching loader bound to a mesh.

    ``prefetch`` batches are staged ahead with ``device_put`` so host
    synthesis overlaps device compute (the CPU analogue of an input
    pipeline; on TPU this is where a real tf.data/grain feed would sit).
    """

    def __init__(self, cfg: CorpusConfig, mesh=None, prefetch: int = 2,
                 start_step: int = 0, data_axes=("data",)):
        self.stream = TokenStream(cfg)
        self.mesh = mesh
        self.prefetch = prefetch
        self.start_step = start_step
        self.data_axes = data_axes

    def __iter__(self):
        import collections

        q: collections.deque = collections.deque()
        step = self.start_step
        while True:
            while len(q) <= self.prefetch:
                b = self.stream.batch(step)
                if self.mesh is not None:
                    b = shard_batch(b, self.mesh, self.data_axes)
                q.append(b)
                step += 1
            yield q.popleft()
