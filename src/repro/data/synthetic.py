"""Synthetic bibliographic datasets mirroring the paper's HEPTH / DBLP.

The paper evaluates on two author-reference corpora:

* **HEPTH** — 58,515 author references, 29,555 papers, 13,092 authors;
  names are often *abbreviated* ("J. Doe"), causing name clashes and
  fewer, larger canopies (13K neighborhoods / 1.3M candidate pairs).
* **DBLP** — 50,195 references, 19,408 papers, 21,278 authors; full
  names with *manually injected mutations*; smaller neighborhoods
  (30K neighborhoods / 0.5M pairs).

Neither corpus ships with this repo, so we generate the same *shape* of
data with controlled ground truth:

1. sample unique authors (first/last names from phoneme pools, with a
   tunable rate of colliding surnames + first initials — the
   disambiguation stress the collective matcher exists for);
2. sample a community-structured coauthorship graph (authors write
   papers with their community — recurring coauthor patterns are what
   rule R2/R4 exploits);
3. emit one *reference* per (paper, author) with a style-dependent
   surface form: HEPTH-style abbreviates the first name, DBLP-style
   keeps full names and injects typo mutations.

The generator is deterministic per seed; ``scale`` ~ references count.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import EntityTable, Relations

_FIRST = [
    "james", "john", "robert", "michael", "william", "david", "mary",
    "maria", "anna", "wei", "lei", "jun", "yan", "hiro", "kenji", "sara",
    "laura", "marco", "andrea", "pavel", "ivan", "olga", "rahul", "amit",
    "priya", "chen", "ming", "tao", "yuki", "akira", "hans", "peter",
    "klaus", "pierre", "jean", "luc", "carlos", "jose", "ana", "sofia",
]
_COMMON_LAST = [
    "smith", "johnson", "lee", "wang", "chen", "kumar", "singh", "patel",
    "mueller", "schmidt", "rossi", "ferrari", "ivanov", "petrov", "sato",
    "tanaka", "kim", "park", "nguyen", "tran", "garcia", "martinez",
]
_SYL_A = ["an", "ber", "cas", "dor", "el", "fal", "gor", "hab", "ir", "jas",
          "kol", "lam", "mor", "nev", "os", "pal", "qui", "ras", "sol", "tem",
          "ul", "var", "wes", "xan", "yor", "zel"]
_SYL_B = ["ak", "bel", "cot", "din", "er", "fas", "gul", "hom", "is", "jor",
          "ket", "lov", "mun", "nor", "ot", "pes", "quin", "rit", "sun", "tov",
          "ur", "vin", "wit", "xi", "yev", "zor"]
_SYL_C = ["a", "ez", "i", "man", "o", "ski", "sen", "son", "ton", "u", "ova"]


def _surname_pool(rng: np.random.Generator, size: int) -> tuple[list[str], np.ndarray]:
    """Zipf-weighted surname pool: a head of common names + a long tail
    of procedurally generated rare surnames (real bibliographic corpora
    have thousands of distinct surnames; the paper's HEPTH ambiguity
    comes from *abbreviation*, not from everyone being named Smith)."""
    pool = list(_COMMON_LAST)
    seen = set(pool)
    while len(pool) < size:
        s = (
            _SYL_A[int(rng.integers(0, len(_SYL_A)))]
            + _SYL_B[int(rng.integers(0, len(_SYL_B)))]
            + (_SYL_C[int(rng.integers(0, len(_SYL_C)))] if rng.random() < 0.6 else "")
        )
        if s not in seen:
            seen.add(s)
            pool.append(s)
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
    w = 1.0 / (ranks + 25.0)
    return pool, w / w.sum()


@dataclasses.dataclass(frozen=True)
class SynthConfig:
    n_authors: int = 400
    n_papers: int = 600
    style: str = "hepth"  # 'hepth' (abbreviated) | 'dblp' (full + typos)
    refs_per_paper: int = 3  # mean coauthors per paper
    n_communities: int = 0  # 0 = auto (n_authors / 12)
    surname_collision_rate: float = 0.35
    typo_rate: float = 0.15
    abbrev_rate: float = 0.75  # hepth only
    chain_motifs: int = 0  # engineered Fig-1 chains/rings (see below)
    seed: int = 0

    @staticmethod
    def hepth(scale: float = 1.0, seed: int = 0) -> "SynthConfig":
        return SynthConfig(
            n_authors=int(400 * scale),
            n_papers=int(600 * scale),
            style="hepth",
            surname_collision_rate=0.12,
            chain_motifs=max(2, int(8 * scale)),
            seed=seed,
        )

    @staticmethod
    def dblp(scale: float = 1.0, seed: int = 0) -> "SynthConfig":
        return SynthConfig(
            n_authors=int(500 * scale),
            n_papers=int(550 * scale),
            style="dblp",
            typo_rate=0.30,
            surname_collision_rate=0.15,
            chain_motifs=max(2, int(8 * scale)),
            seed=seed,
        )


def _typo(rng: np.random.Generator, s: str) -> str:
    if len(s) < 4:
        return s
    op = rng.integers(0, 3)
    i = int(rng.integers(1, len(s) - 1))
    if op == 0:  # drop
        return s[:i] + s[i + 1 :]
    if op == 1:  # swap adjacent
        return s[: i - 1] + s[i] + s[i - 1] + s[i + 1 :]
    c = chr(ord("a") + int(rng.integers(0, 26)))  # substitute
    return s[:i] + c + s[i + 1 :]


@dataclasses.dataclass
class SynthDataset:
    entities: EntityTable
    relations: Relations
    paper_of: np.ndarray  # (N,) paper id per reference
    author_names: list[str]  # canonical name per true author

    @property
    def n_refs(self) -> int:
        return len(self.entities)


def make_dataset(cfg: SynthConfig) -> SynthDataset:
    rng = np.random.default_rng(cfg.seed)
    n_comm = cfg.n_communities or max(8, cfg.n_authors // 12)

    # --- unique authors, with engineered surname/initial collisions -----
    # Canonical names are kept *unique* (middle initials break exact
    # clashes): the ambiguity we want is partial — shared surname and
    # first initial ("wei chen" vs "wang chen") so abbreviated references
    # collide but full references do not.  That is the disambiguation the
    # collective matcher resolves through coauthors.
    last_pool, last_w = _surname_pool(rng, max(150, int(cfg.n_authors * 1.5)))
    canon: list[str] = []
    seen_names: set[str] = set()
    for a in range(cfg.n_authors):
        for _attempt in range(20):
            if a > 0 and rng.random() < cfg.surname_collision_rate:
                # engineered partial collision: share an existing author's
                # surname; sometimes also the first initial (the paper's
                # "J. Doe vs John Doe" abbreviation ambiguity)
                prev = canon[int(rng.integers(0, len(canon)))]
                last = prev.split()[-1]
                prevfirst = prev.split()[0]
                # same surname; same first *initial* only rarely — an
                # identical abbreviated form for two authors is
                # irreducibly ambiguous (even the paper's matcher FPs
                # there), so keep its base rate low like real HEPTH
                pool = [f for f in _FIRST if f[0] == prevfirst[0] and f != prevfirst]
                first = (
                    pool[int(rng.integers(0, len(pool)))]
                    if pool and rng.random() < 0.12
                    else _FIRST[int(rng.integers(0, len(_FIRST)))]
                )
            else:
                first = _FIRST[int(rng.integers(0, len(_FIRST)))]
                last = last_pool[int(rng.choice(len(last_pool), p=last_w))]
            name = f"{first} {last}"
            if name not in seen_names:
                break
            # exact clash: disambiguate with a middle initial
            mid = chr(ord("a") + int(rng.integers(0, 26)))
            name = f"{first} {mid}. {last}"
            if name not in seen_names:
                break
        seen_names.add(name)
        canon.append(name)

    community = rng.integers(0, n_comm, size=cfg.n_authors)

    # --- papers: pick coauthor sets inside a community ------------------
    names: list[str] = []
    truth: list[int] = []
    paper_of: list[int] = []
    coauthor_edges: list[tuple[int, int]] = []
    by_comm: dict[int, np.ndarray] = {
        c: np.where(community == c)[0] for c in range(n_comm)
    }

    for p in range(cfg.n_papers):
        c = int(rng.integers(0, n_comm))
        pool = by_comm[c]
        if len(pool) == 0:
            continue
        n_auth = int(np.clip(rng.poisson(cfg.refs_per_paper - 1) + 1, 1, 6))
        n_auth = min(n_auth, len(pool))
        authors = rng.choice(pool, size=n_auth, replace=False)
        ref_ids = []
        for a in authors:
            parts = canon[int(a)].split()
            first, last = parts[0], parts[-1]
            if cfg.style == "hepth" and rng.random() < cfg.abbrev_rate:
                surface = f"{first[0]}. {last}"
            else:
                surface = canon[int(a)]
            if rng.random() < cfg.typo_rate:
                surface = _typo(rng, surface)
            ref = len(names)
            names.append(surface)
            truth.append(int(a))
            paper_of.append(p)
            ref_ids.append(ref)
        for i in range(len(ref_ids)):
            for j in range(i + 1, len(ref_ids)):
                coauthor_edges.append((ref_ids[i], ref_ids[j]))

    # --- collective-chain motifs (the paper's Fig. 1 at scale) ----------
    # Open chains: a level-3 seed pair + level-1 links hanging off it;
    # neighborhoods split by surname, so deciding link j needs link j+1's
    # match as a *message* (NO-MP < SMP).  Rings: every pair is level-1
    # and only the joint activation is positive (SMP < MMP: maximal
    # messages complete the cycle) — the {(a1,a2),(b2,b3),(c2,c3)} story.
    _LONG_FIRST = ("alessandro", "konstantin", "maximilian", "sebastiano",
                   "evangelina", "bartholomew")

    def _fresh_author(tag: int) -> int:
        # long first names put the full-vs-abbreviated JW in level 1
        # (weak candidate), which is what makes the chain collective;
        # random surnames keep the chain links in *different* canopies
        # (shared-surname n-grams would merge the chain locally)
        a = len(canon)
        surname = "".join(
            chr(ord("a") + int(rng.integers(0, 26))) for _ in range(8)
        )
        canon.append(f"{_LONG_FIRST[tag % len(_LONG_FIRST)]} {surname}")
        return a

    def _pair_refs(a: int, p_id: int, abbrev: bool) -> tuple[int, int]:
        parts = canon[a].split()
        full = canon[a]
        weak = f"{parts[0][0]}. {parts[-1]}" if abbrev else full
        r1, r2 = len(names), len(names) + 1
        names.extend([full, weak])
        truth.extend([a, a])
        paper_of.extend([p_id, p_id])
        return r1, r2

    tag = 0
    for m in range(cfg.chain_motifs):
        ring = m % 2 == 1
        length = 4 + int(rng.integers(0, 2))
        authors = [_fresh_author(tag + i) for i in range(length)]
        tag += length
        refs = [
            _pair_refs(a, cfg.n_papers + m, abbrev=(ring or i > 0))
            for i, a in enumerate(authors)
        ]
        hops = range(length) if ring else range(length - 1)
        for i in hops:
            j = (i + 1) % length
            # two shared papers: ref1s co-occur and ref2s co-occur, so
            # the MLN coupling link(pair_i, pair_j) fires
            coauthor_edges.append((refs[i][0], refs[j][0]))
            coauthor_edges.append((refs[i][1], refs[j][1]))

    edges = (
        np.asarray(coauthor_edges, dtype=np.int64)
        if coauthor_edges
        else np.zeros((0, 2), dtype=np.int64)
    )
    return SynthDataset(
        entities=EntityTable(names=names, truth=np.asarray(truth, dtype=np.int64)),
        relations=Relations(edges={"coauthor": edges}),
        paper_of=np.asarray(paper_of, dtype=np.int64),
        author_names=canon,
    )


# ---------------------------------------------------------------------------
# Evidence-lattice instance (deep multi-round message passing)
# ---------------------------------------------------------------------------

# Lattice rule weights: a candidate pair has u = -5; each matched
# predecessor contributes w_co = 3, so a pair fires only when BOTH of
# its predecessors are matched (-5 + 6 = +1 > 0 > -5 + 3), and no local
# or global group is jointly promotable (3u + 2w = -9 < 0; any suffix
# group's delta inherits the negative single-predecessor entailment).
# Seeds get n_shared = 2 anchor coauthors: u = -5 + 2*3 = +1.
def make_lattice_cover(depth: int, width: int, k: int = 8):
    """Hand-packed evidence lattice: resolution takes ``depth`` rounds.

    ``width`` chains (an even number, grouped into partner pairs).
    Pair ``(c, i)`` becomes matchable only once *both* its predecessors
    ``(c, i-1)`` and ``(partner(c), i-1)`` are matched — evidence must
    flow one neighborhood hop per round, which makes this the paper's
    §2.1 message-passing chain scaled to a benchmarkable instance.
    Because single-predecessor entailment is negative, neighborhoods
    emit no multi-pair maximal messages, so MMP needs the same rounds
    as SMP (no step-7 shortcut) — the multi-round configuration the
    round-parallel engine is benchmarked on.

    Chain-pair lengths are *staggered* between ``depth // 2`` and
    ``depth``: the active frontier shrinks as shorter chains finish,
    so the per-round active-set size varies — the shape-instability a
    per-round gather/dispatch engine pays recompiles for, and the
    statistical-skew effect §6.3 reports on the real corpora.

    Returns ``(packed, relations, weights)`` ready for the drivers; the
    global grounding for MMP comes from ``build_global_grounding(
    packed.pair_levels, relations, weights)``.
    """
    from repro.core import pairs as pairlib
    from repro.core.cover import Cover, PackedCover
    from repro.core.mln import MLNWeights
    from repro.core.types import NeighborhoodBatch

    assert width >= 2 and width % 2 == 0 and depth >= 1
    weights = MLNWeights(w_sim=(0.0, -5.0, -5.0, -5.0), w_co=3.0)
    n_pairs_of_chains = width // 2
    depths = [
        int(round(depth // 2 + (depth - depth // 2) * (j + 1) / n_pairs_of_chains))
        for j in range(n_pairs_of_chains)
    ]

    def chain_depth(c: int) -> int:
        return depths[c // 2]

    def a_id(c: int, i: int) -> int:
        return 2 * (c * depth + i)

    def b_id(c: int, i: int) -> int:
        return a_id(c, i) + 1

    n_chain_ents = 2 * width * depth

    def anchor(c: int, j: int) -> int:
        return n_chain_ents + 2 * c + j

    edges: list[tuple[int, int]] = []
    pair_levels: dict[int, int] = {}
    for c in range(width):
        p = c ^ 1  # partner chain
        edges += [
            (anchor(c, 0), a_id(c, 0)), (anchor(c, 0), b_id(c, 0)),
            (anchor(c, 1), a_id(c, 0)), (anchor(c, 1), b_id(c, 0)),
        ]
        for i in range(chain_depth(c)):
            pair_levels[int(pairlib.make_gid(a_id(c, i), b_id(c, i)))] = 1
            if i:
                edges += [
                    (a_id(c, i), a_id(c, i - 1)), (b_id(c, i), b_id(c, i - 1)),
                    (a_id(c, i), a_id(p, i - 1)), (b_id(c, i), b_id(p, i - 1)),
                ]
    edge_arr = np.asarray(edges, dtype=np.int64)
    relations = Relations(edges={"coauthor": edge_arr})
    adj: dict[int, set[int]] = {}
    for x, y in edges:
        adj.setdefault(x, set()).add(y)
        adj.setdefault(y, set()).add(x)

    P = pairlib.num_pairs(k)
    ii, jj = pairlib.triu_indices(k)
    members_of: list[np.ndarray] = []
    rows = []
    for i in range(depth):
        for c in range(width):
            if i >= chain_depth(c):
                continue
            p = c ^ 1
            mem = [a_id(c, i), b_id(c, i)]
            if i:
                mem += [a_id(c, i - 1), b_id(c, i - 1),
                        a_id(p, i - 1), b_id(p, i - 1)]
            else:
                mem += [anchor(c, 0), anchor(c, 1)]
            mem = sorted(mem)
            members_of.append(np.asarray(mem, dtype=np.int64))
            ids = np.full(k, -1, dtype=np.int64)
            ids[: len(mem)] = mem
            emask = ids >= 0
            co = np.zeros((k, k), dtype=bool)
            for s in range(len(mem)):
                for t in range(s + 1, len(mem)):
                    if mem[t] in adj.get(mem[s], ()):
                        co[s, t] = co[t, s] = True
            lev = np.zeros(P, dtype=np.int8)
            gid = np.full(P, -1, dtype=np.int64)
            pmask = np.zeros(P, dtype=bool)
            for s in range(P):
                x, y = int(ii[s]), int(jj[s])
                if not (emask[x] and emask[y]):
                    continue
                g = int(pairlib.make_gid(int(ids[x]), int(ids[y])))
                if g in pair_levels:
                    lev[s] = 1
                    gid[s] = g
                    pmask[s] = True
            rows.append(dict(ids=ids, emask=emask, co=co, lev=lev, gid=gid,
                             pmask=pmask))

    nb = NeighborhoodBatch(
        entity_ids=np.stack([r["ids"] for r in rows]),
        entity_mask=np.stack([r["emask"] for r in rows]),
        coauthor=np.stack([r["co"] for r in rows]),
        sim_level=np.stack([r["lev"] for r in rows]),
        pair_gid=np.stack([r["gid"] for r in rows]),
        pair_mask=np.stack([r["pmask"] for r in rows]),
    )
    n_nb = len(rows)
    packed = PackedCover(
        bins={k: nb},
        bin_rows={k: np.arange(n_nb, dtype=np.int64)},
        neighborhood_bin=np.full(n_nb, k, dtype=np.int64),
        neighborhood_row=np.arange(n_nb, dtype=np.int64),
        pair_levels=pair_levels,
        cover=Cover(core=members_of, full=members_of),
    )
    return packed, relations, weights


# ---------------------------------------------------------------------------
# Synthetic arrival streams (for repro.stream)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ArrivalBatch:
    """One micro-batch of arriving references, in *global* entity ids.

    ``edges`` may reference earlier arrivals (boundary-crossing relation
    tuples are exactly what delta cover maintenance has to handle); in
    the paper-shaped generator every coauthor edge is intra-paper, so
    cutting at paper boundaries keeps each edge inside one batch.
    """

    ids: np.ndarray  # (B,) int64 global reference ids
    names: list[str]
    truth: np.ndarray  # (B,) int64 ground-truth author ids
    edges: np.ndarray  # (E, 2) int64 coauthor edges, global ids

    def __len__(self) -> int:
        return len(self.names)


def truncate(ds: SynthDataset, n_refs: int) -> SynthDataset:
    """Prefix of a dataset: the first ``n_refs`` references plus every
    relation edge among them — the "corpus as of arrival t" instance a
    from-scratch re-run would resolve (used by the streaming tests and
    benchmarks as the baseline at each arrival point)."""
    out_edges = {}
    for name, e in ds.relations.edges.items():
        keep = (e[:, 0] < n_refs) & (e[:, 1] < n_refs)
        out_edges[name] = e[keep]
    return SynthDataset(
        entities=EntityTable(
            names=ds.entities.names[:n_refs],
            truth=None if ds.entities.truth is None else ds.entities.truth[:n_refs],
        ),
        relations=Relations(edges=out_edges),
        paper_of=ds.paper_of[:n_refs],
        author_names=ds.author_names,
    )


def arrival_stream(
    ds: SynthDataset,
    n_batches: int | None = None,
    *,
    batch_size: int | None = None,
) -> list[ArrivalBatch]:
    """Split a dataset into paper-aligned micro-batches (id order).

    References arrive paper by paper (ids are emitted in paper order by
    the generator), mimicking a live bibliographic feed; each coauthor
    edge is assigned to the batch of its latest endpoint.

    Pass either ``n_batches`` or ``batch_size`` (target references per
    micro-batch) — the latter is the natural knob for long streams,
    where the batch count grows with the corpus (the streaming
    benchmark drives thousands of micro-batches this way).
    """
    n = ds.n_refs
    if batch_size is not None:
        if n_batches is not None:
            raise ValueError("pass n_batches or batch_size, not both")
        n_batches = max(1, round(n / max(1, batch_size)))
    elif n_batches is None:
        raise ValueError("pass n_batches or batch_size")
    n_batches = max(1, min(n_batches, n))
    # candidate cut points: paper boundaries (id i starts a new paper)
    bounds = [
        i for i in range(1, n) if ds.paper_of[i] != ds.paper_of[i - 1]
    ]
    cuts = []
    for j in range(1, n_batches):
        target = round(j * n / n_batches)
        if not bounds:
            break
        best = min(bounds, key=lambda b: abs(b - target))
        if best not in cuts:
            cuts.append(best)
    cuts = sorted(cuts)
    starts = [0] + cuts
    stops = cuts + [n]

    edges = ds.relations.edges.get("coauthor")
    if edges is None:
        edges = np.zeros((0, 2), dtype=np.int64)
    latest = np.maximum(edges[:, 0], edges[:, 1]) if len(edges) else np.zeros(0)

    out = []
    for lo, hi in zip(starts, stops):
        if lo >= hi:
            continue
        sel = (latest >= lo) & (latest < hi) if len(edges) else np.zeros(0, bool)
        out.append(
            ArrivalBatch(
                ids=np.arange(lo, hi, dtype=np.int64),
                names=ds.entities.names[lo:hi],
                truth=ds.entities.truth[lo:hi],
                edges=edges[sel] if len(edges) else edges,
            )
        )
    return out


# ---------------------------------------------------------------------------
# Bipartite 1:1 record-linkage corpus (the assignment-matcher scenario)
# ---------------------------------------------------------------------------


def make_bipartite(
    n_groups: int = 60,
    *,
    double_rate: float = 0.4,
    trap_rate: float = 0.2,
    seed: int = 0,
) -> SynthDataset:
    """Two duplicate-free record sources with known 1:1 ground truth.

    Interleaves a *left* and a *right* source so each true pair is the
    global-id pair ``(2m, 2m + 1)`` — the parity convention the
    assignment and embedding matchers key their sides/buckets on.
    Matching groups are canopy-disjoint (each group shares a distinct
    random name token) and ``paper_of`` is the group id, so
    :func:`arrival_stream`'s paper-boundary cuts yield **group-atomic**
    micro-batches — the streaming deployment contract for 1:1 families
    (a matching group never straddles an ingest).

    Three group shapes:

    * **singleton** — one true pair, identical names (level 3).
    * **double** — two true pairs (level 3) whose cross pairs sit at
      level 2: every family resolves it, the optimum just has to prefer
      the two exact matches over the two near-misses.
    * **trap** — a double plus 6 *anchor* records coauthored with both
      ``L1`` and ``R2``, pushing the crossing pair's shared-coauthor
      count to 6.  Greedy assignment takes the boosted cross edge
      (``2 + 0.25*6 = 3.5 > 3``) and mis-pairs the group; the Hungarian
      optimum keeps the exact matches (``3 + 3 > 3.5 + 2.25``); the
      MLN, with no 1:1 constraint, matches the cross pair *as well*
      (``u = w_sim[2] + 6 w_co > 0``) — the quality separation
      ``benchmarks/fig4_matchers.py`` reports.

    Anchor names are random (level-0 pairs: never candidates) and each
    group contributes an even record count, preserving the parity phase.
    """
    rng = np.random.default_rng(seed)
    consonants = "bcdfghjklmnpqrstvwxz"
    vowels = "aeiou"

    seen: set[str] = set()

    def _word(length: int) -> str:
        while True:
            s = "".join(
                (consonants if i % 2 == 0 else vowels)[
                    int(rng.integers(0, len(consonants if i % 2 == 0 else vowels)))
                ]
                for i in range(length)
            )
            if s not in seen:
                seen.add(s)
                return s

    names: list[str] = []
    truth: list[int] = []
    paper_of: list[int] = []
    coauthor_edges: list[tuple[int, int]] = []
    canon: list[str] = []

    def _add(name: str, author: int, group: int) -> int:
        ref = len(names)
        names.append(name)
        truth.append(author)
        paper_of.append(group)
        return ref

    def _new_author(name: str) -> int:
        canon.append(name)
        return len(canon) - 1

    for g in range(n_groups):
        token = _word(8)
        surname = _word(9)
        r = rng.random()
        kind = "trap" if r < trap_rate else (
            "double" if r < trap_rate + double_rate else "singleton"
        )
        name1 = f"{token} {surname}"
        a1 = _new_author(name1)
        l1 = _add(name1, a1, g)
        r1 = _add(name1, a1, g)
        assert l1 % 2 == 0 and r1 == l1 + 1
        if kind == "singleton":
            continue
        # second pair: same token, surname two *adjacent* substitutions
        # away (chars absent from the original name, so Jaro counts two
        # clean mismatches).  At this name length the cross pairs land
        # at JW ~0.956 -> similarity level 2, while the trigram profile
        # keeps cosine >= the canopy t_loose, so the whole group stays
        # one canopy.
        fresh = [c for c in "abcdefghijklmnopqrstuvwxyz" if c not in name1]
        alt = list(surname)
        alt[3], alt[4] = fresh[0], fresh[1]
        name2 = f"{token} {''.join(alt)}"
        a2 = _new_author(name2)
        l2 = _add(name2, a2, g)
        r2 = _add(name2, a2, g)
        if kind == "trap":
            for _ in range(6):
                anchor = _add(f"zq{_word(7)}", _new_author(f"zq{_word(7)}"), g)
                coauthor_edges.append((l1, anchor))
                coauthor_edges.append((anchor, r2))

    edges = (
        np.asarray(coauthor_edges, dtype=np.int64)
        if coauthor_edges
        else np.zeros((0, 2), dtype=np.int64)
    )
    return SynthDataset(
        entities=EntityTable(names=names, truth=np.asarray(truth, dtype=np.int64)),
        relations=Relations(edges={"coauthor": edges}),
        paper_of=np.asarray(paper_of, dtype=np.int64),
        author_names=canon,
    )
