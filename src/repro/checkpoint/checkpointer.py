"""Fault-tolerant checkpointing: atomic manifests, keep-K GC, async save,
elastic restore.

Layout per step::

    <dir>/step_000042.tmp/        # written first
        arrays.npz                # flattened pytree leaves
        manifest.json             # step, keys, shapes, dtypes, meta
    <dir>/step_000042/            # atomic rename when complete

Restart-safety comes from the write-tmp-then-rename protocol: a
half-written checkpoint never shadows a complete one, and
``latest_step`` only considers renamed directories.  Restore is
*elastic*: arrays are saved device-agnostic and re-placed with whatever
shardings the (possibly re-sized) mesh dictates — a node-count change
between runs only changes the placement step.

(Production note: at real scale each host writes only its local shards;
this single-process implementation gathers, which is exact at test
scale and keeps the manifest/atomicity/GC logic identical.)
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro import faults


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        expect = tuple(leaf.shape) if hasattr(leaf, "shape") else ()
        if tuple(arr.shape) != expect:
            raise ValueError(f"checkpoint shape mismatch at {key}: "
                             f"{arr.shape} vs {expect}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: dict, meta: dict | None = None) -> None:
        flat = {}
        for name, tree in state.items():
            for k, v in _flatten(tree).items():
                flat[f"{name}|{k}"] = v
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta or {})
            )
            self._thread.start()
        else:
            self._write(step, flat, meta or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, meta: dict) -> None:
        name = f"step_{step:09d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(flat.keys()),
            "meta": meta,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        # a crash here leaves a complete .tmp that never shadows the
        # previous checkpoint: latest_step only sees renamed dirs
        faults.maybe_fail("ckpt.rename")
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_raw(self, step: int) -> tuple[dict[str, np.ndarray], dict]:
        """Flat ``"name|key" -> array`` map of one checkpoint plus its
        manifest ``meta``, with no template shape validation — for
        callers whose state is a variable-length blob (e.g. the resolve
        service's pickled logical state, whose byte length changes every
        checkpoint)."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f).get("meta", {})
        return flat, meta

    def restore(self, step: int, templates: dict, mesh=None, shardings=None) -> dict:
        """Restore state trees; optionally re-place onto a (new) mesh.

        ``templates`` maps name -> pytree of arrays/ShapeDtypeStructs
        (shapes to validate against). ``shardings`` (optional) maps
        name -> pytree of NamedSharding for elastic re-placement.
        """
        path = os.path.join(self.dir, f"step_{step:09d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat_all = {k: z[k] for k in z.files}
        out = {}
        for name, template in templates.items():
            flat = {
                k.split("|", 1)[1]: v
                for k, v in flat_all.items()
                if k.startswith(name + "|")
            }
            tree = _unflatten_into(template, flat)
            if shardings is not None and name in shardings:
                tree = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), tree, shardings[name]
                )
            out[name] = tree
        return out
