"""Jamba-style hybrid: Mamba/attention 7:1 interleave + MoE every 2nd FFN.

The stack is heterogeneous, so a plain layer-scan does not apply.
Instead we scan over *periods*: Jamba's layer pattern has period 8
(attention at offset 4, the rest Mamba; MoE FFN on odd layers), so a
32-layer model is a ``lax.scan`` over 4 stacked periods, each period an
unrolled sequence of 8 sublayers.  Compile time stays O(period), memory
O(1) in depth.

Decode carries a heterogeneous cache: per period, 7 SSM states + 1 KV
cache.  For ``long_500k``, only the attention layers hold a 500k cache
(4 of 32 layers) — sequence-sharded over the ``data`` axis (batch=1
frees it), the hybrid's structural advantage the assignment calls out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moelib
from repro.models import ssm
from repro.models.layers import (
    attention_cache_specs,
    attention_decode,
    attention_specs,
    attention_train,
    embed_lookup,
    embed_spec,
    mlp,
    mlp_specs,
    rmsnorm,
    rmsnorm_spec,
    shard_batch,
    softmax_xent,
    unembed,
)
from repro.models.param import stack


def _is_attn(cfg: ModelConfig, i: int) -> bool:
    return i % cfg.attn_layer_period == cfg.attn_layer_offset


def _is_moe(cfg: ModelConfig, i: int) -> bool:
    return cfg.n_experts > 0 and i % cfg.expert_layer_period == cfg.expert_layer_offset


def _n_periods(cfg: ModelConfig) -> int:
    per = cfg.period or cfg.attn_layer_period
    assert cfg.n_layers % per == 0
    return cfg.n_layers // per


def period_specs(cfg: ModelConfig) -> dict:
    """Specs for one period (unrolled heterogeneous sublayers)."""
    per = cfg.period or cfg.attn_layer_period
    layers = {}
    for i in range(per):
        layer = {"ln1": rmsnorm_spec(cfg.d_model), "ln2": rmsnorm_spec(cfg.d_model)}
        layer["mixer"] = attention_specs(cfg) if _is_attn(cfg, i) else ssm.ssm_specs(cfg)
        layer["ffn"] = moelib.moe_specs(cfg) if _is_moe(cfg, i) else mlp_specs(cfg)
        layers[f"l{i}"] = layer
    return layers


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "periods": stack(_n_periods(cfg), period_specs(cfg)),
        "ln_f": rmsnorm_spec(cfg.d_model),
        "lm_head": embed_spec(cfg.vocab_size, cfg.d_model),
    }


def _period_train(cfg: ModelConfig, p, x, positions):
    per = cfg.period or cfg.attn_layer_period
    aux_total = jnp.float32(0.0)
    x = shard_batch(x)

    def sublayer(i, lp, x):
        # each heterogeneous sublayer remats independently: the period
        # backward then holds one sublayer's interior at a time instead
        # of all eight (a Jamba period at 32k tokens is ~30 GB otherwise)
        x = shard_batch(x)
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        if _is_attn(cfg, i):
            x = x + attention_train(cfg, lp["mixer"], h, positions)
        else:
            x = x + ssm.ssm_forward(cfg, lp["mixer"], h)
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if _is_moe(cfg, i):
            f, aux = moelib.moe_ffn(cfg, lp["ffn"], h)
        else:
            f, aux = mlp(cfg, lp["ffn"], h), jnp.float32(0.0)
        return x + f, aux

    for i in range(per):
        body = jax.checkpoint(
            functools.partial(sublayer, i),
            policy=jax.checkpoint_policies.nothing_saveable,
        )
        x, aux = body(p[f"l{i}"], x)
        aux_total = aux_total + aux
    return x, aux_total


def forward_train(cfg: ModelConfig, params, tokens):
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    from repro.models.scan_utils import stacked_scan

    x = shard_batch(embed_lookup(params["embed"], tokens))
    body = functools.partial(_period_train, cfg)
    # one period (8 heterogeneous sublayers) is already remat-group-sized
    x, aux = stacked_scan(body, x, params["periods"], 0, positions)
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), aux


def loss_fn(cfg: ModelConfig, params, batch):
    hidden, aux = forward_train(cfg, params, batch["tokens"])
    logits = shard_batch(unembed(params["lm_head"], hidden), model_dim=-1)
    loss = softmax_xent(logits, batch["labels"])
    return loss + cfg.router_aux_weight * aux, {"xent": loss, "aux": aux}


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    per = cfg.period or cfg.attn_layer_period
    entry = {}
    for i in range(per):
        if _is_attn(cfg, i):
            entry[f"l{i}"] = attention_cache_specs(cfg, batch, s_max)
        else:
            entry[f"l{i}"] = ssm.ssm_cache_specs(cfg, batch)
    return {"periods": stack(_n_periods(cfg), entry)}


def decode_step(cfg: ModelConfig, params, cache, batch):
    tokens, pos = batch["tokens"], batch["pos"]
    per = cfg.period or cfg.attn_layer_period
    x = embed_lookup(params["embed"], tokens)

    def scan_body(x, args):
        pp, pc = args
        new_cache = {}
        for i in range(per):
            lp, lc = pp[f"l{i}"], pc[f"l{i}"]
            h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            if _is_attn(cfg, i):
                out, nc = attention_decode(cfg, lp["mixer"], h, lc, pos)
            else:
                out, nc = ssm.ssm_decode(cfg, lp["mixer"], h, lc)
            x = x + out
            new_cache[f"l{i}"] = nc
            h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            if _is_moe(cfg, i):
                f, _ = moelib.moe_ffn(cfg, lp["ffn"], h)
            else:
                f = mlp(cfg, lp["ffn"], h)
            x = x + f
        return x, new_cache

    x, new_caches = jax.lax.scan(scan_body, x, (params["periods"], cache["periods"]))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return unembed(params["lm_head"], x), {"periods": new_caches}
