"""Two-level layer-scan with grouped activation checkpointing.

``stacked_scan(body, x, stacked_params, group)`` runs ``body(params_i,
x)`` for each of the L stacked layers:

* ``group <= 1``: one ``lax.scan`` with ``jax.checkpoint`` per layer —
  the scan saves every layer input (L × (B,S,D) residuals live for the
  backward pass).
* ``group g > 1``: params are reshaped to (L/g, g, ...) and an *outer*
  scan over groups wraps a checkpointed *inner* scan over the g layers.
  Only L/g group-boundary activations are saved; each group's interior
  is recomputed during backward.  Memory: L/g + g transient instead of
  L — minimized at g ≈ √L (the classic O(√L) checkpointing schedule).

``aux`` outputs (e.g. MoE load-balance losses) are summed across layers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _leading(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


@functools.cache
def _differentiable_barrier():
    """Probed once per process: older jax lacks a differentiation rule
    for optimization_barrier; there the barrier is dropped (correctness
    is unaffected — it only pins the remat memory layout)."""
    try:
        jax.grad(lambda t: jnp.sum(jax.lax.optimization_barrier(t)))(jnp.ones(()))
    except NotImplementedError:  # pragma: no cover - version dependent
        return lambda t: t
    return jax.lax.optimization_barrier


def stacked_scan(body, x, stacked_params, group: int = 0, *args):
    """body(layer_params, x, *args) -> (x, aux). Returns (x, aux_sum).

    The residual entering each checkpointed region passes through an
    ``optimization_barrier``: without it XLA folds the backward's first
    f32 upcast *into the saved activation stack*, storing the boundary
    residuals twice (bf16 + f32) — 3x the intended remat footprint at
    32k tokens (observed on qwen2-72b prefill: 35 GiB vs 12 GiB).
    """
    L = _leading(stacked_params)
    g = group if group and group > 1 else 1

    _barrier = _differentiable_barrier()

    def barriered(lp, xx, *a):
        xx = _barrier(xx)
        return body(lp, xx, *a)

    inner_body = jax.checkpoint(
        barriered, policy=jax.checkpoint_policies.nothing_saveable,
        prevent_cse=False,  # scan already prevents CSE (jax docs)
    )

    if g == 1 or L % g != 0:

        def scan_body(carry, lp):
            x2, aux = inner_body(lp, carry, *args)
            return x2, aux

        x, auxs = jax.lax.scan(scan_body, x, stacked_params)
        return x, jnp.sum(auxs)

    regrouped = jax.tree.map(
        lambda a: a.reshape(L // g, g, *a.shape[1:]), stacked_params
    )

    def group_body(gp, x, *inner_args):
        def scan_body(carry, lp):
            x2, aux = inner_body(lp, carry, *inner_args)
            return x2, aux

        x, auxs = jax.lax.scan(scan_body, x, gp)
        return x, jnp.sum(auxs)

    group_body = jax.checkpoint(
        group_body, policy=jax.checkpoint_policies.nothing_saveable,
        prevent_cse=False,
    )

    def outer_body(carry, gp):
        x2, aux = group_body(gp, carry, *args)
        return x2, aux

    x, auxs = jax.lax.scan(outer_body, x, regrouped)
    return x, jnp.sum(auxs)
