"""Decoder-only transformer (dense / MoE / MLA) — train, prefill, decode.

The layer stack is a ``lax.scan`` over stacked parameters (compile time
O(1) in depth) with ``jax.checkpoint`` on the layer body (activation
remat; the scan stores only layer inputs).  One module serves the
dense (yi, qwen2, qwen1.5), MLA (minicpm3), MoE (moonshot, llama4) and
VLM (qwen2-vl, via extra embedding merge + M-RoPE positions) families.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import moe as moelib
from repro.models.layers import (
    attention_cache_specs,
    attention_decode,
    attention_specs,
    attention_train,
    embed_lookup,
    embed_spec,
    mla_cache_specs,
    mla_decode,
    mla_specs,
    mlp,
    mlp_specs,
    mp,
    rmsnorm,
    rmsnorm_spec,
    shard_batch,
    softmax_xent,
    unembed,
)
from repro.models.param import PSpec, stack


def layer_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    specs = {
        "ln1": rmsnorm_spec(d),
        "attn": mla_specs(cfg) if cfg.mla else attention_specs(cfg),
        "ln2": rmsnorm_spec(d),
    }
    if cfg.n_experts:
        specs["ffn"] = moelib.moe_specs(cfg)
    else:
        specs["ffn"] = mlp_specs(cfg)
    return specs


def param_specs(cfg: ModelConfig) -> dict:
    specs = {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "layers": stack(cfg.n_layers, layer_specs(cfg)),
        "ln_f": rmsnorm_spec(cfg.d_model),
    }
    if cfg.vision_dim:
        specs["vision_proj"] = PSpec((cfg.vision_dim, cfg.d_model), P(None, "model"))
    if not cfg.tie_embeddings:
        specs["lm_head"] = embed_spec(cfg.vocab_size, cfg.d_model)
    return specs


def _ffn(cfg: ModelConfig, p, x):
    if cfg.n_experts:
        return moelib.moe_ffn(cfg, p, x)
    return mlp(cfg, p, x), jnp.float32(0.0)


def _layer_train(cfg: ModelConfig, p, x, positions):
    x = shard_batch(x)
    if cfg.mla:
        from repro.models.layers import mla_train

        a = mla_train(cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), positions)
    else:
        a = attention_train(cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), positions)
    x = x + a
    f, aux = _ffn(cfg, p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + f, aux


def forward_train(cfg: ModelConfig, params, tokens, positions, extra=None):
    """Hidden states for a full sequence. Returns (hidden (B,S,D), aux)."""
    x = embed_lookup(params["embed"], tokens)
    if extra is not None and cfg.vision_dim:
        # merge projected vision-patch embeddings at the given positions
        vis = jnp.einsum("bpv,vd->bpd", mp(extra["vision_embeds"]),
                         mp(params["vision_proj"]))
        upd = jax.vmap(lambda xb, pb, vb: xb.at[pb].set(vb))(
            x, extra["vision_pos"], vis
        )
        x = upd

    from repro.models.scan_utils import stacked_scan

    x = shard_batch(x)
    body = functools.partial(_layer_train, cfg)
    x, aux = stacked_scan(body, x, params["layers"], cfg.remat_group, positions)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, aux


def logits_of(cfg: ModelConfig, params, hidden):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return shard_batch(unembed(table, hidden), model_dim=-1)


def make_positions(cfg: ModelConfig, tokens):
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.mrope:
        return jnp.broadcast_to(pos, (3, B, S))
    return pos


def loss_fn(cfg: ModelConfig, params, batch):
    tokens = batch["tokens"]
    positions = batch.get("positions")
    if positions is None:
        positions = make_positions(cfg, tokens)
    extra = (
        {k: batch[k] for k in ("vision_embeds", "vision_pos") if k in batch} or None
    )
    hidden, aux = forward_train(cfg, params, tokens, positions, extra)
    logits = logits_of(cfg, params, hidden)
    loss = softmax_xent(logits, batch["labels"])
    total = loss + cfg.router_aux_weight * aux
    return total, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serve_step) — KV cache over stacked layers
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    per_layer = (
        mla_cache_specs(cfg, batch, s_max)
        if cfg.mla
        else attention_cache_specs(cfg, batch, s_max)
    )
    return {"layers": stack(cfg.n_layers, per_layer)}


def _layer_decode(cfg: ModelConfig, p, cache, x, pos, positions):
    if cfg.mla:
        a, new_cache = mla_decode(
            cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cache, pos
        )
    else:
        a, new_cache = attention_decode(
            cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cache, pos
        )
    x = x + a
    f, _ = _ffn(cfg, p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + f, new_cache


def decode_step(cfg: ModelConfig, params, cache, batch):
    """One-token decode. batch: tokens (B,1), pos (B,). Returns
    (logits (B,1,V), new_cache)."""
    tokens, pos = batch["tokens"], batch["pos"]
    x = embed_lookup(params["embed"], tokens)
    if cfg.mrope:
        positions = jnp.broadcast_to(pos[None, :, None], (3, pos.shape[0], 1))
    else:
        positions = pos[:, None]

    def scan_body(x, layer):
        lp, lc = layer
        x = shard_batch(x)
        x, new_cache = _layer_decode(cfg, lp, lc, x, pos, positions)
        return x, new_cache

    x, new_caches = jax.lax.scan(scan_body, x, (params["layers"], cache["layers"]))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return logits_of(cfg, params, x), {"layers": new_caches}


def prefill(cfg: ModelConfig, params, tokens, s_max: int):
    """Run the prompt through the stack, returning (logits, cache).

    Full-sequence attention with per-layer K/V collected into the cache
    (MLA: compressed latents).  Used by the serving engine.
    """
    B, S = tokens.shape
    positions = make_positions(cfg, tokens)
    x = embed_lookup(params["embed"], tokens)

    def scan_body(x, lp):
        normed = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        if cfg.mla:
            from repro.models.layers import mla_train

            kr = cfg.kv_lora_rank
            kv = jnp.einsum("bsd,dr->bsr", normed, mp(lp["attn"]["kv_down"]))
            c_kv = rmsnorm(lp["attn"]["kv_norm"], kv[..., :kr], cfg.norm_eps)
            from repro.models.layers import rope as rope_fn

            k_rope = rope_fn(
                kv[..., kr:][:, :, None, :], positions, cfg.rope_theta
            )[:, :, 0, :]
            entry = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, s_max - S), (0, 0))).astype(
                    jnp.bfloat16
                ),
                "k_rope": jnp.pad(k_rope, ((0, 0), (0, s_max - S), (0, 0))).astype(
                    jnp.bfloat16
                ),
            }
            a = mla_train(cfg, lp["attn"], normed, positions)
        else:
            from repro.models.layers import _apply_rope, _qkv

            q, k, v = _qkv(cfg, lp["attn"], normed)
            if not cfg.mla:
                q2, k2 = _apply_rope(cfg, q, k, positions)
            entry = {
                "k": jnp.pad(
                    k2.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, s_max - S), (0, 0))
                ).astype(jnp.bfloat16),
                "v": jnp.pad(
                    v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, s_max - S), (0, 0))
                ).astype(jnp.bfloat16),
            }
            a = attention_train(cfg, lp["attn"], normed, positions)
        x = x + a
        f, _ = _ffn(cfg, lp["ffn"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x + f, entry

    x, caches = jax.lax.scan(scan_body, x, params["layers"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_of(cfg, params, x[:, -1:, :])
    return logits, {"layers": caches}
