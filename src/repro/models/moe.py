"""Mixture-of-Experts FFN: top-k token-choice routing, EP over `model`.

Dispatch is the grouped one-hot einsum formulation (T5X/GSPMD-proven):
tokens are split into groups of ``group_size``; each group builds a
``(g, E, C)`` dispatch tensor (bf16) and the expert contraction
``(g,E,C) x (g,D) -> (E,C,D)`` induces the EP all-to-all when experts
are sharded.  Capacity ``C = ceil(g·k/E · capacity_factor)``; overflow
tokens are dropped (their combine weight is 0), standard for
capacity-based MoE.

Sharding: expert weights ``(E, D, F)`` are ``P('model','data',None)`` —
experts over the tensor axis (EP), the D dim FSDP-sharded over data and
gathered just-in-time by GSPMD.

The router aux (load-balance) loss follows Shazeer et al.:
``E · Σ_e f_e · p_e`` with f the dispatch fraction and p the mean
router probability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import mp, shard_spec
from repro.models.param import PSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    specs = {
        "router": PSpec((d, e), P(None, None), scale=0.02),
        "w_in": PSpec((e, d, 2 * f), P("model", "data", None)),
        "w_out": PSpec((e, f, d), P("model", None, "data")),
    }
    if cfg.n_shared_experts:
        fs = cfg.shared_d_ff or f * cfg.n_shared_experts
        specs["shared_w_in"] = PSpec((d, 2 * fs), P("data", "model"))
        specs["shared_w_out"] = PSpec((fs, d), P("model", "data"))
    return specs


def _capacity(tokens_per_group: int, cfg: ModelConfig, factor: float = 1.25) -> int:
    k, e = cfg.experts_per_token, cfg.n_experts
    c = int(tokens_per_group * k * factor / e) + 1
    return max(c, k)


def moe_ffn(cfg: ModelConfig, p, x, *, group_size: int = 512):
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, f"tokens {T} not divisible by group {g}"
    xt = x.reshape(G, g, D)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G, g, E)
    gate, idx = jax.lax.top_k(probs, K)  # (G, g, K)
    if K > 1:
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    C = _capacity(g, cfg)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (G, g, K, E)
    # position of each (token, choice) within its expert queue
    prio = onehot.transpose(0, 2, 1, 3).reshape(G, K * g, E)  # choice-major
    pos = jnp.cumsum(prio, axis=1) - prio  # (G, K*g, E)
    pos = pos.reshape(G, K, g, E).transpose(0, 2, 1, 3)  # (G, g, K, E)
    within = jnp.sum(pos * onehot, axis=-1)  # (G, g, K)
    keep = within < C
    gate = gate * keep.astype(gate.dtype)

    slot = jax.nn.one_hot(within, C, dtype=jnp.float32)  # (G, g, K, C)
    # combine (G,g,E,C) = Σ_k gate_k · onehot_k ⊗ slot_k
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate, onehot, slot)
    # EP pins: token-group dims stay data-sharded, the expert dim lives
    # on `model`; GSPMD turns the dispatch/combine contractions into the
    # canonical all-to-alls instead of replicating the (G,g,E,C) tensors.
    combine = shard_spec(combine, ("dp", None, "model", None))
    dispatch = (combine > 0.0).astype(mp(x).dtype)

    expert_in = jnp.einsum("gtec,gtd->egcd", dispatch, mp(xt))  # (E,G,C,D)
    expert_in = shard_spec(expert_in, ("model", "dp", None, None))
    f = p["w_out"].shape[1]
    h = jnp.einsum("egcd,edf->egcf", expert_in, mp(p["w_in"]))
    gate_h, up_h = h[..., :f], h[..., f:]
    h = jax.nn.silu(gate_h.astype(jnp.float32)).astype(h.dtype) * up_h
    expert_out = jnp.einsum("egcf,efd->egcd", h, mp(p["w_out"]))
    expert_out = shard_spec(expert_out, ("model", "dp", None, None))
    out = jnp.einsum("gtec,egcd->gtd", combine.astype(jnp.float32),
                     expert_out.astype(jnp.float32))
    out = out.reshape(B, S, D).astype(x.dtype)

    # load-balance aux loss
    frac = jnp.mean(onehot.sum(axis=2), axis=1)  # (G, E) dispatch fraction
    pmean = jnp.mean(probs, axis=1)  # (G, E)
    aux = E * jnp.mean(jnp.sum(frac * pmean, axis=-1))

    if cfg.n_shared_experts:
        fs = p["shared_w_out"].shape[0]
        gu = jnp.einsum("bsd,df->bsf", x, mp(p["shared_w_in"]))
        sg, su = gu[..., :fs], gu[..., fs:]
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        out = out + jnp.einsum("bsf,fd->bsd", sh, mp(p["shared_w_out"]))

    return out, aux
