"""Parameter declaration machinery: shapes + shardings, dry-run friendly.

Models declare their parameters as a pytree of :class:`PSpec` (shape,
partition spec, init law).  From that single declaration we derive:

* ``init_params``      — materialized f32 arrays (CPU smoke tests),
* ``abstract_params``  — ``jax.ShapeDtypeStruct`` stand-ins (dry-run:
  no allocation, exact shapes/shardings for ``.lower()``),
* ``shardings``        — ``NamedSharding`` pytree for pjit in/out specs,
* ``param_count``      — exact parameter count for MODEL_FLOPS and the
  roofline's 6·N·D terms.

Partition specs use *logical* mesh axes ``("data", "model")``; the
launcher maps them onto the physical mesh (the ``pod`` axis never shards
parameters — it is pure data parallelism).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PSpec:
    """One parameter tensor: shape, sharding, initialization."""

    shape: tuple[int, ...]
    spec: P = P()
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed' | 'ssm_dt' | 'ssm_a'
    scale: float | None = None  # None -> 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def stack(n: int, tree):
    """Prepend a stacked-layer axis of size n to every PSpec in a tree."""

    def f(ps: PSpec) -> PSpec:
        return dataclasses.replace(
            ps, shape=(n, *ps.shape), spec=P(None, *ps.spec)
        )

    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, PSpec))


def _materialize(ps: PSpec, key) -> jax.Array:
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, ps.dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, ps.dtype)
    if ps.init == "ssm_a":
        # mamba A_log init: log(1..N) broadcast over channels
        n = ps.shape[-1]
        a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(a, ps.shape).astype(ps.dtype)
    if ps.init == "ssm_dt":
        # dt bias ~ softplus^-1 of uniform(1e-3, 1e-1)
        u = jax.random.uniform(key, ps.shape, minval=1e-3, maxval=1e-1)
        return jnp.log(jnp.expm1(u)).astype(ps.dtype)
    fan_in = ps.shape[-2] if len(ps.shape) >= 2 else max(ps.shape[-1], 1)
    if ps.init == "embed":
        fan_in = 1.0
    scale = ps.scale if ps.scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, ps.shape, jnp.float32) * scale).astype(ps.dtype)


def init_params(tree, seed: int = 0):
    """Materialize a PSpec tree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, PSpec)
    )
    root = jax.random.PRNGKey(seed)
    keys = jax.random.split(root, max(len(leaves), 1))
    out = [_materialize(ps, k) for ps, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(tree):
    """ShapeDtypeStruct stand-ins (no allocation) for .lower()."""
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, ps.dtype),
        tree,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def filter_spec(spec: P, mesh: Mesh) -> P:
    """Drop mesh axes the physical mesh does not have (e.g. 1-dev CPU)."""
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(keep(e) for e in spec))


def shardings(tree, mesh: Mesh):
    """NamedSharding pytree from the PSpec tree for a concrete mesh."""
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, filter_spec(ps.spec, mesh)),
        tree,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, PSpec))
    return sum(ps.size for ps in leaves)


def spec_tree_map(fn: Callable[[PSpec], Any], tree):
    return jax.tree.map(fn, tree, is_leaf=lambda x: isinstance(x, PSpec))
