"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

The conv feature extractor is a stub per the assignment:
``input_specs()`` supplies precomputed frame embeddings
``(B, encoder_frames, d_model)``.  Encoder: bidirectional self-attn +
GELU MLP, pre-LayerNorm (Whisper uses LayerNorm with bias, not
RMSNorm).  Decoder: causal self-attn + cross-attn over encoder memory +
GELU MLP.  Decode caches both the growing self-attn KV and the static
cross-attn KV (computed once at prefill).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import (
    ATTN_CHUNK_THRESHOLD,
    COMPUTE_DTYPE,
    shard_batch,
    attention_cache_specs,
    attention_specs,
    cross_attention_train,
    embed_lookup,
    embed_spec,
    layernorm,
    layernorm_spec,
    mlp,
    mlp_specs,
    mp,
    softmax_xent,
    unembed,
    _gqa_out,
    _gqa_scores,
    _qkv,
)
from repro.models.param import PSpec, stack

NEG_INF = -1e9


def enc_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": layernorm_spec(cfg.d_model),
        "attn": attention_specs(cfg),
        "ln2": layernorm_spec(cfg.d_model),
        "ffn": mlp_specs(cfg),
    }


def dec_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": layernorm_spec(cfg.d_model),
        "self_attn": attention_specs(cfg),
        "ln_x": layernorm_spec(cfg.d_model),
        "cross_attn": attention_specs(cfg),
        "ln2": layernorm_spec(cfg.d_model),
        "ffn": mlp_specs(cfg),
    }


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "enc_pos": PSpec((cfg.encoder_frames, cfg.d_model), P(None, "model"),
                         scale=0.02),
        "enc_layers": stack(cfg.encoder_layers, enc_layer_specs(cfg)),
        "enc_ln_f": layernorm_spec(cfg.d_model),
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "dec_pos": PSpec((cfg.max_position_embeddings, cfg.d_model),
                         P(None, "model"), scale=0.02),
        "dec_layers": stack(cfg.n_layers, dec_layer_specs(cfg)),
        "dec_ln_f": layernorm_spec(cfg.d_model),
    }


def _attn_full(cfg, p, x, *, causal):
    q, k, v = _qkv(cfg, p, x)
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
    if x.shape[1] > ATTN_CHUNK_THRESHOLD:
        from repro.models.layers import chunked_attention

        o = chunked_attention(q, k, v, scale, causal=causal, out_dtype=x.dtype)
        return jnp.einsum("bsh,hd->bsd", o, mp(p["wo"]))
    scores = _gqa_scores(q, k, scale)
    if causal:
        S = x.shape[1]
        scores = jnp.where(jnp.tril(jnp.ones((S, S), bool)), scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(probs, v, x.dtype)
    return jnp.einsum("bsh,hd->bsd", o, mp(p["wo"]))


def encode(cfg: ModelConfig, params, frames):
    """frames (B, F, D) bf16 stub embeddings -> encoder memory (B, F, D)."""
    x = mp(frames) + mp(params["enc_pos"])[None, : frames.shape[1]]

    from repro.models.scan_utils import stacked_scan

    def _layer(lp, x):
        x = shard_batch(x)
        x = x + _attn_full(cfg, lp["attn"], layernorm(lp["ln1"], x, cfg.norm_eps),
                           causal=False)
        x = x + mlp(cfg, lp["ffn"], layernorm(lp["ln2"], x, cfg.norm_eps))
        return x, jnp.float32(0.0)

    x, _ = stacked_scan(_layer, x, params["enc_layers"], cfg.remat_group)
    return layernorm(params["enc_ln_f"], x, cfg.norm_eps)


def _dec_layer_train(cfg, lp, x, memory):
    x = shard_batch(x)
    x = x + _attn_full(cfg, lp["self_attn"], layernorm(lp["ln1"], x, cfg.norm_eps),
                       causal=True)
    x = x + cross_attention_train(
        cfg, lp["cross_attn"], layernorm(lp["ln_x"], x, cfg.norm_eps), memory
    )
    x = x + mlp(cfg, lp["ffn"], layernorm(lp["ln2"], x, cfg.norm_eps))
    return x


def decode_train(cfg: ModelConfig, params, tokens, memory):
    from repro.models.scan_utils import stacked_scan

    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens) + mp(params["dec_pos"])[None, :S]

    def body(lp, x, memory):
        return _dec_layer_train(cfg, lp, x, memory), jnp.float32(0.0)

    x, _ = stacked_scan(body, x, params["dec_layers"], cfg.remat_group, memory)
    return layernorm(params["dec_ln_f"], x, cfg.norm_eps)


def loss_fn(cfg: ModelConfig, params, batch):
    memory = encode(cfg, params, batch["frames"])
    hidden = decode_train(cfg, params, batch["tokens"], memory)
    logits = shard_batch(unembed(params["embed"], hidden), model_dim=-1)
    loss = softmax_xent(logits, batch["labels"])
    return loss, {"xent": loss, "aux": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Decode with self-KV + static cross-KV caches
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    self_kv = attention_cache_specs(cfg, batch, s_max)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cross = {
        "k": PSpec((batch, hkv, cfg.encoder_frames, hd),
                   P("data", "model", None, None), init="zeros", dtype=COMPUTE_DTYPE),
        "v": PSpec((batch, hkv, cfg.encoder_frames, hd),
                   P("data", "model", None, None), init="zeros", dtype=COMPUTE_DTYPE),
    }
    return {"layers": stack(cfg.n_layers, {"self": self_kv, "cross": cross})}


def build_cross_cache(cfg: ModelConfig, params, memory):
    """Precompute per-layer cross-attention K/V from encoder memory."""
    B, F, _ = memory.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim

    def per_layer(_, lp):
        k = jnp.einsum("bfd,dh->bfh", memory, mp(lp["cross_attn"]["wk"]))
        v = jnp.einsum("bfd,dh->bfh", memory, mp(lp["cross_attn"]["wv"]))
        if cfg.qkv_bias:
            k = k + mp(lp["cross_attn"]["bk"])
            v = v + mp(lp["cross_attn"]["bv"])
        k = k.reshape(B, F, hkv, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, F, hkv, hd).transpose(0, 2, 1, 3)
        return None, {"k": k.astype(COMPUTE_DTYPE), "v": v.astype(COMPUTE_DTYPE)}

    _, cross = jax.lax.scan(per_layer, None, params["dec_layers"])
    return cross


def _cross_decode(cfg, p, x, cross):
    B = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, mp(p["wq"]))
    if cfg.qkv_bias:
        q = q + mp(p["bq"])
    q = q.reshape(B, 1, h, hd)
    g = h // hkv
    qg = q.reshape(B, 1, hkv, g, hd)
    from repro.models.layers import mixed_einsum

    scores = mixed_einsum(
        "bskgh,bkth->bkgst", qg.astype(cross["k"].dtype), cross["k"]
    ) / jnp.sqrt(hd).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1)
    o = mixed_einsum("bkgst,bkth->bskgh", probs.astype(cross["v"].dtype),
                     cross["v"])
    o = o.reshape(B, 1, h * hd).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", o, mp(p["wo"]))


def decode_step(cfg: ModelConfig, params, cache, batch):
    """batch: tokens (B,1), pos (B,). Cross K/V already in the cache."""
    from repro.models.layers import attention_decode

    tokens, pos = batch["tokens"], batch["pos"]
    x = embed_lookup(params["embed"], tokens)
    x = x + jax.lax.dynamic_slice_in_dim(mp(params["dec_pos"]), pos[0], 1, 0)[None, 0]

    def scan_body(x, args):
        lp, lc = args
        out, new_self = attention_decode(
            cfg, lp["self_attn"], layernorm(lp["ln1"], x, cfg.norm_eps), lc["self"], pos
        )
        x = x + out
        x = x + _cross_decode(
            cfg, lp["cross_attn"], layernorm(lp["ln_x"], x, cfg.norm_eps), lc["cross"]
        )
        x = x + mlp(cfg, lp["ffn"], layernorm(lp["ln2"], x, cfg.norm_eps))
        return x, {"self": new_self, "cross": lc["cross"]}

    x, new_caches = jax.lax.scan(scan_body, x, (params["dec_layers"], cache["layers"]))
    x = layernorm(params["dec_ln_f"], x, cfg.norm_eps)
    return unembed(params["embed"], x), {"layers": new_caches}
