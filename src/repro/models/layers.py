"""Shared transformer building blocks (pure functions, bf16 compute).

Conventions:
  * params are plain dicts (pytrees) built from PSpec declarations;
  * activations are bf16, norms/softmax/logits in f32;
  * tensor-parallel sharding is megatron-style over the ``model`` axis:
    QKV/up projections column-sharded, O/down projections row-sharded,
    embeddings vocab-sharded;
  * attention is einsum-based with an explicit GQA grouping (no head
    repetition materialized);
  * decode uses a KV cache ``[B, n_kv, S_max, hd]`` updated with
    ``dynamic_update_slice`` at position ``pos``.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.param import PSpec

COMPUTE_DTYPE = jnp.bfloat16
NEG_INF = -1e9

# Sequences longer than this use the query-block-chunked attention path
# (bounded (q_block, T) score working set instead of (S, T)).  The fused
# single-einsum path stays for short sequences where S^2 scores are cheap
# and XLA fuses better.
ATTN_CHUNK_THRESHOLD = int(os.environ.get("REPRO_ATTN_CHUNK_THRESHOLD", 4096))
ATTN_Q_BLOCK = int(os.environ.get("REPRO_ATTN_Q_BLOCK", 1024))


def mp(x):
    """Cast to the compute (mixed-precision) dtype."""
    return x.astype(COMPUTE_DTYPE)


def mixed_einsum(spec, a, b):
    """bf16 x bf16 -> f32 contraction.

    TPU form: operands stay bf16 with f32 accumulation on the MXU
    (``preferred_element_type``) — the ``.astype(f32)`` form makes XLA
    materialize f32 copies of whole K/V tensors (for decode: of the
    entire KV cache, observed +4x cache memory).  The XLA *CPU* runtime
    cannot execute BF16xBF16=F32 dots, so tests upcast there; the
    dry-run pins the TPU form (it lowers but never executes).
    """
    mode = os.environ.get("REPRO_MIXED_DOT", "")
    if mode == "preferred" or (not mode and jax.default_backend() != "cpu"):
        return jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
    return jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))


# Pure-DP layout (launcher-owned): the tensor axis carries batch too.
DP_OVER_MODEL = False



def ambient_mesh():
    """Version-compat ambient-mesh lookup: ``jax.sharding.get_abstract_mesh``
    (new) falls back to the thread-resources physical mesh (jax <= 0.4.x)."""
    gam = getattr(jax.sharding, "get_abstract_mesh", None)
    if gam is not None:
        return gam()
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        return None
    return None if m.empty else m


def _dp_axes():
    """Data-parallel axes of the ambient mesh ('pod' shards batch too)."""
    am = ambient_mesh()
    if am is None or am.empty:
        return None, 1
    names = am.axis_names
    dp_names = ("pod", "data", "model") if DP_OVER_MODEL else ("pod", "data")
    axes = tuple(a for a in dp_names if a in names)
    if not axes:
        return None, 1
    n = 1
    for a in axes:
        n *= am.shape[a]
    return axes, n


def shard_spec(x, entries):
    """Pin an activation to an explicit spec; 'dp' resolves to the
    data-parallel axes (('pod','data') on a multi-pod mesh).  Entries
    whose axes do not divide the dim are dropped. No-op without a mesh."""
    axes, _ = _dp_axes()
    if axes is None:
        return x
    am = ambient_mesh()
    out = []
    for dim, e in zip(x.shape, entries):
        ee = axes if e == "dp" else e
        if ee is None:
            out.append(None)
            continue
        names = ee if isinstance(ee, tuple) else (ee,)
        n = 1
        for a in names:
            n *= am.shape.get(a, 1)
        out.append((ee if len(names) > 1 else names[0]) if dim % n == 0 and n > 1 else None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*out))
    except (RuntimeError, ValueError):
        return x


# Megatron-style sequence parallelism at layer boundaries: when enabled
# (launcher sets it for long-sequence train shapes), the residual stream
# is pinned (dp, model, None) so remat-boundary activations shrink by the
# TP degree; GSPMD inserts the all-gather before attention/SSM mixing and
# the reduce-scatter after.  Module-level because model code is
# mesh-agnostic; the launcher owns the policy.
SEQ_SHARD_BOUNDARY = False


def shard_batch(x, batch_dim: int = 0, model_dim: int | None = None):
    """Pin an activation's batch dim to the data-parallel mesh axes.

    GSPMD sharding propagation is heuristic; through gathers (embedding
    lookups) and FSDP-sharded weights it can drop the batch sharding and
    silently replicate the whole layer stack over ``data``.  Pinning the
    residual-stream batch dim at every layer boundary keeps the
    propagation anchored — the standard megatron/MaxText discipline.

    ``model_dim`` additionally pins that dim to ``model`` (used for the
    vocab dim of logits).  No-op when there is no mesh context (CPU
    smoke tests), or when the dim does not divide evenly.
    """
    axes, n = _dp_axes()
    if axes is None or n == 1 or x.shape[batch_dim] % n != 0:
        return x
    am = ambient_mesh()
    msize = am.shape.get("model", 1)
    entries: list = [None] * x.ndim
    entries[batch_dim] = axes if len(axes) > 1 else axes[0]
    if model_dim is not None and not DP_OVER_MODEL:
        if msize > 1 and x.shape[model_dim] % msize == 0:
            entries[model_dim] = "model"
    elif (
        SEQ_SHARD_BOUNDARY
        and x.ndim == 3
        and batch_dim == 0
        and msize > 1
        and x.shape[1] % msize == 0
    ):
        entries[1] = "model"  # sequence parallelism (residual stream)
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except (RuntimeError, ValueError):  # no concrete mesh resolvable
        return x


# ---------------------------------------------------------------------------
# Norms / activations / embeddings
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> PSpec:
    return PSpec((d,), P(), init="ones")


def rmsnorm(scale, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(d: int) -> dict:
    return {"scale": PSpec((d,), P(), init="ones"), "bias": PSpec((d,), P(), init="zeros")}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


def embed_spec(vocab: int, d: int) -> PSpec:
    return PSpec((vocab, d), P("model", None), init="embed", scale=0.02)


def embed_lookup(table, ids):
    return mp(jnp.take(table, ids, axis=0))


def unembed(table, x):
    """Logits in f32; vocab axis sharded on `model` (GSPMD inserts the
    collective for the downstream softmax reduction)."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), table.astype(jnp.float32))


def softmax_xent(logits, labels, mask=None):
    """Token-mean cross entropy in f32. labels (B,S) int32, mask (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[
        ..., 0
    ]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def _rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope(x, positions, theta: float):
    """x (..., S, H, hd), positions (..., S) -> rotated x (same dtype)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope(x, positions3, theta: float, sections: tuple[int, int, int]):
    """Multimodal RoPE (Qwen2-VL): positions3 (3, B, S) are the
    temporal/height/width position ids; frequency channels are split
    into three sections, each rotated by its own position stream."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)  # (hd/2,)
    sec = jnp.cumsum(jnp.asarray((0,) + sections))
    chan = jnp.arange(hd // 2)
    which = jnp.clip(jnp.searchsorted(sec[1:], chan, side="right"), 0, 2)  # (hd/2,)
    # pos_c (B, S, hd/2): per-channel position stream
    pos = jnp.take(positions3, which, axis=0)  # (hd/2, B, S) -> transpose
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)  # (B, S, hd/2)
    ang = pos * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": PSpec((d, h * hd), P(None, "model")),
        "wk": PSpec((d, hkv * hd), P(None, "model")),
        "wv": PSpec((d, hkv * hd), P(None, "model")),
        "wo": PSpec((h * hd, d), P("model", None)),
    }
    if cfg.qkv_bias:
        p["bq"] = PSpec((h * hd,), P("model"), init="zeros")
        p["bk"] = PSpec((hkv * hd,), P("model"), init="zeros")
        p["bv"] = PSpec((hkv * hd,), P("model"), init="zeros")
    return p


def _qkv(cfg: ModelConfig, p, x):
    B, S, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, mp(p["wq"]))
    k = jnp.einsum("bsd,dh->bsh", x, mp(p["wk"]))
    v = jnp.einsum("bsd,dh->bsh", x, mp(p["wv"]))
    if cfg.qkv_bias:
        q = q + mp(p["bq"])
        k = k + mp(p["bk"])
        v = v + mp(p["bv"])
    return (
        q.reshape(B, S, h, hd),
        k.reshape(B, S, hkv, hd),
        v.reshape(B, S, hkv, hd),
    )


def _apply_rope(cfg: ModelConfig, q, k, positions):
    if not cfg.use_rope:
        return q, k
    if cfg.mrope:
        q = mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k


def _gqa_scores(q, k, scale):
    """q (B,S,H,hd), k (B,T,Hkv,hd) -> scores (B,Hkv,G,S,T) f32."""
    B, S, H, hd = q.shape
    hkv = k.shape[2]
    g = H // hkv
    qg = q.reshape(B, S, hkv, g, hd)
    # bf16 operands + f32 accumulation (preferred_element_type): the
    # .astype(f32) form makes XLA materialize f32 copies of whole
    # K tensors (for decode: of the whole KV cache).
    return mixed_einsum("bskgh,btkh->bkgst", qg, k) * scale


def _gqa_out(probs, v, out_dtype):
    """probs (B,Hkv,G,S,T), v (B,T,Hkv,hd) -> (B,S,H*hd)."""
    B, hkv, g, S, T = probs.shape
    hd = v.shape[-1]
    o = mixed_einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return o.reshape(B, S, hkv * g * hd).astype(out_dtype)


def chunked_attention(q, k, v, scale, *, causal=True, q_block: int | None = None,
                      out_dtype=None):
    """Query-block-chunked exact attention (the XLA long-context path).

    q (B,S,H,hq), k (B,T,Hkv,hq), v (B,T,Hkv,hv) -> (B,S,H*hv).

    Each query block takes its full-row softmax against all T keys —
    numerically identical to the naive path — but only a (q_block, T)
    score tile is ever live.  The block body is rematerialized
    (``jax.checkpoint``) so the backward pass recomputes score tiles
    instead of storing S*T floats.  The Pallas ``flash_attn`` kernel is
    the TPU-target replacement (online softmax + triangular block skip);
    this path is what the dry-run lowers through XLA.
    """
    B, S, H, hq = q.shape
    T, hkv = k.shape[1], k.shape[2]
    g = H // hkv
    hv = v.shape[-1]
    out_dtype = out_dtype or v.dtype
    qb = min(q_block or ATTN_Q_BLOCK, S)
    nb = S // qb
    assert nb * qb == S, f"seq {S} not divisible by q_block {qb}"

    # Sequence-shard K/V over `model` (flash-decoding layout): at one
    # sequence per device GSPMD otherwise "parallelizes" the block
    # contraction across ad-hoc device subgroups and all-reduces the
    # full (qb, T) partial scores every q-block — measured 22 TB/chip
    # on llama4-scout prefill_32k.  With T sharded, the score tile
    # stays sharded and only the softmax statistics and the (qb, H*hv)
    # block output are reduced.  Works for any head count (no
    # divisibility constraint, unlike head sharding).
    def _pin_seq(t):
        try:
            return jax.lax.with_sharding_constraint(
                t, P(None, "model", None, None)
            )
        except (RuntimeError, ValueError):
            return t

    am = ambient_mesh()
    if (
        am is not None and not am.empty
        and "model" in am.axis_names
        and not DP_OVER_MODEL
        and T % am.shape.get("model", 1) == 0
    ):
        k, v = _pin_seq(k), _pin_seq(v)

    qr = q.reshape(B, nb, qb, hkv, g, hq).transpose(1, 0, 2, 3, 4, 5)
    rows0 = jnp.arange(qb)
    cols = jnp.arange(T)

    def block(blk, qblk):
        s = mixed_einsum("bskgh,btkh->bkgst", qblk, k) * scale
        if causal:
            rows = blk * qb + rows0
            m = rows[:, None] >= cols[None, :]
            s = jnp.where(m[None, None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o = mixed_einsum("bkgst,btkh->bskgh", pr.astype(v.dtype), v)
        return o.reshape(B, qb, H * hv).astype(out_dtype)

    block = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)

    def body(blk, qblk):
        return blk + 1, block(blk, qblk)

    _, ob = jax.lax.scan(body, jnp.int32(0), qr)
    return ob.transpose(1, 0, 2, 3).reshape(B, S, H * hv)


def attention_train(cfg: ModelConfig, p, x, positions, *, causal: bool = True):
    """Full-sequence attention. x (B,S,D) bf16, positions (B,S) or (3,B,S)."""
    q, k, v = _qkv(cfg, p, x)
    if not cfg.mla:
        q, k = _apply_rope(cfg, q, k, positions)
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
    if x.shape[1] > ATTN_CHUNK_THRESHOLD:
        o = chunked_attention(q, k, v, scale, causal=causal, out_dtype=x.dtype)
    else:
        scores = _gqa_scores(q, k, scale)
        if causal:
            S = x.shape[1]
            mask = jnp.tril(jnp.ones((S, S), dtype=bool))
            scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        o = _gqa_out(probs, v, x.dtype)
    return jnp.einsum("bsh,hd->bsd", o, mp(p["wo"]))


def cross_attention_train(cfg: ModelConfig, p, x, memory):
    """Encoder-decoder cross attention (no positions, no mask)."""
    B, S, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, mp(p["wq"])).reshape(B, S, h, hd)
    k = jnp.einsum("bsd,dh->bsh", memory, mp(p["wk"])).reshape(
        B, memory.shape[1], hkv, hd
    )
    v = jnp.einsum("bsd,dh->bsh", memory, mp(p["wv"])).reshape(
        B, memory.shape[1], hkv, hd
    )
    scores = _gqa_scores(q, k, 1.0 / jnp.sqrt(hd).astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)
    o = _gqa_out(probs, v, x.dtype)
    return jnp.einsum("bsh,hd->bsd", o, mp(p["wo"]))


def attention_cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    """KV cache sharding:

    * many KV heads (>=16, divisible): batch on `data`, heads on `model`
      (pure TP decode — no softmax collectives);
    * few KV heads (GQA): batch on `data`, *sequence* on `model`
      (flash-decoding-style partial attention; GSPMD inserts the 2-pass
      softmax reduction);
    * batch == 1 (long-context single stream): sequence sharded over
      both axes.
    """
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    if batch == 1:
        spec = P(None, None, ("data", "model"), None)
    elif hkv >= 16 and hkv % 16 == 0:
        spec = P("data", "model", None, None)
    else:
        spec = P("data", None, "model", None)
    return {
        "k": PSpec((batch, hkv, s_max, hd), spec, init="zeros", dtype=COMPUTE_DTYPE),
        "v": PSpec((batch, hkv, s_max, hd), spec, init="zeros", dtype=COMPUTE_DTYPE),
    }


def attention_decode(cfg: ModelConfig, p, x, cache, pos):
    """Single-token decode. x (B,1,D), cache {k,v} (B,Hkv,S,hd), pos (B,)
    current write position (same for all batch rows under SPMD: we use
    pos[0] as the dynamic slice index). Returns (out, new_cache)."""
    B = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _qkv(cfg, p, x)  # (B,1,·,hd)
    if not cfg.mla:
        q, k = _apply_rope(cfg, q, k, pos[:, None])
    # write k/v at pos
    kc = jax.lax.dynamic_update_slice(
        cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype), (0, 0, pos[0], 0)
    )
    vc = jax.lax.dynamic_update_slice(
        cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype), (0, 0, pos[0], 0)
    )
    S = kc.shape[2]
    g = h // hkv
    qg = q.reshape(B, 1, hkv, g, hd).astype(kc.dtype)
    scores = (
        mixed_einsum("bskgh,bkth->bkgst", qg, kc)
        / jnp.sqrt(hd).astype(jnp.float32)
    )  # (B,hkv,g,1,S)
    tmask = jnp.arange(S)[None, :] <= pos[:, None]  # (B,S)
    scores = jnp.where(tmask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = mixed_einsum("bkgst,bkth->bskgh", probs.astype(vc.dtype), vc)
    o = o.reshape(B, 1, h * hd).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", o, mp(p["wo"]))
    return out, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def mla_specs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "q_down": PSpec((d, qr), P(None, None)),
        "q_norm": rmsnorm_spec(qr),
        "q_up": PSpec((qr, h * (dn + dr)), P(None, "model")),
        "kv_down": PSpec((d, kr + dr), P(None, None)),
        "kv_norm": rmsnorm_spec(kr),
        "kv_up": PSpec((kr, h * (dn + dv)), P(None, "model")),
        "wo": PSpec((h * dv, d), P("model", None)),
    }


def mla_train(cfg: ModelConfig, p, x, positions):
    B, S, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank

    ql = rmsnorm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, mp(p["q_down"])), cfg.norm_eps)
    q = jnp.einsum("bsr,rh->bsh", ql, mp(p["q_up"])).reshape(B, S, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    kv = jnp.einsum("bsd,dr->bsr", x, mp(p["kv_down"]))
    c_kv, k_rope = kv[..., :kr], kv[..., kr:]
    c_kv = rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    kvu = jnp.einsum("bsr,rh->bsh", c_kv, mp(p["kv_up"])).reshape(B, S, h, dn + dv)
    k_nope, v = kvu[..., :dn], kvu[..., dn:]

    q_rope = rope(q_rope, positions, cfg.rope_theta)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,dr)

    scale = 1.0 / jnp.sqrt(dn + dr).astype(jnp.float32)
    if S > ATTN_CHUNK_THRESHOLD:
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,h,dn+dr)
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, h, dr))], axis=-1
        )
        o = chunked_attention(qq, kk, v, scale, causal=True, out_dtype=x.dtype)
        return jnp.einsum("bsh,hd->bsd", o, mp(p["wo"]))
    s_nope = mixed_einsum("bshd,bthd->bhst", q_nope, k_nope)
    s_rope = mixed_einsum("bshd,btod->bhst", q_rope, k_rope)
    scores = (s_nope + s_rope) * scale
    mask = jnp.tril(jnp.ones((S, S), dtype=bool))
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = mixed_einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
    o = o.reshape(B, S, h * dv).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", o, mp(p["wo"]))


def mla_cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    """MLA caches the *compressed* latent + rope key — its whole point:
    cache bytes/token = kv_lora_rank + qk_rope_dim instead of
    2 * n_heads * head_dim (a ~17x reduction for MiniCPM3).

    The latent has no head dim to TP-shard, so the *sequence* shards
    over ``model`` (flash-decoding style: GSPMD inserts the two-pass
    softmax reduction); batch shards over ``data``."""
    seq = ("data", "model") if batch == 1 else "model"
    b_ax = None if batch == 1 else "data"
    return {
        "c_kv": PSpec((batch, s_max, cfg.kv_lora_rank), P(b_ax, seq, None),
                      init="zeros", dtype=COMPUTE_DTYPE),
        "k_rope": PSpec((batch, s_max, cfg.qk_rope_dim), P(b_ax, seq, None),
                        init="zeros", dtype=COMPUTE_DTYPE),
    }


def mla_decode(cfg: ModelConfig, p, x, cache, pos):
    """Absorbed-projection MLA decode: attention runs in the latent
    space (W_uk folded into q, W_uv applied after the probability-
    weighted latent sum)."""
    B = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank

    ql = rmsnorm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, mp(p["q_down"])), cfg.norm_eps)
    q = jnp.einsum("bsr,rh->bsh", ql, mp(p["q_up"])).reshape(B, 1, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, pos[:, None], cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, mp(p["kv_down"]))
    c_new, kr_new = kv[..., :kr], kv[..., kr:]
    c_new = rmsnorm(p["kv_norm"], c_new, cfg.norm_eps)
    kr_new = rope(kr_new[:, :, None, :], pos[:, None], cfg.rope_theta)[:, :, 0, :]

    c_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos[0], 0)
    )
    r_cache = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos[0], 0)
    )

    # Absorb W_uk: q_lat[b,h,kr] = sum_dn q_nope[b,h,dn] * W_uk[kr,h,dn]
    kv_up = p["kv_up"].reshape(kr, h, dn + dv)
    w_uk = mp(kv_up[..., :dn])  # (kr, h, dn)
    w_uv = mp(kv_up[..., dn:])  # (kr, h, dv)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)  # (B,1,h,kr)

    S = c_cache.shape[1]
    scale = 1.0 / jnp.sqrt(dn + dr).astype(jnp.float32)
    s_lat = mixed_einsum("bshr,btr->bhst", q_lat.astype(c_cache.dtype), c_cache)
    s_rope = mixed_einsum("bshd,btd->bhst", q_rope.astype(r_cache.dtype), r_cache)
    scores = (s_lat + s_rope) * scale
    tmask = jnp.arange(S)[None, :] <= pos[:, None]
    scores = jnp.where(tmask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    lat = mixed_einsum("bhst,btr->bshr", probs.astype(c_cache.dtype), c_cache)  # (B,1,h,kr)
    o = jnp.einsum("bshr,rhd->bshd", lat, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, h * dv).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", o, mp(p["wo"]))
    return out, {"c_kv": c_cache, "k_rope": r_cache}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.act == "silu":  # gated: fused [gate; up]
        return {
            "w_in": PSpec((d, 2 * f), P(None, "model")),
            "w_out": PSpec((f, d), P("model", None)),
        }
    return {
        "w_in": PSpec((d, f), P(None, "model")),
        "b_in": PSpec((f,), P("model"), init="zeros"),
        "w_out": PSpec((f, d), P("model", None)),
        "b_out": PSpec((d,), P(), init="zeros"),
    }


def mlp(cfg: ModelConfig, p, x):
    if cfg.act == "silu":
        f = p["w_out"].shape[0]
        gu = jnp.einsum("bsd,df->bsf", x, mp(p["w_in"]))
        gate, up = gu[..., :f], gu[..., f:]
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jnp.einsum("bsd,df->bsf", x, mp(p["w_in"])) + mp(p["b_in"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, mp(p["w_out"]))
    if cfg.act != "silu":
        out = out + mp(p["b_out"])
    return out
