"""Mamba-1 selective SSM block (Falcon-Mamba / Jamba mixer).

Training uses a *chunked associative scan*: the selective recurrence

    h_t = exp(dt_t · A) ⊙ h_{t-1} + (dt_t · x_t) ⊗ B_t
    y_t = C_t · h_t + D ⊙ x_t

is a first-order linear recurrence, so within a chunk of ``chunk``
tokens we run ``jax.lax.associative_scan`` (O(log chunk) depth — the
TPU-native replacement for the CUDA selective-scan kernel), and chunks
are chained with a ``lax.scan`` carrying the (B, d_inner, N) state.
This bounds the materialized (chunk, d_inner, N) tensors — the memory
hot spot the original CUDA kernel fuses away — while keeping MXU-sized
batched einsums.

Decode carries (conv window, ssm state): O(1) per token, which is what
makes ``long_500k`` a pure-SSM win.

Sharding: d_inner is the tensor axis (like an FFN hidden dim):
in_proj P('data','model'), per-channel params P('model', ...),
out_proj P('model','data').
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import mp, shard_spec
from repro.models.param import PSpec


def ssm_specs(cfg: ModelConfig) -> dict:
    d, di, n, r, c = (
        cfg.d_model,
        cfg.d_inner,
        cfg.ssm_state,
        cfg.dt_rank,
        cfg.ssm_conv,
    )
    return {
        "in_proj": PSpec((d, 2 * di), P("data", "model")),
        "conv_w": PSpec((di, c), P("model", None), scale=0.5),
        "conv_b": PSpec((di,), P("model"), init="zeros"),
        "x_proj": PSpec((di, r + 2 * n), P("model", None)),
        "dt_proj": PSpec((r, di), P(None, "model")),
        "dt_bias": PSpec((di,), P("model"), init="ssm_dt"),
        "A_log": PSpec((di, n), P("model", None), init="ssm_a"),
        "D": PSpec((di,), P("model"), init="ones"),
        "out_proj": PSpec((di, d), P("model", "data")),
    }


def _causal_conv(p, x):
    """Depthwise causal conv along S. x (B, S, Di)."""
    di, width = p["conv_w"].shape
    w = mp(p["conv_w"]).T[:, None, :]  # (width, 1, Di) for conv_general
    out = jax.lax.conv_general_dilated(
        mp(x),
        w,
        window_strides=(1,),
        padding=[(width - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=di,
    )
    return out + mp(p["conv_b"])


def _ssm_params(cfg: ModelConfig, p, u):
    """u (B, S, Di) conv output -> dt (B,S,Di), Bm/Cm (B,S,N), A (Di,N)."""
    n, r = cfg.ssm_state, cfg.dt_rank
    proj = jnp.einsum("bsd,dk->bsk", u, mp(p["x_proj"]))
    dt_r, Bm, Cm = proj[..., :r], proj[..., r : r + n], proj[..., r + n :]
    dt = jnp.einsum("bsr,rd->bsd", dt_r, mp(p["dt_proj"])) + mp(p["dt_bias"])
    dt = jax.nn.softplus(dt.astype(jnp.float32))  # (B,S,Di) f32
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (Di, N)
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32), A


def _chunk_scan(dt, Bm, Cm, A, u, h0):
    """Selective scan over one chunk via associative_scan.

    dt (B,S,Di) f32 | Bm,Cm (B,S,N) f32 | A (Di,N) f32 | u (B,S,Di)
    h0 (B,Di,N) f32 carried state.  Returns (y (B,S,Di) f32, hS).
    """
    decay = jnp.exp(dt[..., None] * A)  # (B,S,Di,N)
    inp = (dt * u.astype(jnp.float32))[..., None] * Bm[:, :, None, :]  # (B,S,Di,N)
    # fold the carried state into the first step
    inp = inp.at[:, 0].add(decay[:, 0] * h0)

    def op(a, b):
        da, xa = a
        db, xb = b
        return da * db, db * xa + xb

    _, hs = jax.lax.associative_scan(op, (decay, inp), axis=1)  # (B,S,Di,N)
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cm)
    return y, hs[:, -1]


def ssm_forward(cfg: ModelConfig, p, x, *, chunk: int = 128):
    """Full-sequence selective SSM. x (B, S, D) bf16 -> (B, S, D)."""
    B, S, D = x.shape
    di = cfg.d_inner
    xz = jnp.einsum("bsd,de->bse", x, mp(p["in_proj"]))
    xs, z = xz[..., :di], xz[..., di:]
    u = _causal_conv(p, xs)
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    # the depthwise conv loses the channel sharding in propagation;
    # re-pin (B,S,Di) to (dp, None, model) or the f32 dt/u tensors
    # replicate (1 GB+ per layer at 32k tokens)
    u = shard_spec(u, ("dp", None, "model"))

    dt, Bm, Cm, A = _ssm_params(cfg, p, u)
    dt = shard_spec(dt, ("dp", None, "model"))

    c = min(chunk, S)
    n_chunks = S // c
    assert n_chunks * c == S, f"seq {S} not divisible by chunk {c}"

    # remat the chunk body: its (B, c, Di, N) decay/state tensors are
    # recomputed in backward instead of being stacked over all chunks
    # (which is n_chunks x 1 GB-scale at 32k tokens)
    chunk_fn = jax.checkpoint(
        _chunk_scan, policy=jax.checkpoint_policies.nothing_saveable
    )

    def body(h, args):
        dtc, Bc, Cc, uc = args
        y, h2 = chunk_fn(dtc, Bc, Cc, A, uc, h)
        return h2, y

    def reshape(t):
        return t.reshape(B, n_chunks, c, *t.shape[2:]).swapaxes(0, 1)

    h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
    _, ys = jax.lax.scan(body, h0, (reshape(dt), reshape(Bm), reshape(Cm), reshape(u)))
    y = ys.swapaxes(0, 1).reshape(B, S, di)

    y = y + u.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bsd,de->bse", y.astype(x.dtype), mp(p["out_proj"]))


def ssm_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    di, n, c = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    b_ax = "data" if batch > 1 else None
    return {
        "conv": PSpec((batch, c - 1, di), P(b_ax, None, "model"), init="zeros",
                      dtype=jnp.bfloat16),
        "h": PSpec((batch, di, n), P(b_ax, "model", None), init="zeros",
                   dtype=jnp.float32),
    }


def ssm_decode(cfg: ModelConfig, p, x, cache):
    """Single-token step. x (B,1,D); cache {conv (B,c-1,Di), h (B,Di,N)}."""
    B = x.shape[0]
    di = cfg.d_inner
    xz = jnp.einsum("bsd,de->bse", x, mp(p["in_proj"]))
    xs, z = xz[..., :di], xz[..., di:]  # (B,1,Di)

    window = jnp.concatenate([cache["conv"].astype(xs.dtype), xs], axis=1)  # (B,c,Di)
    u = jnp.einsum("bcd,dc->bd", window, mp(p["conv_w"])) + mp(p["conv_b"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)[:, None, :]  # (B,1,Di)

    dt, Bm, Cm, A = _ssm_params(cfg, p, u)
    decay = jnp.exp(dt[:, 0, :, None] * A)  # (B,Di,N)
    inp = (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = decay * cache["h"] + inp
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])
    y = y + u[:, 0].astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = jnp.einsum("bd,de->be", y.astype(x.dtype), mp(p["out_proj"]))[:, None, :]
    return out, {"conv": window[:, 1:].astype(cache["conv"].dtype), "h": h}
