"""Model registry: one uniform API over all architecture families.

``get_model(cfg)`` returns a :class:`ModelAPI` exposing

  * ``param_specs()``      — PSpec tree (shapes + logical shardings)
  * ``loss(params, batch)``            — train objective (+ metrics)
  * ``decode(params, cache, batch)``   — single-token serve step
  * ``cache_specs(batch, s_max)``      — decode-state PSpec tree
  * ``input_specs(shape)``  — ShapeDtypeStruct stand-ins per input
  * ``input_pspecs(shape)`` — logical PartitionSpecs per input

Input stand-ins follow the assignment: modality frontends are stubs —
``[audio]``/``[vlm]`` entries receive precomputed frame/patch
embeddings as inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm_lm, transformer


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    param_specs: Callable[[], Any]
    loss: Callable[[Any, dict], tuple]
    decode: Callable[[Any, Any, dict], tuple]
    cache_specs: Callable[[int, int], Any]
    prefill: Callable[..., tuple] | None = None

    # -- inputs -----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "decode":
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((B,), i32),
            }
            return specs
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_patches, cfg.vision_dim), jnp.bfloat16
            )
            specs["vision_pos"] = jax.ShapeDtypeStruct((B, cfg.vision_patches), i32)
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16
            )
        return specs

    def input_pspecs(self, shape: ShapeConfig) -> dict[str, P]:
        cfg = self.cfg
        batch = P("data")
        if shape.kind == "decode":
            return {"tokens": P("data", None), "pos": batch}
        specs = {"tokens": P("data", None), "labels": P("data", None)}
        if cfg.family == "vlm":
            specs["vision_embeds"] = P("data", None, None)
            specs["vision_pos"] = P("data", None)
            specs["positions"] = P(None, "data", None)
        if cfg.family == "encdec":
            specs["frames"] = P("data", None, "model")
        return specs

    def demo_batch(self, shape: ShapeConfig, seed: int = 0) -> dict[str, np.ndarray]:
        """Concrete random inputs matching input_specs (smoke tests)."""
        rng = np.random.default_rng(seed)
        out = {}
        for name, sds in self.input_specs(shape).items():
            if sds.dtype == jnp.int32:
                if name == "pos":
                    out[name] = np.zeros(sds.shape, np.int32)
                elif name == "positions":
                    S = sds.shape[-1]
                    out[name] = np.broadcast_to(
                        np.arange(S, dtype=np.int32), sds.shape
                    ).copy()
                elif name == "vision_pos":
                    out[name] = np.broadcast_to(
                        np.arange(sds.shape[-1], dtype=np.int32), sds.shape
                    ).copy()
                else:
                    hi = max(self.cfg.vocab_size - 1, 2)
                    out[name] = rng.integers(1, hi, size=sds.shape, dtype=np.int32)
            else:
                out[name] = rng.normal(0, 0.3, size=sds.shape).astype(np.float32)
        return out


def get_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
    elif fam == "ssm":
        mod = ssm_lm
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "encdec":
        mod = encdec
    else:
        raise ValueError(f"unknown family {fam!r}")

    return ModelAPI(
        cfg=cfg,
        param_specs=lambda: mod.param_specs(cfg),
        loss=lambda params, batch: mod.loss_fn(cfg, params, batch),
        decode=lambda params, cache, batch: mod.decode_step(cfg, params, cache, batch),
        cache_specs=lambda batch, s_max: mod.cache_specs(cfg, batch, s_max),
        prefill=(
            (lambda params, tokens, s_max: mod.prefill(cfg, params, tokens, s_max))
            if hasattr(mod, "prefill")
            else None
        ),
    )
