"""Pure-SSM language model (Falcon-Mamba-7B family).

Stack: embed -> n_layers x (RMSNorm -> Mamba block -> residual) ->
RMSNorm -> unembed.  Decode state is O(1) per token (conv window + SSM
state), which is why the ``long_500k`` cell runs here but is skipped
for full-attention archs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.layers import (
    embed_lookup,
    embed_spec,
    rmsnorm,
    rmsnorm_spec,
    shard_batch,
    softmax_xent,
    unembed,
)
from repro.models.param import stack


def layer_specs(cfg: ModelConfig) -> dict:
    return {"ln": rmsnorm_spec(cfg.d_model), "mixer": ssm.ssm_specs(cfg)}


def param_specs(cfg: ModelConfig) -> dict:
    specs = {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "layers": stack(cfg.n_layers, layer_specs(cfg)),
        "ln_f": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = embed_spec(cfg.vocab_size, cfg.d_model)
    return specs


def _layer_train(cfg: ModelConfig, p, x):
    x = shard_batch(x)
    x = x + ssm.ssm_forward(cfg, p["mixer"], rmsnorm(p["ln"], x, cfg.norm_eps))
    return x, jnp.float32(0.0)


def forward_train(cfg: ModelConfig, params, tokens):
    from repro.models.scan_utils import stacked_scan

    x = shard_batch(embed_lookup(params["embed"], tokens))
    body = functools.partial(_layer_train, cfg)
    x, _ = stacked_scan(body, x, params["layers"], cfg.remat_group)
    return rmsnorm(params["ln_f"], x, cfg.norm_eps)


def logits_of(cfg: ModelConfig, params, hidden):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return shard_batch(unembed(table, hidden), model_dim=-1)


def loss_fn(cfg: ModelConfig, params, batch):
    hidden = forward_train(cfg, params, batch["tokens"])
    logits = logits_of(cfg, params, hidden)
    loss = softmax_xent(logits, batch["labels"])
    return loss, {"xent": loss, "aux": jnp.float32(0.0)}


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    # SSM state does not depend on s_max — O(1) decode memory.
    return {"layers": stack(cfg.n_layers, ssm.ssm_cache_specs(cfg, batch))}


def decode_step(cfg: ModelConfig, params, cache, batch):
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens)

    def scan_body(x, layer):
        lp, lc = layer
        out, new_cache = ssm.ssm_decode(
            cfg, lp["mixer"], rmsnorm(lp["ln"], x, cfg.norm_eps), lc
        )
        return x + out, new_cache

    x, new_caches = jax.lax.scan(scan_body, x, (params["layers"], cache["layers"]))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return logits_of(cfg, params, x), {"layers": new_caches}


def prefill(cfg: ModelConfig, params, tokens, s_max: int):
    """Sequential prefill via decode steps is O(S); for the serving demo
    we instead run the train forward for logits and rebuild the state by
    scanning the last ``conv`` window + a full state recompute.  For
    simplicity (and because SSM prefill state == decode state), we run
    chunked decode over the prompt."""
    B, S = tokens.shape
    cache = jax.tree.map(
        lambda ps: jnp.zeros(ps.shape, ps.dtype),
        cache_specs(cfg, B, s_max),
        is_leaf=lambda x: hasattr(x, "init"),
    )

    def step(carry, t):
        cache = carry
        logits, cache = decode_step(cfg, params, cache, {"tokens": t[:, None]})
        return cache, logits[:, 0]

    cache, logits = jax.lax.scan(step, cache, tokens.T)
    return logits[-1][:, None, :], cache
