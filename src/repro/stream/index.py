"""Incremental MinHash-LSH blocking index for streaming ingest.

Arriving entities are shingled into hashed character-3-gram *presence*
vectors over their blocking key (``similarity.block_key``), MinHash
signatures are computed on-device by the ``minhash`` Pallas kernel, and
the signatures are banded into LSH buckets: two entities collide iff
they agree on all ``rows_per_band`` signature slots of some band.

The index answers one question for delta cover maintenance: *which
existing entities could an arrival be t_loose-similar to?*  Bucket
collisions gate the exact (kernel-computed) similarity probes, so an
ingest costs O(batch x candidates) instead of O(batch x corpus) — the
recall/cost trade of the blocking literature (cf. arXiv 1509.03302):
banding parameters set the similarity level above which recall is
near-1 and below which work is saved.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import similarity as simlib
from repro.kernels.minhash import ops as minhash_ops


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    """Banding: ``num_bands`` bands of ``rows_per_band`` signature rows.

    Collision probability at Jaccard ``J`` is ``1 - (1 - J^r)^b``; the
    defaults (r=2, b=64) put the S-curve knee near J~0.1 so candidate
    recall at the canopy t_loose threshold is effectively 1 while
    unrelated names rarely collide.
    """

    num_bands: int = 64
    rows_per_band: int = 2
    shingle_dim: int = 512
    seed: int = 0

    @property
    def num_hashes(self) -> int:
        return self.num_bands * self.rows_per_band


def shingle_presence(names: list[str], dim: int) -> np.ndarray:
    """(N, dim) float32 presence matrix of hashed block-key 3-grams.

    Reuses the deterministic FNV hashing of ``ngram_profiles`` so the
    same name always lands on the same shingle slots, then binarizes —
    MinHash needs sets, not counts.
    """
    keys = [simlib.block_key(n) for n in names]
    prof = simlib.ngram_profiles(keys, dim=dim)
    return (prof > 0).astype(np.float32)


class MinHashLSHIndex:
    """Append-only LSH index over MinHash signatures.

    ``add`` ingests a batch (signatures computed on-device), ``query``
    returns the union of bucket members colliding with each probe.
    """

    def __init__(self, cfg: LSHConfig | None = None):
        self.cfg = cfg or LSHConfig()
        self.table = minhash_ops.hash_table(
            self.cfg.num_hashes, self.cfg.shingle_dim, seed=self.cfg.seed
        )
        # band index -> band key (tuple of signature rows) -> entity ids
        self.buckets: list[dict[tuple, list[int]]] = [
            {} for _ in range(self.cfg.num_bands)
        ]
        self.n_indexed = 0

    def signatures(self, names: list[str]) -> np.ndarray:
        x = shingle_presence(names, self.cfg.shingle_dim)
        return np.asarray(minhash_ops.minhash(x, self.table))

    def _band_keys(self, sig: np.ndarray):
        r = self.cfg.rows_per_band
        for b in range(self.cfg.num_bands):
            yield b, tuple(int(v) for v in sig[b * r : (b + 1) * r])

    def add(self, ids: list[int], names: list[str]) -> np.ndarray:
        """Index a batch; returns the (B, H) signature matrix."""
        sigs = self.signatures(names)
        for eid, sig in zip(ids, sigs):
            for b, key in self._band_keys(sig):
                self.buckets[b].setdefault(key, []).append(int(eid))
        self.n_indexed += len(ids)
        return sigs

    def query(self, sigs: np.ndarray, exclude: set[int] | None = None) -> set[int]:
        """Union of indexed entities colliding with any probe signature."""
        out: set[int] = set()
        for sig in np.atleast_2d(sigs):
            for b, key in self._band_keys(sig):
                out.update(self.buckets[b].get(key, ()))
        if exclude:
            out -= exclude
        return out
