"""Incremental MinHash-LSH blocking index for streaming ingest.

Arriving entities are shingled into hashed character-3-gram *presence*
vectors over their blocking key (``similarity.block_key``), MinHash
signatures are computed on-device by the ``minhash`` Pallas kernel, and
the signatures are banded into LSH buckets: two entities collide iff
they agree on all ``rows_per_band`` signature slots of some band.

The index answers one question for delta cover maintenance: *which
existing entities could an arrival be t_loose-similar to?*  Bucket
collisions gate the exact (kernel-computed) similarity probes, so an
ingest costs O(batch x candidates) instead of O(batch x corpus) — the
recall/cost trade of the blocking literature (cf. arXiv 1509.03302):
banding parameters set the similarity level above which recall is
near-1 and below which work is saved.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core import similarity as simlib, txn
from repro.kernels.minhash import ops as minhash_ops


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    """Banding: ``num_bands`` bands of ``rows_per_band`` signature rows.

    Collision probability at Jaccard ``J`` is ``1 - (1 - J^r)^b``; the
    defaults (r=2, b=64) put the S-curve knee near J~0.1 so candidate
    recall at the canopy t_loose threshold is effectively 1 while
    unrelated names rarely collide.

    ``max_ids`` / ``ttl_adds`` bound the bucket tables for long-lived
    serving: ``max_ids`` caps the number of indexed entities (oldest
    evicted first), ``ttl_adds`` evicts entities older than that many
    ``add`` calls.  Both are **off by default** because eviction trades
    exactness for memory — an evicted entity can no longer collide with
    future arrivals, so the delta cover is only guaranteed equal to the
    batch cover for corpora whose >= t_loose partners arrive within the
    retention window.
    """

    num_bands: int = 64
    rows_per_band: int = 2
    shingle_dim: int = 512
    seed: int = 0
    max_ids: int | None = None
    ttl_adds: int | None = None

    @property
    def num_hashes(self) -> int:
        return self.num_bands * self.rows_per_band

    @property
    def bounded(self) -> bool:
        return self.max_ids is not None or self.ttl_adds is not None


def shingle_presence(names: list[str], dim: int) -> np.ndarray:
    """(N, dim) float32 presence matrix of hashed block-key 3-grams.

    Reuses the deterministic FNV hashing of ``ngram_profiles`` so the
    same name always lands on the same shingle slots, then binarizes —
    MinHash needs sets, not counts.
    """
    keys = [simlib.block_key(n) for n in names]
    prof = simlib.ngram_profiles(keys, dim=dim)
    return (prof > 0).astype(np.float32)


class MinHashLSHIndex:
    """Incremental LSH index over MinHash signatures.

    ``add`` ingests a batch (signatures computed on-device), ``query``
    returns the union of bucket members colliding with each probe.
    With ``LSHConfig.max_ids`` / ``ttl_adds`` set, the bucket tables are
    bounded: the oldest entities are evicted (and scrubbed from their
    buckets) once the cap or age limit is exceeded.
    """

    def __init__(self, cfg: LSHConfig | None = None, *, shard=None, merge=None):
        self.cfg = cfg or LSHConfig()
        # bucket-map partitioning for sharded serving: with a ``shard``
        # (``launch.sharding.ShardSpec``) this process stores and probes
        # only the buckets it owns, and ``merge`` (a cross-process set
        # union, ``launch.sharding.ShardMerger.union``) reassembles each
        # probe's candidate set.  The partition is exhaustive, so the
        # merged set equals the unsharded index's answer exactly; merge
        # runs on EVERY query (it is a collective — all shards must
        # reach it together, even when a shard's local set is empty).
        self.shard = shard
        self.merge = merge
        self.table = minhash_ops.hash_table(
            self.cfg.num_hashes, self.cfg.shingle_dim, seed=self.cfg.seed
        )
        # band index -> band key (tuple of signature rows) -> entity ids
        self.buckets: list[dict[tuple, list[int]]] = [
            {} for _ in range(self.cfg.num_bands)
        ]
        self.n_indexed = 0  # currently live (indexed minus evicted)
        self.n_evicted = 0
        self.n_adds = 0
        # eviction bookkeeping, kept only when a bound is configured:
        # per-id band keys (for O(bands) bucket scrubbing), insertion
        # order, and the add-call stamp for TTL.
        self._keys_of: dict[int, list[tuple[int, tuple]]] = {}
        self._added_at: dict[int, int] = {}
        self._order: deque[int] = deque()

    def signatures(self, names: list[str]) -> np.ndarray:
        x = shingle_presence(names, self.cfg.shingle_dim)
        return np.asarray(minhash_ops.minhash(x, self.table))

    def _band_keys(self, sig: np.ndarray):
        r = self.cfg.rows_per_band
        for b in range(self.cfg.num_bands):
            yield b, tuple(int(v) for v in sig[b * r : (b + 1) * r])

    def add(self, ids: list[int], names: list[str]) -> np.ndarray:
        """Index a batch; returns the (B, H) signature matrix.

        On a *bounded* index, re-adding an id is tolerated: the old
        bucket entries are scrubbed first and the TTL stamp refreshes.
        An unbounded index keeps the original append-only semantics —
        a re-add duplicates bucket entries and counts in ``n_indexed``
        again (the streaming layer rejects duplicate ids before they
        reach the index).
        """
        sigs = self.signatures(names)
        t = txn.active()
        if t is not None:
            # O(batch x bands) journal: counters, the touched bucket
            # lists (copied pre-image, they are collision-sized), and —
            # bounded index only — the eviction bookkeeping
            t.save_attr(self, "n_adds")
            t.save_attr(self, "n_indexed")
            t.save_attr(self, "n_evicted")
            if self.cfg.bounded:
                t.save_key(self.__dict__, "_order", copy=deque.copy)
        self.n_adds += 1
        for eid, sig in zip(ids, sigs):
            eid = int(eid)
            keys = [
                (b, key) for b, key in self._band_keys(sig)
                if self.shard is None or self.shard.owns(b, key)
            ]
            if self.cfg.bounded and eid in self._keys_of:
                self._scrub(eid)
                self._order.remove(eid)
                self.n_indexed -= 1
            for b, key in keys:
                if t is not None:
                    t.save_key(self.buckets[b], key, copy=list)
                self.buckets[b].setdefault(key, []).append(eid)
            if self.cfg.bounded:
                if t is not None:
                    t.save_key(self._keys_of, eid)
                    t.save_key(self._added_at, eid)
                self._keys_of[eid] = keys
                self._added_at[eid] = self.n_adds
                self._order.append(eid)
            self.n_indexed += 1
        self._evict()
        return sigs

    def _scrub(self, eid: int) -> None:
        """Remove an id's entries from its recorded buckets."""
        t = txn.active()
        if t is not None:
            t.save_key(self._added_at, eid)
            t.save_key(self._keys_of, eid)
        del self._added_at[eid]
        for b, key in self._keys_of.pop(eid):
            members = self.buckets[b].get(key)
            if members is None:
                continue
            if t is not None:
                t.save_key(self.buckets[b], key, copy=list)
            members.remove(eid)
            if not members:
                del self.buckets[b][key]

    def _evict(self) -> None:
        cfg = self.cfg
        while self._order:
            oldest = self._order[0]
            over_cap = cfg.max_ids is not None and len(self._order) > cfg.max_ids
            expired = (
                cfg.ttl_adds is not None
                and self._added_at[oldest] <= self.n_adds - cfg.ttl_adds
            )
            if not (over_cap or expired):
                break
            self._order.popleft()
            self._scrub(oldest)
            self.n_indexed -= 1
            self.n_evicted += 1

    def query(self, sigs: np.ndarray, exclude: set[int] | None = None) -> set[int]:
        """Union of indexed entities colliding with any probe signature.

        Sharded: local buckets cover only the owned slice of the bucket
        map, so the probe result is united across shards before the
        exclusion — every shard sees the exact unsharded answer.
        """
        out: set[int] = set()
        for sig in np.atleast_2d(sigs):
            for b, key in self._band_keys(sig):
                if self.shard is not None and not self.shard.owns(b, key):
                    continue
                out.update(self.buckets[b].get(key, ()))
        if self.merge is not None:
            out = self.merge(out)
        if exclude:
            out -= exclude
        return out
