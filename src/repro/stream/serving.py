"""High-traffic serving front-end: continuous ingest batching over
:class:`~repro.stream.service.ResolveService`.

The service's per-ingest cost has a large fixed component (one
``CoverDelta`` maintenance pass + one fused-round fixpoint per call),
so per-request synchronous ingest tops out at the 11–115 entities/s the
``BENCH_stream.json`` throughput block records.  This module amortizes
that fixed cost the way LLM serving stacks amortize theirs — by
**continuous micro-batch coalescing**: producers enqueue arrivals on an
async queue; a single worker thread drains it, accumulating requests up
to a size budget (``ServingConfig.max_batch`` entities) or a latency
budget (``ServingConfig.max_delay_ms``, measured from the oldest queued
request), and runs each coalesced batch through **one** delta/fixpoint
ingest.

Correctness is free: the message-passing decomposition of the paper
(arXiv 1103.2410) makes the micro-batch the natural unit of work — the
service invariant says *any* split of the arrival sequence into
micro-batches reaches the batch pipeline's fixpoint bit-for-bit, so
coalescing k queued requests into one ingest changes the schedule, not
the fixpoint (``tests/test_serving.py`` pins coalesced == per-arrival
differentially).

Admission control bounds the queue: at most ``ServingConfig.max_queue``
requests may be waiting.  Past that, policy ``"block"`` makes
``submit`` wait for drain (backpressure propagates to the producer)
while ``"reject"`` sheds the request immediately with
:class:`AdmissionError` (counted in ``serve.admission.shed``).

Thread-safety contract:

* ``submit`` / ``drain`` / ``close`` — safe from any number of
  producer threads (one shared mutex + condvars around the queue).
* The worker thread is the **only** caller of
  ``ResolveService.ingest`` — the single-writer regime the service
  requires — and the only id allocator, so auto-assigned ids are
  race-free.
* Reads (``resolve`` / ``resolve_many`` / ``snapshot``) delegate to
  the service's lock-free published-snapshot path: they never block on
  queued or in-flight ingests.

**Graceful degradation** (the failure half of ``docs/SERVING.md``): a
flush that raises is retried up to ``ServingConfig.max_retries`` times
with capped exponential backoff (``backoff_base_ms`` doubling up to
``backoff_max_ms``) — the service's transactional ingest guarantees a
failed attempt left no state behind, so a retry is safe by
construction.  A batch that still fails is **bisected**: each half
retries independently, recursively, until the failure is isolated to a
single request, which is quarantined (its ticket fails with the
original error) while every innocent co-batched ticket commits.  Ids
are assigned per attempt from a local cursor and committed only on
success, so an aborted flush never burns id space or mutates tickets.

Observability (the ``serve.*`` families, catalogued in
``docs/ARCHITECTURE.md``): gauge ``serve.queue.depth``; histograms
``serve.batch.coalesced_size`` / ``serve.batch.requests`` /
``serve.queue.wait_ms`` / ``serve.backoff_ms``; counters
``serve.requests``, ``serve.entities``, ``serve.batches``,
``serve.admission.shed``, ``serve.errors``, ``serve.retries``,
``serve.quarantined``, ``serve.faults.flush``,
``serve.faults.bisections``; span ``serve.coalesce`` wrapping each
flush (the ``ingest`` span nests inside it).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from repro.obs import get_registry
from repro.obs import span as obs_span
from repro.stream.service import IngestReport, ResolveService, ResolveSnapshot


class AdmissionError(RuntimeError):
    """Request shed by admission control (queue at ``max_queue`` under
    the ``"reject"`` policy, or a ``"block"`` wait that timed out)."""


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Knobs of the coalescing front-end (see ``docs/SERVING.md``).

    The defaults favor throughput: a flush waits up to ``max_delay_ms``
    for the batch to fill.  Latency-sensitive deployments shrink
    ``max_delay_ms`` (0 flushes whatever is queued immediately);
    memory/overload-sensitive ones shrink ``max_queue`` and pick the
    ``"reject"`` policy so producers fail fast instead of stacking up.
    """

    # coalescing size budget: flush once this many entities are batched
    # (a single larger request still flushes alone, never split)
    max_batch: int = 64
    # coalescing latency budget in milliseconds, measured from the
    # enqueue of the *oldest* request in the forming batch; 0 = flush
    # immediately with whatever is already queued
    max_delay_ms: float = 2.0
    # admission bound: maximum queued (not yet ingesting) requests
    max_queue: int = 1024
    # "block": submit waits for queue space (backpressure);
    # "reject": submit raises AdmissionError immediately (shed)
    admission: str = "block"
    # degradation: a failed flush retries this many times (per batch or
    # bisected sub-batch) before the bisection/quarantine path takes over
    max_retries: int = 2
    # backoff before retry attempt k: min(backoff_max_ms,
    # backoff_base_ms * 2**(k-1)) milliseconds
    backoff_base_ms: float = 1.0
    backoff_max_ms: float = 50.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_delay_ms < 0:
            raise ValueError("max_delay_ms must be >= 0")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.admission not in ("block", "reject"):
            raise ValueError(
                f"admission must be block|reject, got {self.admission!r}"
            )
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_base_ms < 0 or self.backoff_max_ms < 0:
            raise ValueError("backoff budgets must be >= 0")


class IngestTicket:
    """Handle for one submitted request (future-like).

    ``wait`` blocks until the coalesced ingest containing this request
    commits, then returns the shared :class:`IngestReport` (or raises
    the ingest's exception).  ``ids`` are the global entity ids this
    request's names received — explicit ones echoed back, auto-assigned
    ones filled in at flush time.  All methods are thread-safe.
    """

    __slots__ = ("names", "edges", "ids", "t_enq", "_done", "_report", "_error")

    def __init__(self, names, edges, ids):
        self.names = names
        self.edges = edges
        self.ids = ids
        self.t_enq = time.perf_counter()
        self._done = threading.Event()
        self._report: IngestReport | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: float | None = None) -> IngestReport:
        if not self._done.wait(timeout):
            raise TimeoutError("ingest not committed within timeout")
        if self._error is not None:
            raise self._error
        assert self._report is not None
        return self._report

    # -- worker side ------------------------------------------------------

    def _resolve(self, report: IngestReport) -> None:
        self._report = report
        self._done.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._done.set()


class ServingFrontend:
    """Async ingest queue + coalescer in front of a ``ResolveService``.

    One instance owns one service: the frontend's worker thread must be
    the only ingester (it allocates the auto-assigned entity ids).  Use
    as a context manager, or call :meth:`close` to flush and stop::

        svc = ResolveService(scheme="smp")
        with ServingFrontend(svc, ServingConfig(max_batch=64)) as fe:
            t = fe.submit(["john smith", "j. smith"])
            t.wait()                      # until the coalesced commit
            fe.resolve(0)                 # lock-free committed read
    """

    def __init__(
        self,
        service: ResolveService,
        config: ServingConfig | None = None,
        *,
        start: bool = True,
    ):
        self.service = service
        self.cfg = config if config is not None else ServingConfig()
        self._q: deque[IngestTicket] = deque()
        self._mu = threading.Lock()
        self._not_empty = threading.Condition(self._mu)
        self._not_full = threading.Condition(self._mu)
        self._idle = threading.Condition(self._mu)
        self._closed = False
        self._busy = False  # worker holds an un-committed batch
        self._worker: threading.Thread | None = None
        # the worker is the only id allocator; seed past anything the
        # service has already ingested
        self._next_id = len(service.delta.names)
        self._reg = get_registry()
        if start:
            self.start()

    # -- lifecycle --------------------------------------------------------

    def start(self) -> None:
        """Start the worker thread (idempotent).  Safe to construct
        with ``start=False``, pre-fill the queue, then start — tests
        and benchmarks use that for deterministic coalescing."""
        with self._mu:
            if self._worker is not None or self._closed:
                return
            self._worker = threading.Thread(
                target=self._run, name="serving-frontend", daemon=True
            )
            self._worker.start()

    def close(self, timeout: float | None = None) -> None:
        """Flush everything queued, then stop the worker.  Subsequent
        ``submit`` calls raise; reads keep working (the service
        outlives its frontend)."""
        with self._mu:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()
            w = self._worker
            orphans: list[IngestTicket] = []
            if w is None:  # never started: nobody will flush the queue
                orphans = list(self._q)
                self._q.clear()
        for t in orphans:
            t._fail(RuntimeError("frontend closed before it was started"))
        if w is not None:
            w.join(timeout)

    def __enter__(self) -> "ServingFrontend":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- producer side ----------------------------------------------------

    def submit(
        self,
        names: list[str],
        edges: np.ndarray | None = None,
        ids: list[int] | None = None,
        *,
        timeout: float | None = None,
    ) -> IngestTicket:
        """Enqueue one arrival for coalesced ingest; returns immediately
        with a ticket (call ``ticket.wait()`` for the commit).

        Safe from any number of producer threads.  When the queue is at
        ``max_queue``: policy ``"reject"`` raises :class:`AdmissionError`
        at once (counted in ``serve.admission.shed``); policy
        ``"block"`` waits for space — bounded by ``timeout`` seconds if
        given, shedding on expiry.
        """
        ticket = IngestTicket(list(names), edges, ids)
        with self._mu:
            if self._closed:
                raise RuntimeError("frontend is closed")
            if len(self._q) >= self.cfg.max_queue:
                if self.cfg.admission == "reject":
                    self._reg.counter("serve.admission.shed").inc()
                    # keep the gauge honest on the shed path too — the
                    # queue didn't change, but the sample is fresh
                    self._reg.gauge("serve.queue.depth").set(len(self._q))
                    raise AdmissionError(
                        f"queue at max_queue={self.cfg.max_queue}, "
                        "request shed"
                    )
                deadline = (
                    None if timeout is None else time.monotonic() + timeout
                )
                while len(self._q) >= self.cfg.max_queue:
                    if self._closed:
                        raise RuntimeError("frontend is closed")
                    remaining = (
                        None if deadline is None
                        else deadline - time.monotonic()
                    )
                    if remaining is not None and remaining <= 0:
                        self._reg.counter("serve.admission.shed").inc()
                        self._reg.gauge("serve.queue.depth").set(len(self._q))
                        raise AdmissionError(
                            "blocked submit timed out waiting for queue "
                            "space, request shed"
                        )
                    self._not_full.wait(remaining)
            self._q.append(ticket)
            self._reg.counter("serve.requests").inc()
            self._reg.counter("serve.entities").inc(len(ticket.names))
            self._reg.gauge("serve.queue.depth").set(len(self._q))
            self._not_empty.notify()
        return ticket

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every request submitted so far has committed
        (queue empty and no batch in flight).  Returns False on
        timeout.  Producer-side convenience for benchmarks/tests."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._mu:
            while self._q or self._busy:
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    return False
                self._idle.wait(remaining)
            return True

    # -- read side (lock-free, delegates to the published snapshot) -------

    def resolve(self, entity_id: int) -> np.ndarray:
        """Lock-free committed read (see ``ResolveService.resolve``);
        never waits on queued or in-flight ingests."""
        return self.service.resolve(entity_id)

    def resolve_many(self, entity_ids) -> list[np.ndarray]:
        """Lock-free batched committed read; never waits on ingests."""
        return self.service.resolve_many(entity_ids)

    def snapshot(self) -> ResolveSnapshot:
        """The service's current published snapshot (lock-free)."""
        return self.service.snapshot()

    # -- worker side ------------------------------------------------------

    def _run(self) -> None:
        while True:
            batch = self._collect()
            if batch is None:
                return
            self._flush(batch)
            with self._mu:
                self._busy = False
                self._idle.notify_all()

    def _collect(self) -> list[IngestTicket] | None:
        """Form one coalesced batch: block for the first request, then
        accumulate until the size budget fills or the latency budget
        (from the oldest request's enqueue) expires.  Returns None when
        closed and fully drained."""
        with self._mu:
            while not self._q:
                if self._closed:
                    self._idle.notify_all()
                    return None
                self._not_empty.wait()
            self._busy = True
            first = self._q.popleft()
            batch = [first]
            n = len(first.names)
            deadline = first.t_enq + self.cfg.max_delay_ms / 1e3
            while n < self.cfg.max_batch:
                if self._q:
                    nxt = self._q[0]
                    if n and n + len(nxt.names) > self.cfg.max_batch:
                        break  # requests are never split across batches
                    self._q.popleft()
                    batch.append(nxt)
                    n += len(nxt.names)
                    continue
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or self._closed:
                    break
                # wake on new arrivals; the loop re-checks budget/queue
                self._not_empty.wait(remaining)
            self._reg.gauge("serve.queue.depth").set(len(self._q))
            self._not_full.notify_all()
        return batch

    def _plan_ids(
        self, batch: list[IngestTicket], cursor: int
    ) -> tuple[list[list[int]], list[int], int]:
        """Plan the batch's id assignment from a *local* cursor, queue
        order preserved, without touching ticket or frontend state —
        ``self._next_id`` and ``ticket.ids`` commit only after the
        ingest succeeds, so an aborted flush neither burns id space nor
        leaves tickets claiming ids their names never received."""
        per: list[list[int]] = []
        out: list[int] = []
        for t in batch:
            if t.ids is None:
                tids = list(range(cursor, cursor + len(t.names)))
            else:
                tids = [int(i) for i in t.ids]
            if tids:
                cursor = max(cursor, max(tids) + 1)
            per.append(tids)
            out.extend(tids)
        return per, out, cursor

    def _ingest_once(self, batch: list[IngestTicket]) -> IngestReport:
        """One ingest attempt; commits the id assignment on success."""
        per, ids, cursor = self._plan_ids(batch, self._next_id)
        names = [nm for t in batch for nm in t.names]
        edge_arrays = [
            np.asarray(t.edges, dtype=np.int64)
            for t in batch
            if t.edges is not None and len(t.edges)
        ]
        edges = np.vstack(edge_arrays) if edge_arrays else None
        report = self.service.ingest(names, edges, ids=ids)
        self._next_id = cursor
        for t, tids in zip(batch, per):
            t.ids = tids
        return report

    def _try_ingest(
        self, batch: list[IngestTicket]
    ) -> BaseException | None:
        """Ingest ``batch`` with capped-exponential-backoff retries.
        Settles every ticket and returns None on success; returns the
        last error once ``max_retries`` retries are exhausted (a retry
        is always safe: the transactional ingest rolled the failed
        attempt back completely)."""
        last: BaseException | None = None
        for attempt in range(self.cfg.max_retries + 1):
            if attempt:
                delay_ms = min(
                    self.cfg.backoff_max_ms,
                    self.cfg.backoff_base_ms * 2 ** (attempt - 1),
                )
                self._reg.counter("serve.retries").inc()
                self._reg.histogram("serve.backoff_ms").observe(delay_ms)
                time.sleep(delay_ms / 1e3)
            try:
                report = self._ingest_once(batch)
            except BaseException as err:
                self._reg.counter("serve.faults.flush").inc()
                last = err
                continue
            self._reg.counter("serve.batches").inc()
            self._reg.histogram("serve.batch.coalesced_size").observe(
                sum(len(t.names) for t in batch)
            )
            self._reg.histogram("serve.batch.requests").observe(len(batch))
            for t in batch:
                t._resolve(report)
            return None
        return last

    def _settle(self, batch: list[IngestTicket]) -> None:
        """Commit ``batch``, degrading gracefully: retry, then bisect a
        still-failing batch so the poisoned request is isolated down to
        a singleton and quarantined (ticket fails with the original
        error) while innocent co-batched tickets commit.  Coalescing is
        a schedule change only (service invariant), so splitting a
        batch never changes the fixpoint the survivors reach."""
        err = self._try_ingest(batch)
        if err is None:
            return
        if len(batch) == 1:
            self._reg.counter("serve.quarantined").inc()
            self._reg.counter("serve.errors").inc()
            batch[0]._fail(err)
            return
        self._reg.counter("serve.faults.bisections").inc()
        mid = len(batch) // 2
        self._settle(batch[:mid])
        self._settle(batch[mid:])

    def _flush(self, batch: list[IngestTicket]) -> None:
        """Run one coalesced ingest and settle every ticket in it."""
        n_entities = sum(len(t.names) for t in batch)
        t_flush = time.perf_counter()
        for t in batch:
            self._reg.histogram("serve.queue.wait_ms").observe(
                (t_flush - t.t_enq) * 1e3
            )
        with obs_span(
            "serve.coalesce", requests=len(batch), entities=n_entities
        ):
            self._settle(batch)
