"""Incremental message-passing engine: dirty-seeded fixpoint advance.

Each ingest hands the engine a freshly maintained ``PackedCover`` and
the dirty-neighborhood set; the engine re-enters the batch drivers
(``core.driver`` / ``core.parallel``) through their partial-worklist
hooks, warm-starting from the previous fixpoint:

* the worklist is seeded with *only* the dirty neighborhoods — clean
  neighborhoods re-enter solely through evidence-driven re-activation
  (``neighborhoods_of_pairs``), exactly as in Algorithm 1/3;
* ``M+`` starts from the carried previous fixpoint (the matcher is
  monotone in entities and evidence, so previous matches remain valid
  as the instance grows — the continuation computes the least fixpoint
  above them, which by Thm. 2/4 equals the from-scratch fixpoint);
* for MMP the maximal-message pool persists across ingests, and step-7
  promotion re-checks every stored group against the current global
  grounding — the "replay of the affected slice" of the pool;
* the parallel engine additionally persists a device
  :class:`~repro.core.parallel.GroundingCache` across ingests: bins the
  cover delta left untouched keep their grounded arrays on device, and
  dirty bins splice in only the changed rows via
  :meth:`~repro.core.parallel.GroundingCache.splice` (``AdvanceStats.
  reground_rows`` counts them — the grounding analogue of
  ``IngestReport.replay_visits``).  The row keys driving the signature
  diff come straight from the :class:`~repro.core.cover.CoverDelta`
  splice (``PackedCover.row_keys``), so an ingest's device re-grounding
  is bounded by the very rows the cover splice staged.

Carried matches are *invalidated* when a cover delta retracts their
candidate pair (possible when an oversized canopy re-splits): the whole
match-graph component is dropped and every neighborhood touching it is
marked dirty, so the affected region is re-derived from scratch rather
than trusting evidence that may no longer be derivable.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import pairs as pairlib, txn
from repro.core.closure import clusters_of
from repro.core.cover import PackedCover
from repro.core.driver import EMResult, MessagePool, run_mmp, run_smp
from repro.core.global_grounding import GlobalGrounding
from repro.core.types import MatchStore
from repro.obs import span as obs_span


@dataclasses.dataclass
class AdvanceStats:
    result: EMResult
    n_dirty: int
    n_invalidated: int
    reground_rows: int = 0  # neighborhood rows re-ground on device (parallel)


class IncrementalEngine:
    """Dirty-seeded fixpoint advance over a maintained cover.

    Thread-safety contract: the engine is **single-writer, no-reader**
    state.  ``advance`` mutates the persistent fixpoint (``m_plus``),
    the MMP message pool, and the device grounding cache with no
    internal locking — it must only ever be called by the one thread
    that owns the ingest path (``ResolveService.ingest``, itself driven
    by the single ``ServingFrontend`` worker under load).  Concurrent
    *readers* never touch this object: they read the service's
    published :class:`~repro.stream.service.ResolveSnapshot`, which is
    frozen from ``m_plus`` only inside the ingest commit.
    """

    def __init__(
        self,
        matcher,
        *,
        scheme: str = "smp",
        parallel: bool = False,
        mesh=None,
        gcache_capacity: int | None = None,
        gcache_hbm_budget: int | None = None,
    ):
        if scheme not in ("smp", "mmp"):
            raise ValueError(f"streaming scheme must be smp|mmp, got {scheme!r}")
        self.matcher = matcher
        self.scheme = scheme
        self.parallel = parallel
        # Explicit mesh for the parallel drivers (sharded serving hands
        # the cross-process service mesh here); None keeps the default
        # all-local-devices mesh run_parallel builds itself.
        self.mesh = mesh
        self.m_plus = MatchStore()
        self.pool = MessagePool()
        # Persistent device grounding cache (parallel engine only):
        # clean bins keep their grounded arrays on device across
        # ingests; dirty bins splice in only the changed rows.  Created
        # lazily so the sequential engine never imports the mesh stack.
        # ``gcache_capacity`` / ``gcache_hbm_budget`` bound the cache's
        # resident device memory (LRU over bins: cold bins drop their
        # grounded tensors and re-ground on demand, bit-for-bit).
        self.gcache = None
        self.gcache_capacity = gcache_capacity
        self.gcache_hbm_budget = gcache_hbm_budget
        self.total_evals = 0
        self.total_rounds = 0
        self.total_dispatches = 0

    def _invalidate(
        self, packed: PackedCover, dirty: set[int]
    ) -> tuple[MatchStore, set[int], int]:
        """Drop carried matches whose pair left the candidate set.

        Retraction is component-granular: evidence flows inside match
        components, so everything a stale pair could have influenced is
        re-derived.  Returns (carried matches, grown dirty set, #dropped).
        """
        cand = packed.pair_levels
        stale = [g for g in self.m_plus.gids if int(g) not in cand]
        if not stale:
            return self.m_plus, dirty, 0
        bad: set[int] = set()
        stale_set = {int(g) for g in stale}
        for comp in clusters_of(self.m_plus):
            cset = {int(x) for x in comp}
            for g in stale_set:
                a, b = pairlib.split_gid(np.int64(g))
                if int(a) in cset:
                    bad |= cset
                    break
        keep = [
            int(g)
            for g in self.m_plus.gids
            if int(pairlib.split_gid(np.int64(g))[0]) not in bad
        ]
        # per-entity query against the splice-maintained incidence
        # lookup — no per-ingest Cover.entity_index() rebuild
        dirty |= packed.neighborhoods_of_entities(bad)
        carried = MatchStore(np.asarray(keep, dtype=np.int64))
        return carried, dirty, len(self.m_plus) - len(carried)

    def advance(
        self,
        packed: PackedCover,
        dirty: list[int],
        gg: GlobalGrounding | None = None,
        *,
        retracted=None,
    ) -> AdvanceStats:
        """Advance the fixpoint over a freshly maintained cover.

        ``gg`` (MMP only) is the *incrementally maintained* global
        grounding — the service patches it via
        ``GroundingMaintainer.apply_delta`` instead of rebuilding it per
        ingest.  ``retracted`` lists the candidate gids the cover delta
        dropped; they are pruned from the persistent message pool so
        stale groups stop being replayed at every promotion pass.

        Not thread-safe: one in-flight call at a time, from the thread
        that owns the ingest path (see the class docstring).
        """
        t = txn.active()
        if t is not None:
            # pool mutations are journaled entry-wise inside MessagePool;
            # the engine's own carried state is plain attribute rebinds
            for a in ("m_plus", "gcache", "total_evals", "total_rounds",
                      "total_dispatches"):
                t.save_attr(self, a)
        if retracted and self.scheme == "mmp":
            self.pool.discard(retracted)
        carried, dirty_set, dropped = self._invalidate(packed, set(dirty))
        order = sorted(dirty_set)
        rows_before = 0
        with obs_span("ingest.rounds", dirty=len(order)):
            if self.parallel:
                from repro.core.parallel import GroundingCache, run_parallel

                if self.gcache is None:
                    self.gcache = GroundingCache(
                        capacity=self.gcache_capacity,
                        hbm_budget_bytes=self.gcache_hbm_budget,
                    )
                if t is not None:
                    self.gcache.journal_rollback(t)
                rows_before = self.gcache.rows_ground
                result = run_parallel(
                    packed,
                    self.matcher,
                    gg,
                    scheme=self.scheme,
                    mesh=self.mesh,
                    active=order,
                    init_matches=carried,
                    pool=self.pool if self.scheme == "mmp" else None,
                    gcache=self.gcache,
                )
            elif self.scheme == "smp":
                result = run_smp(
                    packed, self.matcher, order, init_matches=carried
                )
            else:
                assert gg is not None, "mmp needs the global grounding"
                result = run_mmp(
                    packed,
                    self.matcher,
                    gg,
                    order,
                    init_matches=carried,
                    pool=self.pool,
                )
        self.m_plus = result.matches
        self.total_evals += result.neighborhood_evals
        self.total_rounds += result.rounds
        self.total_dispatches += result.dispatches
        reground = (
            self.gcache.rows_ground - rows_before if self.parallel else 0
        )
        return AdvanceStats(
            result=result,
            n_dirty=len(order),
            n_invalidated=dropped,
            reground_rows=reground,
        )
