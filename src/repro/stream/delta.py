"""Delta cover maintenance: arriving batches -> dirty neighborhoods.

The batch cover (``core.cover``) is a deterministic function of the
entity set: canopies seeded in id order, split, boundary-expanded, and
swept for totality.  This module maintains *exactly that cover* under
streaming arrivals without recomputing the O(n^2) similarity structure:

1. **Probe** — the MinHash-LSH index proposes candidate partners for
   each arrival; exact cosine similarities are computed on-device (the
   ``ngram_sim`` Pallas kernel) only for the probed rectangle, and
   entries >= ``t_loose`` are inserted into a sparse similarity graph.
   All intra-batch pairs are probed exactly, so within a micro-batch
   LSH recall does not matter.
2. **Replay** — the canonical canopy sweep (id order, t_tight seed
   suppression — the exact loop of ``build_canopies``) is replayed over
   the sparse graph: cheap host set-ops, no kernel work.  Because the
   sweep is a pure function of the similarity graph, arrival order
   cannot change the result (ingest-order invariance), and because new
   entities get fresh ids, old seeds keep their canopies and only gain
   members.

   The replay is *localized*: suppression and membership only propagate
   along similarity edges, so the sweep decomposes exactly over the
   connected components of the sparse graph.  Each ingest expands a
   frontier from the LSH-touched seeds (the arrivals plus every
   existing entity that gained a similarity edge) to the union of their
   components, re-sweeps only that region, and reuses cached canopies
   for every untouched component — O(region), not O(n), per ingest
   (``last_replay_visits`` counts the region; the tests assert both the
   bit-for-bit equality with the full sweep and the locality bound).
3. **Assemble + splice** — ``core.cover.CoverDelta`` (via the
   ``delta=`` path of ``assemble_cover``/``pack_cover``) re-derives
   only the dirty slice of the cover: canopy parts are memoized per
   seed and recomputed only when a member was touched, the totality
   sweep (Def. 7) maintains per-edge cover counts instead of
   re-scanning every neighborhood, and the packed per-bin arrays are
   *spliced* — unchanged bins are reused wholesale, appended-to bins
   concatenate the fresh tail, and only genuinely new rows are staged
   (``DeltaResult.cover_splice_rows`` counts them, asserted O(dirty)
   by the tests).  Bit-for-bit equal to the scratch
   ``assemble_cover`` + ``pack_cover`` at every ingest.

The **dirty set** returned to the engine is exactly the neighborhoods
whose row key ``(bin, members, intra-relation edges)`` is new this
ingest: membership growth, boundary change, or a new
intra-neighborhood relation tuple all change the key, and an unchanged
key means identical tensors — evaluating such a neighborhood under
unchanged evidence reproduces its old output (idempotence), so
skipping it cannot lose matches.

Exactness caveat: equality with the batch cover needs the sparse graph
to contain every >= t_loose pair, i.e. LSH recall 1 at t_loose.  The
default banding puts the collision S-curve knee far below t_loose, and
the streaming tests assert cover equality outright.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import faults
from repro.core import similarity as simlib, txn
from repro.core.cover import (
    DEFAULT_BINS,
    Cover,
    CoverDelta,
    PackedCover,
)
from repro.core.types import EntityTable, Relations
from repro.kernels.ngram_sim import ops as sim_ops
from repro.obs import span as obs_span
from repro.stream.index import LSHConfig, MinHashLSHIndex


@dataclasses.dataclass
class DeltaResult:
    cover: Cover
    packed: PackedCover
    dirty: list[int]  # neighborhood indices whose row key is new
    # candidate-pair delta vs the previous cover — the exact input the
    # incremental grounding maintainer consumes (gid -> level / gids):
    added_pairs: dict[int, int] = dataclasses.field(default_factory=dict)
    retracted_pairs: list[int] = dataclasses.field(default_factory=list)
    new_edges: np.ndarray | None = None  # this ingest's relation tuples
    replay_visits: int = 0  # ids swept by the localized canopy replay
    cover_splice_rows: int = 0  # neighborhood rows (re)staged by the splice


class DeltaCover:
    """Incrementally maintained total cover over a growing entity set."""

    def __init__(
        self,
        *,
        t_loose: float = 0.70,
        t_tight: float = 0.90,
        k_max: int = 32,
        feature_dim: int = 128,
        k_bins: tuple[int, ...] = DEFAULT_BINS,
        thresholds=None,
        boundary_relation: str = "coauthor",
        lsh: LSHConfig | None = None,
        level_cache_max: int | None = None,
        shard=None,
        shard_merge=None,
    ):
        self.t_loose = t_loose
        self.t_tight = t_tight
        self.k_max = k_max
        self.feature_dim = feature_dim
        self.k_bins = k_bins
        self.thresholds = thresholds or simlib.DEFAULT_THRESHOLDS
        self.boundary_relation = boundary_relation
        self.index = MinHashLSHIndex(lsh, shard=shard, merge=shard_merge)

        self.names: list[str | None] = []  # id -> name (None = hole)
        self.present: set[int] = set()
        self.features = np.zeros((0, feature_dim), dtype=np.float32)
        self.edge_chunks: list[np.ndarray] = []
        # sparse similarity graph: only entries >= t_loose are kept
        self.sim_adj: dict[int, dict[int, float]] = {}
        # persistent packing caches (see pack_cover)
        self.level_cache: dict[int, int] = {}
        # cap on the Jaro-Winkler level memo: eviction is safe (a miss
        # recomputes the level from the name-static strings), so a
        # long-lived service can bound this without losing exactness.
        self.level_cache_max = level_cache_max
        # incremental cover assembly + packed splice state (core.cover):
        # re-derives only the touched slice of the cover per ingest and
        # splices the packed arrays instead of re-staging every row.
        self.cover_delta = CoverDelta(
            k_max=k_max,
            k_bins=k_bins,
            thresholds=self.thresholds,
            boundary_relation=boundary_relation,
        )
        # localized-replay state: seed id -> canopy members, plus the
        # visit counters the O(dirty) tests/benchmarks read.
        self._canopy_cache: dict[int, np.ndarray] = {}
        self._last_region: set[int] = set()
        self.last_replay_visits = 0
        self.total_replay_visits = 0

        self.cover: Cover | None = None
        self.packed: PackedCover | None = None

    # -- growing state ----------------------------------------------------

    @property
    def n_entities(self) -> int:
        return len(self.present)

    @property
    def total_splice_rows(self) -> int:
        """Cumulative neighborhood rows (re)staged by the cover splice."""
        return self.cover_delta.total_splice_rows

    def entities(self) -> EntityTable:
        return EntityTable(names=list(self.names), features=self.features)

    def relations(self) -> Relations:
        if not self.edge_chunks:
            edges = np.zeros((0, 2), dtype=np.int64)
        else:
            edges = np.concatenate(self.edge_chunks, axis=0)
        return Relations(edges={self.boundary_relation: edges})

    def _grow(self, ids: list[int], names: list[str]) -> None:
        if not ids:
            return
        t = txn.active()
        hi = max(ids) + 1
        grown = hi > len(self.names)
        if t is not None:
            t.save_len(self.names)
            # growth rebinds ``features`` to a fresh concatenation (the
            # old buffer is never written again), so the ref suffices;
            # hole-fill writes into an unchanged buffer journal rows
            t.save_attr(self, "features")
        if grown:
            self.names.extend([None] * (hi - len(self.names)))
            pad = np.zeros((hi - len(self.features), self.feature_dim), np.float32)
            self.features = np.concatenate([self.features, pad])
        feats = simlib.ngram_profiles(
            [simlib.block_key(n) for n in names], dim=self.feature_dim
        )
        for eid, name, f in zip(ids, names, feats):
            if self.names[eid] is not None:
                # mid-loop failure: earlier iterations already wrote —
                # the journal is what makes this raise leave no trace
                raise ValueError(f"entity id {eid} ingested twice")
            if t is not None:
                t.save_item(self.names, eid)
                if not grown:
                    t.save_row(self.features, eid)
                t.set_add(self.present, eid)
                self.names[eid] = name
                self.features[eid] = f
            else:
                self.names[eid] = name
                self.features[eid] = f
                self.present.add(eid)

    # -- probe ------------------------------------------------------------

    def _probe(self, ids: list[int], names: list[str]) -> set[int]:
        """LSH-gated exact similarity probes.

        Returns the set of ids whose similarity adjacency changed — the
        arrivals plus every existing entity that gained an edge — which
        seeds the localized canopy replay's frontier expansion.
        """
        sigs = self.index.add(ids, names)
        # LSH collisions plus the batch itself: intra-batch similarity is
        # always exact, so a service ingesting everything in one batch
        # reproduces build_canopies regardless of banding parameters.
        cands = sorted(self.index.query(sigs) | set(ids))
        touched = set(ids)
        if not cands:
            return touched
        q = self.features[np.asarray(ids, dtype=np.int64)]
        p = self.features[np.asarray(cands, dtype=np.int64)]
        sims = np.asarray(sim_ops.sim_above(q, p, 0.0))
        t = txn.active()
        for r, a in enumerate(ids):
            row = sims[r]
            for c in np.where(row >= self.t_loose)[0]:
                b = cands[int(c)]
                if b == a:
                    continue
                s = float(row[int(c)])
                if t is not None:
                    t.save_key(self.sim_adj, a, copy=dict)
                    t.save_key(self.sim_adj, b, copy=dict)
                self.sim_adj.setdefault(a, {})[b] = s
                self.sim_adj.setdefault(b, {})[a] = s
                touched.add(b)
        return touched

    # -- replay -----------------------------------------------------------

    def _replay_region(self, touched: set[int]) -> set[int]:
        """Frontier expansion: close the touched ids over the sparse
        similarity graph.  Suppression and membership only propagate
        along similarity edges, so the union of the touched connected
        components is exactly the slice of the sweep that can change."""
        region: set[int] = set()
        stack = [e for e in touched if e in self.present]
        while stack:
            e = stack.pop()
            if e in region:
                continue
            region.add(e)
            stack.extend(o for o in self.sim_adj.get(e, ()) if o not in region)
        return region

    def _canopies(self, touched: set[int]) -> list[np.ndarray]:
        """Localized canonical canopy sweep.

        Re-sweeps only the connected region of the touched ids (exactly
        ``build_canopies`` restricted to it: seeds in ascending id
        order, every >= t_loose partner a member, >= t_tight partners
        suppressed as seeds) and reuses cached canopies everywhere else.
        Bit-for-bit equal to the full sweep (``_canopies_full``) because
        the sweep decomposes over similarity components — O(region)
        set-ops per ingest instead of O(n).
        """
        region = self._replay_region(touched)
        t = txn.active()
        if t is not None:
            t.save_attr(self, "_last_region")
            t.save_attr(self, "last_replay_visits")
            t.save_attr(self, "total_replay_visits")
        self._last_region = region
        self.last_replay_visits = len(region)
        self.total_replay_visits += len(region)
        for seed in region:
            if t is not None:
                t.save_key(self._canopy_cache, seed)
            self._canopy_cache.pop(seed, None)
        suppressed: set[int] = set()
        for e in sorted(region):
            if e in suppressed:
                continue
            nbrs = self.sim_adj.get(e, {})
            if t is not None:
                t.save_key(self._canopy_cache, e)
            self._canopy_cache[e] = np.asarray(
                sorted({e} | set(nbrs)), dtype=np.int64
            )
            for o, s in nbrs.items():
                if s >= self.t_tight:
                    suppressed.add(o)
        return [self._canopy_cache[s] for s in sorted(self._canopy_cache)]

    def canopies(self) -> list[np.ndarray]:
        """Current canopies (seed-id order), from the replay cache."""
        return [self._canopy_cache[s] for s in sorted(self._canopy_cache)]

    def _canopies_full(self) -> list[np.ndarray]:
        """Reference full-id sweep (the pre-localization loop); kept for
        the equality tests proving the replayed slice reproduces it."""
        suppressed: set[int] = set()
        out: list[np.ndarray] = []
        for e in sorted(self.present):
            if e in suppressed:
                continue
            nbrs = self.sim_adj.get(e, {})
            members = np.asarray(sorted({e} | set(nbrs)), dtype=np.int64)
            out.append(members)
            for o, s in nbrs.items():
                if s >= self.t_tight:
                    suppressed.add(o)
        return out

    # -- ingest -----------------------------------------------------------

    def ingest(
        self,
        ids: list[int],
        names: list[str],
        edges: np.ndarray | None = None,
    ) -> DeltaResult:
        if len(ids) != len(names):
            raise ValueError(f"{len(ids)} ids for {len(names)} names")
        if edges is not None and len(edges):
            edges = np.asarray(edges, dtype=np.int64)
            if np.any(edges[:, 0] == edges[:, 1]):
                # A self-loop carries no pairwise evidence but *would*
                # perturb the batch grounding's common-neighbor counts
                # (adjacency_sets puts i in adj(i)); rejecting it keeps
                # the stream == batch equality contract honest instead
                # of silently diverging.
                raise ValueError("self-loop relation edges are not allowed")
            unknown = sorted(
                {int(e) for e in edges.reshape(-1)} - self.present - set(ids)
            )
            if unknown:
                raise ValueError(
                    f"relation edges reference entities never ingested: "
                    f"{unknown[:5]}{'...' if len(unknown) > 5 else ''}"
                )
        else:
            edges = None
        t = txn.active()
        self._grow(ids, names)
        if edges is not None:
            if t is not None:
                t.save_len(self.edge_chunks)
            self.edge_chunks.append(edges)
        faults.maybe_fail("lsh", names)
        with obs_span("ingest.lsh", batch=len(ids)):
            touched = self._probe(ids, names) if ids else set()

        faults.maybe_fail("replay", names)
        with obs_span("ingest.replay", touched=len(touched)):
            canopies = self._canopies(touched)
        seeds = sorted(self._canopy_cache)
        # the cover-delta's dirt set: the re-swept similarity region plus
        # every endpoint of this ingest's relation edges (boundary
        # expansion and intra-edge row keys read members' adjacency)
        assembly_touched = set(self._last_region)
        if edges is not None and len(edges):
            assembly_touched.update(int(e) for e in edges.reshape(-1))
        # Drive the incremental CoverDelta directly: it maintains the
        # boundary adjacency from new_edges itself (no per-ingest O(E)
        # Relations rebuild) and only reads entity *names*, so the live
        # name list is passed without the O(n) copy of entities().
        faults.maybe_fail("cover_splice", names)
        with obs_span("ingest.cover_splice"):
            cover = self.cover_delta.assemble(
                canopies,
                seeds,
                EntityTable(names=self.names, features=self.features),
                present=self.present,
                touched=assembly_touched,
                new_ids=ids,
                new_edges=edges,
            )
            packed = self.cover_delta.pack(
                cover, prev=self.packed, level_cache=self.level_cache
            )

        # Bound the Jaro-Winkler level memo (oldest-inserted first; pure
        # memo, so eviction never changes the cover or the fixpoint).
        if self.level_cache_max is not None:
            while len(self.level_cache) > self.level_cache_max:
                k = next(iter(self.level_cache))
                if t is not None:
                    t.save_key(self.level_cache, k)
                self.level_cache.pop(k)
        if t is not None:
            t.save_attr(self, "cover")
            t.save_attr(self, "packed")
        self.cover, self.packed = cover, packed
        return DeltaResult(
            cover=cover,
            packed=packed,
            dirty=self.cover_delta.last_dirty,
            added_pairs=self.cover_delta.last_added_pairs,
            retracted_pairs=self.cover_delta.last_retracted_pairs,
            new_edges=edges,
            replay_visits=self.last_replay_visits,
            cover_splice_rows=self.cover_delta.last_splice_rows,
        )
