"""Streaming incremental entity matching.

The batch pipeline (``repro.core.pipeline``) builds a total cover once
and runs message passing to a global fixpoint.  This package keeps that
fixpoint *current* under a stream of arriving entities, with per-ingest
cost proportional to the dirty set rather than the corpus:

* :mod:`repro.stream.index` — incremental MinHash-LSH blocking index
  (signatures computed on-device by the ``minhash`` Pallas kernel),
  optionally memory-bounded via ``LSHConfig.max_ids`` / ``ttl_adds``;
* :mod:`repro.stream.delta` — delta cover maintenance: localized canopy
  replay over the touched similarity components, dirty-neighborhood
  diffing, repacking only the affected bins, preserving totality
  (Def. 7);
* :mod:`repro.stream.engine` — incremental driver seeding the batch
  drivers' worklists with only the dirty neighborhoods and patching the
  persistent MMP message pool on candidate retraction;
* :mod:`repro.stream.service` — ``ingest(batch)`` / ``resolve(id)``
  facade backed by an incrementally maintained union-find and the
  incrementally patched global grounding
  (``core.global_grounding.GroundingMaintainer``), with
  ``snapshot()`` / ``resolve_many()`` for consistent concurrent reads.
"""

from repro.stream.service import (  # noqa: F401
    IngestReport,
    ResolveService,
    ResolveSnapshot,
)
