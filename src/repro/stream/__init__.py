"""Streaming incremental entity matching.

The batch pipeline (``repro.core.pipeline``) builds a total cover once
and runs message passing to a global fixpoint.  This package keeps that
fixpoint *current* under a stream of arriving entities, with per-ingest
cost proportional to the dirty set rather than the corpus.  One
``ResolveService.ingest(batch)`` runs five stages (see
``docs/ARCHITECTURE.md`` for the full data-flow diagram):

1. **Probe** (:mod:`repro.stream.index`) — MinHash signatures on-device
   (``minhash`` Pallas kernel), LSH bucket collisions gate the exact
   cosine probes; optionally memory-bounded via ``LSHConfig.max_ids`` /
   ``ttl_adds``.
2. **Replay** (:mod:`repro.stream.delta`) — the canonical canopy sweep
   is replayed over only the touched similarity components
   (``IngestReport.replay_visits`` counts the region).
3. **Assemble + splice** (:class:`repro.core.cover.CoverDelta`) — the
   total cover (Def. 7) is re-derived incrementally: only dirty canopy
   parts / totality groups / leftover chunks are restaged, and the
   packed per-bin arrays are spliced instead of rebuilt
   (``IngestReport.cover_splice_rows``).
4. **Ground + advance** (:mod:`repro.stream.engine`,
   :class:`repro.core.global_grounding.GroundingMaintainer`) — the
   global grounding is patched and its array form spliced
   (``grounding_pair_visits`` / ``grounding_splice_rows``); the batch
   drivers are warm-started with only the dirty neighborhoods seeded,
   and the device :class:`~repro.core.parallel.GroundingCache` splices
   only the changed rows (``reground_rows``).  The cache's resident
   device memory is boundable (``ServiceConfig.gcache_capacity``
   / ``gcache_hbm_budget``): cold bins are LRU-evicted and re-ground
   on demand, bit-for-bit (``peak_resident_bins`` / ``cache_evictions``
   / ``cold_regrounds``); MMP's step-7 promotion runs batched on device
   (``promote_host_scans`` == 0).
5. **Commit** (:mod:`repro.stream.service`) — matches fold into a
   persistent union-find, then the whole ingest publishes to readers
   in one snapshot swap (double-buffered: ``resolve(id)`` /
   ``resolve_many`` / ``snapshot()`` are lock-free reads of committed
   fixpoints and never wait on an in-flight ingest).

Under real traffic the service is fronted by
:class:`repro.stream.serving.ServingFrontend` (stage 0, so to speak):
an async ingest queue that coalesces arrivals up to a size/latency
budget into one delta+fixpoint pass each, with bounded-queue admission
control, capped-backoff retries, and poison-batch bisection — see
``docs/SERVING.md`` for the operator view.

Every ingest is transactional (``repro.core.txn`` undo log: any
mid-ingest failure rolls the service back to the pre-submit state
bit-for-bit), and optionally durable
(``ServiceConfig.durability_dir``: fsync'd write-ahead log
(:mod:`repro.stream.wal`) + periodic atomic checkpoints, with
``ResolveService.recover`` restoring the newest checkpoint and
replaying the WAL tail to the exact pre-crash fixpoint).

The invariant throughout: after any ingest sequence — and any
coalescing of it — cover, grounding, and fixpoint are bit-for-bit what
the batch pipeline computes over the union of everything ingested.
"""

from repro.stream.service import (
    IngestReport,
    ResolveService,
    ResolveSnapshot,
    ServiceConfig,
)
from repro.stream.serving import (
    AdmissionError,
    IngestTicket,
    ServingConfig,
    ServingFrontend,
)
from repro.stream.shard import (
    ShardContext,
    ShardCoordinator,
)

__all__ = [
    "AdmissionError",
    "IngestReport",
    "IngestTicket",
    "ResolveService",
    "ResolveSnapshot",
    "ServiceConfig",
    "ServingConfig",
    "ServingFrontend",
    "ShardContext",
    "ShardCoordinator",
]
