"""Streaming incremental entity matching.

The batch pipeline (``repro.core.pipeline``) builds a total cover once
and runs message passing to a global fixpoint.  This package keeps that
fixpoint *current* under a stream of arriving entities:

* :mod:`repro.stream.index` — incremental MinHash-LSH blocking index
  (signatures computed on-device by the ``minhash`` Pallas kernel);
* :mod:`repro.stream.delta` — delta cover maintenance: maps an arriving
  micro-batch to the set of dirty neighborhoods and repacks only the
  affected bins, preserving totality (Def. 7);
* :mod:`repro.stream.engine` — incremental driver seeding the batch
  drivers' worklists with only the dirty neighborhoods;
* :mod:`repro.stream.service` — ``ingest(batch)`` / ``resolve(id)``
  facade backed by an incrementally maintained union-find.
"""

from repro.stream.service import IngestReport, ResolveService  # noqa: F401
