"""Canonical sha256 digest of a :class:`ResolveService`'s logical state.

The fault-tolerance tests compare *states*, not just match sets: an
aborted ingest must leave the service bit-for-bit where it was, and a
crash-recovered service must land on the uninterrupted run's fixpoint.
``state_digest`` folds every piece of logical state into one hash so
those comparisons are a string equality.

What "canonical" means here:

* **Sets and dicts are order-normalized.**  Rollback restores set
  *contents* exactly, but a rebuilt ``set()`` may iterate in a
  different order than the original (CPython table geometry is
  insertion-history dependent), so anything unordered is sorted before
  hashing.
* **Union-find structure is cluster-normalized.**  Root identity
  depends on union order; the digest hashes the partition (sorted
  tuples of sorted members), not the parent pointers.
* **Caches and device state are excluded**: the engine's
  ``GroundingCache`` (lazy, re-grounds bit-for-bit), the matcher
  (pure function of the weights), ``GlobalGrounding._device`` (lazy
  upload cache), the obs registry (monotone counters, not logical
  state), and the packed cover's *backing buffers* — only the
  published array views are hashed, because rolled-back tail appends
  legitimately leave garbage beyond every published view length.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


def _feed(h, obj) -> None:
    """Recursively fold ``obj`` into hash ``h``, type-tagged so that
    e.g. ``[1, 2]`` and ``[(1, 2)]`` cannot collide."""
    if obj is None:
        h.update(b"\x00N")
    elif isinstance(obj, (bool, np.bool_)):
        h.update(b"\x00B1" if obj else b"\x00B0")
    elif isinstance(obj, (int, np.integer)):
        h.update(f"\x00i{int(obj)}".encode())
    elif isinstance(obj, (float, np.floating)):
        h.update(f"\x00f{float(obj).hex()}".encode())
    elif isinstance(obj, str):
        b = obj.encode()
        h.update(f"\x00s{len(b)}:".encode())
        h.update(b)
    elif isinstance(obj, bytes):
        h.update(f"\x00b{len(obj)}:".encode())
        h.update(obj)
    elif isinstance(obj, np.ndarray):
        a = np.ascontiguousarray(obj)
        h.update(f"\x00a{a.dtype.str}{a.shape}:".encode())
        h.update(a.tobytes())
    elif isinstance(obj, (list, tuple)):
        h.update(f"\x00l{len(obj)}:".encode())
        for x in obj:
            _feed(h, x)
    elif isinstance(obj, dict):
        h.update(f"\x00d{len(obj)}:".encode())
        for k in sorted(obj, key=repr):
            _feed(h, k)
            _feed(h, obj[k])
    elif isinstance(obj, (set, frozenset)):
        h.update(f"\x00S{len(obj)}:".encode())
        for x in sorted(obj, key=repr):
            _feed(h, x)
    else:
        raise TypeError(f"state_digest: unhashable state type {type(obj)!r}")


def _pool_partition(pool) -> list[tuple[int, ...]]:
    """The message pool as a canonical partition, via a *non-mutating*
    root walk (``pool._find`` would path-compress and journal)."""
    by_root: dict[int, list[int]] = {}
    for g in pool.parent:
        p = int(g)
        while pool.parent[p] != p:
            p = pool.parent[p]
        by_root.setdefault(p, []).append(int(g))
    return sorted(tuple(sorted(v)) for v in by_root.values())


def match_digest(matches) -> str:
    """Hex sha256 of a match fixpoint alone (a :class:`MatchStore` or a
    gid array) — the equivalence oracle for engine-level runs that have
    no surrounding service, e.g. the sharded lattice legs that drive
    ``run_parallel`` on a hand-packed cover."""
    h = hashlib.sha256()
    gids = getattr(matches, "gids", matches)
    _feed(h, ["m_plus", np.sort(np.asarray(gids, dtype=np.int64))])
    return h.hexdigest()


def state_digest(service) -> str:
    """Hex sha256 over the service's canonicalized logical state."""
    h = hashlib.sha256()
    d = service.delta
    _feed(h, ["names", d.names])
    cov = d.cover
    if cov is not None:
        _feed(h, ["cover.core", list(cov.core)])
        _feed(h, ["cover.full", list(cov.full)])
    p = d.packed
    if p is not None:
        _feed(h, ["pair_levels", p.pair_levels])
        _feed(h, ["row_keys", p.row_keys])
        _feed(h, ["bin_rows", p.bin_rows])
        _feed(h, ["nb_bin", p.neighborhood_bin])
        _feed(h, ["nb_row", p.neighborhood_row])
        for k in sorted(p.bins):
            nb = p.bins[k]
            _feed(h, ["bin", k, nb.entity_ids, nb.entity_mask, nb.coauthor,
                      nb.sim_level, nb.pair_gid, nb.pair_mask])
    eng = service.engine
    _feed(h, ["m_plus", eng.m_plus.gids])
    _feed(h, ["pool", _pool_partition(eng.pool)])
    _feed(h, ["fixpoint", service._fixpoint.gids])
    _feed(h, ["clusters",
              sorted(tuple(sorted(m)) for m in service._members.values())])
    pub = service._published
    _feed(h, ["published", pub.matches.gids, pub.n_entities, pub.n_ingests,
              sorted(tuple(int(x) for x in arr)
                     for arr in pub._members.values())])
    g = service.grounding
    if g is not None:
        _feed(h, ["g.levels", g.levels])
        _feed(h, ["g.common", g.common])
        _feed(h, ["g.coup", g.coup])
        _feed(h, ["g.pairs_of", g.pairs_of])
        _feed(h, ["g.adj", g.adj])
        _feed(h, ["g.coup_adj", g.coup_adj])
        _feed(h, ["g.pend", g._pend_add, g._pend_del, g._pend_u,
                  g._pend_cadd, g._pend_cdel])
        gg = g._gg
        if gg is not None:
            for f in dataclasses.fields(gg):
                if f.name == "_device":
                    continue
                _feed(h, [f"gg.{f.name}", getattr(gg, f.name)])
    return h.hexdigest()
