"""Resolve-query service: ``ingest(batch)`` / ``resolve(id) -> cluster``.

The user-facing streaming facade.  Each ingest runs the full incremental
path — LSH probe, delta cover maintenance, incremental grounding patch,
dirty-seeded fixpoint advance — and folds the new matches into a
persistent union-find, so resolve queries are O(alpha) lookups between
ingests.  The service's invariant, checked by the streaming tests:
after any sequence of micro-batches its match fixpoint is bit-for-bit
the one the batch pipeline computes over the union of everything
ingested.

Every per-ingest cost tracks the dirty set, not the corpus:

* the canopy replay sweeps only the touched similarity components
  (``IngestReport.replay_visits``);
* for MMP, the global grounding is patched in place via
  ``GroundingMaintainer.apply_delta`` instead of rebuilt
  (``IngestReport.grounding_pair_visits``);
* only dirty neighborhoods seed the fixpoint advance;
* serving memory is boundable: ``gcache_capacity`` /
  ``gcache_hbm_budget`` cap the device grounding cache (LRU over bins,
  cold bins re-ground on demand bit-for-bit —
  ``IngestReport.peak_resident_bins`` proves the bound).

Serving reads don't race ingests — and they don't *wait* on them
either.  The service keeps **double-buffered snapshots**: readers
always resolve against an immutable published :class:`ResolveSnapshot`
(a plain attribute read — no lock), while the in-flight ingest mutates
a private write buffer; the commit section freezes the write buffer
into a fresh snapshot and publishes it by a single reference swap.  A
reader therefore observes the fixpoint before or after an ingest,
never a half-applied one, and its latency is independent of ingest
wall time (``tests/test_serving.py`` pins both properties).

Thread-safety contract (per lock):

* ``_lock`` — the **writer** lock.  Serializes concurrent ``ingest``
  commits and the write-buffer mutation (``uf``/``_members``/
  ``_fixpoint``/``reports``).  Readers never take it.
* ``_published`` — the read buffer.  Immutable once published;
  replaced, never mutated (reference assignment is atomic under the
  GIL), so ``resolve``/``resolve_many``/``snapshot``/``clusters`` are
  lock-free and safe from any number of threads.

The higher-traffic front-end (async ingest queue, micro-batch
coalescing, admission control) lives in :mod:`repro.stream.serving`
and drives this service single-writer; see ``docs/SERVING.md``.
"""

from __future__ import annotations

import dataclasses
import pickle
import threading
import time
import warnings
from pathlib import Path

import numpy as np

from repro import faults
from repro.checkpoint.checkpointer import Checkpointer
from repro.core import pairs as pairlib, txn
from repro.core.closure import UnionFind
from repro.core.cover import DEFAULT_BINS
from repro.core.global_grounding import GroundingMaintainer
from repro.core.mln import MLNMatcher, MLNWeights, PAPER_LEARNED
from repro.core.types import MatchStore
from repro.obs import get_registry, total_upload_bytes
from repro.obs import span as obs_span
from repro.stream.delta import DeltaCover
from repro.stream.engine import IncrementalEngine
from repro.stream.index import LSHConfig
from repro.stream.wal import WriteAheadLog


@dataclasses.dataclass
class IngestReport:
    ids: list[int]  # global entity ids assigned to the batch
    n_entities: int  # total entities resolved so far
    n_neighborhoods: int  # current cover size
    n_dirty: int  # neighborhoods re-seeded this ingest
    n_invalidated: int  # carried matches dropped by cover retraction
    neighborhood_evals: int  # matcher evaluations this ingest
    new_matches: int  # matches added this ingest
    replay_visits: int  # ids swept by the localized canopy replay
    grounding_pair_visits: int  # pairs patched in the grounding (mmp)
    wall_time_s: float
    # device rows re-ground this ingest (parallel engine: clean bins hit
    # the persistent GroundingCache, dirty bins splice changed rows only)
    reground_rows: int = 0
    # neighborhood rows (re)staged by the incremental cover assembly +
    # packed-array splice (CoverDelta) — O(dirty), not O(neighborhoods)
    cover_splice_rows: int = 0
    # grounding array rows spliced by GroundingMaintainer.grounding()
    # (mmp) — O(delta), not the O(candidate pairs) full materialization
    grounding_splice_rows: int = 0
    # Bounded serving memory (parallel engine, LRU GroundingCache):
    # high-water mark of array-resident bins, plus this ingest's LRU
    # evictions and cold (eviction-forced) re-grounds.
    peak_resident_bins: int = 0
    cache_evictions: int = 0
    cold_regrounds: int = 0
    # step-7 promotion passes that fell back to the host coupling-COO
    # walk — 0 on the device-resident path (gated in CI)
    promote_host_scans: int = 0
    # packed-array append accounting (CoverDelta backing buffers):
    # tail rows written by the append path and rows memcpy'd by
    # capacity-doubling growth — amortized O(fresh), gated in CI
    append_rows: int = 0
    growth_copy_rows: int = 0
    # host->device bytes uploaded during this ingest, summed over the
    # three transfer sites (repro.obs.transfer: grounding cache,
    # promoter, bin staging) — the per-ingest delta of the cumulative
    # ``transfer.*_bytes`` registry counters
    upload_bytes: int = 0


# IngestReport fields published as monotone ``ingest.*`` counters;
# n_entities / n_neighborhoods / peak_resident_bins become gauges and
# wall_time_s the ``ingest.wall_ms`` histogram (see _publish_ingest).
_INGEST_COUNTER_FIELDS = (
    "n_dirty",
    "n_invalidated",
    "neighborhood_evals",
    "new_matches",
    "replay_visits",
    "grounding_pair_visits",
    "reground_rows",
    "cover_splice_rows",
    "grounding_splice_rows",
    "cache_evictions",
    "cold_regrounds",
    "promote_host_scans",
    "append_rows",
    "growth_copy_rows",
    "upload_bytes",
)


def _publish_ingest(report: IngestReport) -> IngestReport:
    """Publish an :class:`IngestReport` into the runtime registry.

    The dataclass stays the per-call API; the cumulative ``ingest.*``
    family is what ``benchmarks/stream_throughput.py`` snapshots.  The
    ``dirty_frac`` / ``replay_frac`` histograms are the O(dirty)-story
    ratios (work per ingest over corpus size) whose tails ROADMAP item 2
    asks for.
    """
    reg = get_registry()
    reg.counter("ingest.count").inc()
    for name in _INGEST_COUNTER_FIELDS:
        v = int(getattr(report, name))
        if v:
            reg.counter(f"ingest.{name}").inc(v)
    reg.gauge("ingest.n_entities").set(report.n_entities)
    reg.gauge("ingest.n_neighborhoods").set(report.n_neighborhoods)
    reg.gauge("ingest.peak_resident_bins").max(report.peak_resident_bins)
    reg.histogram("ingest.wall_ms").observe(report.wall_time_s * 1e3)
    reg.histogram("ingest.upload_bytes").observe(report.upload_bytes)
    reg.histogram("ingest.grounding_pair_visits").observe(
        report.grounding_pair_visits
    )
    reg.histogram("ingest.dirty_frac").observe(
        report.n_dirty / max(report.n_neighborhoods, 1)
    )
    reg.histogram("ingest.replay_frac").observe(
        report.replay_visits / max(report.n_entities, 1)
    )
    return report


def _observe_resolve(t0: float, n_queries: int) -> None:
    """Record one resolve call: latency histogram + query counter."""
    reg = get_registry()
    reg.histogram("resolve.latency_ms").observe(
        (time.perf_counter() - t0) * 1e3
    )
    reg.counter("resolve.queries").inc(n_queries)
    reg.counter("resolve.calls").inc()


@dataclasses.dataclass(frozen=True)
class ResolveSnapshot:
    """An immutable, consistent view of the match fixpoint.

    Frozen at the end of an ingest commit (the read buffer of the
    service's double-buffered pair), so a reader thread never observes
    a half-applied ingest.  Resolution against a snapshot is pure dict
    lookups — no locks, no interaction with ongoing ingests.  All
    methods are safe from any number of threads; the backing dicts and
    arrays are never mutated after publication.

    What a reader can observe mid-ingest: exactly the fixpoint of some
    prefix of the ingest sequence.  A snapshot taken at ingest k keeps
    answering for ingest k forever — a polling reader re-calls
    ``ResolveService.snapshot()`` to step forward.
    """

    matches: MatchStore
    n_entities: int
    n_ingests: int
    _root: dict[int, int]  # entity -> cluster root (pre-flattened)
    _members: dict[int, np.ndarray]  # root -> sorted cluster members

    def resolve(self, entity_id: int) -> np.ndarray:
        eid = int(entity_id)
        root = self._root.get(eid)
        if root is None:
            return np.asarray([eid], dtype=np.int64)
        return self._members[root]

    def resolve_many(self, entity_ids) -> list[np.ndarray]:
        t0 = time.perf_counter()
        out = [self.resolve(e) for e in entity_ids]
        _observe_resolve(t0, len(out))
        return out

    def clusters(self) -> list[np.ndarray]:
        return [m for m in self._members.values() if len(m) >= 2]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Typed configuration for :class:`ResolveService` (mirrors
    :class:`repro.stream.serving.ServingConfig`).

    ``matcher`` accepts a registered family name (resolved through
    :func:`repro.core.matchers.get_matcher`), a matcher instance, or
    ``None`` for the paper's collective MLN at ``weights``.
    """

    scheme: str = "smp"  # 'nomp' | 'smp' | 'mmp'
    matcher: object = None  # family name (str), instance, or None
    weights: MLNWeights = PAPER_LEARNED
    parallel: bool = False
    t_loose: float = 0.70
    t_tight: float = 0.90
    k_max: int = 32
    feature_dim: int = 128
    k_bins: tuple[int, ...] = DEFAULT_BINS
    thresholds: tuple | None = None
    boundary_relation: str = "coauthor"
    lsh: LSHConfig | None = None
    level_cache_max: int | None = None
    gcache_capacity: int | None = None
    gcache_hbm_budget: int | None = None
    durability_dir: str | None = None
    checkpoint_every: int = 0
    wal_fsync: bool = True

    def __post_init__(self):
        if self.scheme not in ("nomp", "smp", "mmp"):
            raise ValueError(f"unknown scheme {self.scheme!r}")
        if self.checkpoint_every < 0:
            raise ValueError("checkpoint_every must be >= 0")
        if not 0.0 < self.t_loose <= self.t_tight <= 1.0:
            raise ValueError("need 0 < t_loose <= t_tight <= 1")
        if self.checkpoint_every > 0 and self.durability_dir is None:
            raise ValueError("checkpoint_every > 0 needs durability_dir")

    def build_matcher(self):
        if self.matcher is None:
            return MLNMatcher(self.weights)
        if isinstance(self.matcher, str):
            from repro.core.matchers import get_matcher

            return get_matcher(self.matcher)
        return self.matcher


class ResolveService:
    """Streaming entity resolution over micro-batches.

    Construct with a :class:`ServiceConfig` (``ResolveService(config)``);
    the accreted constructor keywords of earlier releases still work as
    a deprecated shim (``ResolveService(scheme="mmp", ...)`` warns and
    folds the kwargs into a config).
    """

    def __init__(self, config: ServiceConfig | None = None, *, shard=None,
                 **deprecated_kwargs):
        """``gcache_capacity`` / ``gcache_hbm_budget`` (parallel engine
        only) bound the device grounding cache — the HBM-budget knob of
        the serving path: at most ``gcache_capacity`` bins (or
        ``gcache_hbm_budget`` bytes of grounded tensors) stay resident;
        colder bins are dropped LRU-first and re-ground on demand,
        bit-for-bit, trading compute for bounded memory.

        ``durability_dir`` turns on crash durability: every ingest is
        appended to a write-ahead log (fsync'd unless ``wal_fsync`` is
        off) *before* any in-memory state mutates, and — when
        ``checkpoint_every`` > 0 — every that-many ingests the full
        logical state is snapshotted through
        :class:`repro.checkpoint.checkpointer.Checkpointer` and the WAL
        is rotated/GC'd.  :meth:`recover` rebuilds a service from the
        latest snapshot plus the WAL tail; by stream/batch
        schedule-invariance the recovered fixpoint is bit-for-bit the
        uninterrupted one.

        ``shard`` (a :class:`repro.stream.shard.ShardContext`) turns on
        sharded serving: the LSH bucket map is partitioned across the
        context's processes (probes merge by cross-process union) and
        the parallel engine runs its rounds on the context's mesh.  The
        logical state stays SPMD-replicated — see
        :mod:`repro.stream.shard` for the equivalence argument."""
        if deprecated_kwargs:
            if config is not None:
                raise TypeError(
                    "pass either a ServiceConfig or keyword arguments, "
                    f"not both (got {sorted(deprecated_kwargs)})"
                )
            warnings.warn(
                "ResolveService(**kwargs) is deprecated; pass "
                "ResolveService(ServiceConfig(...)) instead",
                DeprecationWarning,
                stacklevel=2,
            )
            config = ServiceConfig(**deprecated_kwargs)
        cfg = config if config is not None else ServiceConfig()
        self.config = cfg
        self.weights = cfg.weights
        self.scheme = cfg.scheme
        self.shard = shard
        self.delta = DeltaCover(
            t_loose=cfg.t_loose,
            t_tight=cfg.t_tight,
            k_max=cfg.k_max,
            feature_dim=cfg.feature_dim,
            k_bins=cfg.k_bins,
            thresholds=cfg.thresholds,
            boundary_relation=cfg.boundary_relation,
            lsh=cfg.lsh,
            level_cache_max=cfg.level_cache_max,
            shard=shard.spec if shard is not None else None,
            shard_merge=shard.merger.union if shard is not None else None,
        )
        matcher = cfg.build_matcher()
        # families that score by entity *name* (the embedding matcher's
        # ngram/lm encoders) read the live id -> name table the cover
        # maintains; the hook is capability-based so any registered
        # family inherits it
        bind = getattr(matcher, "bind_names", None)
        if bind is not None:
            bind(self.delta.names)
        self.engine = IncrementalEngine(
            matcher,
            scheme=cfg.scheme,
            parallel=cfg.parallel,
            mesh=shard.mesh if shard is not None else None,
            gcache_capacity=cfg.gcache_capacity,
            gcache_hbm_budget=cfg.gcache_hbm_budget,
        )
        # MMP needs the global grounding; maintained incrementally so no
        # ingest pays the O(corpus) from-scratch build.  The delta's
        # new_edges are boundary-relation tuples, as the maintainer's
        # caller contract requires.
        self.grounding = (
            GroundingMaintainer(cfg.weights) if cfg.scheme == "mmp" else None
        )
        self.uf = UnionFind()
        self._members: dict[int, set[int]] = {}  # uf root -> cluster members
        self._fixpoint = MatchStore()
        # Writer lock: serializes ingest commits and write-buffer
        # mutation.  The read path never takes it (see module docstring).
        self._lock = threading.RLock()
        # Write-buffer freeze caches, maintained incrementally by
        # _add_match so the per-commit publish cost is O(clusters
        # touched this ingest), not O(all clusters):
        self._root_cache: dict[int, int] = {}  # entity -> flattened root
        self._frozen: dict[int, np.ndarray] = {}  # root -> sorted members
        # The read buffer: swapped by reference at the end of each
        # commit, immutable afterwards.
        self._published = ResolveSnapshot(
            matches=self._fixpoint,
            n_entities=0,
            n_ingests=0,
            _root={},
            _members={},
        )
        self.reports: list[IngestReport] = []
        # Durability plane (optional): WAL + checkpointer.  ``_seq`` is
        # the last *assigned* ingest sequence number — aborted ingests
        # consume their seq (an abort marker records the outcome), so
        # replay never confuses a rolled-back batch with a committed one.
        self.durability_dir = cfg.durability_dir
        self.checkpoint_every = int(cfg.checkpoint_every)
        self.wal: WriteAheadLog | None = None
        self._ckpt: Checkpointer | None = None
        self._seq = 0
        self._replaying = False
        if cfg.durability_dir is not None:
            base = Path(cfg.durability_dir)
            self.wal = WriteAheadLog(base / "wal", fsync=cfg.wal_fsync)
            self._ckpt = Checkpointer(str(base / "ckpt"), keep=2)

    # -- ingest path ------------------------------------------------------

    def ingest(
        self,
        names: list[str],
        edges: np.ndarray | None = None,
        ids: list[int] | None = None,
    ) -> IngestReport:
        """Resolve a micro-batch of arriving entity references.

        ``ids`` (optional) are explicit global entity ids — they must be
        fresh; relation ``edges`` are given in global ids and may point
        at earlier arrivals.  Without ``ids``, fresh sequential ids are
        assigned.

        Thread safety: the cover/grounding/engine stages mutate
        unprotected incremental state, so ``ingest`` must be called
        from **one writer at a time** (the commit section additionally
        takes ``_lock`` against racing writers, but the stages before
        it are not serialized — use :class:`repro.stream.serving.
        ServingFrontend`, whose single worker thread owns this method,
        to multiplex many producers).  Readers are unaffected
        throughout: they keep resolving against the previously
        published snapshot until the commit swaps in the new one.

        Failure atomicity: the whole ingest runs inside one
        :func:`repro.core.txn.transaction`.  If *any* stage raises —
        LSH probe, canopy replay, cover splice, grounding patch,
        fixpoint rounds, or the commit itself — the undo journal rolls
        every touched structure back and the service is bit-for-bit the
        state it had before the call (``tests/test_faults.py`` pins
        this differentially at every fault site).  With durability on,
        the batch is WAL-appended (fsync'd) *before* any state mutates,
        and an abort marker records a rollback so recovery skips it.
        """
        t0 = time.perf_counter()
        if ids is None:
            base = len(self.delta.names)
            ids = list(range(base, base + len(names)))
        else:
            ids = [int(i) for i in ids]
        names = list(names)
        seq = None
        if self.wal is not None and not self._replaying:
            self._seq += 1
            seq = self._seq
            faults.maybe_fail("wal.append", names)
            self.wal.append(seq, names, edges, ids)
        try:
            with txn.transaction():
                report = self._ingest_body(t0, names, edges, ids)
        except BaseException:
            get_registry().counter("ingest.aborts").inc()
            if seq is not None:
                try:
                    self.wal.append_abort(seq)
                except Exception:
                    # Best-effort: without the marker, recovery replays
                    # the batch and (deterministically) re-aborts it.
                    pass
            raise
        if (
            seq is not None
            and self.checkpoint_every
            and seq % self.checkpoint_every == 0
        ):
            self._checkpoint(seq)
        return report

    def _ingest_body(
        self,
        t0: float,
        names: list[str],
        edges: np.ndarray | None,
        ids: list[int],
    ) -> IngestReport:
        """The journaled ingest body (caller holds the open
        transaction)."""
        bytes0 = total_upload_bytes()
        prev_matches = self.engine.m_plus
        with obs_span("ingest", batch=len(ids)):
            d = self.delta.ingest(ids, names, edges)
            grounding_visits = 0
            grounding_splice = 0
            gg = None
            if self.grounding is not None:
                faults.maybe_fail("grounding_splice", names)
                with obs_span("ingest.grounding_splice"):
                    gstats = self.grounding.apply_delta(
                        d.added_pairs, d.retracted_pairs, d.new_edges
                    )
                    grounding_visits = gstats.pairs_visited
                    gg = self.grounding.grounding()
                    grounding_splice = self.grounding.last_splice_rows
            faults.maybe_fail("rounds", names)
            stats = self.engine.advance(
                d.packed, d.dirty, gg, retracted=d.retracted_pairs
            )

            # Commit: the write buffer mutates under the writer lock,
            # then the whole ingest is published to readers in one
            # reference swap — snapshot()/resolve() observe the state
            # before or after this ingest, never mid-way, and never
            # wait on it.
            with self._lock, obs_span("ingest.commit"):
                faults.maybe_fail("commit", names)
                t = txn.active()
                if t is not None:
                    # Attribute-level saves cover both the invalidation
                    # rebinds and the plain rebinds below; entry-level
                    # mutations inside the (possibly kept) dicts are
                    # journaled by _add_match itself.
                    for a in ("uf", "_members", "_root_cache", "_frozen",
                              "_fixpoint", "_published"):
                        t.save_attr(self, a)
                    t.save_len(self.reports)
                new = stats.result.matches.difference(prev_matches)
                if stats.n_invalidated:
                    self.uf = UnionFind()
                    self._members = {}
                    self._root_cache = {}
                    self._frozen = {}
                    new = stats.result.matches.gids
                for g in new:
                    a, b = pairlib.split_gid(np.int64(g))
                    self._add_match(int(a), int(b))
                self._fixpoint = stats.result.matches

                report = IngestReport(
                    ids=ids,
                    n_entities=self.delta.n_entities,
                    n_neighborhoods=len(d.cover),
                    n_dirty=stats.n_dirty,
                    n_invalidated=stats.n_invalidated,
                    neighborhood_evals=stats.result.neighborhood_evals,
                    new_matches=int(len(new)),
                    replay_visits=d.replay_visits,
                    grounding_pair_visits=grounding_visits,
                    wall_time_s=time.perf_counter() - t0,
                    reground_rows=stats.reground_rows,
                    cover_splice_rows=d.cover_splice_rows,
                    grounding_splice_rows=grounding_splice,
                    peak_resident_bins=stats.result.peak_resident_bins,
                    cache_evictions=stats.result.cache_evictions,
                    cold_regrounds=stats.result.cold_regrounds,
                    promote_host_scans=stats.result.promote_host_scans,
                    append_rows=self.delta.cover_delta.last_append_rows,
                    growth_copy_rows=(
                        self.delta.cover_delta.last_growth_copy_rows
                    ),
                    upload_bytes=total_upload_bytes() - bytes0,
                )
                self.reports.append(report)
                _publish_ingest(report)
                # Swap-on-commit: freeze the write buffer into the new
                # read snapshot.  The dict() copies are O(entities)
                # pointer copies; the member arrays are shared with the
                # freeze caches and never mutated after publication.
                self._published = ResolveSnapshot(
                    matches=self._fixpoint,
                    n_entities=self.delta.n_entities,
                    n_ingests=len(self.reports),
                    _root=dict(self._root_cache),
                    _members=dict(self._frozen),
                )
        return report

    # -- durability: checkpoint + WAL recovery ----------------------------

    def _logical_state(self) -> dict:
        """Everything needed to resume bit-for-bit, as one picklable
        dict.  Excluded on purpose: the matcher (rebuilt by the ctor
        from ``weights`` at recover time), the device grounding cache
        (lazy; a cold re-ground is bit-for-bit), and the obs registry
        (monotone counters, not logical state)."""
        eng = self.engine
        return {
            "seq": self._seq,
            "delta": self.delta,
            "grounding": self.grounding,
            "engine": {
                "m_plus": eng.m_plus,
                "pool": eng.pool,
                "total_evals": eng.total_evals,
                "total_rounds": eng.total_rounds,
                "total_dispatches": eng.total_dispatches,
            },
            "uf": self.uf,
            "members": self._members,
            "fixpoint": self._fixpoint,
            "root_cache": self._root_cache,
            "frozen": self._frozen,
            "published": self._published,
            "reports": self.reports,
        }

    def _load_logical_state(self, state: dict) -> None:
        self._seq = int(state["seq"])
        self.delta = state["delta"]
        self.grounding = state["grounding"]
        eng = state["engine"]
        self.engine.m_plus = eng["m_plus"]
        self.engine.pool = eng["pool"]
        self.engine.total_evals = eng["total_evals"]
        self.engine.total_rounds = eng["total_rounds"]
        self.engine.total_dispatches = eng["total_dispatches"]
        self.engine.gcache = None  # re-grounds lazily, bit-for-bit
        self.uf = state["uf"]
        self._members = state["members"]
        self._fixpoint = state["fixpoint"]
        self._root_cache = state["root_cache"]
        self._frozen = state["frozen"]
        self._published = state["published"]
        self.reports = state["reports"]

    def _checkpoint(self, seq: int) -> None:
        """Snapshot the logical state, then rotate + GC the WAL so
        recovery replays only the post-checkpoint tail.  Ordering
        matters: the checkpoint rename commits *before* any WAL segment
        is dropped, so a crash anywhere in between only leaves extra
        (idempotently skippable) WAL records behind."""
        blob = np.frombuffer(
            pickle.dumps(self._logical_state(),
                         protocol=pickle.HIGHEST_PROTOCOL),
            dtype=np.uint8,
        )
        self._ckpt.save(seq, {"service": {"blob": blob}}, meta={"seq": seq})
        self.wal.rotate(seq + 1)
        self.wal.gc(seq)
        reg = get_registry()
        reg.counter("ckpt.saves").inc()
        reg.gauge("ckpt.last_seq").set(seq)

    @classmethod
    def recover(
        cls,
        durability_dir: str,
        config: "ServiceConfig | None" = None,
        **ctor_kwargs,
    ) -> "ResolveService":
        """Rebuild a service from ``durability_dir``: restore the latest
        checkpoint (if any), then replay the WAL tail — committed
        records past the checkpoint, in sequence order, skipping
        aborted ones.  ``config`` (or the deprecated ``ctor_kwargs``)
        must match the original construction (scheme/weights/
        thresholds...); the matcher and device caches are rebuilt,
        everything logical comes from disk.  The result is bit-for-bit
        the fixpoint of an uninterrupted run over the same committed
        batches (schedule invariance)."""
        if config is not None:
            shard = ctor_kwargs.pop("shard", None)
            if ctor_kwargs:
                raise TypeError(
                    "pass either a ServiceConfig or keyword arguments, "
                    f"not both (got {sorted(ctor_kwargs)})"
                )
            svc = cls(
                dataclasses.replace(config, durability_dir=durability_dir),
                shard=shard,
            )
        else:
            svc = cls(durability_dir=durability_dir, **ctor_kwargs)
        t0 = time.perf_counter()
        ckpt_seq = 0
        step = svc._ckpt.latest_step()
        if step is not None:
            flat, meta = svc._ckpt.restore_raw(step)
            svc._load_logical_state(
                pickle.loads(flat["service|blob"].tobytes())
            )
            ckpt_seq = int(meta.get("seq", step))
        records, aborted = WriteAheadLog.scan(svc.wal.directory)
        replayed = 0
        svc._replaying = True
        try:
            for rec in records:
                if rec.seq <= ckpt_seq or rec.seq in aborted:
                    continue
                try:
                    svc.ingest(rec.names, rec.edges, ids=rec.ids)
                except Exception:
                    # The live run crashed before this batch's abort
                    # marker hit disk; the replay re-derives the same
                    # abort and rollback restores pre-batch state.
                    pass
                replayed += 1
        finally:
            svc._replaying = False
        svc._seq = max(
            [svc._seq, ckpt_seq]
            + [r.seq for r in records]
            + list(aborted)
        )
        reg = get_registry()
        reg.counter("recover.replayed").inc(replayed)
        reg.histogram("recover.wall_ms").observe(
            (time.perf_counter() - t0) * 1e3
        )
        return svc

    def close(self) -> None:
        """Release durability file handles (safe to call twice)."""
        if self.wal is not None:
            self.wal.close()
        if self._ckpt is not None:
            self._ckpt.wait()

    # -- query path -------------------------------------------------------

    @property
    def matches(self) -> MatchStore:
        """Live engine fixpoint — the *write side*.  Coherent only
        between ingests; concurrent readers should prefer
        ``snapshot().matches`` (committed, immutable)."""
        return self.engine.m_plus

    @property
    def total_evals(self) -> int:
        """Cumulative matcher evaluations (write side; read it between
        ingests or accept a momentarily stale value)."""
        return self.engine.total_evals

    def _add_match(self, a: int, b: int) -> None:
        """Union a matched pair into the write buffer (caller holds
        ``_lock``), keeping the root -> members map *and* the freeze
        caches current, so the per-commit publish is O(touched
        clusters) and resolve queries stay O(1) dict lookups."""
        t = txn.active()
        ra, rb = self.uf.find(a), self.uf.find(b)
        if t is not None:
            # Popped member sets are never mutated afterwards (merged is
            # a fresh set), so reference saves suffice.
            t.save_key(self._members, ra)
            t.save_key(self._members, rb)
        ma = self._members.pop(ra, {ra})
        mb = self._members.pop(rb, {rb})
        self.uf.union(a, b)
        merged = ma | mb
        r = self.uf.find(a)
        if t is not None:
            t.save_key(self._members, r)
            t.save_key(self._frozen, ra)
            t.save_key(self._frozen, rb)
            t.save_key(self._frozen, r)
        self._members[r] = merged
        # freeze caches: new sorted array per touched cluster, stale
        # root entries retargeted (fresh array, never in-place — the
        # previous array may be shared with a published snapshot)
        self._frozen.pop(ra, None)
        self._frozen.pop(rb, None)
        self._frozen[r] = np.asarray(sorted(merged), dtype=np.int64)
        for e in merged:
            if self._root_cache.get(e) != r:
                if t is not None:
                    t.save_key(self._root_cache, e)
                self._root_cache[e] = r

    def snapshot(self) -> ResolveSnapshot:
        """The current read buffer: the fixpoint of the last committed
        ingest, frozen.

        Lock-free (a single attribute read) and safe from any thread at
        any time — including while an ingest is in flight, which it
        never waits on.  Successive calls between two commits return
        the identical object; a polling reader re-calls to step to the
        next committed fixpoint."""
        return self._published

    def resolve(self, entity_id: int) -> np.ndarray:
        """Cluster of ``entity_id`` under the last committed fixpoint.

        Lock-free: resolves against the published snapshot, so latency
        is independent of any in-flight ingest.  Safe from any thread.
        Unknown ids resolve to singletons."""
        t0 = time.perf_counter()
        out = self._published.resolve(int(entity_id))
        _observe_resolve(t0, 1)
        return out

    def resolve_many(self, entity_ids) -> list[np.ndarray]:
        """Batched resolve against one consistent committed fixpoint.

        The whole batch is answered from a single published snapshot
        (lock-free — no reader ever waits on an ingest), at O(1) dict
        lookups per query.  Each call lands one sample in the
        ``resolve.latency_ms`` histogram — pure read-path latency now
        that there is no lock wait to include."""
        t0 = time.perf_counter()
        snap = self._published
        out = [snap.resolve(int(e)) for e in entity_ids]
        _observe_resolve(t0, len(out))
        return out

    def clusters(self) -> list[np.ndarray]:
        """Non-singleton clusters of the last committed fixpoint
        (lock-free, reads the published snapshot)."""
        return self._published.clusters()
