"""Resolve-query service: ``ingest(batch)`` / ``resolve(id) -> cluster``.

The user-facing streaming facade.  Each ingest runs the full incremental
path — LSH probe, delta cover maintenance, incremental grounding patch,
dirty-seeded fixpoint advance — and folds the new matches into a
persistent union-find, so resolve queries are O(alpha) lookups between
ingests.  The service's invariant, checked by the streaming tests:
after any sequence of micro-batches its match fixpoint is bit-for-bit
the one the batch pipeline computes over the union of everything
ingested.

Every per-ingest cost tracks the dirty set, not the corpus:

* the canopy replay sweeps only the touched similarity components
  (``IngestReport.replay_visits``);
* for MMP, the global grounding is patched in place via
  ``GroundingMaintainer.apply_delta`` instead of rebuilt
  (``IngestReport.grounding_pair_visits``);
* only dirty neighborhoods seed the fixpoint advance;
* serving memory is boundable: ``gcache_capacity`` /
  ``gcache_hbm_budget`` cap the device grounding cache (LRU over bins,
  cold bins re-ground on demand bit-for-bit —
  ``IngestReport.peak_resident_bins`` proves the bound).

Serving reads don't race ingests: :meth:`ResolveService.snapshot`
returns an immutable :class:`ResolveSnapshot` of a consistent fixpoint
(cluster mutation happens atomically under a lock at the end of each
ingest), and :meth:`ResolveService.resolve_many` answers a batch of
queries under one lock acquisition.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.core import pairs as pairlib
from repro.core.closure import UnionFind
from repro.core.cover import DEFAULT_BINS
from repro.core.global_grounding import GroundingMaintainer
from repro.core.mln import MLNMatcher, MLNWeights, PAPER_LEARNED
from repro.core.types import MatchStore
from repro.obs import get_registry, total_upload_bytes
from repro.obs import span as obs_span
from repro.stream.delta import DeltaCover
from repro.stream.engine import IncrementalEngine
from repro.stream.index import LSHConfig


@dataclasses.dataclass
class IngestReport:
    ids: list[int]  # global entity ids assigned to the batch
    n_entities: int  # total entities resolved so far
    n_neighborhoods: int  # current cover size
    n_dirty: int  # neighborhoods re-seeded this ingest
    n_invalidated: int  # carried matches dropped by cover retraction
    neighborhood_evals: int  # matcher evaluations this ingest
    new_matches: int  # matches added this ingest
    replay_visits: int  # ids swept by the localized canopy replay
    grounding_pair_visits: int  # pairs patched in the grounding (mmp)
    wall_time_s: float
    # device rows re-ground this ingest (parallel engine: clean bins hit
    # the persistent GroundingCache, dirty bins splice changed rows only)
    reground_rows: int = 0
    # neighborhood rows (re)staged by the incremental cover assembly +
    # packed-array splice (CoverDelta) — O(dirty), not O(neighborhoods)
    cover_splice_rows: int = 0
    # grounding array rows spliced by GroundingMaintainer.grounding()
    # (mmp) — O(delta), not the O(candidate pairs) full materialization
    grounding_splice_rows: int = 0
    # Bounded serving memory (parallel engine, LRU GroundingCache):
    # high-water mark of array-resident bins, plus this ingest's LRU
    # evictions and cold (eviction-forced) re-grounds.
    peak_resident_bins: int = 0
    cache_evictions: int = 0
    cold_regrounds: int = 0
    # step-7 promotion passes that fell back to the host coupling-COO
    # walk — 0 on the device-resident path (gated in CI)
    promote_host_scans: int = 0
    # packed-array append accounting (CoverDelta backing buffers):
    # tail rows written by the append path and rows memcpy'd by
    # capacity-doubling growth — amortized O(fresh), gated in CI
    append_rows: int = 0
    growth_copy_rows: int = 0
    # host->device bytes uploaded during this ingest, summed over the
    # three transfer sites (repro.obs.transfer: grounding cache,
    # promoter, bin staging) — the per-ingest delta of the cumulative
    # ``transfer.*_bytes`` registry counters
    upload_bytes: int = 0


# IngestReport fields published as monotone ``ingest.*`` counters;
# n_entities / n_neighborhoods / peak_resident_bins become gauges and
# wall_time_s the ``ingest.wall_ms`` histogram (see _publish_ingest).
_INGEST_COUNTER_FIELDS = (
    "n_dirty",
    "n_invalidated",
    "neighborhood_evals",
    "new_matches",
    "replay_visits",
    "grounding_pair_visits",
    "reground_rows",
    "cover_splice_rows",
    "grounding_splice_rows",
    "cache_evictions",
    "cold_regrounds",
    "promote_host_scans",
    "append_rows",
    "growth_copy_rows",
    "upload_bytes",
)


def _publish_ingest(report: IngestReport) -> IngestReport:
    """Publish an :class:`IngestReport` into the runtime registry.

    The dataclass stays the per-call API; the cumulative ``ingest.*``
    family is what ``benchmarks/stream_throughput.py`` snapshots.  The
    ``dirty_frac`` / ``replay_frac`` histograms are the O(dirty)-story
    ratios (work per ingest over corpus size) whose tails ROADMAP item 2
    asks for.
    """
    reg = get_registry()
    reg.counter("ingest.count").inc()
    for name in _INGEST_COUNTER_FIELDS:
        v = int(getattr(report, name))
        if v:
            reg.counter(f"ingest.{name}").inc(v)
    reg.gauge("ingest.n_entities").set(report.n_entities)
    reg.gauge("ingest.n_neighborhoods").set(report.n_neighborhoods)
    reg.gauge("ingest.peak_resident_bins").max(report.peak_resident_bins)
    reg.histogram("ingest.wall_ms").observe(report.wall_time_s * 1e3)
    reg.histogram("ingest.upload_bytes").observe(report.upload_bytes)
    reg.histogram("ingest.grounding_pair_visits").observe(
        report.grounding_pair_visits
    )
    reg.histogram("ingest.dirty_frac").observe(
        report.n_dirty / max(report.n_neighborhoods, 1)
    )
    reg.histogram("ingest.replay_frac").observe(
        report.replay_visits / max(report.n_entities, 1)
    )
    return report


def _observe_resolve(t0: float, n_queries: int) -> None:
    """Record one resolve call: latency histogram + query counter."""
    reg = get_registry()
    reg.histogram("resolve.latency_ms").observe(
        (time.perf_counter() - t0) * 1e3
    )
    reg.counter("resolve.queries").inc(n_queries)
    reg.counter("resolve.calls").inc()


@dataclasses.dataclass(frozen=True)
class ResolveSnapshot:
    """An immutable, consistent view of the match fixpoint.

    Taken atomically between cluster updates, so a reader thread never
    observes a half-applied ingest.  Resolution against a snapshot is
    pure dict lookups — no locks, no interaction with ongoing ingests.
    """

    matches: MatchStore
    n_entities: int
    n_ingests: int
    _root: dict[int, int]  # entity -> cluster root (pre-flattened)
    _members: dict[int, np.ndarray]  # root -> sorted cluster members

    def resolve(self, entity_id: int) -> np.ndarray:
        eid = int(entity_id)
        root = self._root.get(eid)
        if root is None:
            return np.asarray([eid], dtype=np.int64)
        return self._members[root]

    def resolve_many(self, entity_ids) -> list[np.ndarray]:
        t0 = time.perf_counter()
        out = [self.resolve(e) for e in entity_ids]
        _observe_resolve(t0, len(out))
        return out

    def clusters(self) -> list[np.ndarray]:
        return [m for m in self._members.values() if len(m) >= 2]


class ResolveService:
    """Streaming entity resolution over micro-batches."""

    def __init__(
        self,
        *,
        scheme: str = "smp",
        matcher=None,
        weights: MLNWeights = PAPER_LEARNED,
        parallel: bool = False,
        t_loose: float = 0.70,
        t_tight: float = 0.90,
        k_max: int = 32,
        feature_dim: int = 128,
        k_bins: tuple[int, ...] = DEFAULT_BINS,
        thresholds=None,
        boundary_relation: str = "coauthor",
        lsh: LSHConfig | None = None,
        level_cache_max: int | None = None,
        gcache_capacity: int | None = None,
        gcache_hbm_budget: int | None = None,
    ):
        """``gcache_capacity`` / ``gcache_hbm_budget`` (parallel engine
        only) bound the device grounding cache — the HBM-budget knob of
        the serving path: at most ``gcache_capacity`` bins (or
        ``gcache_hbm_budget`` bytes of grounded tensors) stay resident;
        colder bins are dropped LRU-first and re-ground on demand,
        bit-for-bit, trading compute for bounded memory."""
        self.weights = weights
        self.scheme = scheme
        self.delta = DeltaCover(
            t_loose=t_loose,
            t_tight=t_tight,
            k_max=k_max,
            feature_dim=feature_dim,
            k_bins=k_bins,
            thresholds=thresholds,
            boundary_relation=boundary_relation,
            lsh=lsh,
            level_cache_max=level_cache_max,
        )
        self.engine = IncrementalEngine(
            matcher if matcher is not None else MLNMatcher(weights),
            scheme=scheme,
            parallel=parallel,
            gcache_capacity=gcache_capacity,
            gcache_hbm_budget=gcache_hbm_budget,
        )
        # MMP needs the global grounding; maintained incrementally so no
        # ingest pays the O(corpus) from-scratch build.  The delta's
        # new_edges are boundary-relation tuples, as the maintainer's
        # caller contract requires.
        self.grounding = GroundingMaintainer(weights) if scheme == "mmp" else None
        self.uf = UnionFind()
        self._members: dict[int, set[int]] = {}  # uf root -> cluster members
        self._fixpoint = MatchStore()
        self._lock = threading.RLock()
        self._snapshot_cache: ResolveSnapshot | None = None
        self.reports: list[IngestReport] = []

    # -- ingest path ------------------------------------------------------

    def ingest(
        self,
        names: list[str],
        edges: np.ndarray | None = None,
        ids: list[int] | None = None,
    ) -> IngestReport:
        """Resolve a micro-batch of arriving entity references.

        ``ids`` (optional) are explicit global entity ids — they must be
        fresh; relation ``edges`` are given in global ids and may point
        at earlier arrivals.  Without ``ids``, fresh sequential ids are
        assigned.
        """
        t0 = time.perf_counter()
        if ids is None:
            base = len(self.delta.names)
            ids = list(range(base, base + len(names)))
        else:
            ids = [int(i) for i in ids]
        bytes0 = total_upload_bytes()
        prev_matches = self.engine.m_plus
        with obs_span("ingest", batch=len(ids)):
            d = self.delta.ingest(ids, list(names), edges)
            grounding_visits = 0
            grounding_splice = 0
            gg = None
            if self.grounding is not None:
                with obs_span("ingest.grounding_splice"):
                    gstats = self.grounding.apply_delta(
                        d.added_pairs, d.retracted_pairs, d.new_edges
                    )
                    grounding_visits = gstats.pairs_visited
                    gg = self.grounding.grounding()
                    grounding_splice = self.grounding.last_splice_rows
            stats = self.engine.advance(
                d.packed, d.dirty, gg, retracted=d.retracted_pairs
            )

            # Commit: cluster updates and the published fixpoint mutate
            # atomically so snapshot()/resolve() readers see a consistent
            # state — either before or after this ingest, never mid-way.
            with self._lock, obs_span("ingest.commit"):
                new = stats.result.matches.difference(prev_matches)
                if stats.n_invalidated:
                    self.uf = UnionFind()
                    self._members = {}
                    new = stats.result.matches.gids
                for g in new:
                    a, b = pairlib.split_gid(np.int64(g))
                    self._add_match(int(a), int(b))
                self._fixpoint = stats.result.matches

                report = IngestReport(
                    ids=ids,
                    n_entities=self.delta.n_entities,
                    n_neighborhoods=len(d.cover),
                    n_dirty=stats.n_dirty,
                    n_invalidated=stats.n_invalidated,
                    neighborhood_evals=stats.result.neighborhood_evals,
                    new_matches=int(len(new)),
                    replay_visits=d.replay_visits,
                    grounding_pair_visits=grounding_visits,
                    wall_time_s=time.perf_counter() - t0,
                    reground_rows=stats.reground_rows,
                    cover_splice_rows=d.cover_splice_rows,
                    grounding_splice_rows=grounding_splice,
                    peak_resident_bins=stats.result.peak_resident_bins,
                    cache_evictions=stats.result.cache_evictions,
                    cold_regrounds=stats.result.cold_regrounds,
                    promote_host_scans=stats.result.promote_host_scans,
                    append_rows=self.delta.cover_delta.last_append_rows,
                    growth_copy_rows=(
                        self.delta.cover_delta.last_growth_copy_rows
                    ),
                    upload_bytes=total_upload_bytes() - bytes0,
                )
                self.reports.append(report)
                _publish_ingest(report)
        return report

    # -- query path -------------------------------------------------------

    @property
    def matches(self) -> MatchStore:
        return self.engine.m_plus

    @property
    def total_evals(self) -> int:
        return self.engine.total_evals

    def _add_match(self, a: int, b: int) -> None:
        """Union a matched pair, keeping the root -> members map current
        so resolve queries stay O(alpha) + O(|cluster|)."""
        ra, rb = self.uf.find(a), self.uf.find(b)
        ma = self._members.pop(ra, {ra})
        mb = self._members.pop(rb, {rb})
        self.uf.union(a, b)
        self._members[self.uf.find(a)] = ma | mb

    def snapshot(self) -> ResolveSnapshot:
        """Freeze the current fixpoint for lock-free batched reads.

        Cached between ingests: cluster state only mutates in the
        ingest commit section (which bumps ``reports``), so a polling
        reader pays the O(clusters) freeze once per ingest, not per
        call.
        """
        with self._lock:
            cached = self._snapshot_cache
            if cached is not None and cached.n_ingests == len(self.reports):
                return cached
            members = {
                r: np.asarray(sorted(m), dtype=np.int64)
                for r, m in self._members.items()
            }
            root = {int(e): self.uf.find(int(e)) for e in self.uf.parent}
            snap = ResolveSnapshot(
                matches=self._fixpoint,
                n_entities=self.delta.n_entities,
                n_ingests=len(self.reports),
                _root=root,
                _members=members,
            )
            self._snapshot_cache = snap
            return snap

    def _resolve_locked(self, eid: int) -> np.ndarray:
        if eid not in self.uf.parent:
            return np.asarray([eid], dtype=np.int64)
        members = self._members[self.uf.find(eid)]
        return np.asarray(sorted(members), dtype=np.int64)

    def resolve(self, entity_id: int) -> np.ndarray:
        """Cluster of ``entity_id`` under the current match fixpoint."""
        t0 = time.perf_counter()
        with self._lock:
            out = self._resolve_locked(int(entity_id))
        _observe_resolve(t0, 1)
        return out

    def resolve_many(self, entity_ids) -> list[np.ndarray]:
        """Batched resolve under a single lock acquisition — the whole
        batch is answered against one consistent fixpoint, at O(alpha)
        + O(|cluster|) per query (no full-state snapshot copy).  Each
        call lands one sample in the ``resolve.latency_ms`` histogram
        (lock wait included — it is the latency a reader experiences
        under concurrent ingests)."""
        t0 = time.perf_counter()
        with self._lock:
            out = [self._resolve_locked(int(e)) for e in entity_ids]
        _observe_resolve(t0, len(out))
        return out

    def clusters(self) -> list[np.ndarray]:
        with self._lock:
            return [
                np.asarray(sorted(m), dtype=np.int64)
                for m in self._members.values()
                if len(m) >= 2
            ]
