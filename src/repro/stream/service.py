"""Resolve-query service: ``ingest(batch)`` / ``resolve(id) -> cluster``.

The user-facing streaming facade.  Each ingest runs the full incremental
path — LSH probe, delta cover maintenance, dirty-seeded fixpoint advance
— and folds the new matches into a persistent union-find, so resolve
queries are O(alpha) lookups between ingests.  The service's invariant,
checked by the streaming tests: after any sequence of micro-batches its
match fixpoint is bit-for-bit the one the batch pipeline computes over
the union of everything ingested.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.closure import UnionFind
from repro.core.cover import DEFAULT_BINS
from repro.core.global_grounding import GlobalGrounding, build_global_grounding
from repro.core.mln import MLNMatcher, MLNWeights, PAPER_LEARNED
from repro.core.types import MatchStore
from repro.core import pairs as pairlib
from repro.stream.delta import DeltaCover
from repro.stream.engine import IncrementalEngine
from repro.stream.index import LSHConfig


@dataclasses.dataclass
class IngestReport:
    ids: list[int]  # global entity ids assigned to the batch
    n_entities: int  # total entities resolved so far
    n_neighborhoods: int  # current cover size
    n_dirty: int  # neighborhoods re-seeded this ingest
    n_invalidated: int  # carried matches dropped by cover retraction
    neighborhood_evals: int  # matcher evaluations this ingest
    new_matches: int  # matches added this ingest
    wall_time_s: float


class ResolveService:
    """Streaming entity resolution over micro-batches."""

    def __init__(
        self,
        *,
        scheme: str = "smp",
        matcher=None,
        weights: MLNWeights = PAPER_LEARNED,
        parallel: bool = False,
        t_loose: float = 0.70,
        t_tight: float = 0.90,
        k_max: int = 32,
        feature_dim: int = 128,
        k_bins: tuple[int, ...] = DEFAULT_BINS,
        thresholds=None,
        boundary_relation: str = "coauthor",
        lsh: LSHConfig | None = None,
    ):
        self.weights = weights
        self.scheme = scheme
        self.delta = DeltaCover(
            t_loose=t_loose,
            t_tight=t_tight,
            k_max=k_max,
            feature_dim=feature_dim,
            k_bins=k_bins,
            thresholds=thresholds,
            boundary_relation=boundary_relation,
            lsh=lsh,
        )
        self.engine = IncrementalEngine(
            matcher if matcher is not None else MLNMatcher(weights),
            scheme=scheme,
            parallel=parallel,
        )
        self.uf = UnionFind()
        self._members: dict[int, set[int]] = {}  # uf root -> cluster members
        self.reports: list[IngestReport] = []

    # -- ingest path ------------------------------------------------------

    def ingest(
        self,
        names: list[str],
        edges: np.ndarray | None = None,
        ids: list[int] | None = None,
    ) -> IngestReport:
        """Resolve a micro-batch of arriving entity references.

        ``ids`` (optional) are explicit global entity ids — they must be
        fresh; relation ``edges`` are given in global ids and may point
        at earlier arrivals.  Without ``ids``, fresh sequential ids are
        assigned.
        """
        t0 = time.perf_counter()
        if ids is None:
            base = len(self.delta.names)
            ids = list(range(base, base + len(names)))
        else:
            ids = [int(i) for i in ids]
        prev_matches = self.engine.m_plus
        d = self.delta.ingest(ids, list(names), edges)
        gg = self._grounding(d.packed) if self.scheme == "mmp" else None
        stats = self.engine.advance(d.packed, d.dirty, gg)

        new = stats.result.matches.difference(prev_matches)
        if stats.n_invalidated:
            self.uf = UnionFind()
            self._members = {}
            new = stats.result.matches.gids
        for g in new:
            a, b = pairlib.split_gid(np.int64(g))
            self._add_match(int(a), int(b))

        report = IngestReport(
            ids=ids,
            n_entities=self.delta.n_entities,
            n_neighborhoods=len(d.cover),
            n_dirty=stats.n_dirty,
            n_invalidated=stats.n_invalidated,
            neighborhood_evals=stats.result.neighborhood_evals,
            new_matches=int(len(new)),
            wall_time_s=time.perf_counter() - t0,
        )
        self.reports.append(report)
        return report

    def _grounding(self, packed) -> GlobalGrounding:
        return build_global_grounding(
            packed.pair_levels,
            self.delta.relations(),
            self.weights,
            boundary_relation=self.delta.boundary_relation,
        )

    # -- query path -------------------------------------------------------

    @property
    def matches(self) -> MatchStore:
        return self.engine.m_plus

    @property
    def total_evals(self) -> int:
        return self.engine.total_evals

    def _add_match(self, a: int, b: int) -> None:
        """Union a matched pair, keeping the root -> members map current
        so resolve queries stay O(alpha) + O(|cluster|)."""
        ra, rb = self.uf.find(a), self.uf.find(b)
        ma = self._members.pop(ra, {ra})
        mb = self._members.pop(rb, {rb})
        self.uf.union(a, b)
        self._members[self.uf.find(a)] = ma | mb

    def resolve(self, entity_id: int) -> np.ndarray:
        """Cluster of ``entity_id`` under the current match fixpoint."""
        eid = int(entity_id)
        if eid not in self.uf.parent:
            return np.asarray([eid], dtype=np.int64)
        members = self._members[self.uf.find(eid)]
        return np.asarray(sorted(members), dtype=np.int64)

    def clusters(self) -> list[np.ndarray]:
        return [
            np.asarray(sorted(m), dtype=np.int64)
            for m in self._members.values()
            if len(m) >= 2
        ]
