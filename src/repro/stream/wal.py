"""Write-ahead ingest log: the durability half of fault tolerance.

Every ingest batch is appended (and fsync'd) here *before* any service
state is mutated, so a worker killed at any point can be recovered:
``ResolveService.recover`` restores the latest checkpoint and replays
the WAL tail through the normal ingest path — the stream==batch
schedule-invariance theorem is what turns "replay the arrivals" into
"reach the interrupted run's fixpoint bit-for-bit".

Format — append-only segment files ``wal-<startseq>.log`` of
length-prefixed, CRC-guarded pickle records::

    [u32 payload_len][u32 crc32(payload)][payload]

``payload`` pickles ``{"type": "ingest"|"abort", "seq": int, ...}``;
ingest records carry the *resolved* ``names``/``edges``/``ids`` (ids
are materialized before logging so replay never re-runs auto-id
assignment).  An ``abort`` record marks a sequence number whose ingest
was transactionally rolled back — replay skips it.  A torn tail (the
crash landed mid-append) is detected by the length/CRC check and
truncated on open; a record missing its abort marker because the
worker died mid-ingest is simply replayed, which is exactly the
all-or-nothing semantics the undo log gives the live path.

Segments exist so checkpoints can garbage-collect the log: after a
checkpoint at sequence ``s`` the service rotates to a fresh segment
and drops every segment whose records are all ``<= s``.
"""

from __future__ import annotations

import os
import pickle
import struct
import time
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator

from repro import faults, obs

_HEADER = struct.Struct("<II")
_SEGMENT_FMT = "wal-{:016d}.log"


@dataclass
class WalRecord:
    seq: int
    names: list
    edges: object  # (E, 2) int64 ndarray or None
    ids: list


def _segment_start(path: Path) -> int:
    return int(path.stem.split("-")[1])


def _segments(directory: Path) -> list[Path]:
    return sorted(directory.glob("wal-*.log"), key=_segment_start)


def _read_segment(path: Path, *, repair: bool = False) -> Iterator[dict]:
    """Yield good records; on a torn/corrupt tail stop (and truncate the
    file back to the last good record when ``repair``)."""
    good_end = 0
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    while off + _HEADER.size <= len(data):
        length, crc = _HEADER.unpack_from(data, off)
        payload = data[off + _HEADER.size : off + _HEADER.size + length]
        if len(payload) < length or zlib.crc32(payload) != crc:
            break
        off += _HEADER.size + length
        good_end = off
        yield pickle.loads(payload)
    if repair and good_end < len(data):
        with open(path, "r+b") as f:
            f.truncate(good_end)


class WriteAheadLog:
    """Single-writer, fsync-per-append ingest log over segment files."""

    def __init__(self, directory: str | os.PathLike, *, fsync: bool = True):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        segs = _segments(self.directory)
        if segs:
            # drop a torn tail before appending after it
            for _ in _read_segment(segs[-1], repair=True):
                pass
            self._path = segs[-1]
        else:
            self._path = self.directory / _SEGMENT_FMT.format(0)
        self._f = open(self._path, "ab")

    # -- append side --------------------------------------------------------

    def _append(self, payload: dict) -> int:
        blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
        t0 = time.perf_counter()
        self._f.write(_HEADER.pack(len(blob), zlib.crc32(blob)))
        self._f.write(blob)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        reg = obs.get_registry()
        reg.counter("wal.appends").inc()
        reg.counter("wal.bytes").inc(_HEADER.size + len(blob))
        reg.histogram("wal.append_ms").observe(
            (time.perf_counter() - t0) * 1e3
        )
        return _HEADER.size + len(blob)

    def append(self, seq: int, names, edges, ids) -> int:
        """Durably log one ingest batch; returns bytes written."""
        return self._append(
            {"type": "ingest", "seq": int(seq), "names": list(names),
             "edges": edges, "ids": [int(i) for i in ids]}
        )

    def append_abort(self, seq: int) -> None:
        """Mark ``seq`` as transactionally rolled back (replay skips it)."""
        self._append({"type": "abort", "seq": int(seq)})

    # -- checkpoint coordination -------------------------------------------

    def rotate(self, next_seq: int) -> None:
        """Start a fresh segment whose records will all be >= next_seq."""
        # a crash here (checkpoint durable, old segment still live) must
        # recover cleanly: the checkpoint wins, the stale tail is skipped
        faults.maybe_fail("wal.rotate")
        self._f.close()
        self._path = self.directory / _SEGMENT_FMT.format(int(next_seq))
        self._f = open(self._path, "ab")

    def gc(self, upto_seq: int) -> int:
        """Delete segments fully covered by a checkpoint at ``upto_seq``
        (every record <= upto_seq); returns segments removed."""
        segs = _segments(self.directory)
        removed = 0
        for seg, nxt in zip(segs, segs[1:]):
            if seg == self._path:
                continue
            if _segment_start(nxt) - 1 <= upto_seq:
                seg.unlink()
                removed += 1
        return removed

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    # -- replay side --------------------------------------------------------

    @staticmethod
    def scan(directory: str | os.PathLike) -> tuple[list[WalRecord], set[int]]:
        """All good ingest records (seq order) + the aborted-seq set.
        Repairs a torn tail in the final segment as a side effect."""
        directory = Path(directory)
        records: dict[int, WalRecord] = {}
        aborted: set[int] = set()
        segs = _segments(directory)
        for i, seg in enumerate(segs):
            for rec in _read_segment(seg, repair=(i == len(segs) - 1)):
                if rec["type"] == "ingest":
                    records[rec["seq"]] = WalRecord(
                        rec["seq"], rec["names"], rec["edges"], rec["ids"]
                    )
                elif rec["type"] == "abort":
                    aborted.add(rec["seq"])
        return [records[s] for s in sorted(records)], aborted
