"""Sharded serving coordinator: one service replica per mesh process.

The paper's decomposition maps onto a multi-process JAX mesh with no
new algorithm: bins are the neighborhoods, so sharding the bin batch
axis over the mesh partitions the neighborhoods across hosts, and the
psum'd match-bitset exchange of ``core.parallel`` *is* the cross-host
boundary-message pass — generalizing it from one device to the mesh is
a collective swap, not a rewrite.  What this module adds is the serving
topology around that engine:

* **SPMD-replicated logical state.**  Every process runs the same
  ``ResolveService`` and ingests every micro-batch in the same order
  (the coordinator routes each ingest to *all* shards — the shard
  owning an arrival's LSH buckets does the bucket work, see below).
  Host-side maintenance (canopy replay, cover splice, union-find) is
  deterministic, so the logical state stays bit-for-bit identical on
  every process; :func:`repro.stream.digest.state_digest` is the
  machine-checked witness.  Only *device* work is partitioned.

* **Partitioned LSH bucket map.**  Each process stores and probes only
  the buckets :func:`repro.launch.sharding.bucket_shard` assigns to it
  (a deterministic FNV hash — routing needs no directory), and each
  probe's candidate set is reassembled by a cross-process union
  (:class:`repro.launch.sharding.ShardMerger`).  The partition is
  exhaustive and disjoint, and ``delta._probe`` sorts the union, so the
  candidate sets — and everything downstream — are exactly the
  unsharded ones.

* **Partitioned bin rounds.**  The engine receives the cross-process
  service mesh; ``run_parallel`` shards every bin's row batch over it
  (rows are padded to a mesh-size multiple) and merges each round's
  matches with the same ``psum`` it already used on one device — the
  boundary-message merge at every round and quiescence point.

Equivalence argument, in one line: the sharded run performs the same
deterministic host schedule on every process, and every partitioned
step (bucket probe, bin round) reassembles its exact unsharded result
before any state depends on it — so the fixpoint is bit-for-bit the
single-host one (Thms. 2/4 make the fixpoint schedule-invariant in the
first place; here even the schedule is identical).
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.launch.sharding import ShardMerger, ShardSpec


@dataclasses.dataclass(frozen=True)
class ShardContext:
    """This process's view of the sharded serving topology.

    ``spec`` partitions the LSH bucket map (per *process*), ``mesh``
    partitions bin rows (per *device*), ``merger`` reassembles probe
    candidate sets.  On a single-process mesh every component degrades
    to the identity: ``spec`` owns every bucket, ``merger.union`` is a
    no-op, and the engine mesh is the ordinary local-device mesh — so
    a 1-shard service is literally the unsharded service.
    """

    mesh: object
    spec: ShardSpec
    merger: ShardMerger

    @classmethod
    def create(cls, n_shards: int | None = None) -> "ShardContext":
        """Build the context for this process.

        Joins the ``jax.distributed`` service first when the
        ``REPRO_SHARD_COORD`` environment is set (subprocess workers of
        the CI mesh leg and the scaling benchmark), then derives the
        shard layout from the global device topology.
        """
        import jax

        from repro.launch.mesh import em_service_mesh, init_em_distributed

        init_em_distributed()
        mesh = em_service_mesh(n_shards)
        procs = sorted({d.process_index for d in mesh.devices.flat})
        spec = ShardSpec(
            n_shards=len(procs), shard_id=procs.index(jax.process_index())
        )
        return cls(mesh=mesh, spec=spec, merger=ShardMerger(mesh))

    @property
    def n_shards(self) -> int:
        return self.spec.n_shards

    @property
    def shard_id(self) -> int:
        return self.spec.shard_id


class ShardCoordinator:
    """Thin ingest router over one shard's :class:`ResolveService`.

    Construction wires the shard context through the service: the LSH
    index gets the bucket partition + merge hook, the engine gets the
    cross-process mesh.  ``ingest`` routes a micro-batch into the local
    replica (every shard calls it with the same batch — the collective
    probe merge and the psum'd rounds are the synchronization points),
    and ``digest``/``digests_agree`` expose the equivalence oracle.
    """

    def __init__(self, ctx: ShardContext | None = None, config=None,
                 **service_kwargs):
        """``config`` is a :class:`repro.stream.service.ServiceConfig`;
        bare service keywords still work as a deprecated shim."""
        import warnings

        from repro.stream.service import ResolveService, ServiceConfig

        self.ctx = ctx if ctx is not None else ShardContext.create()
        if service_kwargs:
            if config is not None:
                raise TypeError(
                    "pass either config= or service keywords, not both "
                    f"(got {sorted(service_kwargs)})"
                )
            warnings.warn(
                "ShardCoordinator(**service_kwargs) is deprecated; pass "
                "ShardCoordinator(ctx, config=ServiceConfig(...)) instead",
                DeprecationWarning,
                stacklevel=2,
            )
            config = ServiceConfig(**service_kwargs)
        self.service = ResolveService(config, shard=self.ctx)

    def ingest(self, names, edges=None, **kwargs):
        """Route one micro-batch to the owning shards.

        Ownership is per LSH bucket, and an arrival's buckets are spread
        across shards by the FNV partition — so every ingest touches
        every shard (each does its owned slice of the bucket work) and
        the local replica advances the replicated logical state.  All
        shards MUST ingest the same batches in the same order: the probe
        union is a collective.
        """
        return self.service.ingest(names, edges, **kwargs)

    def resolve(self, entity_id: int):
        return self.service.resolve(entity_id)

    def snapshot(self):
        return self.service.snapshot()

    def digest(self) -> str:
        from repro.stream.digest import state_digest

        return state_digest(self.service)

    def digests_agree(self) -> bool:
        """Cross-process check that every replica holds the same state.

        All-gathers the 32-byte state digest over the mesh; on a
        single-process context this is trivially True.
        """
        d = self.digest()
        raw = hashlib.sha256(d.encode()).digest()
        local = np.frombuffer(raw, dtype=np.uint8).copy()
        gathered = self.merged_digests(local)
        return all(np.array_equal(g, local) for g in gathered)

    def merged_digests(self, local: np.ndarray) -> list[np.ndarray]:
        from repro.kernels.common import mesh_spans_processes

        if not mesh_spans_processes(self.ctx.mesh):
            return [local]
        flat = self.ctx.merger._gather(local.astype(np.uint8), 0)
        return list(flat.reshape(-1, len(local)))
