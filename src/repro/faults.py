"""Deterministic fault injection for the ingest path.

Chaos testing only earns its keep when a failure reproduces: every
hook here is seeded and counts deterministically, so a failing CI seed
replays bit-for-bit on a laptop.  The injection sites mirror the
serving span taxonomy (``docs/ARCHITECTURE.md``):

=================  ====================================================
site               fires at
=================  ====================================================
``lsh``            MinHash probe, after entity rows are staged
``replay``         localized canopy replay
``cover_splice``   incremental cover assembly + packed-array splice
``grounding_splice``  grounding delta application (MMP)
``rounds``         the fixpoint round loop
``commit``         match-store commit / snapshot publication
``wal.append``     the write-ahead-log append (before the fsync)
``wal.rotate``     the WAL segment rotation after a checkpoint commits
``ckpt.rename``    the checkpoint tmp-dir -> final atomic rename
=================  ====================================================

Modes:

* **raise** (default) — ``maybe_fail`` raises :class:`InjectedFault`;
  the transactional ingest path must roll back and the caller sees a
  clean failure.
* **crash** — ``os._exit(CRASH_EXIT_CODE)``: the process dies without
  unwinding, flushing, or atexit handlers, simulating a SIGKILL'd
  worker.  Crash-recovery tests run this in a subprocess and then
  ``ResolveService.recover`` the durability directory.
* **poison** — a request-level fault: ``maybe_fail`` raises whenever
  the in-flight batch contains one of ``poison_names``.  Poison is
  keyed on *names*, not ids, because the serving front-end assigns ids
  per flush attempt — a bisected retry legitimately re-ids a request.

Plans install process-globally (single-writer ingest means no
per-thread plumbing is needed) via :func:`install` / :func:`clear` or
the :func:`injected` context manager.  With no plan installed,
``maybe_fail`` is one global read and a ``None`` check.
"""

from __future__ import annotations

import os
import random
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Sequence

SITES = (
    "lsh",
    "replay",
    "cover_splice",
    "grounding_splice",
    "rounds",
    "commit",
    "wal.append",
    "wal.rotate",
    "ckpt.rename",
)

CRASH_EXIT_CODE = 117  # distinguishable from python tracebacks (1) and signals


class InjectedFault(RuntimeError):
    """A deterministic injected failure (transient-style)."""


class PoisonedRequest(ValueError):
    """An injected request-level failure: this batch contains a name
    the active :class:`FaultPlan` declared poisonous."""


@dataclass
class FaultPlan:
    """Which hits of which sites fail, and how.

    ``site_hits`` maps a site name to the set of 1-based hit counts
    that fail (``{"rounds": {1, 2}}`` fails the first two times the
    ``rounds`` site is reached, then passes).  ``crash=True`` switches
    from raising to ``os._exit``.  ``poison_names`` makes any site hit
    whose batch contains one of the names raise
    :class:`PoisonedRequest` (independent of ``site_hits``).
    """

    site_hits: dict[str, frozenset[int]] = field(default_factory=dict)
    crash: bool = False
    poison_names: frozenset[str] = frozenset()
    poison_site: str = "rounds"

    def __post_init__(self) -> None:
        for site in list(self.site_hits) + [self.poison_site]:
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r} (have {SITES})")
        self.site_hits = {k: frozenset(v) for k, v in self.site_hits.items()}
        self.poison_names = frozenset(self.poison_names)
        self._hits: dict[str, int] = {}
        self._lock = threading.Lock()

    @staticmethod
    def fail_once(site: str, hit: int = 1, *, crash: bool = False) -> "FaultPlan":
        """Fail exactly the ``hit``-th arrival at ``site``."""
        return FaultPlan(site_hits={site: frozenset({hit})}, crash=crash)

    @staticmethod
    def seeded(seed: int, sites: Sequence[str] = SITES, max_hit: int = 3) -> "FaultPlan":
        """A reproducible chaos plan: pick one site and one early hit
        from ``seed``.  Same seed -> same plan, forever."""
        rng = random.Random(seed)
        site = rng.choice(list(sites))
        hit = rng.randint(1, max_hit)
        return FaultPlan(site_hits={site: frozenset({hit})})

    def describe(self) -> str:
        parts = [f"{s}@{sorted(h)}" for s, h in sorted(self.site_hits.items())]
        if self.poison_names:
            parts.append(f"poison[{self.poison_site}]={sorted(self.poison_names)}")
        return ",".join(parts) + (" crash" if self.crash else "")

    # -- called from maybe_fail --------------------------------------------

    def check(self, site: str, names: Iterable[str] | None) -> None:
        if names is not None and self.poison_names and site == self.poison_site:
            bad = self.poison_names.intersection(names)
            if bad:
                raise PoisonedRequest(
                    f"poisoned request at site {site!r}: names {sorted(bad)}"
                )
        hits = self.site_hits.get(site)
        if hits is None:
            return
        with self._lock:
            n = self._hits.get(site, 0) + 1
            self._hits[site] = n
        if n in hits:
            if self.crash:
                os._exit(CRASH_EXIT_CODE)
            raise InjectedFault(f"injected fault at site {site!r} (hit {n})")


_plan: FaultPlan | None = None


def install(plan: FaultPlan) -> None:
    global _plan
    _plan = plan


def clear() -> None:
    global _plan
    _plan = None


@contextmanager
def injected(plan: FaultPlan) -> Iterator[FaultPlan]:
    install(plan)
    try:
        yield plan
    finally:
        clear()


def maybe_fail(site: str, names: Iterable[str] | None = None) -> None:
    """Fault hook; call at the entry of each named ingest stage."""
    plan = _plan
    if plan is not None:
        plan.check(site, names)
