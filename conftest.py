"""Repo-root conftest: make `tests.*` and `repro.*` importable under any
invocation (`pytest tests/`, `python -m pytest`, with or without
PYTHONPATH)."""

import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running tests (subprocess multi-shard runs etc.)"
    )
