"""Quickstart: collective entity matching on bibliographic data.

Builds a HEPTH-like dataset (author references with abbreviations,
typos, and name collisions + a coauthorship relation), covers it with
canopy neighborhoods, and resolves entities with the three
message-passing schemes of Rastogi et al. (VLDB 2011):

    NO-MP  — the matcher per neighborhood, no communication
    SMP    — simple message passing (Alg. 1)
    MMP    — maximal message passing (Alg. 3, Type-II matchers)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from __future__ import annotations

import numpy as np

from repro.core import pipeline
from repro.core import pairs as pairlib
from repro.data.synthetic import SynthConfig, make_dataset


def main():
    ds = make_dataset(SynthConfig.hepth(scale=0.12, seed=7))
    print(f"dataset: {len(ds.entities)} author references, "
          f"{len(ds.author_names)} true authors, "
          f"{len(ds.relations.edges['coauthor'])} coauthor edges")

    packed, gg, t_cover = pipeline.prepare(ds.entities, ds.relations)
    print(f"cover: {packed.num_neighborhoods} neighborhoods, "
          f"{len(gg.gids)} candidate pairs ({t_cover:.2f}s)\n")

    print(f"{'scheme':8s} {'prec':>6s} {'rec':>6s} {'f1':>6s} "
          f"{'evals':>6s} {'promoted':>9s}")
    results = {}
    for scheme in ("nomp", "smp", "mmp"):
        res = pipeline.resolve(
            ds.entities, ds.relations, scheme=scheme, packed=packed, gg=gg
        )
        prf = pipeline.evaluate(res, ds.entities.truth)
        results[scheme] = res
        print(f"{scheme:8s} {prf.precision:6.3f} {prf.recall:6.3f} "
              f"{prf.f1:6.3f} {res.result.neighborhood_evals:6d} "
              f"{res.result.messages_promoted:9d}")

    # show a few resolved matches
    print("\nsample matches (MMP):")
    for g in results["mmp"].closed.gids[:8]:
        a, b = pairlib.split_gid(np.int64(g))
        print(f"  {ds.entities.names[int(a)]!r:32s} == "
              f"{ds.entities.names[int(b)]!r}")

    # matches only the collective schemes recover
    smp_set = results["smp"].closed.as_set()
    extra = [g for g in results["mmp"].closed.gids if int(g) not in smp_set]
    if extra:
        print("\nrecovered ONLY by maximal message passing "
              "(the paper's chicken-and-egg chains):")
        for g in extra[:6]:
            a, b = pairlib.split_gid(np.int64(g))
            print(f"  {ds.entities.names[int(a)]!r:32s} == "
                  f"{ds.entities.names[int(b)]!r}")


if __name__ == "__main__":
    main()
