"""The paper's §6.3 grid experiment: round-parallel SPMD entity matching.

Every active neighborhood is evaluated in parallel on the mesh each
round (the Hadoop Map), the discovered matches are exchanged as a
match-bitset all-reduce (the Reduce), and newly-affected neighborhoods
form the next round's active set.  On this container the mesh has one
CPU device; on a pod the same code shards rounds over 256 chips (see
``repro/launch/dryrun.py --em`` for the production-mesh lowering).

Run:  PYTHONPATH=src python examples/grid_em.py
"""

from __future__ import annotations

from repro.core import pipeline
from repro.core.mln import MLNMatcher, PAPER_LEARNED
from repro.core.parallel import make_em_mesh, run_parallel
from repro.data.synthetic import SynthConfig, make_dataset


def main():
    ds = make_dataset(SynthConfig.dblp(scale=0.2, seed=3))
    packed, gg, _ = pipeline.prepare(ds.entities, ds.relations)
    mesh = make_em_mesh()
    print(f"{len(ds.entities)} references -> {packed.num_neighborhoods} "
          f"neighborhoods on a {mesh.devices.size}-device mesh")

    for scheme in ("nomp", "smp", "mmp"):
        res = run_parallel(packed, MLNMatcher(PAPER_LEARNED), gg, scheme=scheme)
        print(f"{scheme:5s}: {len(res.matches):4d} matches  "
              f"rounds={res.rounds}  evals={res.neighborhood_evals}  "
              f"active-per-round={res.history}")

    # verify against the sequential fixpoint (Theorems 2/4: consistency)
    from repro.core.driver import run_mmp

    seq = run_mmp(packed, MLNMatcher(PAPER_LEARNED), gg)
    par = run_parallel(packed, MLNMatcher(PAPER_LEARNED), gg, scheme="mmp")
    assert seq.matches.as_set() == par.matches.as_set()
    print("parallel MMP == sequential MMP  (consistency verified)")


if __name__ == "__main__":
    main()
