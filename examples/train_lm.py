"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Exercises the full training substrate on CPU: EM-deduplicated data
pipeline -> qwen1.5-0.5B-family model (width-reduced to ~100M params)
-> microbatched AdamW train step -> checkpointing with a simulated
preemption + restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

from __future__ import annotations

import argparse
import dataclasses
import tempfile

import numpy as np

from repro.configs.base import get_config
from repro.data.corpus import CorpusConfig
from repro.data.dedup import dedup_documents, filter_corpus
from repro.models.param import param_count
from repro.models.registry import get_model
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def make_model():
    """qwen1.5-0.5B family, width-reduced to ~100M params.

    (vocab 8k instead of 152k: this container is a single CPU core at
    ~25 GFLOP/s and the unembed matmul dominates; the architecture and
    the whole substrate are unchanged.)"""
    base = get_config("qwen1_5_0_5b")
    return dataclasses.replace(
        base, name="qwen1.5-100m", d_model=640, n_heads=10, n_kv_heads=10,
        d_ff=1792, n_layers=16, vocab_size=8192,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--preempt-at", type=int, default=0,
                    help="simulate a preemption at this step (0 = off)")
    args = ap.parse_args()

    cfg = make_model()
    api = get_model(cfg)
    print(f"model: {cfg.name}  params={param_count(api.param_specs())/1e6:.1f}M")

    # --- data: the paper's technique as the dedup stage -----------------
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, cfg.vocab_size, size=512) for _ in range(64)]
    docs += [d.copy() for d in docs[:16]]  # inject duplicates
    report = dedup_documents(docs, source_of=np.arange(len(docs)) % 8)
    docs = filter_corpus(docs, report)
    print(f"dedup: {report.n_docs} docs -> {len(docs)} "
          f"({report.n_removed} near-duplicates removed by collective EM)")

    data = CorpusConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0,
    )
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=100, log_every=20, microbatches=2,
        ckpt_dir=ckpt_dir, async_ckpt=True,
    )
    opt = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)

    if args.preempt_at:
        t = Trainer(api, data, opt, dataclasses.replace(tcfg, steps=args.preempt_at))
        t.preempted = False
        out = t.run()
        print(f"-- simulated preemption after step {out['steps_done']}; restarting --")

    trainer = Trainer(api, data, opt, tcfg)
    out = trainer.run()
    print(f"trained to step {out['steps_done']} "
          f"in {out['wall_time_s']:.1f}s; checkpoints in {ckpt_dir}")
    for step, loss in out["losses"]:
        print(f"  step {step:4d}  loss {loss:.4f}")
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
