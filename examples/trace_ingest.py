"""Export a Chrome-trace/Perfetto timeline from a streamed ingest.

Streams a HEPTH-like corpus through ``ResolveService`` in micro-batches
and writes the ``repro.obs`` span log as a Chrome ``trace_event`` file:
every ingest shows up as a nested timeline
(lsh → replay → cover-splice → grounding-splice → rounds → commit),
one track per thread.  Open the output at https://ui.perfetto.dev or
``chrome://tracing``.

Also prints the registry snapshot's per-stage rollup and the resolve
latency percentiles, i.e. the numbers the benchmarks consume.

Run:  PYTHONPATH=src python examples/trace_ingest.py [trace.json]

CI runs this on every push and uploads the trace as a workflow
artifact, so there is always a browsable timeline for the current HEAD.
"""

from __future__ import annotations

import sys

from repro import obs
from repro.data.synthetic import SynthConfig, arrival_stream, make_dataset
from repro.stream import ResolveService, ServiceConfig


def main(out: str = "trace.json") -> None:
    obs.reset()
    ds = make_dataset(SynthConfig.hepth(scale=0.05, seed=7))
    batches = arrival_stream(ds, 4)
    svc = ResolveService(ServiceConfig(scheme="mmp"))
    print(f"streaming {len(ds.entities)} entities in {len(batches)} batches")
    for b in batches:
        svc.ingest(b.names, b.edges, ids=b.ids)
    svc.resolve_many(range(min(64, svc.snapshot().n_entities)))

    snap = obs.get_registry().snapshot()
    print(f"\n{'span':28s} {'count':>5s} {'total_ms':>9s}")
    for name in sorted(snap["spans"]):
        agg = snap["spans"][name]
        print(f"{name:28s} {agg['count']:5d} {agg['total_s'] * 1e3:9.1f}")
    lat = snap["histograms"]["resolve.latency_ms"]
    print(f"\nresolve latency: p50={lat['p50']:.3f}ms p99={lat['p99']:.3f}ms "
          f"({lat['count']} calls)")
    up = sum(v for k, v in snap["counters"].items()
             if k.startswith("transfer."))
    print(f"host->device uploads: {up} bytes")

    n = obs.write_chrome_trace(out)
    print(f"\nwrote {n} span events to {out} — open at "
          "https://ui.perfetto.dev")


if __name__ == "__main__":
    main(*sys.argv[1:2])
