"""Batched serving example: prefill + greedy decode with a KV cache.

Loads a small dense model (random weights — the point is the serving
machinery: static-shape batched prefill, cached single-token decode,
the same ``serve_step`` the multi-pod dry-run lowers at 32k/500k
context).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.base import smoke_config
from repro.models.registry import get_model
from repro.serve.engine import demo_engine


def main():
    cfg = smoke_config("yi_6b")
    api = get_model(cfg)
    engine = demo_engine(api, batch=4, s_max=96)
    print(f"serving {cfg.name}: batch=4, cache={engine.s_max} positions")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size - 1, size=24).astype(np.int32)
               for _ in range(10)]

    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new=16)
    dt = time.perf_counter() - t0
    total_new = sum(len(o) for o in outs)
    print(f"{len(prompts)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: prompt[-4:]={prompts[i][-4:].tolist()} -> {o[:8]}...")

    # steady-state decode throughput (compile excluded)
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new=16)
    dt = time.perf_counter() - t0
    print(f"steady-state: {total_new/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
