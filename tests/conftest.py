"""Shared fixtures. Tests run on ONE CPU device (the dry-run is the only
place that forces 512 placeholder devices, in its own process)."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import fig1
from repro.core.mln import MLNMatcher, PAPER_LEARNED, PEDAGOGICAL
from repro.data.synthetic import SynthConfig, make_dataset


@pytest.fixture(scope="session")
def fig1_packed():
    return fig1.packed_cover()


@pytest.fixture(scope="session")
def mln_pedagogical():
    return MLNMatcher(PEDAGOGICAL)


@pytest.fixture(scope="session")
def mln_paper():
    return MLNMatcher(PAPER_LEARNED)


@pytest.fixture(scope="session")
def hepth_small():
    """A small HEPTH-like synthetic dataset (abbreviated names, clashes)."""
    return make_dataset(SynthConfig.hepth(scale=0.035, seed=7))


@pytest.fixture(scope="session")
def dblp_small():
    """A small DBLP-like synthetic dataset (full names + typo noise)."""
    return make_dataset(SynthConfig.dblp(scale=0.035, seed=11))


def random_neighborhood_batch(rng: np.random.Generator, B: int = 2, k: int = 6):
    """Random padded NeighborhoodBatch for property tests."""
    from repro.core import pairs as pairlib
    from repro.core.types import NeighborhoodBatch

    P = pairlib.num_pairs(k)
    n_live = rng.integers(2, k + 1, size=B)
    ids = np.full((B, k), -1, dtype=np.int64)
    for b in range(B):
        ids[b, : n_live[b]] = rng.choice(100, size=n_live[b], replace=False)
    emask = ids >= 0
    co = rng.random((B, k, k)) < 0.35
    co = np.triu(co, 1)
    co = co | co.transpose(0, 2, 1)
    co &= emask[:, :, None] & emask[:, None, :]
    ii, jj = pairlib.triu_indices(k)
    pmask = emask[:, ii] & emask[:, jj]
    lev = rng.integers(0, 4, size=(B, P)).astype(np.int8)
    lev = np.where(pmask, lev, 0).astype(np.int8)
    gid = np.where(
        pmask,
        pairlib.make_gid(
            np.minimum(ids[:, ii], ids[:, jj]), np.maximum(ids[:, ii], ids[:, jj])
        ),
        -1,
    )
    return NeighborhoodBatch(
        entity_ids=ids, entity_mask=emask, coauthor=co,
        sim_level=lev, pair_gid=gid, pair_mask=pmask & (lev > 0),
    )
