"""Conformance matrix for the matcher plug-in registry.

Every registered family must ride through the *unchanged* drivers:

* scheme equivalence — NO-MP == SMP for every family, and MMP == SMP
  for every Type-II family (Thms. 1/2/4 applied per family);
* stream == batch — ``ResolveService`` reaches bit-for-bit the batch
  fixpoint for every family, with zero driver/stream changes;
* device path — ``run_parallel`` matches the sequential fixpoint for
  families that declare a parallel backend, and rejects (with a clear
  TypeError) families that do not;
* incrementality — the embedding family re-encodes only dirty
  entities under stream ingest (O(dirty), not O(corpus));
* quality separation — on the bipartite corpus the optimal assignment
  beats its greedy ablation and the embedding matcher disambiguates
  the coauthor trap that fools the MLN (the Fig. 4-style story the
  ``fig4_matchers`` benchmark measures).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import pipeline
from repro.core.driver import run_mmp, run_nomp, run_smp
from repro.core.matchers import get_matcher, list_matchers, matcher_info
from repro.core.parallel import run_parallel
from repro.data.synthetic import arrival_stream, make_bipartite
from repro.stream import ResolveService, ServiceConfig

FAMILIES = list_matchers()
TYPE_II = [n for n in FAMILIES if matcher_info(n).type_ii]
DEVICE = [n for n in FAMILIES if matcher_info(n).device_parallel]
HOST_ONLY = [n for n in FAMILIES if not matcher_info(n).device_parallel]


@pytest.fixture(scope="module")
def bip_ds():
    return make_bipartite(40, seed=1)


@pytest.fixture(scope="module")
def bip_state(bip_ds):
    packed, gg, _ = pipeline.prepare(bip_ds.entities, bip_ds.relations)
    return packed, gg


def _matcher(name):
    # registry defaults: embedding uses the hash encoder (deterministic,
    # name-free, cheap) — the lm/ngram encoders ride the same ground
    # path and are exercised by the fig4_matchers benchmark
    return get_matcher(name)


# ---------------------------------------------------------------------------
# Scheme equivalence: NO-MP == SMP == MMP through unchanged drivers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_nomp_equals_smp(name, bip_state):
    packed, _ = bip_state
    m = _matcher(name)
    a = run_nomp(packed, m)
    b = run_smp(packed, m)
    assert a.matches.as_set() == b.matches.as_set(), name


@pytest.mark.parametrize("name", TYPE_II)
def test_mmp_equals_smp(name, bip_state):
    packed, gg = bip_state
    m = _matcher(name)
    a = run_mmp(packed, m, gg)
    b = run_smp(packed, m)
    assert a.matches.as_set() == b.matches.as_set(), name


# ---------------------------------------------------------------------------
# Stream == batch, bit-for-bit, per family — no service changes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_stream_equals_batch(name, bip_ds, bip_state):
    packed, _ = bip_state
    batch = run_smp(packed, _matcher(name))
    svc = ResolveService(ServiceConfig(matcher=name, scheme="smp"))
    for b in arrival_stream(bip_ds, 5):
        svc.ingest(b.names, b.edges, ids=b.ids)
    assert svc.matches.as_set() == batch.matches.as_set(), name


def test_stream_accepts_matcher_instance(bip_ds, bip_state):
    """``ServiceConfig.matcher`` takes an instance, not just a name."""
    packed, _ = bip_state
    m = get_matcher("hungarian")
    batch = run_smp(packed, m)
    svc = ResolveService(ServiceConfig(matcher=m, scheme="smp"))
    for b in arrival_stream(bip_ds, 4):
        svc.ingest(b.names, b.edges, ids=b.ids)
    assert svc.matches.as_set() == batch.matches.as_set()


# ---------------------------------------------------------------------------
# Embedding incrementality: stream ingest re-encodes only dirty entities
# ---------------------------------------------------------------------------


def test_embedding_reencodes_only_dirty(bip_ds):
    m = get_matcher("embedding")
    svc = ResolveService(ServiceConfig(matcher=m, scheme="smp"))
    batches = arrival_stream(bip_ds, 6)
    seen = 0
    for b in batches:
        before = m.encoded_ids
        svc.ingest(b.names, b.edges, ids=b.ids)
        seen += len(b.ids)
        # each arrival is encoded exactly once, ever: the per-ingest
        # growth is the batch's own (dirty) entities, never the corpus
        assert m.encoded_ids - before == len(b.ids), (b.ids, m.encoded_ids)
        assert m.encoded_ids == seen
    assert m.encoded_ids == bip_ds.n_refs
    # every forward pass encoded at least one fresh entity — memo hits
    # never trigger an encoder call, so calls can't exceed unique ids
    assert 0 < m.encode_calls <= m.encoded_ids, m.encode_calls


# ---------------------------------------------------------------------------
# Device path: run_parallel per declared capability
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", DEVICE)
def test_parallel_smp_equals_sequential(name, bip_state):
    packed, _ = bip_state
    m = _matcher(name)
    par = run_parallel(packed, m, scheme="smp")
    seq = run_smp(packed, m)
    assert par.matches.as_set() == seq.matches.as_set(), name


@pytest.mark.parametrize("name", HOST_ONLY)
def test_parallel_rejects_host_only_families(name, bip_state):
    packed, _ = bip_state
    with pytest.raises(TypeError, match="parallel"):
        run_parallel(packed, _matcher(name), scheme="smp")


def test_parallel_mmp_requires_device_promoter(bip_state):
    """The batched step-7 promoter is MLN-specific; other families get a
    clear redirect to the sequential MMP driver instead of wrong math."""
    packed, gg = bip_state
    with pytest.raises(TypeError, match="run_mmp"):
        run_parallel(packed, get_matcher("embedding"), gg, scheme="mmp")


# ---------------------------------------------------------------------------
# Quality separation on the bipartite corpus (the fig4_matchers story)
# ---------------------------------------------------------------------------


def _f1(name, bip_ds, bip_state):
    packed, gg = bip_state
    res = pipeline.resolve(
        bip_ds.entities, bip_ds.relations, scheme="smp",
        matcher=_matcher(name), packed=packed, gg=gg,
    )
    return pipeline.evaluate(res, bip_ds.entities.truth).f1


def test_hungarian_beats_greedy_on_traps(bip_ds, bip_state):
    opt = _f1("hungarian", bip_ds, bip_state)
    greedy = _f1("hungarian_greedy", bip_ds, bip_state)
    assert opt == 1.0, opt
    assert greedy < opt, (greedy, opt)


def test_embedding_disambiguates_coauthor_trap(bip_ds, bip_state):
    emb = _f1("embedding", bip_ds, bip_state)
    mln = _f1("mln", bip_ds, bip_state)
    assert emb == 1.0, emb
    assert mln < emb, (mln, emb)
