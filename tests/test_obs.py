"""Runtime observability (repro.obs): registry, spans, exporters.

Covers the ISSUE-6 acceptance surface:

* exact nearest-rank percentiles over raw histogram samples;
* registry and span-log thread-safety under ``ResolveService``
  concurrent readers (the serving read path records latency samples
  from many threads while ingests commit);
* span nesting/ordering through a real end-to-end ingest (the
  ``ingest -> {lsh, replay, cover_splice, rounds, commit}`` taxonomy);
* device-transfer accounting plumbed through ``IngestReport``;
* registry-backed counters staying consistent with the dataclass views;
* tracing overhead on the ingest path bounded (<5% + noise slack);
* Chrome-trace/JSON exporters producing parseable output.
"""

from __future__ import annotations

import json
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.data.synthetic import arrival_stream
from repro.obs.registry import MetricsRegistry
from repro.stream import ResolveService


@pytest.fixture(autouse=True)
def _fresh_registry():
    obs.reset()
    obs.get_registry().set_tracing(True)
    yield
    obs.get_registry().set_tracing(True)


def _stream(ds, n_batches, **kwargs):
    batches = arrival_stream(ds, n_batches)
    svc = ResolveService(**kwargs)
    for b in batches:
        svc.ingest(b.names, b.edges, ids=b.ids)
    return svc


# ---------------------------------------------------------------------------
# Histogram: exact percentiles, reservoir degradation
# ---------------------------------------------------------------------------


def test_histogram_percentiles_exact():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in range(1, 101):  # 1..100, shuffled order must not matter
        h.observe(((v * 37) % 100) + 1)
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == 1 and s["max"] == 100
    assert s["p50"] == 50
    assert s["p90"] == 90
    assert s["p99"] == 99
    assert h.percentile(100) == 100
    assert h.percentile(0) == 1  # nearest-rank: rank clamps to 1


def test_histogram_single_sample_and_empty():
    reg = MetricsRegistry()
    h = reg.histogram("x")
    assert h.summary()["p99"] == 0.0
    h.observe(42.0)
    s = h.summary()
    assert s["p50"] == s["p99"] == 42.0
    assert s["mean"] == 42.0


def test_histogram_reservoir_keeps_exact_aggregates():
    reg = MetricsRegistry()
    h = reg.histogram("r")
    h.max_samples = 64  # force the reservoir path
    n = 1000
    for v in range(n):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == n
    assert s["sum"] == sum(range(n))
    assert s["min"] == 0.0 and s["max"] == float(n - 1)
    assert len(h.samples) == 64  # bounded
    # percentiles degrade to an estimate but stay inside the value range
    assert 0.0 <= s["p50"] <= n - 1


def test_counter_gauge_and_reset_keep_cached_refs():
    reg = obs.get_registry()
    c = reg.counter("x.count")
    g = reg.gauge("x.peak")
    c.inc(5)
    g.max(3)
    g.max(2)  # high-water: must not lower
    assert reg.value("x.count") == 5
    assert reg.snapshot()["gauges"]["x.peak"] == 3
    obs.reset()
    # cached instrument references survive reset and stay wired in
    c.inc(2)
    assert reg.value("x.count") == 2
    assert reg.snapshot()["gauges"]["x.peak"] == 0.0


# ---------------------------------------------------------------------------
# Spans: nesting, disable, cap
# ---------------------------------------------------------------------------


def test_span_nesting_records_parent_and_depth():
    reg = obs.get_registry()
    with obs.span("outer"):
        with obs.span("inner"):
            time.sleep(0.001)
    spans = {s.name: s for s in reg.spans}
    assert spans["inner"].parent == "outer"
    assert spans["inner"].depth == 1
    assert spans["outer"].parent is None and spans["outer"].depth == 0
    # the child closes first and lies inside the parent's window
    assert spans["inner"].t_start >= spans["outer"].t_start
    assert (spans["inner"].t_start + spans["inner"].dur_s
            <= spans["outer"].t_start + spans["outer"].dur_s + 1e-9)


def test_span_disabled_is_noop():
    reg = obs.get_registry()
    reg.set_tracing(False)
    with obs.span("quiet", arg=1) as s:
        s.set(more=2)
        assert s.fence(123) == 123
    assert reg.spans == []


def test_span_log_cap_drops_oldest():
    reg = MetricsRegistry(max_spans=8)
    for i in range(20):
        with obs.span(f"s{i}", registry=reg):
            pass
    assert len(reg.spans) == 8
    assert reg.spans_dropped == 12
    assert reg.spans[-1].name == "s19"  # newest survives
    assert reg.snapshot()["spans_dropped"] == 12


# ---------------------------------------------------------------------------
# End-to-end: one ingest produces the span taxonomy + counters
# ---------------------------------------------------------------------------


def test_e2e_ingest_spans_and_counters(hepth_small):
    svc = _stream(hepth_small, 3, scheme="mmp")
    assert len(svc.reports) == 3
    snap = obs.get_registry().snapshot()
    c = snap["counters"]
    assert c["ingest.count"] == 3
    # registry-backed counters agree with the dataclass views
    assert c.get("ingest.neighborhood_evals", 0) == sum(
        r.neighborhood_evals for r in svc.reports
    )
    assert c.get("ingest.cover_splice_rows", 0) == sum(
        r.cover_splice_rows for r in svc.reports
    )
    assert c.get("ingest.grounding_splice_rows", 0) == sum(
        r.grounding_splice_rows for r in svc.reports
    )
    # per-stage spans, rolled up per name, one entry per ingest
    for name in ("ingest", "ingest.lsh", "ingest.replay",
                 "ingest.cover_splice", "ingest.grounding_splice",
                 "ingest.rounds", "ingest.commit"):
        assert snap["spans"][name]["count"] == 3, name
    # parent links form the documented tree
    by_name = {}
    for s in obs.get_registry().spans:
        by_name.setdefault(s.name, s)
    for child in ("ingest.lsh", "ingest.replay", "ingest.cover_splice",
                  "ingest.grounding_splice", "ingest.rounds",
                  "ingest.commit"):
        assert by_name[child].parent == "ingest", child
    # the ingest wall-clock histogram has one sample per ingest and the
    # stage spans sum to no more than the root span
    assert snap["histograms"]["ingest.wall_ms"]["count"] == 3
    stage_total = sum(
        snap["spans"][n]["total_s"]
        for n in snap["spans"] if n.startswith("ingest.")
    )
    assert stage_total <= snap["spans"]["ingest"]["total_s"] + 0.05


def test_e2e_parallel_ingest_transfer_accounting(hepth_small):
    svc = _stream(hepth_small, 2, scheme="mmp", parallel=True)
    snap = obs.get_registry().snapshot()
    c = snap["counters"]
    # the parallel engine stages bins and grounds rows -> bytes recorded
    assert c.get("transfer.prepare_bytes", 0) > 0
    assert c.get("transfer.gcache_bytes", 0) > 0
    assert obs.total_upload_bytes() == sum(
        c.get(f"transfer.{s}_bytes", 0) for s in ("gcache", "promoter",
                                                  "prepare")
    )
    # per-ingest deltas on the report sum to the cumulative counters
    assert sum(r.upload_bytes for r in svc.reports) == obs.total_upload_bytes()
    assert all(r.upload_bytes > 0 for r in svc.reports)
    # engine rounds published under em.*
    assert c.get("em.runs", 0) == 2
    assert snap["histograms"]["em.wall_ms"]["count"] == 2


def test_resolve_latency_histogram(hepth_small):
    svc = _stream(hepth_small, 2, scheme="smp")
    obs.reset()
    snap_obj = svc.snapshot()
    for _ in range(10):
        snap_obj.resolve_many([0, 1, 2, 3])
    svc.resolve_many([0, 1])
    svc.resolve(0)
    snap = obs.get_registry().snapshot()
    lat = snap["histograms"]["resolve.latency_ms"]
    assert lat["count"] == 12  # one sample per call, not per id
    assert snap["counters"]["resolve.queries"] == 10 * 4 + 2 + 1
    assert lat["p50"] <= lat["p99"]
    assert lat["p99"] < 1000.0  # sane units: milliseconds


# ---------------------------------------------------------------------------
# Thread-safety under concurrent readers
# ---------------------------------------------------------------------------


def test_registry_thread_safety_under_concurrent_readers(hepth_small):
    batches = arrival_stream(hepth_small, 6)
    svc = ResolveService(scheme="smp")
    svc.ingest(batches[0].names, batches[0].edges, ids=batches[0].ids)
    obs.reset()
    stop = threading.Event()
    errors: list[Exception] = []
    calls = [0] * 4

    def reader(i: int) -> None:
        rng = np.random.default_rng(i)
        try:
            while not stop.is_set():
                snap_obj = svc.snapshot()
                ids = rng.integers(0, max(snap_obj.n_entities, 1), size=16)
                snap_obj.resolve_many(ids)
                calls[i] += 1
                # concurrent snapshot() of the registry must never throw
                # and always be internally consistent JSON
                json.dumps(obs.get_registry().snapshot())
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    try:
        for b in batches[1:]:
            svc.ingest(b.names, b.edges, ids=b.ids)
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors
    snap = obs.get_registry().snapshot()
    # every reader call landed exactly one latency sample
    assert snap["histograms"]["resolve.latency_ms"]["count"] == sum(calls)
    assert snap["counters"]["resolve.queries"] == 16 * sum(calls)
    assert snap["counters"]["ingest.count"] == len(batches) - 1
    # span records from the ingest thread interleaved safely
    assert snap["spans"]["ingest"]["count"] == len(batches) - 1


# ---------------------------------------------------------------------------
# Overhead: tracing must stay cheap on the ingest path
# ---------------------------------------------------------------------------


def test_tracing_overhead_under_5_percent(hepth_small):
    def run_once() -> float:
        obs.reset()
        t0 = time.perf_counter()
        _stream(hepth_small, 4, scheme="smp")
        return time.perf_counter() - t0

    obs.get_registry().set_tracing(False)
    run_once()  # warm caches (jit, name levels) off the clock
    t_off = min(run_once() for _ in range(2))
    obs.get_registry().set_tracing(True)
    t_on = min(run_once() for _ in range(2))
    # <5% relative overhead, plus an absolute allowance for timer noise
    # at this corpus scale (CI machines jitter more than spans cost)
    assert t_on <= t_off * 1.05 + 0.35, (t_on, t_off)


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------


def test_chrome_trace_export(tmp_path, hepth_small):
    _stream(hepth_small, 2, scheme="smp")
    path = tmp_path / "trace.json"
    n = obs.write_chrome_trace(str(path))
    assert n > 0
    data = json.loads(path.read_text())
    events = data["traceEvents"]
    assert len(events) == n + 1  # + the process_name metadata record
    xs = [e for e in events if e["ph"] == "X"]
    assert xs, "no complete events exported"
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert isinstance(e["name"], str)
    roots = [e for e in xs if e["name"] == "ingest"]
    assert len(roots) == 2
    kids = [e for e in xs if e.get("args", {}).get("parent") == "ingest"]
    assert kids


def test_snapshot_export(tmp_path):
    reg = obs.get_registry()
    reg.counter("a.b").inc(7)
    reg.histogram("c").observe(1.5)
    path = tmp_path / "snap.json"
    snap = obs.write_snapshot(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(snap))
    assert on_disk["counters"]["a.b"] == 7
    assert on_disk["histograms"]["c"]["count"] == 1


def test_profiler_session_noop_without_logdir(monkeypatch):
    monkeypatch.delenv("REPRO_JAX_PROFILE_DIR", raising=False)
    with obs.profiler_session() as active:
        assert active is False


def test_quality_reexport_is_core_metrics():
    from repro.core import metrics as core_metrics
    from repro.obs import quality

    assert quality.prf is core_metrics.prf
    assert quality.PRF is core_metrics.PRF
    assert quality.soundness is core_metrics.soundness
    assert quality.completeness is core_metrics.completeness
