"""End-to-end EM on synthetic bibliographic data (paper §6 protocol).

HEPTH-like (abbreviated names, collisions) and DBLP-like (full names +
typos) datasets; canopy total cover; NO-MP / SMP / MMP with the
Appendix-B MLN and the RULES matcher.  Checks the paper's qualitative
claims: soundness vs UB, recall ordering NO-MP <= SMP <= MMP, high
precision, near-1 completeness of MMP vs UB.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import metrics as metricslib
from repro.core import pipeline
from repro.core.cover import is_total
from repro.core.mln import MLNMatcher, PAPER_LEARNED
from repro.core.rules import RulesMatcher


@pytest.fixture(scope="module")
def prepared(hepth_small):
    packed, gg, t = pipeline.prepare(hepth_small.entities, hepth_small.relations)
    return packed, gg


@pytest.fixture(scope="module")
def results(hepth_small, prepared):
    packed, gg = prepared
    out = {}
    for scheme in ("nomp", "smp", "mmp"):
        out[scheme] = pipeline.resolve(
            hepth_small.entities, hepth_small.relations,
            scheme=scheme, packed=packed, gg=gg,
        )
    return out


def test_cover_is_total(hepth_small, prepared):
    packed, gg = prepared
    assert is_total(packed.cover, hepth_small.relations, gg.gids)


def test_recall_ordering(hepth_small, results):
    truth = hepth_small.entities.truth
    rec = {
        s: pipeline.evaluate(results[s], truth).recall for s in results
    }
    assert rec["nomp"] <= rec["smp"] + 1e-9
    assert rec["smp"] <= rec["mmp"] + 1e-9
    assert rec["mmp"] > 0.5, rec


def test_precision_high(hepth_small, results):
    truth = hepth_small.entities.truth
    for s in results:
        prf = pipeline.evaluate(results[s], truth)
        assert prf.precision > 0.9, (s, prf)


def test_soundness_vs_ub(hepth_small, results):
    """UB (§6.1) upper-bounds the full-run matches; soundness of every
    message-passing scheme implies its matches are inside UB."""
    truth = hepth_small.entities.truth
    ub = pipeline.upper_bound(results["mmp"], truth)
    for s in results:
        snd = metricslib.soundness(results[s].result.matches, ub)
        assert snd >= 0.99, (s, snd)


def test_mmp_completeness_near_one(hepth_small, results):
    """Paper finds completeness ~1 for MMP (Fig. 3c)."""
    truth = hepth_small.entities.truth
    ub = pipeline.upper_bound(results["mmp"], truth)
    comp = metricslib.completeness(results["mmp"].result.matches, ub)
    assert comp >= 0.9, comp


def test_rules_matcher_e2e(dblp_small):
    res = pipeline.resolve(
        dblp_small.entities, dblp_small.relations,
        scheme="smp", matcher=RulesMatcher(),
    )
    prf = pipeline.evaluate(res, dblp_small.entities.truth)
    assert prf.precision > 0.9 and prf.recall > 0.4, prf


def test_linear_scaling_in_neighborhoods(hepth_small, prepared):
    """Theorem 3: evals grow linearly (bounded re-activations)."""
    packed, gg = prepared
    m = MLNMatcher(PAPER_LEARNED)
    from repro.core.driver import run_smp

    res = run_smp(packed, m)
    assert res.neighborhood_evals <= 4 * packed.num_neighborhoods


def test_dedup_pipeline(dblp_small):
    """The EM technique as the LM-corpus dedup stage (DESIGN §4)."""
    from repro.data.dedup import dedup_documents

    rng = np.random.default_rng(0)
    base = [rng.integers(0, 1000, size=200) for _ in range(12)]
    docs = []
    source = []
    for i, d in enumerate(base):
        docs.append(d)
        source.append(i % 4)  # crawl-source relation (the Coauthor analogue)
        if i % 3 == 0:  # near-duplicate: small mutation
            d2 = d.copy()
            d2[::17] += 1
            docs.append(d2)
            source.append(i % 4)
    report = dedup_documents(docs, source_of=np.asarray(source))
    # the engineered near-duplicates form multi-document clusters and
    # one representative per cluster is kept
    multi = [c for c in report.clusters if len(c) >= 2]
    assert len(multi) >= 3, report
    assert report.n_removed >= 3
    assert report.keep_mask.sum() == len(docs) - report.n_removed
