"""Subprocess entry for the crash-recovery tests: a worker that gets
killed mid-ingest.

Usage::

    python tests/crash_worker.py <durability_dir> <scheme> <site> \
                                 <ckpt_every> [hit]

Ingests the shared ``faultcorpus`` schedule with durability on and a
crash plan armed at ``site`` hit ``hit`` (default 3), then dies at the
injected site via ``os._exit(CRASH_EXIT_CODE)``: no unwinding, no
flush, no atexit, exactly a SIGKILL'd worker.  With the default hit 3
the first two batches commit cleanly (exercising the checkpoint at
``ckpt_every=2``) and the third dies mid-ingest; the durability-path
sites (``ckpt.rename``, ``wal.rotate``) fire once per checkpoint, not
per batch, so their matrix entries arm hit 1 — the crash lands inside
the first checkpoint's rename/rotation window instead.  Exits 0 only
if the site was never reached (the parent asserts it was).
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def main() -> int:
    dur_dir, scheme, site, ckpt_every = sys.argv[1:5]
    hit = int(sys.argv[5]) if len(sys.argv) > 5 else 3

    import faultcorpus
    from repro import faults
    from repro.faults import FaultPlan
    from repro.stream import ResolveService

    svc = ResolveService(
        scheme=scheme,
        durability_dir=dur_dir,
        checkpoint_every=int(ckpt_every),
    )
    faults.install(FaultPlan.fail_once(site, hit=hit, crash=True))
    for b in faultcorpus.batches():
        svc.ingest(b.names, b.edges, ids=b.ids)
    return 0


if __name__ == "__main__":
    sys.exit(main())
