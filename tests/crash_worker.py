"""Subprocess entry for the crash-recovery tests: a worker that gets
killed mid-ingest.

Usage::

    python tests/crash_worker.py <durability_dir> <scheme> <site> <ckpt_every>

Ingests the shared ``faultcorpus`` schedule with durability on and a
crash plan armed at ``site`` hit 3 — so the first two batches commit
cleanly (exercising the checkpoint at ``ckpt_every=2``) and the third
dies at the injected site via ``os._exit(CRASH_EXIT_CODE)``: no
unwinding, no flush, no atexit, exactly a SIGKILL'd worker.  Exits 0
only if the site was never reached (the parent asserts it was).
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def main() -> int:
    dur_dir, scheme, site, ckpt_every = sys.argv[1:5]

    import faultcorpus
    from repro import faults
    from repro.faults import FaultPlan
    from repro.stream import ResolveService

    svc = ResolveService(
        scheme=scheme,
        durability_dir=dur_dir,
        checkpoint_every=int(ckpt_every),
    )
    faults.install(FaultPlan.fail_once(site, hit=3, crash=True))
    for b in faultcorpus.batches():
        svc.ingest(b.names, b.edges, ids=b.ids)
    return 0


if __name__ == "__main__":
    sys.exit(main())
