"""Round-parallel SPMD message passing == sequential drivers (Thm 2/4
consistency), plus an 8-shard subprocess run proving the multi-device
path (this process holds exactly one CPU device).

The fused device-resident engine is checked three ways per scheme:
bit-for-bit fixpoint equality against the sequential drivers, equality
against the legacy per-round host loop (``fused=False``), and the
device-residency accounting itself — the grounding is computed exactly
once per bin per cover (ground-call counter) and the host dispatch
count collapses from O(bins x rounds) to O(bins + quiescence points).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import fig1, pipeline
from repro.core.driver import run_mmp, run_nomp, run_smp
from repro.core.global_grounding import build_global_grounding
from repro.core.mln import MLNMatcher, PAPER_LEARNED, PEDAGOGICAL
from repro.core.parallel import GroundingCache, run_parallel
from repro.core.rules import RulesMatcher


@pytest.fixture(scope="module")
def hepth_state(hepth_small):
    packed, gg, _ = pipeline.prepare(hepth_small.entities, hepth_small.relations)
    return packed, gg


def test_parallel_smp_equals_sequential_fig1(fig1_packed, mln_pedagogical):
    seq = run_smp(fig1_packed, mln_pedagogical)
    par = run_parallel(fig1_packed, mln_pedagogical, scheme="smp")
    assert seq.matches.as_set() == par.matches.as_set()


def test_parallel_mmp_equals_sequential_fig1(fig1_packed, mln_pedagogical):
    gg = build_global_grounding(
        fig1_packed.pair_levels, fig1.relations(), PEDAGOGICAL
    )
    seq = run_mmp(fig1_packed, mln_pedagogical, gg)
    par = run_parallel(fig1_packed, mln_pedagogical, gg, scheme="mmp")
    assert seq.matches.as_set() == par.matches.as_set()
    assert fig1.names_of(par.matches) == fig1.EXPECTED_MMP


@pytest.mark.parametrize(
    "scheme,fast_rounds",
    [("nomp", True), ("smp", True), ("mmp", True), ("mmp", False)],
)
def test_parallel_schemes_equal_sequential(hepth_state, mln_paper, scheme,
                                           fast_rounds):
    """All three schemes, fast_rounds on/off: the fused device engine,
    the legacy per-round host loop, and the sequential driver agree
    bit-for-bit on the fixpoint."""
    packed, gg = hepth_state
    if scheme == "nomp":
        seq = run_nomp(packed, mln_paper)
    elif scheme == "smp":
        seq = run_smp(packed, mln_paper)
    else:
        seq = run_mmp(packed, mln_paper, gg)
    par = run_parallel(
        packed, mln_paper, gg, scheme=scheme, fast_rounds=fast_rounds
    )
    legacy = run_parallel(
        packed, mln_paper, gg, scheme=scheme, fast_rounds=fast_rounds,
        fused=False,
    )
    assert par.matches.as_set() == seq.matches.as_set()
    assert legacy.matches.as_set() == seq.matches.as_set()


def test_parallel_rules(hepth_state):
    packed, _ = hepth_state
    m = RulesMatcher()
    seq = run_smp(packed, m)
    par = run_parallel(packed, m, scheme="smp")
    legacy = run_parallel(packed, m, scheme="smp", fused=False)
    assert seq.matches.as_set() == par.matches.as_set()
    assert seq.matches.as_set() == legacy.matches.as_set()


def test_grounding_once_per_bin_per_cover(hepth_state, mln_paper):
    """The multi-round run grounds each bin exactly once; a second run
    over the same cover re-grounds nothing (device arrays are reused)."""
    packed, gg = hepth_state
    gcache = GroundingCache()
    res = run_parallel(packed, mln_paper, gg, scheme="mmp", gcache=gcache)
    assert res.rounds >= 1
    assert gcache.ground_calls == len(packed.bins)
    rows_after = gcache.rows_ground
    assert rows_after > 0
    hits_before = gcache.bin_hits

    res2 = run_parallel(packed, mln_paper, gg, scheme="mmp", gcache=gcache)
    assert res2.matches.as_set() == res.matches.as_set()
    assert gcache.rows_ground == rows_after  # zero rows re-ground
    assert gcache.bin_hits == hits_before + len(packed.bins)


def test_fused_dispatch_counts(hepth_state, mln_paper):
    """Dispatch accounting of the device-resident engine: a cheap
    (greedy/rules) matcher's whole multi-round closure is ONE host
    dispatch; the collective MLN pays O(bins) per quiescence point plus
    one dispatch per greedy segment — O(bins + quiescence points), not
    the legacy O(bins x rounds)."""
    packed, gg = hepth_state
    n_bins = len(packed.bins)

    rules = run_parallel(packed, RulesMatcher(), scheme="smp")
    assert rules.dispatches == 1
    rules_legacy = run_parallel(packed, RulesMatcher(), scheme="smp", fused=False)
    assert rules_legacy.dispatches > rules.dispatches

    # collective SMP/MMP: full rounds only at the start and at greedy-
    # quiescence points; every re-activation round is inside a fused
    # greedy segment (one dispatch, however many rounds it runs) — the
    # dispatch count is O(bins x quiescence points + segments), not
    # O(bins x rounds).
    for scheme in ("smp", "mmp"):
        res = run_parallel(packed, mln_paper, gg, scheme=scheme)
        assert 0 < res.full_rounds < res.rounds
        segments = res.rounds - res.full_rounds  # each is >= 1 round
        assert res.dispatches <= n_bins * res.full_rounds + segments
        legacy = run_parallel(packed, mln_paper, gg, scheme=scheme, fused=False)
        assert res.matches.as_set() == legacy.matches.as_set()


def test_lru_capacity_bounds_and_fixpoint(hepth_state, mln_paper):
    """LRU-bounded GroundingCache (serving HBM budget): under capacities
    {1, 2, all} the fixpoint is bit-for-bit the unbounded cache's, the
    array-resident bin count never exceeds the capacity, and with
    capacity < bins the eviction and cold-reground paths actually fire
    (cold bins are re-ground on demand — grounding is pure, so the
    recomputed tensors are the evicted ones)."""
    packed, gg = hepth_state
    n_bins = len(packed.bins)
    assert n_bins > 2  # capacities {1, 2} below actually evict
    ref = {
        s: run_parallel(packed, mln_paper, gg, scheme=s).matches.as_set()
        for s in ("smp", "mmp")
    }
    for cap in (1, 2, n_bins):
        for scheme in ("smp", "mmp"):
            gcache = GroundingCache(capacity=cap)
            res = run_parallel(
                packed, mln_paper, gg, scheme=scheme, gcache=gcache
            )
            assert res.matches.as_set() == ref[scheme], (cap, scheme)
            assert gcache.peak_resident_bins <= cap
            assert res.peak_resident_bins <= cap
            if cap < n_bins:
                assert res.cache_evictions > 0, (cap, scheme)
                assert res.cold_regrounds > 0, (cap, scheme)
            else:
                assert res.cache_evictions == 0

    # spill mode must also cover the non-collective single-fused-dispatch
    # paths (rules/greedy closure, nomp): with the bound tighter than the
    # bin count they reroute through per-bin full rounds — same fixpoint,
    # residency genuinely capped (no all-bins fused materialization)
    for scheme in ("nomp", "smp"):
        ref_rules = run_parallel(packed, RulesMatcher(), scheme=scheme)
        gcache = GroundingCache(capacity=1)
        res = run_parallel(
            packed, RulesMatcher(), scheme=scheme, gcache=gcache
        )
        assert res.matches.as_set() == ref_rules.matches.as_set(), scheme
        assert gcache.peak_resident_bins <= 1
        assert res.dispatches > ref_rules.dispatches  # per-bin, not fused


def test_lru_hbm_budget_bounds_and_fixpoint(hepth_state, mln_paper):
    """The byte-budget knob: a budget below one bin's tensors degrades
    gracefully to exactly one resident bin (never zero — the hot bin
    must stay cached for the current dispatch), same fixpoint."""
    packed, gg = hepth_state
    ref = run_parallel(packed, mln_paper, gg, scheme="mmp").matches.as_set()
    gcache = GroundingCache(hbm_budget_bytes=1)
    res = run_parallel(packed, mln_paper, gg, scheme="mmp", gcache=gcache)
    assert res.matches.as_set() == ref
    assert gcache.peak_resident_bins == 1
    assert gcache.evictions > 0


def test_lru_lattice_fixpoint(mln_paper):
    """The multi-round lattice instance under bounded caches: depth
    rounds of fused greedy segments with eviction between dispatches
    still reach the unbounded fixpoint for both schemes."""
    from repro.data.synthetic import make_lattice_cover

    packed, rel, weights = make_lattice_cover(6, 2)
    gg = build_global_grounding(packed.pair_levels, rel, weights)
    m = MLNMatcher(weights)
    ref = {
        s: run_parallel(packed, m, gg, scheme=s).matches.as_set()
        for s in ("smp", "mmp")
    }
    n_bins = len(packed.bins)
    for cap in (1, 2, n_bins):
        for scheme in ("smp", "mmp"):
            gcache = GroundingCache(capacity=cap)
            res = run_parallel(packed, m, gg, scheme=scheme, gcache=gcache)
            assert res.matches.as_set() == ref[scheme], (cap, scheme)
            assert gcache.peak_resident_bins <= cap


def test_device_promotion_no_host_scans(hepth_state, mln_paper,
                                        fig1_packed, mln_pedagogical):
    """Step-7 promotion runs on device in the fused engine: zero host
    coupling-COO walks, same fixpoint as the host-promoting legacy loop
    and sequential driver (which both count their host scans)."""
    packed, gg = hepth_state
    res = run_parallel(packed, mln_paper, gg, scheme="mmp")
    assert res.promote_host_scans == 0
    legacy = run_parallel(packed, mln_paper, gg, scheme="mmp", fused=False)
    assert legacy.promote_host_scans > 0
    assert res.matches.as_set() == legacy.matches.as_set()

    # fig1 is the paper's promotion example: messages must actually be
    # promoted through the device path, not just trivially skipped
    gg1 = build_global_grounding(
        fig1_packed.pair_levels, fig1.relations(), PEDAGOGICAL
    )
    res1 = run_parallel(fig1_packed, mln_pedagogical, gg1, scheme="mmp")
    assert res1.promote_host_scans == 0
    assert res1.messages_promoted > 0
    assert fig1.names_of(res1.matches) == fig1.EXPECTED_MMP


@pytest.mark.slow
def test_parallel_8_shards_subprocess():
    """The paper's §6.3 grid experiment in miniature: 8 SPMD shards
    reach the same fixpoint as 1 (device count is locked at jax init,
    hence the subprocess)."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        from repro.core import fig1, pipeline
        from repro.core.mln import MLNMatcher, PAPER_LEARNED
        from repro.core.parallel import run_parallel
        from repro.data.synthetic import SynthConfig, make_dataset

        ds = make_dataset(SynthConfig.hepth(scale=0.02, seed=3))
        packed, gg, _ = pipeline.prepare(ds.entities, ds.relations)
        m = MLNMatcher(PAPER_LEARNED)
        par = run_parallel(packed, m, gg, scheme="mmp")
        print(json.dumps(sorted(int(g) for g in par.matches.gids)))
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    got = set(json.loads(out.stdout.strip().splitlines()[-1]))

    from repro.data.synthetic import SynthConfig, make_dataset

    ds = make_dataset(SynthConfig.hepth(scale=0.02, seed=3))
    packed, gg, _ = pipeline.prepare(ds.entities, ds.relations)
    seq = run_mmp(packed, MLNMatcher(PAPER_LEARNED), gg)
    assert got == seq.matches.as_set()
