"""Round-parallel SPMD message passing == sequential drivers (Thm 2/4
consistency), plus an 8-shard subprocess run proving the multi-device
path (this process holds exactly one CPU device).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import fig1, pipeline
from repro.core.driver import run_mmp, run_smp
from repro.core.global_grounding import build_global_grounding
from repro.core.mln import MLNMatcher, PAPER_LEARNED, PEDAGOGICAL
from repro.core.parallel import run_parallel
from repro.core.rules import RulesMatcher


def test_parallel_smp_equals_sequential_fig1(fig1_packed, mln_pedagogical):
    seq = run_smp(fig1_packed, mln_pedagogical)
    par = run_parallel(fig1_packed, mln_pedagogical, scheme="smp")
    assert seq.matches.as_set() == par.matches.as_set()


def test_parallel_mmp_equals_sequential_fig1(fig1_packed, mln_pedagogical):
    gg = build_global_grounding(
        fig1_packed.pair_levels, fig1.relations(), PEDAGOGICAL
    )
    seq = run_mmp(fig1_packed, mln_pedagogical, gg)
    par = run_parallel(fig1_packed, mln_pedagogical, gg, scheme="mmp")
    assert seq.matches.as_set() == par.matches.as_set()
    assert fig1.names_of(par.matches) == fig1.EXPECTED_MMP


def test_parallel_equals_sequential_synthetic(hepth_small):
    packed, gg, _ = pipeline.prepare(hepth_small.entities, hepth_small.relations)
    m = MLNMatcher(PAPER_LEARNED)
    seq = run_smp(packed, m)
    par = run_parallel(packed, m, gg, scheme="smp")
    assert seq.matches.as_set() == par.matches.as_set()


def test_parallel_rules(hepth_small):
    packed, gg, _ = pipeline.prepare(hepth_small.entities, hepth_small.relations)
    m = RulesMatcher()
    seq = run_smp(packed, m)
    par = run_parallel(packed, m, scheme="smp")
    assert seq.matches.as_set() == par.matches.as_set()


@pytest.mark.slow
def test_parallel_8_shards_subprocess():
    """The paper's §6.3 grid experiment in miniature: 8 SPMD shards
    reach the same fixpoint as 1 (device count is locked at jax init,
    hence the subprocess)."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        from repro.core import fig1, pipeline
        from repro.core.mln import MLNMatcher, PAPER_LEARNED
        from repro.core.parallel import run_parallel
        from repro.data.synthetic import SynthConfig, make_dataset

        ds = make_dataset(SynthConfig.hepth(scale=0.02, seed=3))
        packed, gg, _ = pipeline.prepare(ds.entities, ds.relations)
        m = MLNMatcher(PAPER_LEARNED)
        par = run_parallel(packed, m, gg, scheme="mmp")
        print(json.dumps(sorted(int(g) for g in par.matches.gids)))
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    got = set(json.loads(out.stdout.strip().splitlines()[-1]))

    from repro.data.synthetic import SynthConfig, make_dataset

    ds = make_dataset(SynthConfig.hepth(scale=0.02, seed=3))
    packed, gg, _ = pipeline.prepare(ds.entities, ds.relations)
    seq = run_mmp(packed, MLNMatcher(PAPER_LEARNED), gg)
    assert got == seq.matches.as_set()
