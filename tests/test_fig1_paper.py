"""Replay of the paper's running example (Figures 1-2, §2.1-§2.2).

The pedagogical MLN (R1 = -5, R2 = +8) on the C1/C2/C3 cover must
reproduce the paper's narrative exactly:

* NO-MP finds only (c1, c2)                                    [§2.2]
* SMP additionally recovers (b1, b2) via a simple message      [§2.2]
* MMP completes the {(a1,a2), (b2,b3), (c2,c3)} chain via
  maximal messages                                             [§5.2]
* the full-instance run equals the MMP output (completeness)
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import fig1
from repro.core.driver import run_mmp, run_nomp, run_smp
from repro.core.global_grounding import build_global_grounding
from repro.core.mln import PEDAGOGICAL
from repro.core.types import MatchStore


@pytest.fixture(scope="module")
def gg():
    packed = fig1.packed_cover()
    return build_global_grounding(packed.pair_levels, fig1.relations(), PEDAGOGICAL)


def test_nomp_matches_paper(fig1_packed, mln_pedagogical):
    res = run_nomp(fig1_packed, mln_pedagogical)
    assert fig1.names_of(res.matches) == fig1.EXPECTED_NOMP


def test_smp_matches_paper(fig1_packed, mln_pedagogical):
    res = run_smp(fig1_packed, mln_pedagogical)
    assert fig1.names_of(res.matches) == fig1.EXPECTED_SMP


def test_mmp_matches_paper(fig1_packed, mln_pedagogical, gg):
    res = run_mmp(fig1_packed, mln_pedagogical, gg)
    assert fig1.names_of(res.matches) == fig1.EXPECTED_MMP


def test_full_instance_run(mln_pedagogical):
    """One neighborhood containing everything = the 'run EM on all of E'
    reference.  The purely-collective chain activates (§2.1 arithmetic:
    3 x (-5) + 2 x 8 = +1 > 0)."""
    batch = fig1.full_batch()
    x = mln_pedagogical.run(batch)
    got = fig1.names_of(MatchStore(batch.pair_gid[x & (batch.pair_gid >= 0)]))
    assert got == fig1.EXPECTED_FULL


def test_mmp_complete_on_fig1(fig1_packed, mln_pedagogical, gg):
    """MMP == full run here: completeness 1 on the paper's example."""
    res = run_mmp(fig1_packed, mln_pedagogical, gg)
    assert fig1.names_of(res.matches) == fig1.EXPECTED_FULL


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_consistency_order_invariance(fig1_packed, mln_pedagogical, gg, seed):
    """Theorem 2/4 (consistency): any neighborhood order, same fixpoint."""
    rng = np.random.default_rng(seed)
    order = list(rng.permutation(fig1_packed.num_neighborhoods))
    smp = run_smp(fig1_packed, mln_pedagogical, order=order)
    assert fig1.names_of(smp.matches) == fig1.EXPECTED_SMP
    mmp = run_mmp(fig1_packed, mln_pedagogical, gg, order=order)
    assert fig1.names_of(mmp.matches) == fig1.EXPECTED_MMP


def test_smp_soundness_on_fig1(fig1_packed, mln_pedagogical):
    """Theorem 2 (soundness): SMP output subset of full-run output."""
    res = run_smp(fig1_packed, mln_pedagogical)
    assert fig1.names_of(res.matches) <= fig1.EXPECTED_FULL


def test_score_arithmetic_of_section_2_1(mln_pedagogical):
    """The -5/+8 arithmetic: {c1,c2} scores +3; the 3-chain adds +1."""
    batch = fig1.full_batch()
    B, P = batch.sim_level.shape
    x0 = np.zeros((B, P), dtype=bool)
    s_empty = mln_pedagogical.score(batch, x0)

    def with_pairs(pairs):
        x = x0.copy()
        for a, b in pairs:
            g = fig1.gid_of(a, b)
            slot = np.where(batch.pair_gid[0] == g)[0]
            assert len(slot) == 1
            x[0, slot[0]] = True
        return x

    s_c = mln_pedagogical.score(batch, with_pairs([("c1", "c2")]))
    assert np.isclose(s_c[0] - s_empty[0], 3.0, atol=1e-4)  # -5 + 8

    chain = [("a1", "a2"), ("b2", "b3"), ("c2", "c3")]
    base = [("c1", "c2"), ("b1", "b2")]
    s_base = mln_pedagogical.score(batch, with_pairs(base))
    s_all = mln_pedagogical.score(batch, with_pairs(base + chain))
    assert np.isclose(s_all[0] - s_base[0], 1.0, atol=1e-4)  # -15 + 16
