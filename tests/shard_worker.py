"""Subprocess worker for the sharded-equivalence battery.

Usage: ``python shard_worker.py <mode> <scheme> <n_batches> <perm_seed>``

* ``mode`` — ``hepth`` (stream a synthetic corpus through a
  :class:`~repro.stream.shard.ShardCoordinator`), ``lattice`` (drive
  ``run_parallel`` on the hand-packed evidence lattice), or ``probe``
  (minimal cross-process collective check, used to gate the distributed
  leg on jax builds without a CPU collectives client).
* ``perm_seed`` — ``-1`` for arrival order; otherwise the seed of a
  batch-order permutation (global ids are preserved via ``ingest(...,
  ids=...)``, so the permuted schedule resolves the same corpus).

Topology comes entirely from the environment, set by the parent test:
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for the
single-process multi-device leg, ``REPRO_SHARD_COORD`` / ``_N`` /
``_ID`` for the true multi-process leg (both must be set before jax
imports, which is why this is a subprocess).  Prints ``DIGEST <hex>``
and ``AGREE <0|1>`` on stdout.
"""

from __future__ import annotations

import sys


def main() -> None:
    mode, scheme, n_batches, perm_seed = sys.argv[1:5]

    import numpy as np

    from repro.stream.shard import ShardContext

    ctx = ShardContext.create()

    if mode == "probe":
        # one collective round-trip: every shard contributes its id, all
        # must see the full set back
        got = ctx.merger.union({ctx.shard_id})
        ok = got == set(range(ctx.n_shards))
        print("DIGEST", "probe")
        print("AGREE", int(ok), flush=True)
        raise SystemExit(0 if ok else 1)

    if mode == "lattice":
        from repro.core.global_grounding import build_global_grounding
        from repro.core.mln import MLNMatcher
        from repro.core.parallel import run_parallel
        from repro.data.synthetic import make_lattice_cover
        from repro.stream.digest import match_digest

        packed, relations, weights = make_lattice_cover(depth=6, width=4)
        gg = (
            build_global_grounding(packed.pair_levels, relations, weights)
            if scheme == "mmp"
            else None
        )
        res = run_parallel(
            packed, MLNMatcher(weights), gg, scheme=scheme, mesh=ctx.mesh
        )
        print("DIGEST", match_digest(res.matches))
        print("AGREE", 1, flush=True)
        return

    from repro.data.synthetic import SynthConfig, arrival_stream, make_dataset
    from repro.stream.shard import ShardCoordinator

    batches = arrival_stream(
        make_dataset(SynthConfig.hepth(scale=0.02, seed=3)), int(n_batches)
    )
    order = list(range(len(batches)))
    if int(perm_seed) >= 0:
        order = [
            int(i)
            for i in np.random.default_rng(int(perm_seed)).permutation(
                len(batches)
            )
        ]
    coord = ShardCoordinator(ctx, scheme=scheme, parallel=True)
    for i in order:
        b = batches[i]
        coord.ingest(list(b.names), b.edges, ids=[int(x) for x in b.ids])
    print("DIGEST", coord.digest())
    print("AGREE", int(coord.digests_agree()), flush=True)


if __name__ == "__main__":
    main()
