"""Property-based tests (hypothesis) for the paper's §3 axioms.

Parametrized over **every registered matcher family** through the
plug-in registry (:mod:`repro.core.matchers`): each family must satisfy
the axioms its :class:`~repro.core.matchers.MatcherInfo` capability
surface declares — idempotence (Def. 2) and evidence monotonicity
(Def. 3 ii/iii) for all, entity monotonicity (Def. 3 i) where
``monotone_entities``, supermodularity (Def. 6) where ``supermodular``.
These are the exact hypotheses of Theorems 1/2/4 — if they hold,
soundness/consistency of SMP/MMP follow for that family.
"""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import matcher as axioms
from repro.core.matchers import get_matcher, list_matchers, matcher_info
from repro.core.mln import MLNMatcher, PAPER_LEARNED, PEDAGOGICAL
from tests.conftest import random_neighborhood_batch

SETTINGS = dict(max_examples=25, deadline=None)

# every registered family, plus a non-registry pedagogical-weights MLN
# (same capability row as "mln") to keep the weight ablation covered
matchers = {name: get_matcher(name) for name in list_matchers()}
matchers["mln_pedagogical"] = MLNMatcher(PEDAGOGICAL)
CAPS = {name: matcher_info(name) for name in list_matchers()}
CAPS["mln_pedagogical"] = matcher_info("mln")

ENTITY_MONOTONE = [n for n in matchers if CAPS[n].monotone_entities]
SUPERMODULAR = [n for n in matchers if CAPS[n].supermodular]


def _batch(seed: int, B: int = 2, k: int = 6):
    return random_neighborhood_batch(np.random.default_rng(seed), B=B, k=k)


def _random_masks(rng, shape, p=0.25):
    return rng.random(shape) < p


@pytest.mark.parametrize("name", list(matchers))
@given(seed=st.integers(0, 10**6))
@settings(**SETTINGS)
def test_idempotence(name, seed):
    """Def. 2: E(E, E(E, V+), V-) == E(E, V+, V-)."""
    m = matchers[name]
    rng = np.random.default_rng(seed)
    batch = _batch(seed)
    ev = _random_masks(rng, batch.sim_level.shape) & np.asarray(batch.pair_mask)
    ok, detail = axioms.check_idempotence(m, batch, ev_pos=ev)
    assert ok, detail


@pytest.mark.parametrize("name", list(matchers))
@given(seed=st.integers(0, 10**6))
@settings(**SETTINGS)
def test_monotone_positive_evidence(name, seed):
    """Def. 3(ii): growing V+ grows the output."""
    m = matchers[name]
    rng = np.random.default_rng(seed)
    batch = _batch(seed)
    small = _random_masks(rng, batch.sim_level.shape, 0.15) & np.asarray(batch.pair_mask)
    big = (small | _random_masks(rng, batch.sim_level.shape, 0.15)) & np.asarray(
        batch.pair_mask
    )
    ok, detail = axioms.check_monotone_evidence(m, batch, small, big)
    assert ok, detail


@pytest.mark.parametrize("name", list(matchers))
@given(seed=st.integers(0, 10**6))
@settings(**SETTINGS)
def test_monotone_negative_evidence(name, seed):
    """Def. 3(iii): growing V- shrinks the output."""
    m = matchers[name]
    rng = np.random.default_rng(seed)
    batch = _batch(seed)
    small = _random_masks(rng, batch.sim_level.shape, 0.15)
    big = small | _random_masks(rng, batch.sim_level.shape, 0.15)
    ok, detail = axioms.check_monotone_negative(m, batch, small, big)
    assert ok, detail


@pytest.mark.parametrize("name", SUPERMODULAR)
@given(seed=st.integers(0, 10**6))
@settings(**SETTINGS)
def test_supermodularity(name, seed):
    """Def. 6: delta(p | T) >= delta(p | S) for S subset T (log space)."""
    m = matchers[name]
    rng = np.random.default_rng(seed)
    batch = _batch(seed)
    B, P = batch.sim_level.shape
    s = _random_masks(rng, (B, P), 0.2)
    t = s | _random_masks(rng, (B, P), 0.3)
    p_idx = rng.integers(0, P, size=B)
    # Def. 6 is the standard supermodular inequality, i.e. for p not
    # already in T (else P(T u p)/P(T) = 1 trivially breaks it).
    s[np.arange(B), p_idx] = False
    t[np.arange(B), p_idx] = False
    ok, detail = axioms.check_supermodular(m, batch, s, t, p_idx)
    assert ok, detail


@pytest.mark.parametrize("name", ENTITY_MONOTONE)
@given(seed=st.integers(0, 10**6))
@settings(**SETTINGS)
def test_monotone_entities(name, seed):
    """Def. 3(i): adding entities (a bigger neighborhood) grows matches.

    Runs only for families whose capability surface declares it — 1:1
    assignment genuinely violates it (a new record can outcompete an
    old match), which is why the declaration exists.
    """
    m = matchers[name]
    big = _batch(seed, B=1, k=8)
    # drop the last live entity -> sub-neighborhood
    ids = big.entity_ids.copy()
    live = np.where(ids[0] >= 0)[0]
    drop = live[-1]
    import dataclasses as dc

    from repro.core import pairs as pairlib

    k = big.k
    emask = big.entity_mask.copy()
    emask[0, drop] = False
    ids[0, drop] = -1
    co = big.coauthor.copy()
    co[0, drop, :] = False
    co[0, :, drop] = False
    ii, jj = pairlib.triu_indices(k)
    pmask = emask[0, ii] & emask[0, jj]
    lev = np.where(pmask, big.sim_level[0], 0).astype(np.int8)[None]
    gid = np.where(pmask, big.pair_gid[0], -1)[None]
    small = dc.replace(
        big, entity_ids=ids, entity_mask=emask, coauthor=co,
        sim_level=lev, pair_gid=gid, pair_mask=pmask[None] & (lev > 0),
    )
    ok, detail = axioms.check_monotone_entities(m, small, big, None)
    assert ok, detail


@given(seed=st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_maximal_messages_are_maximal(seed):
    """Def. 8 on random instances: every emitted component is all-or-
    nothing under the matcher when given any one member as evidence.
    Only ``emits_messages`` families produce non-trivial components —
    today that is the collective MLN."""
    (name,) = [n for n in list_matchers() if CAPS[n].emits_messages]
    m = matchers[name]
    batch = _batch(seed, B=1, k=6)
    x, lab = m.run_with_messages(batch)
    P = lab.shape[1]
    valid = np.asarray(batch.pair_mask[0])
    for lab_id in set(lab[0][lab[0] < P].tolist()):
        members = np.where((lab[0] == lab_id) & valid & ~x[0])[0]
        if len(members) < 2:
            continue
        # evidence = one member -> all members must activate
        ev = np.zeros((1, P), dtype=bool)
        ev[0, members[0]] = True
        x2 = m.run(batch, ev)
        assert x2[0][members].all(), (members, x2[0])


@pytest.mark.parametrize("name", [n for n in list_matchers() if CAPS[n].type_ii])
def test_type_ii_capability_is_real(name):
    """A family declaring ``type_ii`` actually exposes the Def. 5
    surface: score() and run_with_messages()."""
    m = matchers[name]
    batch = _batch(0)
    x = m.run(batch)
    s = m.score(batch, x)
    assert s.shape == (batch.sim_level.shape[0],)
    x2, lab = m.run_with_messages(batch)
    assert np.array_equal(x, x2) and lab.shape == x.shape


def test_paper_learned_weights_are_appendix_b():
    """Faithfulness pin: Appendix B weights -2.28/-3.84/12.75, +2.46."""
    w = PAPER_LEARNED
    assert w.w_sim == (0.0, -2.28, -3.84, 12.75)
    assert w.w_co == 2.46


@given(seed=st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_greedy_subset_of_collective(seed):
    """The iterative (closure-only) matcher under-matches the purely-
    collective one — the App. D iterative-vs-collective gap."""
    batch = _batch(seed, B=2, k=6)
    greedy = matchers["mln_greedy"].run(batch)
    coll = matchers["mln"].run(batch)
    assert np.all(coll | ~greedy)
