"""Shared deterministic arrival schedule for the fault-injection tests.

Both the in-process tests (``tests/test_faults.py``) and the
crash-recovery subprocess (``tests/crash_worker.py``) build the exact
same micro-batch schedule from here, so the parent process can compute
the uninterrupted baseline a killed-and-recovered child must land on
bit-for-bit.
"""

from __future__ import annotations

from repro.data.synthetic import SynthConfig, arrival_stream, make_dataset

N_BATCHES = 4


def batches():
    ds = make_dataset(SynthConfig.hepth(scale=0.02, seed=3))
    return arrival_stream(ds, N_BATCHES)


def run_uninterrupted(scheme: str = "smp", **kwargs):
    """The baseline: every batch ingested with no faults injected."""
    from repro.stream import ResolveService

    svc = ResolveService(scheme=scheme, **kwargs)
    for b in batches():
        svc.ingest(b.names, b.edges, ids=b.ids)
    return svc


# The adversarial canopy re-split corpus (mirrors
# tests/test_stream.py::test_resplit_retraction_still_equals_batch): a
# near-duplicate clique larger than k_core whose second interleaved
# half forces a re-split, retracting candidate pairs — the schedule
# that exercises the engine's invalidation path under rollback.
RESPLIT_NAMES = [
    f"john smithsonian{chr(97 + i // 26)}{chr(97 + i % 26)}" for i in range(28)
]
RESPLIT_FIRST = [i for i in range(28) if i % 2 == 0]
RESPLIT_SECOND = [i for i in range(28) if i % 2 == 1]
