"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Every kernel is swept over shapes (aligned + deliberately unaligned,
forcing the padding path) and dtypes, asserting allclose against its
``ref.py`` oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import assert_allclose

jax.config.update("jax_enable_x64", False)

SHAPES_PP = [(8, 8), (16, 16), (128, 128), (96, 96), (130, 130), (33, 33)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------------------
# icm_sweep: delta = u + X @ C
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("P", [p for p, _ in SHAPES_PP])
@pytest.mark.parametrize("S", [1, 8, 96])
@pytest.mark.parametrize("dtype", DTYPES)
def test_icm_sweep_matrix(P, S, dtype):
    from repro.kernels.icm_sweep import kernel, ref

    rng = np.random.default_rng(P * 1000 + S)
    u = _rand(rng, (P,), jnp.float32)
    C = np.abs(rng.standard_normal((P, P))).astype(np.float32)
    C = jnp.asarray(np.triu(C, 1) + np.triu(C, 1).T)
    X = (rng.random((S, P)) < 0.3).astype(np.float32)
    X = jnp.asarray(X, dtype=dtype)
    got = kernel.sweep_matrix(u, C, X, interpret=True)
    want = ref.sweep_matrix(u, C, X)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("P", [8, 128, 57])
def test_icm_sweep_vector(P):
    from repro.kernels.icm_sweep import kernel, ref

    rng = np.random.default_rng(P)
    u = _rand(rng, (P,), jnp.float32)
    C = jnp.asarray(np.abs(rng.standard_normal((P, P))).astype(np.float32))
    x = jnp.asarray((rng.random((P,)) < 0.5).astype(np.float32))
    assert_allclose(
        kernel.sweep(u, C, x, interpret=True), ref.sweep(u, C, x), rtol=1e-5
    )


@pytest.mark.parametrize("B,P", [(1, 8), (3, 28), (4, 96)])
def test_icm_sweep_batch(B, P):
    """Batched bin sweep: kernel and oracle agree with vmapped sweep."""
    from repro.kernels.icm_sweep import kernel, ref

    rng = np.random.default_rng(B * 100 + P)
    u = _rand(rng, (B, P), jnp.float32)
    C = np.abs(rng.standard_normal((B, P, P))).astype(np.float32)
    C = jnp.asarray(np.triu(C, 1) + np.triu(C, 1).transpose(0, 2, 1))
    X = jnp.asarray((rng.random((B, P)) < 0.4).astype(np.float32))
    want = jax.vmap(ref.sweep)(u, C, X)
    assert_allclose(ref.sweep_batch(u, C, X), want, rtol=1e-6)
    assert_allclose(kernel.sweep_batch(u, C, X, interpret=True), want, rtol=1e-5)


# ---------------------------------------------------------------------------
# mln_score: f(X_s) = u . x_s + 1/2 x_s C x_s  batched over candidate sets
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,P", [(1, 1, 8), (2, 4, 16), (3, 5, 96), (2, 2, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_mln_score_sets(B, S, P, dtype):
    from repro.kernels.mln_score import kernel, ref

    rng = np.random.default_rng(B * 100 + S * 10 + P)
    u = jnp.asarray(rng.standard_normal((B, P)).astype(np.float32))
    C = np.abs(rng.standard_normal((B, P, P))).astype(np.float32)
    C = jnp.asarray(np.triu(C, 1) + np.transpose(np.triu(C, 1), (0, 2, 1)))
    X = jnp.asarray((rng.random((B, S, P)) < 0.4).astype(dtype))
    got = kernel.score_sets(u, C, X, interpret=True)
    want = ref.score_sets(u, C, X)
    assert_allclose(got, want, rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# ngram_sim: thresholded cosine similarity A @ B^T
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,N,F", [(8, 8, 32), (128, 64, 128), (100, 70, 96)])
@pytest.mark.parametrize("threshold", [0.0, 0.7])
def test_ngram_sim(M, N, F, threshold):
    from repro.kernels.ngram_sim import kernel, ref

    rng = np.random.default_rng(M + N + F)
    A = rng.standard_normal((M, F)).astype(np.float32)
    B = rng.standard_normal((N, F)).astype(np.float32)
    A /= np.linalg.norm(A, axis=1, keepdims=True)
    B /= np.linalg.norm(B, axis=1, keepdims=True)
    got = kernel.sim_above(jnp.asarray(A), jnp.asarray(B), threshold, interpret=True)
    want = ref.sim_above(jnp.asarray(A), jnp.asarray(B), threshold)
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# minhash: masked-min signatures for streaming LSH blocking
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,D,H", [(8, 64, 16), (128, 512, 128), (33, 96, 50), (1, 512, 128)])
def test_minhash(N, D, H):
    from repro.kernels.minhash import kernel, ops, ref

    rng = np.random.default_rng(N * 7 + D + H)
    X = jnp.asarray((rng.random((N, D)) < 0.1).astype(np.float32))
    A = jnp.asarray(ops.hash_table(H, D, seed=3))
    got = kernel.minhash(X, A, interpret=True)
    want = ref.minhash(X, A)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_minhash_empty_rows():
    from repro.kernels.minhash import ops, ref

    A = jnp.asarray(ops.hash_table(32, 64, seed=0))
    sig = ref.minhash(jnp.zeros((3, 64)), A)
    assert np.all(np.asarray(sig) == ref.EMPTY)


def test_minhash_jaccard_estimate():
    """Signature agreement rate estimates Jaccard similarity."""
    from repro.kernels.minhash import ops, ref

    rng = np.random.default_rng(0)
    D, H = 512, 256
    a = rng.random(D) < 0.2
    b = a.copy()
    flip = rng.choice(D, size=40, replace=False)
    b[flip] = ~b[flip]
    jac = (a & b).sum() / (a | b).sum()
    X = jnp.asarray(np.stack([a, b]).astype(np.float32))
    A = jnp.asarray(ops.hash_table(H, D, seed=1))
    sig = np.asarray(ref.minhash(X, A))
    est = (sig[0] == sig[1]).mean()
    assert abs(est - jac) < 0.12, (est, jac)


# ---------------------------------------------------------------------------
# flash_attn: online-softmax attention vs the naive oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,H,hkv,hd", [(128, 4, 2, 32), (256, 2, 2, 64), (192, 4, 1, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attn(S, H, hkv, hd, causal):
    from repro.kernels.flash_attn import kernel, ref

    rng = np.random.default_rng(S + H)
    B = 2
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, hkv, hd)).astype(np.float32))
    scale = 1.0 / np.sqrt(hd)
    got = kernel.flash_attention(q, k, v, scale, causal=causal, interpret=True)
    want = ref.attention(q, k, v, scale, causal=causal)
    assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attn_matches_chunked_xla():
    """The Pallas kernel, the XLA chunked path and the naive path agree."""
    from repro.kernels.flash_attn import kernel
    from repro.models import layers

    rng = np.random.default_rng(0)
    B, S, H, hkv, hd = 2, 256, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, hkv, hd)).astype(np.float32))
    scale = 1.0 / np.sqrt(hd)
    xla = layers.chunked_attention(q, k, v, scale, causal=True, q_block=64)
    pallas = kernel.flash_attention(q, k, v, scale, causal=True, interpret=True)
    assert_allclose(pallas.reshape(xla.shape), xla, rtol=2e-3, atol=2e-3)
