"""Per-architecture smoke tests (assignment requirement).

Each of the 10 assigned architectures instantiates a REDUCED config of
the same family and runs one forward/train step on CPU, asserting
output shapes and the absence of NaNs; decode paths run one cached
serve step; prefill==forward consistency is checked for the dense
family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, ShapeConfig, get_config, smoke_config
from repro.models.param import init_params, param_count
from repro.models.registry import get_model

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")


@pytest.fixture(scope="module")
def smoke_models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(arch)
            api = get_model(cfg)
            params = init_params(api.param_specs(), seed=0)
            cache[arch] = (cfg, api, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, smoke_models):
    cfg, api, params = smoke_models(arch)
    batch = api.demo_batch(SMOKE_SHAPE)
    loss, metrics = jax.jit(api.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    assert float(loss) > 0
    for k, v in metrics.items():
        assert np.all(np.isfinite(np.asarray(v))), f"{arch}: metric {k}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, smoke_models):
    cfg, api, params = smoke_models(arch)
    B, s_max = 2, 16
    cache = init_params(api.cache_specs(B, s_max), seed=1)
    batch = {
        "tokens": jnp.ones((B, 1), jnp.int32),
        "pos": jnp.zeros((B,), jnp.int32),
    }
    logits, new_cache = jax.jit(api.decode)(params, cache, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    # cache tree structure is preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["yi_6b", "qwen1_5_0_5b", "minicpm3_4b", "falcon_mamba_7b"])
def test_prefill_decode_consistency(arch, smoke_models):
    """Greedy continuation via prefill+decode == teacher-forced forward."""
    cfg, api, params = smoke_models(arch)
    if api.prefill is None:
        pytest.skip("no prefill")
    B, S, s_max = 2, 8, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size - 1, (B, S)), jnp.int32)
    logits_p, cache = jax.jit(lambda p, t: api.prefill(p, t, s_max))(params, toks)

    batch = {"tokens": np.asarray(toks), "labels": np.asarray(toks)}
    if cfg.family == "vlm":
        pytest.skip("vlm needs vision inputs")
    # teacher-forced logits at the last position from the train path
    from repro.models import registry  # noqa: F401

    if cfg.family == "ssm":
        from repro.models import ssm_lm as mod

        hidden = mod.forward_train(cfg, params, toks)
        logits_t = mod.logits_of(cfg, params, hidden)
    else:
        from repro.models import transformer as mod

        hidden, _ = mod.forward_train(cfg, params, toks, mod.make_positions(cfg, toks))
        logits_t = mod.logits_of(cfg, params, hidden)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(logits_t[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )
    # one decode step at position S matches the next teacher-forced pos
    nxt = jnp.argmax(logits_p[:, -1, :], axis=-1).astype(jnp.int32)
    logits_d, _ = jax.jit(api.decode)(
        params, cache, {"tokens": nxt[:, None], "pos": jnp.full((B,), S, jnp.int32)}
    )
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    if cfg.family == "ssm":
        hidden2 = mod.forward_train(cfg, params, toks2)
    else:
        hidden2, _ = mod.forward_train(
            cfg, params, toks2, mod.make_positions(cfg, toks2)
        )
    logits_t2 = mod.logits_of(cfg, params, hidden2)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(logits_t2[:, -1], np.float32),
        rtol=5e-2, atol=5e-2,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs pin the assigned literature hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "falcon_mamba_7b": (64, 4096, 0, 0, 0, 65024),
        "jamba_v0_1_52b": (32, 4096, 32, 8, 14336, 65536),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen2_72b": (80, 8192, 64, 8, 29568, 152064),
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
        "qwen1_5_0_5b": (24, 1024, 16, 16, 2816, 151936),
    }[arch]
    got = (cfg.n_layers, cfg.d_model,
           cfg.n_heads if cfg.family != "ssm" else 0,
           cfg.n_kv_heads if cfg.family != "ssm" else 0,
           cfg.d_ff if cfg.family != "ssm" else 0,
           cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_moe_expert_counts():
    assert get_config("moonshot_v1_16b_a3b").n_experts == 64
    assert get_config("moonshot_v1_16b_a3b").experts_per_token == 6
    assert get_config("llama4_scout_17b_a16e").n_experts == 16
    assert get_config("llama4_scout_17b_a16e").experts_per_token == 1
    assert get_config("jamba_v0_1_52b").n_experts == 16
    assert get_config("jamba_v0_1_52b").experts_per_token == 2


def test_param_counts_plausible():
    """Full configs land near their nameplate sizes."""
    for arch, lo, hi in [
        ("qwen1_5_0_5b", 0.3e9, 0.8e9),
        ("yi_6b", 5e9, 7e9),
        ("falcon_mamba_7b", 6e9, 8.5e9),
        ("qwen2_72b", 65e9, 80e9),
        ("minicpm3_4b", 3e9, 5e9),
        # assignment pins 48L x 64 experts x d_ff 1408 => ~28B total (3B-active
        # class); the hf nameplate "16B" reflects a shallower public config
        ("moonshot_v1_16b_a3b", 24e9, 32e9),
        ("jamba_v0_1_52b", 45e9, 60e9),
    ]:
        api = get_model(get_config(arch))
        n = param_count(api.param_specs())
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_chunked_attention_equals_fused_path(smoke_models):
    """The >threshold chunked path is numerically the fused path."""
    from repro.models import layers

    rng = np.random.default_rng(0)
    B, S, H, hkv, hd = 2, 128, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, hkv, hd)), jnp.float32)
    o1 = layers.chunked_attention(q, k, v, 0.25, causal=True, q_block=32)
    s = layers._gqa_scores(q, k, 0.25)
    s = jnp.where(jnp.tril(jnp.ones((S, S), bool)), s, layers.NEG_INF)
    o2 = layers._gqa_out(jax.nn.softmax(s, -1), v, jnp.float32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)
