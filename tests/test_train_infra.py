"""Training-infrastructure tests: checkpoint/restart determinism,
preemption, elastic restore, gradient compression, launch heuristics.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import smoke_config
from repro.data.corpus import CorpusConfig
from repro.launch.sharding import default_remat_group, pick_microbatches
from repro.models.registry import get_model
from repro.train.optimizer import OptConfig
from repro.train.train_step import split_microbatches
from repro.train.trainer import Trainer, TrainerConfig


def _mk_trainer(tmp, steps, ckpt_every=4, microbatches=1):
    cfg = smoke_config("qwen1_5_0_5b")
    api = get_model(cfg)
    data = CorpusConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4, seed=0)
    tcfg = TrainerConfig(
        steps=steps, ckpt_every=ckpt_every, log_every=2,
        microbatches=microbatches, ckpt_dir=tmp, async_ckpt=False,
    )
    return Trainer(api, data, OptConfig(lr=1e-3, warmup_steps=2), tcfg)


def test_train_loss_decreases(tmp_path):
    t = _mk_trainer(str(tmp_path / "a"), steps=12)
    out = t.run()
    losses = [loss for _, loss in out["losses"]]
    assert losses[-1] < losses[0], losses


def test_checkpoint_restart_bitwise(tmp_path):
    """Crash at step 8, restart, finish: bitwise == uninterrupted run."""
    d1, d2 = str(tmp_path / "x"), str(tmp_path / "y")
    full = _mk_trainer(d1, steps=10).run()

    t = _mk_trainer(d2, steps=8)
    t.run()
    resumed = _mk_trainer(d2, steps=10).run()

    f1 = jax.tree.leaves(full["params"])
    f2 = jax.tree.leaves(resumed["params"])
    for a, b in zip(f1, f2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_checkpoints_and_stops(tmp_path):
    t = _mk_trainer(str(tmp_path / "p"), steps=100, ckpt_every=1000)
    t.preempted = True
    t.run()
    ck = Checkpointer(str(tmp_path / "p"))
    assert ck.latest_step() is not None  # the preemption save happened


def test_checkpointer_keep_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = {"w": np.arange(8, dtype=np.float32)}
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.all_steps() == [3, 4]
    got = ck.restore(4, {"w": np.zeros(8, dtype=np.float32)})
    np.testing.assert_array_equal(got["w"], state["w"])


def test_checkpoint_atomicity(tmp_path):
    """A torn write (missing manifest) is never listed as restorable."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, {"w": np.ones(4, np.float32)})
    step_dir = os.path.join(str(tmp_path), "step_00000002")
    os.makedirs(step_dir)  # fake partial checkpoint, no manifest
    np.save(os.path.join(step_dir, "w.npy"), np.zeros(4))
    assert ck.all_steps() == [1]


def test_elastic_restore_under_new_mesh(tmp_path):
    """Restore re-places arrays under whatever mesh exists now — the
    elastic-rescale path (save on N devices, restore on M)."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    w = np.arange(64, dtype=np.float32).reshape(8, 8)
    ck.save(3, {"w": w})
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    got = ck.restore(
        3, {"w": np.zeros((8, 8), np.float32)},
        shardings={"w": NamedSharding(mesh, P("data", None))},
    )
    np.testing.assert_array_equal(np.asarray(got["w"]), w)


def test_compressed_psum_error_feedback():
    """int8 compression with error feedback: quantize+dequantize error
    is carried, so the running sum stays unbiased."""
    from repro.train.compress import quantize

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((128,)).astype(np.float32))
    q, scale = quantize(g, None)
    deq = q.astype(jnp.float32) * scale
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert rel < 0.02, rel


def test_split_microbatches_layout():
    batch = {
        "tokens": np.arange(8 * 4).reshape(8, 4),
        "positions": np.arange(3 * 8 * 4).reshape(3, 8, 4),
    }
    out = split_microbatches(batch, 2)
    assert out["tokens"].shape == (2, 4, 4)
    assert out["positions"].shape == (2, 3, 4, 4)
    np.testing.assert_array_equal(out["tokens"][0], batch["tokens"][:4])
    np.testing.assert_array_equal(out["positions"][1], batch["positions"][:, 4:])


def test_launch_heuristics():
    assert pick_microbatches(256, 16, 4096) == 8      # 8k tokens/dev/mb
    assert pick_microbatches(32, 16, 32768) == 2
    assert pick_microbatches(128, 32, 32768) == 4
    assert pick_microbatches(4, 16, 128) == 1
    assert default_remat_group(80) == 8
    assert default_remat_group(24) == 4
    assert default_remat_group(62) == 2
    assert default_remat_group(28) == 4


def test_microbatched_train_matches_single(tmp_path):
    """Grad accumulation over 2 microbatches == one full batch step
    (up to accumulation-order float error)."""
    cfg = smoke_config("qwen1_5_0_5b")
    api = get_model(cfg)
    from repro.models.param import init_params
    from repro.train.optimizer import init_opt_state
    from repro.train.train_step import make_train_step

    params = init_params(api.param_specs(), seed=0)
    opt = init_opt_state(params)
    batch = api.demo_batch(
        __import__("repro.configs.base", fromlist=["ShapeConfig"]).ShapeConfig(
            "t", 16, 4, "train"
        )
    )
    s1 = jax.jit(make_train_step(api, OptConfig(lr=1e-3)))
    s2 = jax.jit(make_train_step(api, OptConfig(lr=1e-3), microbatches=2))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, split_microbatches(batch, 2))
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-3, atol=2e-4,
        )
