"""Streaming incremental EM (repro.stream) — the paper's consistency
property extended to arrivals.

The core contract: ingesting any sequence of micro-batches reaches the
*same* MatchStore fixpoint the batch pipeline computes over the union,
while evaluating strictly fewer neighborhoods than re-running from
scratch at every arrival.  Delta cover maintenance must reproduce the
batch cover exactly (equality is asserted structurally), and the LSH
index must have full candidate recall at the canopy threshold on the
synthetic corpora — that recall is what makes the cover equality hold.
"""

from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.core import pipeline
from repro.core.cover import is_total
from repro.core.driver import run_mmp, run_smp
from repro.core.global_grounding import build_global_grounding
from repro.core.mln import MLNMatcher, PAPER_LEARNED
from repro.data.synthetic import arrival_stream, truncate
from repro.stream import ResolveService
from repro.stream.index import LSHConfig, MinHashLSHIndex


@pytest.fixture(scope="module")
def stream_ds(hepth_small):
    return hepth_small


@pytest.fixture(scope="module")
def batch_state(stream_ds):
    packed, gg, _ = pipeline.prepare(stream_ds.entities, stream_ds.relations)
    return packed, gg


@pytest.fixture(scope="module")
def batch_smp(batch_state):
    packed, _ = batch_state
    return run_smp(packed, MLNMatcher(PAPER_LEARNED))


def _stream(ds, n_batches, order=None, **kwargs):
    batches = arrival_stream(ds, n_batches)
    svc = ResolveService(**kwargs)
    for i in order if order is not None else range(len(batches)):
        b = batches[i]
        svc.ingest(b.names, b.edges, ids=b.ids)
    return svc


# ---------------------------------------------------------------------------
# Equivalence: stream N batches == batch run on the union
# ---------------------------------------------------------------------------


def test_stream_equals_batch_smp(stream_ds, batch_state, batch_smp):
    packed, _ = batch_state
    svc = _stream(stream_ds, 4, scheme="smp")
    assert svc.matches.as_set() == batch_smp.matches.as_set()
    # ... while having evaluated strictly fewer neighborhoods than
    # re-running from scratch at each of the 4 arrival points.
    batches = arrival_stream(stream_ds, 4)
    scratch_evals = 0
    for b in batches:
        pre = truncate(stream_ds, int(b.ids[-1]) + 1)
        p, _, _ = pipeline.prepare(pre.entities, pre.relations)
        scratch_evals += run_smp(p, MLNMatcher(PAPER_LEARNED)).neighborhood_evals
    assert svc.total_evals < scratch_evals, (svc.total_evals, scratch_evals)


def test_stream_cover_equals_batch_cover(stream_ds, batch_state):
    """Delta maintenance reproduces the batch cover structurally."""
    packed, _ = batch_state
    svc = _stream(stream_ds, 4, scheme="smp")
    sp = svc.delta.packed
    assert len(sp.cover) == len(packed.cover)
    for a, b in zip(sp.cover.full, packed.cover.full):
        assert np.array_equal(a, b)
    for a, b in zip(sp.cover.core, packed.cover.core):
        assert np.array_equal(a, b)
    assert set(sp.bins) == set(packed.bins)
    for k in packed.bins:
        for field in ("entity_ids", "entity_mask", "coauthor", "sim_level",
                      "pair_gid", "pair_mask"):
            assert np.array_equal(
                getattr(sp.bins[k], field), getattr(packed.bins[k], field)
            ), (k, field)
    assert sp.pair_levels == packed.pair_levels


def test_stream_equals_batch_mmp(stream_ds, batch_state):
    packed, gg = batch_state
    mm = run_mmp(packed, MLNMatcher(PAPER_LEARNED), gg)
    svc = _stream(stream_ds, 5, scheme="mmp")
    assert svc.matches.as_set() == mm.matches.as_set()


def test_stream_parallel_engine(stream_ds, batch_smp):
    """The SPMD round driver accepts the partial-worklist seed too."""
    svc = _stream(stream_ds, 3, scheme="smp", parallel=True)
    assert svc.matches.as_set() == batch_smp.matches.as_set()


def test_stream_parallel_mmp_equals_batch(stream_ds, batch_state):
    """Warm-started device rounds (fused greedy segments + cached
    groundings + persistent pool) reach run_mmp's fixpoint exactly."""
    packed, gg = batch_state
    mm = run_mmp(packed, MLNMatcher(PAPER_LEARNED), gg)
    svc = _stream(stream_ds, 3, scheme="mmp", parallel=True)
    assert svc.matches.as_set() == mm.matches.as_set()


def test_grounding_cache_regrounds_only_dirty():
    """An ingest that leaves a bin untouched must not re-ground it: the
    persistent device GroundingCache serves it whole, and the dirty
    bins splice in only the changed rows (counter-based, the grounding
    analogue of IngestReport.replay_visits)."""
    groups = [
        [f"alessandro brunelleschi{chr(97 + i)}" for i in range(10)],
        [f"konstantin verkhovsky{chr(97 + i)}" for i in range(10)],
    ]
    svc = ResolveService(scheme="smp", parallel=True)
    r1 = svc.ingest([n for g in groups for n in g])
    g = svc.engine.gcache
    assert r1.reground_rows > 0
    rows_after_1 = g.rows_ground
    hits_before = g.bin_hits

    # A fresh, dissimilar component: dirties only its own neighborhoods.
    r2 = svc.ingest([f"evangelina montgomery{chr(97 + i)}" for i in range(5)])
    assert r2.reground_rows > 0  # the new rows were ground ...
    assert r2.reground_rows <= r2.n_dirty  # ... and only dirty rows
    assert r2.reground_rows < rows_after_1  # no full re-ground
    # the untouched groups' bin was served from cache outright
    assert g.bin_hits > hits_before

    # Warm-started device rounds stay bit-for-bit equal to the batch run.
    from repro.core.types import EntityTable

    entities = EntityTable(names=list(svc.delta.names))
    packed, _, _ = pipeline.prepare(entities, svc.delta.relations())
    batch = run_smp(packed, MLNMatcher(PAPER_LEARNED))
    assert svc.matches.as_set() == batch.matches.as_set()


# ---------------------------------------------------------------------------
# Ingest-order invariance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", [[2, 0, 4, 1, 3], [4, 3, 2, 1, 0]])
def test_ingest_order_invariance(stream_ds, batch_smp, order):
    svc = _stream(stream_ds, 5, order=order, scheme="smp")
    assert svc.matches.as_set() == batch_smp.matches.as_set()


def test_single_batch_equals_batch(stream_ds, batch_smp):
    """Degenerate stream (one batch = everything) is the batch pipeline."""
    svc = _stream(stream_ds, 1, scheme="smp")
    assert svc.matches.as_set() == batch_smp.matches.as_set()


# ---------------------------------------------------------------------------
# Totality is preserved at every ingest (Def. 7)
# ---------------------------------------------------------------------------


def test_totality_preserved_per_ingest(stream_ds):
    batches = arrival_stream(stream_ds, 4)
    svc = ResolveService(scheme="smp")
    for b in batches:
        svc.ingest(b.names, b.edges, ids=b.ids)
        cand = np.asarray(sorted(svc.delta.packed.pair_levels), dtype=np.int64)
        assert is_total(svc.delta.cover, svc.delta.relations(), cand)


# ---------------------------------------------------------------------------
# The resolve-query path
# ---------------------------------------------------------------------------


def test_resolve_returns_truth_cluster(stream_ds):
    svc = _stream(stream_ds, 4, scheme="smp")
    truth = stream_ds.entities.truth
    groups: dict[int, list[int]] = {}
    for i, t in enumerate(truth):
        groups.setdefault(int(t), []).append(i)
    checked = 0
    for g in groups.values():
        if len(g) < 2:
            continue
        cluster = set(int(x) for x in svc.resolve(g[0]))
        if cluster == {g[0]}:
            continue  # unresolved singleton: recall is not 1.0
        # precision-style check: resolved cluster stays inside the truth group
        assert cluster <= set(g) or len(cluster & set(g)) >= 2
        checked += 1
    assert checked >= 3  # the engineered duplicates actually resolve


def test_resolve_unknown_is_singleton(stream_ds):
    svc = _stream(stream_ds, 2, scheme="smp")
    far = 10_000_000
    assert list(svc.resolve(far)) == [far]


def test_clusters_match_closure(stream_ds):
    from repro.core.closure import clusters_of

    svc = _stream(stream_ds, 4, scheme="smp")
    want = {tuple(int(x) for x in c) for c in clusters_of(svc.matches)}
    got = {tuple(int(x) for x in c) for c in svc.clusters()}
    assert got == want


# ---------------------------------------------------------------------------
# LSH index: recall at the canopy threshold, filtering below it
# ---------------------------------------------------------------------------


def test_lsh_full_recall_at_t_loose(stream_ds):
    """Every >= t_loose pair collides in the index — the condition under
    which delta cover maintenance is exact (see stream.delta docstring)."""
    from repro.core import similarity as simlib

    names = stream_ds.entities.names
    feats = simlib.ngram_profiles([simlib.block_key(n) for n in names], dim=128)
    sims = feats @ feats.T
    idx = MinHashLSHIndex()
    sigs = idx.add(list(range(len(names))), names)
    for i in range(len(names)):
        cands = idx.query(sigs[i : i + 1])
        for j in np.where(sims[i] >= 0.70)[0]:
            assert int(j) in cands, (i, int(j), names[i], names[int(j)])


def test_lsh_filters_dissimilar():
    rng = np.random.default_rng(0)
    names = [
        "".join(chr(ord("a") + int(c)) for c in rng.integers(0, 26, size=12))
        for _ in range(200)
    ]
    idx = MinHashLSHIndex(LSHConfig(num_bands=32, rows_per_band=4))
    sigs = idx.add(list(range(len(names))), names)
    hits = sum(len(idx.query(sigs[i : i + 1]) - {i}) for i in range(len(names)))
    # random 12-char strings share almost no 3-grams: candidates ~ none
    assert hits < 0.02 * len(names) ** 2


def test_resplit_retraction_still_equals_batch():
    """Adversarial canopy re-split: a dense near-duplicate clique larger
    than k_core, ingested in two interleaved halves, forces the second
    ingest to re-split the canopy into different windows — retracting
    candidate pairs and firing the engine's match-invalidation path.
    The final fixpoint must still equal the batch run, and the retracted
    pairs must have left ``pair_levels`` (regression: a persistent level
    cache once leaked them into the global grounding)."""
    from repro.core.types import EntityTable, Relations

    names = [f"john smithsonian{chr(97 + i // 26)}{chr(97 + i % 26)}" for i in range(28)]
    first = [i for i in range(28) if i % 2 == 0]
    second = [i for i in range(28) if i % 2 == 1]

    svc = ResolveService(scheme="smp")
    svc.ingest([names[i] for i in first], ids=first)
    svc.ingest([names[i] for i in second], ids=second)
    assert svc.reports[-1].n_invalidated > 0  # the retraction path fired

    packed, _, _ = pipeline.prepare(EntityTable(names=list(names)), Relations(edges={}))
    seq = run_smp(packed, MLNMatcher(PAPER_LEARNED))
    assert svc.delta.packed.pair_levels == packed.pair_levels
    assert svc.matches.as_set() == seq.matches.as_set()


def test_resplit_retraction_mmp_pool_replay():
    """Same adversarial re-split under scheme='mmp': the persistent
    message pool must not promote gids retracted from the grounding
    (regression: _promote once unioned whole groups, leaking retracted
    pairs back into the match store)."""
    from repro.core.global_grounding import build_global_grounding
    from repro.core.types import EntityTable, Relations

    names = [f"john smithsonian{chr(97 + i // 26)}{chr(97 + i % 26)}" for i in range(28)]
    first = [i for i in range(28) if i % 2 == 0]
    second = [i for i in range(28) if i % 2 == 1]

    svc = ResolveService(scheme="mmp")
    svc.ingest([names[i] for i in first], ids=first)
    svc.ingest([names[i] for i in second], ids=second)

    ents = EntityTable(names=list(names))
    rels = Relations(edges={})
    packed, _, _ = pipeline.prepare(ents, rels)
    gg = build_global_grounding(packed.pair_levels, rels, PAPER_LEARNED)
    seq = run_mmp(packed, MLNMatcher(PAPER_LEARNED), gg)
    cand = set(packed.pair_levels)
    assert all(int(g) in cand for g in svc.matches.gids)  # no retracted leaks
    assert svc.matches.as_set() == seq.matches.as_set()


def test_ingest_duplicate_id_rejected(stream_ds):
    svc = ResolveService(scheme="smp")
    svc.ingest(["john doe"], ids=[0])
    with pytest.raises(ValueError):
        svc.ingest(["john doe"], ids=[0])


def test_ingest_self_loop_edge_rejected():
    """Self-loop relation edges would make the incremental grounding
    diverge from the batch build (adjacency_sets puts i in adj(i)), so
    the ingest boundary rejects them outright."""
    svc = ResolveService(scheme="smp")
    with pytest.raises(ValueError, match="self-loop"):
        svc.ingest(["john doe", "jane roe"], edges=np.asarray([[0, 0]]))


# ---------------------------------------------------------------------------
# Incremental cover assembly + grounding splice: bit-for-bit differential
# ---------------------------------------------------------------------------


def _assert_packed_equal(sp, packed):
    """Spliced PackedCover == scratch build, field by field — including
    the splice-maintained incidence lookups vs the scratch CSR/index."""
    assert len(sp.cover) == len(packed.cover)
    for a, b in zip(sp.cover.full, packed.cover.full):
        assert np.array_equal(a, b)
    for a, b in zip(sp.cover.core, packed.cover.core):
        assert np.array_equal(a, b)
    assert np.array_equal(sp.neighborhood_bin, packed.neighborhood_bin)
    assert np.array_equal(sp.neighborhood_row, packed.neighborhood_row)
    assert set(sp.bins) == set(packed.bins)
    for k in packed.bins:
        assert np.array_equal(sp.bin_rows[k], packed.bin_rows[k])
        for field in ("entity_ids", "entity_mask", "coauthor", "sim_level",
                      "pair_gid", "pair_mask"):
            assert np.array_equal(
                getattr(sp.bins[k], field), getattr(packed.bins[k], field)
            ), (k, field)
    assert sp.pair_levels == packed.pair_levels
    # incidence queries: the spliced cover answers from the maintained
    # gid/entity -> row-key maps, the scratch one from its lazily built
    # CSR / entity index — per-query equality, every gid and entity
    assert sp.slot_lookup is not None and packed.slot_lookup is None
    for g in sorted(packed.pair_levels):
        arr = np.asarray([g], dtype=np.int64)
        assert sp.neighborhoods_of_slot_pairs(arr) == \
            packed.neighborhoods_of_slot_pairs(arr), g
        assert sp.neighborhoods_of_pairs(arr) == \
            packed.neighborhoods_of_pairs(arr), g
    ents = sorted({int(e) for m in packed.cover.full for e in m})
    for e in ents:
        assert sp.neighborhoods_of_entities([e]) == \
            packed.neighborhoods_of_entities([e]), e


def _scratch_packed(delta):
    """Scratch assemble + pack over the delta's current canopy state."""
    from repro.core.cover import assemble_cover, pack_cover

    entities = delta.entities()
    relations = delta.relations()
    cover = assemble_cover(
        delta.canopies(),
        entities,
        relations,
        k_max=delta.k_max,
        boundary_relation=delta.boundary_relation,
        present=delta.present,
    )
    return pack_cover(
        cover,
        entities,
        relations,
        k_bins=delta.k_bins,
        thresholds=delta.thresholds,
        boundary_relation=delta.boundary_relation,
    )


def _check_grounding_equals_scratch(svc):
    gi = svc.grounding.grounding()
    gr = build_global_grounding(
        svc.delta.packed.pair_levels, svc.delta.relations(), PAPER_LEARNED
    )
    assert np.array_equal(gi.gids, gr.gids)
    assert np.array_equal(gi.u, gr.u)  # bitwise float32 equality
    assert np.array_equal(gi.coup_p, gr.coup_p)
    assert np.array_equal(gi.coup_q, gr.coup_q)


@pytest.mark.parametrize(
    "scheme,n_batches,order",
    [
        ("mmp", 4, None),          # in-order arrivals
        ("smp", 5, [2, 0, 4, 1, 3]),  # permuted arrivals (id holes)
        ("smp", 3, [2, 1, 0]),     # reversed arrivals
    ],
)
def test_spliced_cover_equals_scratch_every_ingest(
    stream_ds, scheme, n_batches, order
):
    """The CoverDelta splice path reproduces the scratch assemble+pack
    bit-for-bit at EVERY ingest of several schedules, and (mmp) the
    spliced grounding arrays reproduce build_global_grounding."""
    batches = arrival_stream(stream_ds, n_batches)
    svc = ResolveService(scheme=scheme)
    for i in order if order is not None else range(len(batches)):
        b = batches[i]
        svc.ingest(b.names, b.edges, ids=b.ids)
        _assert_packed_equal(svc.delta.packed, _scratch_packed(svc.delta))
        if scheme == "mmp":
            _check_grounding_equals_scratch(svc)


def test_spliced_cover_survives_resplit_retraction():
    """The adversarial canopy re-split (retracting candidate pairs and
    re-splitting windows mid-cover) still splices to the exact scratch
    build, including the retraction leg of the grounding splice."""
    names = [f"john smithsonian{chr(97 + i // 26)}{chr(97 + i % 26)}" for i in range(28)]
    first = [i for i in range(28) if i % 2 == 0]
    second = [i for i in range(28) if i % 2 == 1]
    svc = ResolveService(scheme="mmp")
    for batch in (first, second):
        svc.ingest([names[i] for i in batch], ids=batch)
        _assert_packed_equal(svc.delta.packed, _scratch_packed(svc.delta))
        _check_grounding_equals_scratch(svc)
    assert svc.reports[-1].n_invalidated > 0  # the retraction path fired


def test_spliced_cover_with_edges_equals_scratch(stream_ds):
    """Relation edges arriving after their endpoints (boundary growth,
    intra-edge row-key invalidation, totality-group churn) keep the
    splice bit-for-bit equal to scratch."""
    batches = arrival_stream(stream_ds, 6)
    svc = ResolveService(scheme="smp")
    # ingest entities first, then their edges in a later micro-batch, so
    # edges always reference previously ingested entities
    pending = []
    for b in batches:
        svc.ingest(b.names, None, ids=b.ids)
        _assert_packed_equal(svc.delta.packed, _scratch_packed(svc.delta))
        if pending:
            svc.ingest([], pending.pop())
            _assert_packed_equal(svc.delta.packed, _scratch_packed(svc.delta))
        if b.edges is not None and len(b.edges):
            pending.append(b.edges)
    if pending:
        svc.ingest([], pending.pop())
        _assert_packed_equal(svc.delta.packed, _scratch_packed(svc.delta))


@pytest.mark.parametrize("seed", [0, 3, 9])
def test_spliced_cover_randomized_schedules(seed):
    """Randomized adversarial schedules: heavy name collisions (shared
    surname stems force duplicate part keys, canopy splits/re-splits and
    ownership transfers), out-of-order ids with holes, and random
    relation edges (totality-group churn + intra-edge row-key
    invalidation).  Splice == scratch at every single ingest."""
    from repro.stream.delta import DeltaCover

    surnames = ["brunelleschi", "verkhovsky", "fitzgerald", "montgomery",
                "oppenheimer", "fairbanks", "thornberry", "castellanos"]
    rng = np.random.default_rng(seed)
    n = 40
    pool_sz = max(2, len(surnames) // (1 + seed % 3))
    names = [
        f"{'abcdefghij'[rng.integers(0, 10)]}. "
        f"{surnames[rng.integers(0, pool_sz)]}{'abcd'[rng.integers(0, 4)]}"
        for _ in range(n)
    ]
    perm = rng.permutation(n)
    delta = DeltaCover()
    ingested: list[int] = []
    i = 0
    while i < n:
        bs = int(rng.integers(1, 8))
        ids = [int(x) for x in perm[i : i + bs]]
        i += bs
        pool = ingested + ids
        edges = None
        if len(pool) >= 2 and rng.random() < 0.7:
            es = set()
            for _ in range(int(rng.integers(1, 5))):
                a, b = rng.choice(pool, size=2, replace=False)
                if a != b:
                    es.add((int(a), int(b)))
            if es:
                edges = np.asarray(sorted(es), dtype=np.int64)
        delta.ingest(ids, [names[e] for e in ids], edges)
        ingested = pool
        _assert_packed_equal(delta.packed, _scratch_packed(delta))


# ---------------------------------------------------------------------------
# O(dirty) ingest: incremental grounding + localized canopy replay
# ---------------------------------------------------------------------------


def test_localized_replay_equals_full_sweep(stream_ds):
    """The replayed slice reproduces the full-id sweep bit-for-bit at
    every ingest (the sweep decomposes over similarity components)."""
    batches = arrival_stream(stream_ds, 5)
    svc = ResolveService(scheme="smp")
    for b in batches:
        svc.ingest(b.names, b.edges, ids=b.ids)
        inc = svc.delta.canopies()
        full = svc.delta._canopies_full()
        assert len(inc) == len(full)
        for a, c in zip(inc, full):
            assert np.array_equal(a, c)


def test_incremental_grounding_equals_scratch(stream_ds):
    """GroundingMaintainer.apply_delta reproduces build_global_grounding
    exactly — gids, float32 unaries, and coupling arrays — per ingest."""
    batches = arrival_stream(stream_ds, 4)
    svc = ResolveService(scheme="mmp")
    for b in batches:
        svc.ingest(b.names, b.edges, ids=b.ids)
        gi = svc.grounding.grounding()
        gr = build_global_grounding(
            svc.delta.packed.pair_levels, svc.delta.relations(), PAPER_LEARNED
        )
        assert np.array_equal(gi.gids, gr.gids)
        assert np.array_equal(gi.u, gr.u)  # bitwise float32 equality
        assert np.array_equal(gi.coup_p, gr.coup_p)
        assert np.array_equal(gi.coup_q, gr.coup_q)
        assert gi.w_co == gr.w_co


def test_incremental_grounding_survives_retraction():
    """Canopy re-split retracts candidate pairs; the patched grounding
    must still equal the from-scratch build (regression for the
    retraction branch of apply_delta)."""
    names = [f"john smithsonian{chr(97 + i // 26)}{chr(97 + i % 26)}" for i in range(28)]
    first = [i for i in range(28) if i % 2 == 0]
    second = [i for i in range(28) if i % 2 == 1]
    svc = ResolveService(scheme="mmp")
    for batch in (first, second):
        svc.ingest([names[i] for i in batch], ids=batch)
        gi = svc.grounding.grounding()
        gr = build_global_grounding(
            svc.delta.packed.pair_levels, svc.delta.relations(), PAPER_LEARNED
        )
        assert np.array_equal(gi.gids, gr.gids)
        assert np.array_equal(gi.u, gr.u)
        assert np.array_equal(gi.coup_p, gr.coup_p)
        assert np.array_equal(gi.coup_q, gr.coup_q)


def _name_group(base: str, size: int) -> list[str]:
    return [f"{base}{chr(97 + i)}" for i in range(size)]


def test_ingest_cost_tracks_dirty_set():
    """A micro-batch touching k of n entities must not trigger an O(n)
    grounding rebuild or a full-id replay sweep: the op/visit counters
    stay bounded by the touched similarity region, not the corpus."""
    groups = [
        _name_group("alessandro brunelleschi", 10),
        _name_group("konstantin verkhovsky", 10),
        _name_group("bartholomew fitzgerald", 10),
    ]
    svc = ResolveService(scheme="mmp")
    svc.ingest([n for g in groups for n in g])
    n_before = svc.delta.n_entities
    pairs_before = len(svc.delta.packed.pair_levels)

    # Arrival similar only to itself: a fresh, small similarity component.
    r = svc.ingest(_name_group("evangelina montgomery", 5))
    n_total = svc.delta.n_entities
    total_pairs = len(svc.delta.packed.pair_levels)
    n_nbhd = len(svc.delta.cover)
    assert n_before == 30 and n_total == 35
    assert total_pairs > pairs_before  # the new component added candidates
    # Replay swept only the new component (5 ids), not all 35.
    assert r.replay_visits <= 6, r.replay_visits
    # Grounding patched only the new component's pairs (10), not all.
    assert 0 < r.grounding_pair_visits <= 12, r.grounding_pair_visits
    assert r.grounding_pair_visits < total_pairs // 3
    # Cover splice staged only the new component's neighborhood rows —
    # no term proportional to the number of neighborhoods/corpus.
    assert 0 < r.cover_splice_rows <= 3, r.cover_splice_rows
    assert r.cover_splice_rows < n_nbhd
    # Grounding arrays spliced only the new component's rows, not the
    # O(total_pairs) full materialization.
    assert 0 < r.grounding_splice_rows <= 14, r.grounding_splice_rows
    assert r.grounding_splice_rows < total_pairs // 3

    # Second probe: an arrival similar to ONE existing group re-sweeps
    # that group's component only.
    r2 = svc.ingest(["alessandro brunelleschiz"])
    assert r2.replay_visits <= 12, r2.replay_visits  # group + arrival
    assert r2.replay_visits < svc.delta.n_entities // 2
    # ... and restages only that component's neighborhoods.
    assert r2.cover_splice_rows <= 4, r2.cover_splice_rows
    assert r2.cover_splice_rows < len(svc.delta.cover)


def test_splice_counters_zero_on_untouched_ingest():
    """An ingest whose batch touches nothing previously covered must not
    restage any pre-existing neighborhood row: total splice work across
    a run of disjoint components stays O(sum of component sizes)."""
    svc = ResolveService(scheme="smp")
    bases = ["alessandro brunelleschi", "konstantin verkhovsky",
             "bartholomew fitzgerald", "evangelina montgomery"]
    rows_per_ingest = []
    for base in bases:
        r = svc.ingest(_name_group(base, 8))
        rows_per_ingest.append(r.cover_splice_rows)
    # every later ingest splices about as much as the first (its own
    # component), instead of restaging the whole growing cover
    assert max(rows_per_ingest[1:]) <= rows_per_ingest[0] + 2, rows_per_ingest
    total_rows_staged = sum(rows_per_ingest)
    scratch_rows = sum(
        r.n_neighborhoods for r in svc.reports
    )  # what per-ingest full restaging would have staged
    assert total_rows_staged < scratch_rows


def test_append_buffer_copies_amortized():
    """Capacity-doubling backing buffers: appending components one by
    one never re-copies the whole bin per ingest — total growth-copy
    traffic stays amortized O(total appended rows), where the old
    per-append ``np.concatenate`` copied the full bin every time."""
    svc = ResolveService(scheme="smp")
    bases = ["alessandro brunelleschi", "konstantin verkhovsky",
             "bartholomew fitzgerald", "evangelina montgomery",
             "thaddeus oppenheimer", "wilhelmina fairbanks"]
    for base in bases:
        svc.ingest(_name_group(base, 8))
    cd = svc.delta.cover_delta
    moved = cd.total_append_rows + cd.total_restack_rows
    assert cd.total_append_rows > 0  # the append fast path actually ran
    # doubling growth re-copies each resident row at most ~once per
    # doubling: total copies bounded by 2x the rows ever placed
    assert cd.total_growth_copy_rows <= 2 * moved, (
        cd.total_growth_copy_rows, moved
    )


def test_stream_lru_mid_stream_evictions(stream_ds, batch_smp, batch_state):
    """Bounded serving memory end to end: a parallel service with LRU
    capacity 1 over a 4-bin cover evicts mid-stream (cold bins re-ground
    on demand between and within ingests) and still reaches the batch
    fixpoint bit-for-bit; the IngestReport counters expose the bound."""
    svc = _stream(stream_ds, 3, scheme="smp", parallel=True, gcache_capacity=1)
    assert svc.matches.as_set() == batch_smp.matches.as_set()
    g = svc.engine.gcache
    assert len(svc.delta.packed.bins) > 1  # eviction was actually possible
    assert g.peak_resident_bins <= 1
    assert g.evictions > 0 and g.cold_regrounds > 0
    assert sum(r.cache_evictions for r in svc.reports) == g.evictions
    assert max(r.peak_resident_bins for r in svc.reports) <= 1

    # mmp too: device promotion + bounded cache across ingests
    packed, gg = batch_state
    mm = run_mmp(packed, MLNMatcher(PAPER_LEARNED), gg)
    svc2 = _stream(stream_ds, 3, scheme="mmp", parallel=True, gcache_capacity=2)
    assert svc2.matches.as_set() == mm.matches.as_set()
    g2 = svc2.engine.gcache
    assert g2.peak_resident_bins <= 2 and g2.evictions > 0
    assert all(r.promote_host_scans == 0 for r in svc2.reports)


def test_level_cache_bound_keeps_fixpoint(stream_ds, batch_smp):
    """Bounding the Jaro-Winkler memo is pure eviction: the cover and
    the fixpoint are unchanged, only recompute cost varies."""
    svc = _stream(stream_ds, 4, scheme="smp", level_cache_max=64)
    assert len(svc.delta.level_cache) <= 64
    assert svc.matches.as_set() == batch_smp.matches.as_set()


# ---------------------------------------------------------------------------
# LSH bucket eviction (bounded serving memory)
# ---------------------------------------------------------------------------


def test_lsh_eviction_max_ids():
    names = [f"author number {i:03d}" for i in range(120)]
    idx = MinHashLSHIndex(LSHConfig(max_ids=50))
    for lo in range(0, 120, 30):
        idx.add(list(range(lo, lo + 30)), names[lo : lo + 30])
    assert idx.n_indexed == 50
    assert idx.n_evicted == 70
    live = {e for band in idx.buckets for m in band.values() for e in m}
    assert live == set(range(70, 120))  # oldest evicted, newest kept
    # bucket tables hold no dangling entries for evicted ids
    assert all(len(m) > 0 for band in idx.buckets for m in band.values())


def test_lsh_eviction_ttl():
    names = [f"author number {i:03d}" for i in range(80)]
    idx = MinHashLSHIndex(LSHConfig(ttl_adds=2))
    for lo in range(0, 80, 20):
        idx.add(list(range(lo, lo + 20)), names[lo : lo + 20])
    # 4 add calls, ttl 2: only the last two batches survive
    assert idx.n_indexed == 40
    live = {e for band in idx.buckets for m in band.values() for e in m}
    assert live == set(range(40, 80))


def test_lsh_bounded_tolerates_readd():
    """Re-adding an id to a bounded index refreshes it instead of
    corrupting the eviction bookkeeping (regression: duplicate _order
    entries used to raise KeyError at eviction time)."""
    idx = MinHashLSHIndex(LSHConfig(max_ids=2))
    idx.add([1], ["anna lee"])
    idx.add([1], ["anna lee"])
    idx.add([2], ["ben cho"])
    idx.add([3], ["cara diaz"])  # evicts id 1 cleanly
    assert idx.n_indexed == 2
    live = {e for band in idx.buckets for m in band.values() for e in m}
    assert live == {2, 3}


def test_lsh_bounded_long_stream_window_resolution():
    """Long arrival stream against a bounded index: the bucket tables
    stay bounded throughout, and because every entity's >= t_loose
    partners arrive within the retention window, resolution on the
    retained window still matches the batch run over the union."""
    from repro.core.types import EntityTable, Relations

    bases = [
        "alessandro brunelleschi", "konstantin verkhovsky",
        "bartholomew fitzgerald", "evangelina montgomery",
        "thaddeus oppenheimer", "wilhelmina fairbanks",
        "maximilian thornberry", "serafina castellanos",
        "archibald winterbottom", "theodora blankenship",
        "montgomery abernathy", "clementine vandergrift",
    ]
    n_groups, group_size = len(bases), 4
    names = [f"{base}{chr(97 + i)}" for base in bases for i in range(group_size)]
    cap = 3 * group_size  # window >= one group: similar pairs co-resident
    svc = ResolveService(scheme="smp", lsh=LSHConfig(max_ids=cap))
    idx = svc.delta.index
    for g in range(n_groups):
        svc.ingest(names[g * group_size : (g + 1) * group_size])
        # bucket-table bound holds at every point of the stream: at most
        # one live entry per (band, live id)
        assert idx.n_indexed <= cap
        entries = sum(len(m) for band in idx.buckets for m in band.values())
        assert entries <= idx.cfg.num_bands * cap, entries
    assert idx.n_evicted == (n_groups * group_size) - cap

    # eviction never touched intra-group similarity (groups co-arrive),
    # so the stream fixpoint equals the batch pipeline over the union
    packed, _, _ = pipeline.prepare(
        EntityTable(names=list(names)), Relations(edges={})
    )
    batch = run_smp(packed, MLNMatcher(PAPER_LEARNED))
    assert svc.matches.as_set() == batch.matches.as_set()
    assert len(svc.matches) > 0


def test_lsh_unbounded_by_default():
    names = [f"author number {i:03d}" for i in range(60)]
    idx = MinHashLSHIndex()
    for lo in range(0, 60, 20):
        idx.add(list(range(lo, lo + 20)), names[lo : lo + 20])
    assert idx.n_indexed == 60 and idx.n_evicted == 0


# ---------------------------------------------------------------------------
# Snapshot / batched resolve: reads don't race ingests
# ---------------------------------------------------------------------------


def _cluster_state(clusters) -> frozenset:
    return frozenset(tuple(int(x) for x in c) for c in clusters)


def test_snapshot_consistent_under_concurrent_ingest(stream_ds):
    """A reader thread snapshotting during ingests only ever observes a
    committed fixpoint — one of the states reached after some prefix of
    the ingest sequence, never a half-applied cluster update."""
    batches = arrival_stream(stream_ds, 5)
    ref = ResolveService(scheme="smp")
    expected = {_cluster_state([])}
    for b in batches:
        ref.ingest(b.names, b.edges, ids=b.ids)
        expected.add(_cluster_state(ref.clusters()))

    svc = ResolveService(scheme="smp")
    seen: list[frozenset] = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            seen.append(_cluster_state(svc.snapshot().clusters()))

    t = threading.Thread(target=reader)
    t.start()
    try:
        for b in batches:
            svc.ingest(b.names, b.edges, ids=b.ids)
    finally:
        stop.set()
        t.join()
    assert seen, "reader thread never ran"
    bad = [s for s in set(seen) if s not in expected]
    assert not bad, f"reader observed {len(bad)} non-fixpoint states"
    assert _cluster_state(svc.snapshot().clusters()) == _cluster_state(
        ref.clusters()
    )


def test_snapshot_immutable_across_ingests(stream_ds):
    batches = arrival_stream(stream_ds, 4)
    svc = ResolveService(scheme="smp")
    for b in batches[:2]:
        svc.ingest(b.names, b.edges, ids=b.ids)
    snap = svc.snapshot()
    frozen = _cluster_state(snap.clusters())
    n_matches = len(snap.matches)
    for b in batches[2:]:
        svc.ingest(b.names, b.edges, ids=b.ids)
    assert _cluster_state(snap.clusters()) == frozen
    assert len(snap.matches) == n_matches
    assert snap.n_ingests == 2
    # the live service moved on
    assert len(svc.matches) >= n_matches


def test_resolve_many_matches_resolve(stream_ds):
    svc = _stream(stream_ds, 4, scheme="smp")
    ids = list(range(0, svc.delta.n_entities, 3)) + [10_000_000]
    batched = svc.resolve_many(ids)
    for eid, got in zip(ids, batched):
        assert np.array_equal(got, svc.resolve(eid))
    snap = svc.snapshot()
    for eid in ids:
        assert np.array_equal(snap.resolve(eid), svc.resolve(eid))
