"""Serving front-end (repro.stream.serving) + double-buffered reads.

Two families of properties:

* **Coalescing is schedule-invariant.**  The frontend merges queued
  arrivals into one ``CoverDelta`` + one fixpoint per flush; by the
  stream==batch theorem that must be *bit-for-bit* the fixpoint of
  per-arrival synchronous ingest — asserted differentially on the
  hepth stream and on an evidence-lattice-style chain stream (the
  paper's §2.1 chain: matches derivable only through coauthor evidence
  arriving in *other* requests; the hand-packed ``make_lattice_cover``
  instance itself has no name/relation stream form, so the chain
  corpus reproduces its structure through the real ingest path).

* **Readers never block on an ingest.**  resolve/resolve_many/snapshot
  are lock-free reads of the published snapshot: they complete even
  while the writer lock is held (deterministic) and their latency is
  decoupled from ingest wall time (measured under a live ingest).

Plus admission control (reject sheds + counts, block backpressures,
timed-out blocks shed), coalescing budgets, and ticket semantics.
"""

from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import pipeline
from repro.core.driver import run_mmp, run_smp
from repro.core.global_grounding import build_global_grounding
from repro.core.mln import MLNMatcher, PAPER_LEARNED
from repro.core.types import EntityTable, Relations
from repro.data.synthetic import arrival_stream
from repro.stream import (
    AdmissionError,
    ResolveService,
    ServingConfig,
    ServingFrontend,
)


def _cluster_state(clusters) -> frozenset:
    return frozenset(tuple(int(x) for x in c) for c in clusters)


def _coalesced(requests, *, scheme="smp", cfg=None, **svc_kwargs):
    """Queue every request up front, then let the worker coalesce —
    deterministic batch formation (no arrival-timing dependence)."""
    svc = ResolveService(scheme=scheme, **svc_kwargs)
    fe = ServingFrontend(
        svc,
        cfg or ServingConfig(max_batch=64, max_delay_ms=0),
        start=False,
    )
    tickets = [fe.submit(n, e, i) for n, e, i in requests]
    fe.start()
    assert fe.drain(120)
    fe.close()
    for t in tickets:
        assert t.wait(0) is not None
    return svc, tickets


def _synchronous(requests, *, scheme="smp", **svc_kwargs):
    svc = ResolveService(scheme=scheme, **svc_kwargs)
    for names, edges, ids in requests:
        svc.ingest(names, edges, ids=ids)
    return svc


def _hepth_requests(ds, batch_size=4):
    return [
        (b.names, b.edges, [int(i) for i in b.ids])
        for b in arrival_stream(ds, batch_size=batch_size)
    ]


# ---------------------------------------------------------------------------
# Differential: coalesced ingest == per-arrival synchronous ingest
# ---------------------------------------------------------------------------


def test_coalesced_equals_per_arrival_hepth(hepth_small):
    """Paper-sized requests coalesced up to 64 entities reach the exact
    per-arrival fixpoint — and actually coalesced (fewer ingests than
    requests, the whole point of the front-end)."""
    requests = _hepth_requests(hepth_small)
    sync = _synchronous(requests, scheme="smp")
    svc, tickets = _coalesced(requests, scheme="smp")
    assert len(svc.reports) < len(requests)  # coalescing really happened
    assert svc.matches.as_set() == sync.matches.as_set()
    assert svc.delta.packed.pair_levels == sync.delta.packed.pair_levels
    assert _cluster_state(svc.clusters()) == _cluster_state(sync.clusters())
    # every ticket saw the report of the coalesced ingest containing it
    for t, (_, _, ids) in zip(tickets, requests):
        assert t.ids == ids
        assert set(ids) <= set(t.wait(0).ids)


def test_coalesced_equals_per_arrival_hepth_mmp(hepth_small):
    """Same differential under MMP: the coalesced grounding deltas and
    message-pool replay must also be schedule-invariant."""
    requests = _hepth_requests(hepth_small, batch_size=8)
    sync = _synchronous(requests, scheme="mmp")
    svc, _ = _coalesced(requests, scheme="mmp")
    assert len(svc.reports) < len(requests)
    assert svc.matches.as_set() == sync.matches.as_set()
    # both equal the batch pipeline over the union (ground truth)
    packed, gg, _ = pipeline.prepare(
        hepth_small.entities, hepth_small.relations
    )
    batch = run_mmp(packed, MLNMatcher(PAPER_LEARNED), gg)
    assert svc.matches.as_set() == batch.matches.as_set()


def _chain_requests():
    """Evidence-lattice-style stream: ``depth`` stages of ambiguous name
    pairs, with coauthor edges linking stage i to stage i-1 — the §2.1
    chain shape of ``make_lattice_cover``, expressed through names +
    relations so it can stream.  Each request carries one stage and the
    edges into the previous stage, so coalescing merges evidence
    producers with their consumers."""
    depth, per_stage = 6, 4
    names, ids, edges_of = [], [], []
    nid = 0
    prev_stage: list[int] = []
    for _ in range(depth):
        stage = []
        stage_names = []
        base = f"rosalind feynmanova{chr(97 + len(edges_of))}"
        for j in range(per_stage):
            stage_names.append(f"{base}{chr(97 + j)}")
            stage.append(nid)
            nid += 1
        e = [
            (a, b)
            for a, b in zip(stage, prev_stage)
        ]
        edges_of.append(
            (stage_names, np.asarray(e, dtype=np.int64) if e else None, stage)
        )
        names.extend(stage_names)
        ids.extend(stage)
        prev_stage = stage
    return edges_of, names


@pytest.mark.parametrize("scheme", ["smp", "mmp"])
def test_coalesced_equals_per_arrival_evidence_chain(scheme):
    requests, all_names = _chain_requests()
    sync = _synchronous(requests, scheme=scheme)
    svc, _ = _coalesced(
        requests,
        scheme=scheme,
        cfg=ServingConfig(max_batch=10, max_delay_ms=0),
    )
    assert len(svc.reports) < len(requests)
    assert svc.matches.as_set() == sync.matches.as_set()
    assert len(svc.matches) > 0  # the chain actually resolves
    # and both equal the batch pipeline over the union
    ents = EntityTable(names=list(all_names))
    rels = sync.delta.relations()
    packed, _, _ = pipeline.prepare(ents, rels)
    if scheme == "smp":
        batch = run_smp(packed, MLNMatcher(PAPER_LEARNED))
    else:
        gg = build_global_grounding(
            packed.pair_levels, rels, PAPER_LEARNED
        )
        batch = run_mmp(packed, MLNMatcher(PAPER_LEARNED), gg)
    assert svc.matches.as_set() == batch.matches.as_set()


def test_coalesced_survives_resplit_retraction():
    """The adversarial canopy re-split (match invalidation + candidate
    retraction) fires *inside* a coalesced flush and still reaches the
    batch fixpoint."""
    names = [
        f"john smithsonian{chr(97 + i // 26)}{chr(97 + i % 26)}"
        for i in range(28)
    ]
    first = [i for i in range(28) if i % 2 == 0]
    second = [i for i in range(28) if i % 2 == 1]
    # first half committed, second half split over many tiny coalesced
    # requests — the re-split happens mid-stream under the frontend
    svc = ResolveService(scheme="smp")
    svc.ingest([names[i] for i in first], ids=first)
    fe = ServingFrontend(
        svc, ServingConfig(max_batch=8, max_delay_ms=0), start=False
    )
    for i in second:
        fe.submit([names[i]], None, [i])
    fe.start()
    assert fe.drain(60)
    fe.close()
    assert any(r.n_invalidated for r in svc.reports)  # retraction fired
    packed, _, _ = pipeline.prepare(
        EntityTable(names=list(names)), Relations(edges={})
    )
    seq = run_smp(packed, MLNMatcher(PAPER_LEARNED))
    assert svc.matches.as_set() == seq.matches.as_set()


# ---------------------------------------------------------------------------
# Readers never block on an ingest
# ---------------------------------------------------------------------------


def test_reads_complete_while_writer_lock_held(hepth_small):
    """Deterministic non-blocking proof: resolve/resolve_many/snapshot
    complete while the writer lock is held (simulating the commit
    section of an in-flight ingest).  Under the old reader-side RLock
    these would deadlock here."""
    requests = _hepth_requests(hepth_small)
    svc = _synchronous(requests[:4], scheme="smp")
    out: dict = {}

    def reader():
        out["resolve"] = svc.resolve(0)
        out["many"] = svc.resolve_many(range(8))
        out["snap"] = svc.snapshot().clusters()

    with svc._lock:  # a writer is mid-commit, forever (as far as readers know)
        t = threading.Thread(target=reader)
        t.start()
        t.join(timeout=5)
        assert not t.is_alive(), "reader blocked on the writer lock"
    assert len(out["many"]) == 8
    assert out["snap"] == svc.snapshot().clusters()


def test_reader_latency_decoupled_from_ingest(hepth_small):
    """Latency under active ingest: while one large ingest runs, a
    reader thread's per-call resolve latency stays far below the ingest
    wall time — the double-buffered swap means readers wait on nothing."""
    requests = _hepth_requests(hepth_small)
    svc = _synchronous(requests[:2], scheme="smp")
    union_names = [n for r in requests[2:] for n in r[0]]
    union_ids = [i for r in requests[2:] for i in r[2]]
    edge_arrays = [r[1] for r in requests[2:] if r[1] is not None and len(r[1])]
    union_edges = np.vstack(edge_arrays) if edge_arrays else None

    lat: list[float] = []
    stop = threading.Event()

    def reader():
        ids = list(range(16))
        while not stop.is_set():
            t0 = time.perf_counter()
            svc.resolve_many(ids)
            lat.append(time.perf_counter() - t0)
            time.sleep(0.001)

    t = threading.Thread(target=reader)
    t.start()
    t0 = time.perf_counter()
    svc.ingest(union_names, union_edges, ids=union_ids)  # one big ingest
    ingest_s = time.perf_counter() - t0
    stop.set()
    t.join()
    assert lat, "reader never ran"
    # generous bound: lock-free reads are ~us; blocking on the ingest
    # would cost its full wall time (>= hundreds of ms)
    assert max(lat) < max(0.5 * ingest_s, 0.05), (max(lat), ingest_s)


def test_resolve_observes_only_committed_states(hepth_small):
    """The resolve() path (not just snapshot()) only ever sees cluster
    states that exist after some ingest prefix."""
    batches = arrival_stream(hepth_small, 5)
    ref = ResolveService(scheme="smp")
    expected = {_cluster_state([])}
    for b in batches:
        ref.ingest(b.names, b.edges, ids=b.ids)
        expected.add(_cluster_state(ref.clusters()))

    svc = ResolveService(scheme="smp")
    seen: list[frozenset] = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            seen.append(_cluster_state(svc.clusters()))

    t = threading.Thread(target=reader)
    t.start()
    try:
        for b in batches:
            svc.ingest(b.names, b.edges, ids=b.ids)
    finally:
        stop.set()
        t.join()
    bad = [s for s in set(seen) if s not in expected]
    assert not bad, f"reader observed {len(bad)} non-committed states"


# ---------------------------------------------------------------------------
# Admission control / backpressure
# ---------------------------------------------------------------------------


def test_admission_reject_sheds_and_counts():
    obs.reset()
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(
        svc,
        ServingConfig(max_queue=2, admission="reject", max_delay_ms=0),
        start=False,  # worker paused: the queue genuinely fills
    )
    t1 = fe.submit(["ada one"])
    t2 = fe.submit(["ada two"])
    with pytest.raises(AdmissionError):
        fe.submit(["ada three"])
    reg = obs.get_registry()
    assert reg.value("serve.admission.shed") == 1
    assert reg.value("serve.requests") == 2
    fe.start()
    assert fe.drain(60)
    fe.close()
    assert t1.done() and t2.done()
    assert svc.delta.n_entities == 2  # the shed request never ingested


def test_admission_block_timeout_sheds():
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(
        svc,
        ServingConfig(max_queue=1, admission="block", max_delay_ms=0),
        start=False,
    )
    fe.submit(["bea one"])
    t0 = time.perf_counter()
    with pytest.raises(AdmissionError):
        fe.submit(["bea two"], timeout=0.05)
    assert time.perf_counter() - t0 >= 0.04  # it did wait before shedding
    fe.start()
    assert fe.drain(60)
    fe.close()


def test_admission_block_backpressure_releases():
    """A blocked submit parks until the worker drains queue space, then
    completes — backpressure propagates to producers and releases."""
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(
        svc,
        ServingConfig(max_queue=1, admission="block", max_delay_ms=0),
        start=False,
    )
    fe.submit(["cleo one"])
    unblocked = threading.Event()

    def producer():
        fe.submit(["cleo two"])  # blocks: queue is at max_queue
        unblocked.set()

    p = threading.Thread(target=producer)
    p.start()
    assert not unblocked.wait(0.1), "submit should have blocked"
    fe.start()  # worker drains -> space -> producer completes
    assert unblocked.wait(30)
    p.join()
    assert fe.drain(60)
    fe.close()
    assert svc.delta.n_entities == 2


# ---------------------------------------------------------------------------
# Coalescing budgets + ticket semantics
# ---------------------------------------------------------------------------


def test_size_budget_shapes_batches():
    obs.reset()
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(
        svc, ServingConfig(max_batch=16, max_delay_ms=0), start=False
    )
    for k in range(10):  # 10 requests x 4 entities, budget 16 -> 4+4+2
        fe.submit([f"dora eleanor{chr(97 + k)}{chr(97 + j)}" for j in range(4)])
    fe.start()
    assert fe.drain(120)
    fe.close()
    sizes = [len(r.ids) for r in svc.reports]
    assert sizes == [16, 16, 8], sizes
    h = obs.get_registry().histogram("serve.batch.coalesced_size").summary()
    assert h["count"] == 3 and h["max"] == 16
    reqs = obs.get_registry().histogram("serve.batch.requests").summary()
    assert reqs["count"] == 3 and reqs["max"] == 4


def test_oversized_request_never_split():
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(
        svc, ServingConfig(max_batch=4, max_delay_ms=0), start=False
    )
    fe.submit([f"edna fitzwilliam{chr(97 + j)}" for j in range(9)])  # > budget
    fe.submit(["edna extra"])
    fe.start()
    assert fe.drain(60)
    fe.close()
    sizes = [len(r.ids) for r in svc.reports]
    assert sizes[0] == 9, sizes  # one atomic ingest for the big request


def test_latency_budget_flushes_partial_batch():
    """With a size budget far above the traffic, the delay budget alone
    must flush: a lone sub-budget request commits within ~max_delay."""
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(
        svc, ServingConfig(max_batch=1024, max_delay_ms=25)
    )
    t = fe.submit(["freya gorostiza"])
    report = t.wait(timeout=30)  # would hang forever if only size flushed
    assert len(report.ids) == 1
    fe.close()


def test_ticket_error_and_recovery():
    """A poisoned request fails only its own flush; the frontend keeps
    serving, and the error surfaces through the ticket."""
    obs.reset()
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(svc, ServingConfig(max_delay_ms=0))
    ok1 = fe.submit(["gwen hypatia"], None, [0]).wait(30)
    assert ok1.ids == [0]
    bad = fe.submit(["gwen dup"], None, [0])  # duplicate explicit id
    with pytest.raises(ValueError):
        bad.wait(30)
    ok2 = fe.submit(["gwen later"]).wait(30)  # service still serves
    assert ok2.ids == [1]
    fe.close()
    assert obs.get_registry().value("serve.errors") == 1


def test_mixed_explicit_and_auto_ids_coalesce():
    """Auto-assigned ids skip past explicit ones inside the same
    coalesced flush (the worker is the single id allocator)."""
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(
        svc, ServingConfig(max_batch=64, max_delay_ms=0), start=False
    )
    ta = fe.submit(["hana ibrahimovic"])          # auto -> 0
    tb = fe.submit(["hana jimenez"], None, [7])   # explicit hole
    tc = fe.submit(["hana kowalczyk"])            # auto -> 8 (past 7)
    fe.start()
    assert fe.drain(60)
    fe.close()
    assert ta.ids == [0] and tb.ids == [7] and tc.ids == [8]
    assert svc.delta.n_entities == 3


def test_close_without_start_fails_tickets():
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(svc, start=False)
    t = fe.submit(["ines jaramillo"])
    fe.close()
    with pytest.raises(RuntimeError):
        t.wait(1)
    with pytest.raises(RuntimeError):
        fe.submit(["ines again"])


def test_queue_depth_gauge_tracks():
    obs.reset()
    svc = ResolveService(scheme="smp")
    fe = ServingFrontend(svc, ServingConfig(max_delay_ms=0), start=False)
    for k in range(5):
        fe.submit([f"jo kalinowski{chr(97 + k)}"])
    assert obs.get_registry().gauge("serve.queue.depth").value == 5
    fe.start()
    assert fe.drain(60)
    fe.close()
    assert obs.get_registry().gauge("serve.queue.depth").value == 0
